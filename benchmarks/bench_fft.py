"""FFT hot-path benchmark: legacy copy layout vs zero-copy vs rfft.

Tracks the perf claims so the trajectory is machine-readable
(BENCH_fft.json at the repo root):

  1. the zero-copy four-step moves strictly fewer HBM bytes than the
     seed's reshape+swapaxes path (4 traversals vs 10 at level 1);
  2. the real-input fast path costs <= ~55% of the full complex transform
     at the same n on the roofline byte/flop counters;
  3. the plan cache amortizes compilation the way the paper amortizes
     `cufftPlanMany`: the first execute on a spec pays trace+compile, a
     cache-hit plan's execute does not, and repeat executes trigger zero
     retraces (`plan_build` per size; `checks.plan_cache_*`).

Everything runs through the `repro.fft` facade; bytes/flops come from each
plan's analytic cost model (`plan.hbm_bytes_per_row` etc., the exact
planar payload traffic of each pallas pass / transpose — wall clock on
this CPU container runs the interpreter, so it sanity-checks but does not
measure HBM). The roofline cost of a variant is
max(flops/PEAK_FLOPS, bytes/HBM_BW) with the constants from
benchmarks/roofline.py.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from benchmarks.common import block_until_ready, timeit
from benchmarks.roofline import HBM_BW, PEAK_FLOPS
import repro.fft as fft_api

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_fft.json"

# (n, rows): level-0 leaf, the fused-rfft sweet spot, and two level-1
# four-step sizes (n > MAX_LEAF) where the transpose elimination bites.
SIZES = [(4096, 16), (8192, 16), (32768, 4), (1 << 16, 2)]
QUICK_SIZES = [(8192, 8), (32768, 2)]


def _roofline_s(flops: float, bytes_: float) -> float:
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def _variant_rec(plan, wall_us: float) -> dict:
    return {
        "wall_us": wall_us,
        "hbm_bytes_per_row": plan.hbm_bytes_per_row,
        "flops_per_row": plan.flops_per_row,
        "roofline_s_per_row": _roofline_s(plan.flops_per_row,
                                          plan.hbm_bytes_per_row),
    }


def bench_size(n: int, rows: int, iters: int) -> dict:
    rng = np.random.default_rng(0)
    xr = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))
    xi = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))

    plans = {
        "copy": fft_api.plan(kind="c2c", n=n, batch_shape=(rows,),
                             layout="copy"),
        "zero_copy": fft_api.plan(kind="c2c", n=n, batch_shape=(rows,),
                                  layout="zero_copy"),
    }
    p_rfft = fft_api.plan(kind="r2c", n=n, batch_shape=(rows,))

    rec = {"n": n, "rows": rows, "levels": plans["zero_copy"].levels,
           "variants": {}}

    # first-build vs cache-hit: the paper's plan-amortization, measurable.
    # The first execute of the zero_copy plan pays trace+compile; a
    # second plan() on the same spec returns the SAME object, and its
    # execute reuses the compiled fn (trace_count stays 1).
    p_zc = plans["zero_copy"]
    t0 = time.perf_counter()
    block_until_ready(p_zc.execute(xr, xi))
    first_s = time.perf_counter() - t0
    p_again = fft_api.plan(kind="c2c", n=n, batch_shape=(rows,),
                           layout="zero_copy")
    t0 = time.perf_counter()
    block_until_ready(p_again.execute(xr, xi))
    cached_s = time.perf_counter() - t0
    rec["plan_build"] = {
        "first_call_us": first_s * 1e6,
        "cache_hit_call_us": cached_s * 1e6,
        "plan_is_cached": p_again is p_zc,
        "traces": p_zc.trace_counts["forward"],
    }

    for name, p in plans.items():
        wall = timeit(lambda p=p: block_until_ready(p.execute(xr, xi)),
                      warmup=1, iters=iters)
        rec["variants"][name] = _variant_rec(p, wall * 1e6)
    wall = timeit(lambda: block_until_ready(p_rfft.execute_real(xr)),
                  warmup=1, iters=iters)
    rec["variants"]["rfft"] = _variant_rec(p_rfft, wall * 1e6)
    rec["rfft_fused_untangle"] = p_rfft.fused_untangle

    v = rec["variants"]
    rec["zero_copy_bytes_ratio"] = (v["zero_copy"]["hbm_bytes_per_row"]
                                    / v["copy"]["hbm_bytes_per_row"])
    rec["rfft_cost_ratio"] = (v["rfft"]["roofline_s_per_row"]
                              / v["zero_copy"]["roofline_s_per_row"])
    return rec


def run(quick: bool = False):
    sizes = QUICK_SIZES if quick else SIZES
    iters = 2 if quick else 3
    fft_api.clear_plan_cache()  # make first-build timings honest
    recs = [bench_size(n, rows, iters) for n, rows in sizes]

    level1 = [r for r in recs if r["levels"] > 1]
    fused_rfft = [r for r in recs if r["rfft_fused_untangle"]]
    checks = {
        # acceptance: strictly fewer HBM bytes than the seed path at level 1
        "zero_copy_fewer_bytes": all(
            r["variants"]["zero_copy"]["hbm_bytes_per_row"]
            < r["variants"]["copy"]["hbm_bytes_per_row"] for r in level1),
        # acceptance: rfft <= ~55% of the complex transform at the same n
        # (fused-epilogue regime: n//2 is a leaf length)
        "rfft_cost_le_55pct": all(
            r["rfft_cost_ratio"] <= 0.55 for r in fused_rfft),
        # acceptance: the plan cache returns the same object and repeat
        # executes never retrace (the zero-recompilation claim)
        "plan_cache_no_retrace": all(
            r["plan_build"]["plan_is_cached"]
            and r["plan_build"]["traces"] == 1 for r in recs),
        # acceptance: a cache-hit execute skips the first call's
        # trace+compile cost
        "plan_cache_hit_faster": all(
            r["plan_build"]["cache_hit_call_us"]
            < r["plan_build"]["first_call_us"] for r in recs),
    }
    OUT_PATH.write_text(json.dumps(
        {"quick": quick, "checks": checks, "plan_cache": fft_api.cache_info(),
         "sizes": recs}, indent=1))

    out = []
    for r in recs:
        for name, v in r["variants"].items():
            out.append({
                "name": f"fft_{r['n']}_{name}",
                "us_per_call": v["wall_us"],
                "derived": (f"bytes/row={v['hbm_bytes_per_row']} "
                            f"roofline={v['roofline_s_per_row']:.3e}s"),
            })
        out.append({
            "name": f"fft_{r['n']}_summary",
            "us_per_call": 0.0,
            "derived": (f"zero_copy/copy bytes={r['zero_copy_bytes_ratio']:.3f} "
                        f"rfft/complex cost={r['rfft_cost_ratio']:.3f}"),
        })
        pb = r["plan_build"]
        out.append({
            "name": f"fft_{r['n']}_plan_build",
            "us_per_call": pb["first_call_us"],
            "derived": (f"cache_hit={pb['cache_hit_call_us']:.1f}us "
                        f"traces={pb['traces']}"),
        })
    out.append({"name": "fft_checks", "us_per_call": 0.0,
                "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                                    for k, ok in checks.items())})
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
