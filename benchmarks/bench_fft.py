"""FFT hot-path benchmark: legacy copy layout vs zero-copy vs rfft.

Tracks the PR's two perf claims so the trajectory is machine-readable
(BENCH_fft.json at the repo root):

  1. the zero-copy four-step moves strictly fewer HBM bytes than the
     seed's reshape+swapaxes path (4 traversals vs 10 at level 1);
  2. the real-input fast path costs <= ~55% of the full complex transform
     at the same n on the roofline byte/flop counters.

Bytes come from the analytic counters in kernels/fft/plan.py (exact planar
payload traffic of each pallas pass / transpose, the roofline numerators —
wall clock on this CPU container runs the interpreter, so it sanity-checks
but does not measure HBM). The roofline cost of a variant is
max(flops/PEAK_FLOPS, bytes/HBM_BW) with the constants from
benchmarks/roofline.py.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import block_until_ready, timeit
from benchmarks.roofline import HBM_BW, PEAK_FLOPS
from repro.kernels.fft import ops, plan

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_fft.json"

# (n, rows): level-0 leaf, the fused-rfft sweet spot, and two level-1
# four-step sizes (n > MAX_LEAF) where the transpose elimination bites.
SIZES = [(4096, 16), (8192, 16), (32768, 4), (1 << 16, 2)]
QUICK_SIZES = [(8192, 8), (32768, 2)]


def _complex_flops(n: int) -> float:
    """Algorithmic roofline numerator, roofline.py convention."""
    return 5.0 * n * math.log2(n)


def _rfft_flops(n: int) -> float:
    """Half-length transform + O(m) untangle (~10 real ops per bin)."""
    m = n // 2
    return 5.0 * m * math.log2(m) + 10.0 * m


def _roofline_s(flops: float, bytes_: float) -> float:
    return max(flops / PEAK_FLOPS, bytes_ / HBM_BW)


def bench_size(n: int, rows: int, iters: int) -> dict:
    rng = np.random.default_rng(0)
    xr = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))
    xi = jnp.asarray(rng.standard_normal((rows, n)).astype(np.float32))

    fns = {
        "copy": jax.jit(lambda a, b: ops.fft(a, b, layout="copy")),
        "zero_copy": jax.jit(lambda a, b: ops.fft(a, b, layout="zero_copy")),
    }
    rfft_fn = jax.jit(lambda a: ops.rfft(a))

    rec = {"n": n, "rows": rows, "levels": plan.make_plan(n).levels,
           "variants": {}}
    for name, fn in fns.items():
        wall = timeit(lambda: block_until_ready(fn(xr, xi)),
                      warmup=1, iters=iters)
        bytes_row = plan.fft_hbm_bytes(n, layout=name)
        flops_row = _complex_flops(n)
        rec["variants"][name] = {
            "wall_us": wall * 1e6,
            "hbm_bytes_per_row": bytes_row,
            "flops_per_row": flops_row,
            "roofline_s_per_row": _roofline_s(flops_row, bytes_row),
        }
    wall = timeit(lambda: block_until_ready(rfft_fn(xr)),
                  warmup=1, iters=iters)
    bytes_row = plan.rfft_hbm_bytes(n)
    flops_row = _rfft_flops(n)
    rec["variants"]["rfft"] = {
        "wall_us": wall * 1e6,
        "hbm_bytes_per_row": bytes_row,
        "flops_per_row": flops_row,
        "roofline_s_per_row": _roofline_s(flops_row, bytes_row),
    }

    v = rec["variants"]
    rec["zero_copy_bytes_ratio"] = (v["zero_copy"]["hbm_bytes_per_row"]
                                    / v["copy"]["hbm_bytes_per_row"])
    rec["rfft_cost_ratio"] = (v["rfft"]["roofline_s_per_row"]
                              / v["zero_copy"]["roofline_s_per_row"])
    return rec


def run(quick: bool = False):
    sizes = QUICK_SIZES if quick else SIZES
    iters = 2 if quick else 3
    recs = [bench_size(n, rows, iters) for n, rows in sizes]

    level1 = [r for r in recs if r["levels"] > 1]
    fused_rfft = [r for r in recs
                  if plan.make_plan(r["n"] // 2).levels == 1]
    checks = {
        # acceptance: strictly fewer HBM bytes than the seed path at level 1
        "zero_copy_fewer_bytes": all(
            r["variants"]["zero_copy"]["hbm_bytes_per_row"]
            < r["variants"]["copy"]["hbm_bytes_per_row"] for r in level1),
        # acceptance: rfft <= ~55% of the complex transform at the same n
        # (fused-epilogue regime: n//2 is a leaf length)
        "rfft_cost_le_55pct": all(
            r["rfft_cost_ratio"] <= 0.55 for r in fused_rfft),
    }
    OUT_PATH.write_text(json.dumps(
        {"quick": quick, "checks": checks, "sizes": recs}, indent=1))

    out = []
    for r in recs:
        for name, v in r["variants"].items():
            out.append({
                "name": f"fft_{r['n']}_{name}",
                "us_per_call": v["wall_us"],
                "derived": (f"bytes/row={v['hbm_bytes_per_row']} "
                            f"roofline={v['roofline_s_per_row']:.3e}s"),
            })
        out.append({
            "name": f"fft_{r['n']}_summary",
            "us_per_call": 0.0,
            "derived": (f"zero_copy/copy bytes={r['zero_copy_bytes_ratio']:.3f} "
                        f"rfft/complex cost={r['rfft_cost_ratio']:.3f}"),
        })
    out.append({"name": "fft_checks", "us_per_call": 0.0,
                "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                                    for k, ok in checks.items())})
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
