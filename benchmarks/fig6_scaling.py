"""Paper Figure 6: single machine vs cluster computation time.

Paper: one machine vs an 8-node EC2 GPU Hadoop cluster. Container
analogue: the same block job over 1..N worker threads ("servers" — jit'd
FFT work releases the GIL so threads genuinely overlap), overlaid with the
paper's O(n log n / (0.8*S*C)) runtime model calibrated on the 1-worker
measurement. The reproduced claim: near-linear scaling with S, modest
efficiency loss (their 0.8 factor).
"""

from __future__ import annotations

import tempfile
from pathlib import Path

from benchmarks.common import make_signal_store
from benchmarks.fig2_total_time import run_pipeline
from repro.core.amdahl import ClusterModel, calibrate_unit_time

FFT_LEN = 1024


def run(quick: bool = False):
    size = 8 if quick else 24
    workers = [1, 2] if quick else [1, 2, 4]
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        store, _ = make_signal_store(Path(tmp) / "in", size_mb=size,
                                     fft_len=FFT_LEN,
                                     segments_per_block=256)
        n = size * (1 << 20) // 8
        results = {}
        for w in workers:
            r = run_pipeline(store, Path(tmp) / f"out_w{w}", "matfft",
                             FFT_LEN, workers=w)
            results[w] = r["total_s"]
            rows.append({"name": f"fig6_workers_{w}",
                         "us_per_call": r["total_s"] * 1e6,
                         "derived": f"size={size}MB"})
        unit = calibrate_unit_time(n, results[workers[0]], cores=1,
                                   efficiency=1.0)
        model = ClusterModel(unit_time_s=unit, efficiency=0.8)
        for w in workers[1:]:
            pred = model.predict(n, 1, w)
            eff = results[workers[0]] / (w * results[w])
            rows.append({
                "name": f"fig6_model_w{w}", "us_per_call": pred * 1e6,
                "derived": f"measured={results[w]:.2f}s "
                           f"model={pred:.2f}s efficiency={eff:.2f} "
                           f"(paper assumes 0.8)"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
