"""Distributed-FFT exchange engines: monolithic all_to_all vs the chunked
ppermute overlap pipeline (BENCH_distributed.json).

Two measurements, honestly separated (PR-3 precedent: CI has no latency to
hide, so the gate runs on a deterministic model, and the raw container
numbers are recorded un-gated):

  * **Executed parity + wall** — both engines run the SAME signal on this
    host's CPU mesh in Pallas interpret mode. The overlapped output must be
    bitwise identical to the monolithic path (the exchange is pure data
    movement and the slab kernels issue exactly the monolithic GEMMs — the
    acceptance property). On MXU hardware this holds for every slab width
    (the systolic array's contraction order is shape-independent); on this
    container XLA CPU swaps dot algorithms across its parallelization
    threshold on exactly one probed shape boundary (M=32, K=N=256), so the
    bitwise gate runs at N_EXEC in the emitter-stable regime and a
    tolerance-level parity check (~f32 round-off) covers N_TOL on the
    other side of that boundary. Wall times are recorded for the
    trajectory but NOT gated: XLA CPU executes collectives synchronously
    on one thread, so there is no interconnect latency for the pipeline to
    hide here — exactly like the tmpfs "disk" in bench_pipeline.py.
  * **Deterministic timing model** — a two-resource (ICI link, MXU) event
    simulation of the per-device schedule, evaluated from the plan's
    analytic counters at the production regime the overlap targets
    (N_MODEL, this mesh's device count). Constants: the dryrun's 50 GB/s
    ICI figure, an effective 2e13 MAC/s for the small leaf GEMMs (~10% of
    v5e nominal peak: short contractions, strided tiles, twiddle
    epilogues), and 1 us launch latency per collective — charged per
    ppermute ROUND for the pipeline (D-1 rounds per slab) and only once
    per all_to_all for the baseline, i.e. charitable to the baseline. The
    gate: the pipelined schedule must be strictly faster than the serial
    one, and the plan's exposed_collective_bytes must be strictly below
    its total.

The same model explains the overlap="auto" heuristic's floor (DESIGN.md
§8): below OVERLAP_AUTO_MIN_N the per-round latency term exceeds the
compute the pipeline can hide, and the model correctly prefers "off" —
``modeled_small`` in the JSON records that regime too.
"""

from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft_api  # noqa: E402
from repro import compat  # noqa: E402
from repro.core.fft.distributed import plan_distributed  # noqa: E402
from repro.kernels.fft import plan as kplan  # noqa: E402

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_distributed.json"

N_EXEC = 1 << 14   # executed bitwise gate (emitter-stable shape regime)
N_TOL = 1 << 16    # executed tolerance parity (crosses the CPU dot boundary)
N_MODEL = 1 << 28  # modeled at the regime the overlap targets (dryrun's n)
CHUNKS = 4
TOL = 1e-4         # relative; f32 round-off from a different dot algorithm

ICI_BPS = 50e9     # per-device ICI bandwidth (same figure as fft_dryrun)
MACS_PS = 2e13     # effective leaf-GEMM MAC rate (~10% of nominal peak)
RING_LAT_S = 1e-6  # launch latency per ppermute round
A2A_LAT_S = 1e-6   # launch latency per all_to_all (once per leg)


def modeled_wall_s(n: int, d: int, chunks: int | None,
                   natural_order: bool = True) -> float:
    """Deterministic per-device schedule time (two resources: link, MXU).

    chunks=None serializes legs and passes (the all_to_all engine);
    chunks=k runs the jaxpr's actual slab order — xchg#1 slab c+1 and
    xchg#2 slab c share the link while slab c's pass-1 FFT runs, pass-2
    slab j feeds xchg#3 slab j.
    """
    dist = plan_distributed(n, d, natural_order=natural_order,
                            chunks=chunks)
    n1l, n2l = dist.n1 // d, dist.n2 // d
    comm_leg = dist.bytes_per_exchange_per_device / ICI_BPS
    comp1 = n2l * kplan.make_plan(dist.n1).gemm_macs / MACS_PS
    comp2 = n1l * kplan.make_plan(dist.n2).gemm_macs / MACS_PS
    if chunks is None:
        return (dist.n_exchanges * (comm_leg + A2A_LAT_S) + comp1 + comp2)
    k = chunks
    ring = (d - 1) * RING_LAT_S
    slab = comm_leg / k
    comm = comp = 0.0
    ex1_done = [0.0] * k
    ex2_done = [0.0] * k
    comm += slab + ring
    ex1_done[0] = comm
    for c in range(k):
        if c + 1 < k:
            comm += slab + ring
            ex1_done[c + 1] = comm
        comp = max(comp, ex1_done[c]) + comp1 / k
        comm = max(comm, comp) + slab + ring
        ex2_done[c] = comm
    for _ in range(k):
        comp = max(comp, ex2_done[k - 1]) + comp2 / k
        if natural_order:
            comm = max(comm, comp) + slab + ring
    return comm if natural_order else comp


def _time_execute(plan, xr, xi, iters: int) -> float:
    plan.execute(xr, xi)  # warm: trace + compile outside the clock
    best = float("inf")
    for _ in range(iters):
        t0 = time.monotonic()
        yr, yi = plan.execute(xr, xi)
        jax.block_until_ready((yr, yi))
        best = min(best, time.monotonic() - t0)
    return best


def run(quick: bool = False):
    iters = 2 if quick else 3
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    d = jax.device_count()

    rng = np.random.default_rng(0)
    x = rng.standard_normal(N_EXEC).astype(np.float32)
    y = rng.standard_normal(N_EXEC).astype(np.float32)
    xr, xi = jnp.asarray(x), jnp.asarray(y)

    p_off = fft_api.plan(kind="c2c", n=N_EXEC, mesh=mesh,
                         placement="distributed", overlap="off",
                         interpret=True)
    p_on = fft_api.plan(kind="c2c", n=N_EXEC, mesh=mesh,
                        placement="distributed", overlap=CHUNKS,
                        interpret=True)

    off_r, off_i = p_off.execute(xr, xi)
    on_r, on_i = p_on.execute(xr, xi)
    identical = bool((np.asarray(on_r) == np.asarray(off_r)).all()
                     and (np.asarray(on_i) == np.asarray(off_i)).all())
    want = np.fft.fft(x + 1j * y)
    err = float(np.abs((np.asarray(off_r) + 1j * np.asarray(off_i))
                       - want).max() / np.abs(want).max())

    wall_off = _time_execute(p_off, xr, xi, iters)
    wall_on = _time_execute(p_on, xr, xi, iters)

    # tolerance parity at a size whose monolithic GEMM sits on the other
    # side of the CPU emitter's parallelization boundary (see docstring)
    xt = rng.standard_normal(N_TOL).astype(np.float32)
    yt = rng.standard_normal(N_TOL).astype(np.float32)
    t_off = fft_api.plan(kind="c2c", n=N_TOL, mesh=mesh,
                         placement="distributed", overlap="off",
                         interpret=True).execute(jnp.asarray(xt),
                                                 jnp.asarray(yt))
    t_on = fft_api.plan(kind="c2c", n=N_TOL, mesh=mesh,
                        placement="distributed", overlap=CHUNKS,
                        interpret=True).execute(jnp.asarray(xt),
                                                jnp.asarray(yt))
    t_scale = float(max(np.abs(np.asarray(t_off[0])).max(),
                        np.abs(np.asarray(t_off[1])).max()))
    tol_err = float(max(np.abs(np.asarray(t_on[0]) -
                               np.asarray(t_off[0])).max(),
                        np.abs(np.asarray(t_on[1]) -
                               np.asarray(t_off[1])).max()) / t_scale)

    m_off = modeled_wall_s(N_MODEL, d, None)
    m_on = modeled_wall_s(N_MODEL, d, CHUNKS)
    m_small_off = modeled_wall_s(N_EXEC, d, None)
    m_small_on = modeled_wall_s(N_EXEC, d, CHUNKS)

    checks = {
        # acceptance: the pipelined schedule beats the serial one on the
        # deterministic model at the regime overlap targets
        "overlap_modeled_faster": m_on < m_off,
        # acceptance: overlapped output is bitwise-equal to monolithic
        "outputs_bitwise_identical": identical,
        # the cost model exposes strictly fewer bytes with overlap on
        "exposed_lt_total": (p_on.exposed_collective_bytes
                             < p_on.collective_bytes),
        "oracle_close": err < 5e-6,
        "outputs_close_large": tol_err < TOL,
    }
    doc = {
        "quick": quick,
        "config": {"n_exec": N_EXEC, "n_tol": N_TOL, "n_model": N_MODEL,
                   "chunks": CHUNKS, "devices": d, "ici_bps": ICI_BPS,
                   "macs_ps": MACS_PS, "ring_lat_s": RING_LAT_S,
                   "a2a_lat_s": A2A_LAT_S},
        "modeled": {
            "off_s": m_off, "on_s": m_on,
            "speedup_x": round(m_off / m_on, 4),
            "hidden_fraction": round(
                p_on.hidden_collective_bytes / p_on.collective_bytes, 4),
        },
        # same model at the executed (small) size: the pipeline loses to
        # its own round latency there — the overlap="auto" floor's regime
        "modeled_small": {"off_s": m_small_off, "on_s": m_small_on},
        "executed": {
            # interpret-mode CPU walls; recorded, NOT gated (see docstring)
            "off_wall_s": round(wall_off, 4),
            "on_wall_s": round(wall_on, 4),
        },
        "collective_bytes": {
            "total": p_on.collective_bytes,
            "exposed": p_on.exposed_collective_bytes,
            "hidden": p_on.hidden_collective_bytes,
        },
        "checks": checks,
        "plan_traces": {"off": p_off.trace_counts, "on": p_on.trace_counts},
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = [
        {"name": "dist_modeled_off", "us_per_call": m_off * 1e6,
         "derived": f"n=2^{N_MODEL.bit_length() - 1} D={d} serial a2a"},
        {"name": "dist_modeled_overlap", "us_per_call": m_on * 1e6,
         "derived": (f"chunks={CHUNKS} speedup={m_off / m_on:.2f}x "
                     f"exposed={p_on.exposed_collective_bytes}B"
                     f"/{p_on.collective_bytes}B")},
        {"name": "dist_exec_off", "us_per_call": wall_off * 1e6,
         "derived": f"n=2^{N_EXEC.bit_length() - 1} interpret-mode wall"},
        {"name": "dist_exec_overlap", "us_per_call": wall_on * 1e6,
         "derived": (f"bitwise_identical={identical} "
                     f"tol_err@2^16={tol_err:.1e}")},
        {"name": "dist_checks", "us_per_call": 0.0,
         "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                             for k, ok in checks.items())},
    ]
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
