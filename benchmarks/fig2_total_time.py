"""Paper Figure 2: TOTAL processing time (I/O + FFT) for a file.

Paper setup: 16 GB file, JTransforms (CPU library) vs JCUFFT (GPU).
Container analogue (scaled to laptop size): library-CPU baseline
(impl="ref" = pocketfft via jnp) vs our accelerated MXU-formulated kernel
(impl="matfft"), end-to-end through the block pipeline including all reads,
writes and the merge. The paper's observation to reproduce: the accelerated
path wins only modestly END-TO-END (their 10-15%) because I/O dominates.
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from benchmarks.common import make_signal_store
from repro.core.pipeline import (JobConfig, MapOnlyJob, block_of_segments,
                                 segments_of_block)
import repro.fft as fft_api

SIZE_MB = 24
FFT_LEN = 1024


def run_pipeline(store, out_dir, impl: str, fft_len: int, workers: int = 2):
    io_s, fft_s = [0.0], [0.0]

    def map_fn(data, idx):
        t = time.monotonic()
        re, im = segments_of_block(data, fft_len)
        re, im = jnp.asarray(re), jnp.asarray(im)
        io_s[0] += time.monotonic() - t
        t = time.monotonic()
        p = fft_api.plan(kind="c2c", n=fft_len, batch_shape=re.shape[:-1],
                         impl=impl)
        yr, yi = p.execute(re, im)
        yr.block_until_ready()
        fft_s[0] += time.monotonic() - t
        t = time.monotonic()
        out = block_of_segments(np.asarray(yr), np.asarray(yi))
        io_s[0] += time.monotonic() - t
        return out

    job = MapOnlyJob(store, out_dir, map_fn, JobConfig(workers=workers))
    t0 = time.monotonic()
    job.run()
    job.merge(Path(out_dir).parent / "merged.bin")
    total = time.monotonic() - t0
    return {"total_s": total, "io_s": io_s[0], "fft_s": fft_s[0]}


def run(quick: bool = False):
    size = 8 if quick else SIZE_MB
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        store, _ = make_signal_store(Path(tmp) / "in", size_mb=size,
                                     fft_len=FFT_LEN)
        for impl in ("ref", "matfft"):
            r = run_pipeline(store, Path(tmp) / f"out_{impl}", impl, FFT_LEN)
            rows.append({"name": f"fig2_total_{impl}",
                         "us_per_call": r["total_s"] * 1e6,
                         "derived": f"io={r['io_s']:.2f}s fft={r['fft_s']:.2f}s "
                                    f"size={size}MB"})
    base = rows[0]["us_per_call"]
    accel = rows[1]["us_per_call"]
    rows.append({"name": "fig2_end_to_end_speedup",
                 "us_per_call": 0.0,
                 "derived": f"{base / accel:.3f}x (paper: 1.10-1.15x)"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
