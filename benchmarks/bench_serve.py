"""Serve gate: chaos-under-load for the FFT-as-a-service front-end.

The service's acceptance property (DESIGN.md §12) is that overload and
injected faults change *which* requests run and how often they retry —
never the correctness of what comes back, and never the boundedness of
the system. This benchmark drives a synthetic open-loop many-client
workload (mixed n, c2c + r2c) at offered load > capacity under a seeded
25% fault storm across all three serve.* sites and records the
trajectory in BENCH_serve.json:

  * **Classified-or-correct** — every submitted request lands in exactly
    one bucket: ``ok`` (and then its result is BITWISE identical to a
    fault-free oracle that executes the request ALONE at the same launch
    batch size — co-batched content and row position provably don't
    affect a row, so any dynamic grouping must reproduce the oracle
    exactly) or a structured, named rejection/shed/failure. Zero silent
    drops, zero unclassified errors, zero tickets pending after drain.
  * **Boundedness** — service occupancy never exceeds ``queue_depth``
    (the admission bound holds even while retries recirculate), the
    overload actually produced queue_full rejections (offered > capacity
    was real), p99 stays finite (no deadlock), and the batcher coalesced
    >= 2 requests/launch on average.
  * **Deadline shedding** — a burst submitted against a ~ms deadline
    while the batcher is held is shed entirely BEFORE launch, each with
    a structured `DeadlineExceeded` whose breakdown shows queue_s > 0
    and execute_s == 0 (late work never reached the device).

Wall times and p50/p99/QPS are recorded un-gated except for the finite-
p99 deadlock guard. The storm is a pure function of SEED — rerunning
this benchmark anywhere replays byte-for-byte the same faults.
"""

from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

from repro.core.resilience import (FaultInjector, FaultPlan,  # noqa: E402
                                   RetryPolicy, clear_events, events)
import repro.fft as fft_api  # noqa: E402
from repro.serve import FftService  # noqa: E402
from repro.serve import loadgen  # noqa: E402

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

SEED = 1407              # the fault storm is a pure function of this
RATE = 0.25              # per (site, request) fault probability
IMPL = "ref"             # serving orchestration under fault, not kernels
CLIENTS = 3
COALESCE = 4
QUEUE_DEPTH = 40         # < the open-loop flood, so admission must reject
MAX_INFLIGHT = 2
MAX_ATTEMPTS = 4
SITES = ("serve.admit", "serve.batch", "serve.execute")
REJECT_BUCKETS = ("queue_full", "rate_limit", "inflight_cap",
                  "admit_fault", "closed")


def _storm_scenario(num_requests: int) -> dict:
    """Open-loop flood through a 25% fault storm; classify everything."""
    plan = FaultPlan.random(SEED, num_requests, sites=SITES, rate=RATE)
    injector = FaultInjector(plan)
    clear_events()
    service = FftService(
        impl=IMPL, coalesce=COALESCE, queue_depth=QUEUE_DEPTH,
        max_inflight=MAX_INFLIGHT, injector=injector,
        retry=RetryPolicy(max_attempts=MAX_ATTEMPTS, base_delay_s=0.0))
    t0 = time.monotonic()
    records = loadgen.drive(service, num_requests=num_requests,
                            clients=CLIENTS, seed=SEED)
    outcomes = {rec.rid: loadgen.classify(rec) for rec in records}
    service.close(drain=True)
    wall = time.monotonic() - t0
    drained_idle = service.idle()

    buckets: dict = {}
    for o in outcomes.values():
        buckets[o] = buckets.get(o, 0) + 1
    bitwise_ok = mismatches = 0
    for rec in records:
        if outcomes[rec.rid] != "ok":
            continue
        want = loadgen.oracle(
            rec.shape, loadgen.request_operands(SEED, rec.rid, rec.shape),
            impl=IMPL, batch_rows=rec.ticket.batch_rows)
        if loadgen.bitwise_equal(rec.ticket.value, want):
            bitwise_ok += 1
        else:
            mismatches += 1
    stats = service.stats.snapshot()
    classified = sum(buckets.get(b, 0) for b in REJECT_BUCKETS) + sum(
        buckets.get(b, 0) for b in ("ok", "shed", "deadline", "failed"))
    return {
        "num_requests": num_requests,
        "wall_s": round(wall, 4),
        "qps": round(buckets.get("ok", 0) / wall, 1),
        "outcomes": dict(sorted(buckets.items())),
        "bitwise_ok": bitwise_ok,
        "bitwise_mismatches": mismatches,
        "all_classified": classified == len(records) == num_requests,
        "drained_idle": drained_idle,
        "stats": stats,
        "injector": injector.summary(),
        "degrade_events": len(events("service_degrade")),
        "plan_cache": fft_api.cache_info(),
    }


def _deadline_scenario(burst: int = 24) -> dict:
    """A burst against a ~ms deadline, batcher held: all shed pre-launch."""
    service = FftService(impl=IMPL, coalesce=COALESCE, queue_depth=burst,
                         default_deadline_s=0.002, start=False)
    records = loadgen.drive(service, num_requests=burst, clients=1,
                            seed=SEED + 1)
    time.sleep(0.05)          # every deadline lapses while nothing runs
    service.start()           # the sweep now sheds the whole backlog
    outcomes = [loadgen.classify(r, timeout=10.0) for r in records]
    breakdowns = [r.ticket.error for r in records
                  if outcomes[records.index(r)] == "deadline"]
    service.close(drain=True)
    return {
        "burst": burst,
        "admitted": service.stats.admitted,
        "deadline": outcomes.count("deadline"),
        "shed_before_launch": sum(
            1 for e in breakdowns
            if e.queue_s > 0 and e.execute_s == 0.0 and e.stage == "queue"),
        "other": {o: outcomes.count(o) for o in set(outcomes)
                  if o != "deadline"},
    }


def run(quick: bool = False):
    fft_api.clear_plan_cache()
    num_requests = 96 if quick else 240
    storm = _storm_scenario(num_requests)
    deadline = _deadline_scenario()

    s = storm["stats"]
    checks = {
        # acceptance: every admitted request is bitwise-correct or a
        # classified structured error — no silent drops
        "serve_all_requests_classified": storm["all_classified"],
        "serve_ok_results_bitwise": storm["bitwise_mismatches"] == 0
            and storm["bitwise_ok"] == storm["outcomes"].get("ok", 0),
        "serve_no_silent_drops":
            storm["outcomes"].get("silent_drop", 0) == 0,
        # acceptance: the admission bound holds, retries included
        "serve_queue_bounded": s["max_queued"] <= QUEUE_DEPTH,
        "serve_overload_rejections":
            storm["outcomes"].get("queue_full", 0) > 0,
        # acceptance: drains to idle on shutdown; p99 finite = no deadlock
        "serve_drained_idle": storm["drained_idle"],
        "serve_p99_bounded": 0.0 < s["latency"]["p99_ms"] < 60_000.0,
        # acceptance: >= 2 requests/launch mean coalescing on mixed specs
        "serve_coalescing_ge_2": s.get("mean_requests_per_launch", 0) >= 2,
        "serve_faults_fired": storm["injector"]["total_fired"] > 0,
        # deadline misses shed BEFORE launch, with the queue-stage
        # breakdown (execute_s == 0) on every one
        "serve_deadline_shed_pre_launch":
            deadline["deadline"] == deadline["admitted"] > 0
            and deadline["shed_before_launch"] == deadline["deadline"],
    }
    doc = {
        "quick": quick,
        "config": {"seed": SEED, "rate": RATE, "impl": IMPL,
                   "clients": CLIENTS, "coalesce": COALESCE,
                   "queue_depth": QUEUE_DEPTH,
                   "max_inflight": MAX_INFLIGHT,
                   "max_attempts": MAX_ATTEMPTS, "sites": SITES,
                   "mix": [sh.label for sh in loadgen.DEFAULT_MIX]},
        "storm": storm,
        "deadline": deadline,
        "checks": checks,
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = [
        {"name": "serve_storm", "us_per_call": storm["wall_s"] * 1e6,
         "derived": f"ok={storm['outcomes'].get('ok', 0)}/{num_requests} "
                    f"qps={storm['qps']} "
                    f"p50={s['latency']['p50_ms']}ms "
                    f"p99={s['latency']['p99_ms']}ms "
                    f"coalesce={s.get('mean_requests_per_launch', 0)} "
                    f"retries={s['retries']} "
                    f"fired={storm['injector']['total_fired']}"},
        {"name": "serve_deadline_burst", "us_per_call": 0.0,
         "derived": f"admitted={deadline['admitted']} "
                    f"shed_pre_launch={deadline['shed_before_launch']}"},
        {"name": "serve_checks", "us_per_call": 0.0,
         "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                             for k, ok in checks.items())},
    ]
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
