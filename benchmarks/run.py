"""Benchmark harness: one module per paper figure + the roofline table.

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks sizes;
``--only fig3`` runs one module. Figures 2-6 measure the real pipeline on
this host (scaled from the paper's 16GB to laptop sizes); the roofline rows
read the dry-run artifacts in results/dryrun/.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (bench_chaos, bench_distributed, bench_fft,
                        bench_fft2, bench_outofcore, bench_pipeline,
                        bench_serve, fig2_total_time, fig3_fft_time,
                        fig45_io_fraction, fig6_scaling, roofline)

MODULES = {
    "fig2": fig2_total_time,
    "fig3": fig3_fft_time,
    "fig45": fig45_io_fraction,
    "fig6": fig6_scaling,
    "fft": bench_fft,
    "fft2": bench_fft2,
    "pipeline": bench_pipeline,
    "distributed": bench_distributed,
    "chaos": bench_chaos,
    "outofcore": bench_outofcore,
    "serve": bench_serve,
    "roofline": roofline,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", choices=list(MODULES), default=None)
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        try:
            for row in MODULES[name].run(quick=args.quick):
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row['derived']}\"", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},nan,\"FAILED\"", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
