"""ABFT silent-corruption gate: BENCH_verify.json (DESIGN.md §13).

Every integrity layer below this one (block CRCs, replica SHA, the tile
journal) checks BYTES — a value corrupted before the bytes are hashed is
invisible to all of them. The ``corrupt`` fault kind injects exactly that:
a seeded, deterministic perturbation at post-CRC checkpoints
(``stream.realize`` host results, ``ooc.shuffle`` tile payloads,
``serve.execute`` realized slices). This gate proves the ABFT invariants
(`core/resilience/verify.py`) are the defense the CRCs cannot be:

  * **Detection + bitwise recovery** — seeded corrupt storms over the
    pipelined stream job, the out-of-core four-step, and the serving
    front-end. With ``verify`` on, every storm run must (a) record
    ``verify_failed`` detections, (b) quarantine-and-recompute through
    the ONE retry path, and (c) end BITWISE IDENTICAL to the clean run /
    oracle — detection without correct recovery is not recovery.
  * **Negative control** — the SAME storms with ``verify="off"`` must
    complete "successfully" with silently wrong bytes and zero retries:
    proof the corruption is real and nothing else catches it.
  * **Zero false positives** — clean (fault-free) runs across >= 20
    seeds through the serving path plus the clean stream/ooc baselines:
    no ``verify_failed`` event may fire on honest data. The derived
    tolerances (eps- and depth-scaled) make this a sharp test.
  * **Overhead** — the pipelined stream job on the shared deterministic
    disk model (`ThrottledStore`, 250 MB/s): wall-clock with
    ``verify="abft"`` must stay within 10% of ``verify="off"`` — the
    O(n) invariants hide under O(n log n) compute and throttled I/O.
    The planner's analytic ``verify_flops`` / ``verify_hbm_bytes`` /
    ``verify_overhead`` are recorded alongside.

impl="ref" everywhere a result is compared bitwise (batch-size-invariant
rounding, same contract as bench_chaos/bench_outofcore).
"""

from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import shutil  # noqa: E402
import tempfile  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402

from benchmarks.common import make_signal_store  # noqa: E402
from repro.core.fft.outofcore import reference_out_of_core  # noqa: E402
from repro.core.pipeline import (JobConfig, MapOnlyJob,  # noqa: E402
                                 SegmentFFTTransform)
from repro.core.pipeline.blockstore import BlockStore  # noqa: E402
from repro.core.pipeline.testing import DISK_MB_S, ThrottledStore  # noqa: E402
from repro.core.resilience import (FaultInjector, FaultPlan,  # noqa: E402
                                   clear_events, events)
from repro.serve import FftService, loadgen  # noqa: E402
import repro.fft as fft_api  # noqa: E402

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_verify.json"

SEED = 1407
IMPL = "ref"
FFT_LEN = 512
SEGMENTS_PER_BLOCK = 256   # 1 MB blocks
SIZE_MB = 8                # -> 8 blocks
COALESCE = 4
MAX_RETRIES = 6
CORRUPT_RATE = 0.5         # per-block corrupt probability in the storms
CLEAN_SEEDS = 20           # false-positive sweep
OVERHEAD_BUDGET = 0.10


# --------------------------------------------------------------- stream

def _stream_job(store, out_dir: Path, injector, verify: str):
    if out_dir.exists():
        shutil.rmtree(out_dir)
    cfg = JobConfig(readers=2, writers=2, coalesce=COALESCE, inflight=2,
                    speculation=False, poll_interval_s=0.005,
                    max_retries=MAX_RETRIES, injector=injector)
    store.injector = injector
    t0 = time.monotonic()
    job = MapOnlyJob(store, out_dir, config=cfg, pipelined=True,
                     transform=SegmentFFTTransform(FFT_LEN, impl=IMPL,
                                                   verify=verify))
    stats = job.run()
    wall = time.monotonic() - t0
    merged = out_dir.parent / f"{out_dir.name}_merged.bin"
    job.merge(merged)
    return stats, merged.read_bytes(), wall


def _stream_scenario(work: Path) -> dict:
    store, _ = make_signal_store(work / "in", size_mb=SIZE_MB,
                                 fft_len=FFT_LEN,
                                 segments_per_block=SEGMENTS_PER_BLOCK)
    num_blocks = len(store.blocks)
    storm = FaultPlan.random(SEED, num_blocks, sites=("stream.realize",),
                             rate=CORRUPT_RATE, kind="corrupt")

    clear_events()
    _, clean_abft, _ = _stream_job(store, work / "clean_abft", None, "abft")
    clean_fp = len(events("verify_failed"))

    clear_events()
    inj = FaultInjector(storm)
    stats, storm_bytes, _ = _stream_job(store, work / "storm_abft", inj,
                                        "abft")
    detected = len(events("verify_failed"))

    _, clean_off, _ = _stream_job(store, work / "clean_off", None, "off")
    inj_off = FaultInjector(storm)
    stats_off, storm_off, _ = _stream_job(store, work / "storm_off",
                                          inj_off, "off")
    return {
        "blocks": num_blocks,
        "corrupt_rules": len(storm.rules),
        "corrupted": inj.total_corrupted,
        "detected": detected,
        "retries": stats.retries,
        "failed_blocks": stats.failed_blocks,
        "clean_false_positives": clean_fp,
        "recovered_bitwise": storm_bytes == clean_abft,
        "off_corrupted": inj_off.total_corrupted,
        "off_retries": stats_off.retries,
        "off_silently_wrong": storm_off != clean_off,
    }


# ----------------------------------------------------------- out-of-core

def _ooc_run(work: Path, sig, n: int, budget: int, injector,
             verify: str) -> tuple:
    f = fft_api.factor_out_of_core(n, budget)
    store = BlockStore(work / "in", block_bytes=f.pass1_panel_bytes)
    store.put_bytes(sig.tobytes())
    cfg = JobConfig(readers=2, writers=2, inflight=2, speculation=False,
                    max_retries=MAX_RETRIES, injector=injector)
    plan = fft_api.plan(kind="c2c", n=n, placement="out_of_core",
                        store=store, work_dir=work / "ooc", impl=IMPL,
                        budget_bytes=budget, job_config=cfg, verify=verify)
    stats = plan.execute()
    merged = work / "merged.bin"
    plan.merge(merged)
    return plan, stats, merged.read_bytes()


def _ooc_scenario(work: Path, quick: bool) -> dict:
    n = 1 << (12 if quick else 14)
    budget = (8 * n) // 4
    f = fft_api.factor_out_of_core(n, budget)
    rng = np.random.default_rng(SEED)
    sig = rng.standard_normal((n, 2)).astype(np.float32)
    oracle = reference_out_of_core(sig, f, impl=IMPL)

    # storm across BOTH post-CRC checkpoints: tile payloads (pre-journal,
    # so the CRCs bless the corrupt bytes) and realized pass outputs
    tile_rules = FaultPlan.random(SEED, f.tiles, sites=("ooc.shuffle",),
                                  rate=0.25, kind="corrupt")
    panel_rules = FaultPlan.random(SEED + 1, max(f.pass1_jobs, f.pass2_jobs),
                                   sites=("stream.realize",),
                                   rate=0.25, kind="corrupt")
    storm = FaultPlan(tile_rules.rules + panel_rules.rules)

    clear_events()
    _, _, clean_bytes = _ooc_run(work / "clean", sig, n, budget, None,
                                 "parseval")
    clean_fp = len(events("verify_failed"))

    clear_events()
    inj = FaultInjector(storm)
    _, stats, storm_bytes = _ooc_run(work / "storm", sig, n, budget, inj,
                                     "parseval")
    detected = len(events("verify_failed"))
    sites = sorted({e.get("site") for e in events("verify_failed")})

    inj_off = FaultInjector(storm)
    _, stats_off, off_bytes = _ooc_run(work / "off", sig, n, budget,
                                       inj_off, "off")
    retries = stats.pass1.retries + stats.pass2.retries
    return {
        "n": n, "tiles": f.tiles,
        "corrupt_rules": len(storm.rules),
        "corrupted": inj.total_corrupted,
        "detected": detected,
        "detected_sites": sites,
        "retries": retries,
        "clean_false_positives": clean_fp,
        "clean_bitwise_equals_oracle": clean_bytes == oracle,
        "recovered_bitwise": storm_bytes == oracle,
        "off_corrupted": inj_off.total_corrupted,
        "off_retries": stats_off.pass1.retries + stats_off.pass2.retries,
        "off_silently_wrong": off_bytes != oracle,
    }


# ----------------------------------------------------------------- serve

class _Shape:
    kind = "c2c"
    n = FFT_LEN
    rows = 2


def _serve_run(reqs, verify: str, injector, seed: int) -> tuple:
    fft_api.clear_plan_cache()
    svc = FftService(impl=IMPL, coalesce=COALESCE, queue_depth=256,
                     max_batch_delay_s=0.001, injector=injector,
                     verify=verify)
    tickets = [svc.submit("c2c", xr, xi) for xr, xi in reqs]
    for t in tickets:
        t.wait(60)
    svc.close(drain=True)
    return svc, tickets


def _serve_requests(seed: int, count: int = 16):
    rng = np.random.default_rng(seed)
    return [tuple(rng.standard_normal((_Shape.rows, _Shape.n))
                  .astype(np.float32) for _ in range(2))
            for _ in range(count)]


def _serve_bitwise(tickets, reqs) -> bool:
    for t, ops in zip(tickets, reqs):
        if t.error is not None:
            return False
        want = loadgen.oracle(_Shape, ops, impl=IMPL,
                              batch_rows=t.batch_rows)
        for g, w in zip(t.value, want):
            if np.asarray(g).tobytes() != np.asarray(w).tobytes():
                return False
    return True


def _serve_scenario() -> dict:
    reqs = _serve_requests(SEED)
    storm = FaultPlan.random(SEED, len(reqs), sites=("serve.execute",),
                             rate=CORRUPT_RATE, kind="corrupt")

    clear_events()
    inj = FaultInjector(storm)
    svc, tickets = _serve_run(reqs, "abft", inj, SEED)
    detected = len(events("verify_failed"))

    inj_off = FaultInjector(storm)
    svc_off, tk_off = _serve_run(reqs, "off", inj_off, SEED)
    off_wrong = sum(
        1 for t, ops in zip(tk_off, reqs)
        if t.error is None and any(
            np.asarray(g).tobytes() != np.asarray(w).tobytes()
            for g, w in zip(t.value, loadgen.oracle(
                _Shape, ops, impl=IMPL, batch_rows=t.batch_rows))))
    return {
        "requests": len(reqs),
        "corrupt_rules": len(storm.rules),
        "corrupted": inj.total_corrupted,
        "detected": detected,
        "stats_detected": svc.stats.corruption_detected,
        "stats_recomputed": svc.stats.corruption_recomputed,
        "retries": svc.stats.retries,
        "all_completed": all(t.error is None for t in tickets),
        "recovered_bitwise": _serve_bitwise(tickets, reqs),
        "off_corrupted": inj_off.total_corrupted,
        "off_retries": svc_off.stats.retries,
        "off_silently_wrong_requests": off_wrong,
    }


def _false_positive_sweep() -> dict:
    """>= CLEAN_SEEDS clean serve runs under verify="abft": the derived
    tolerances must never trip on honest data."""
    clear_events()
    fp = 0
    for seed in range(CLEAN_SEEDS):
        svc, tickets = _serve_run(_serve_requests(seed, count=8), "abft",
                                  None, seed)
        fp += svc.stats.corruption_detected
        fp += sum(1 for t in tickets if t.error is not None)
    return {"seeds": CLEAN_SEEDS, "false_positives": fp,
            "verify_failed_events": len(events("verify_failed"))}


# -------------------------------------------------------------- overhead

def _overhead(work: Path, iters: int = 5) -> dict:
    """Wall-clock cost of verification on the deterministic disk model:
    the same throttled store, pipelined job with verify off vs abft.
    Each mode is warmed once (plan builds don't bill to either side) and
    then timed ``iters`` times; the medians are compared — single-run
    walls at this size are thread-scheduling noisy (+-30%)."""
    store, _ = make_signal_store(work / "in", size_mb=SIZE_MB,
                                 fft_len=FFT_LEN,
                                 segments_per_block=SEGMENTS_PER_BLOCK)
    store = ThrottledStore.open(store.root)
    walls = {}
    for mode in ("off", "abft"):
        _stream_job(store, work / f"warm_{mode}", None, mode)
        runs = []
        for i in range(iters):
            _, _, w = _stream_job(store, work / f"timed_{mode}_{i}",
                                  None, mode)
            runs.append(w)
        walls[mode] = float(np.median(runs))
    rel = walls["abft"] / walls["off"] - 1.0 if walls["off"] else 0.0

    # the planner's analytic attribution for the launch shape this job used
    rows = COALESCE * SEGMENTS_PER_BLOCK
    p = fft_api.plan(kind="c2c", n=FFT_LEN, batch_shape=(rows + 1,),
                     impl=IMPL, verify="abft")
    return {
        "disk_model_mb_s": DISK_MB_S,
        "wall_off_s": round(walls["off"], 4),
        "wall_abft_s": round(walls["abft"], 4),
        "overhead_frac": round(rel, 4),
        "model": {"verify_flops": p.verify_flops,
                  "verify_hbm_bytes": p.verify_hbm_bytes,
                  "verify_overhead_flops_frac": round(p.verify_overhead, 4)},
    }


# ------------------------------------------------------------------ main

def run(quick: bool = False):
    fft_api.clear_plan_cache()
    with tempfile.TemporaryDirectory() as tmp:
        work = Path(tmp)
        stream = _stream_scenario(work / "stream")
        ooc = _ooc_scenario(work / "ooc", quick)
        serve = _serve_scenario()
        sweep = _false_positive_sweep()
        overhead = _overhead(work / "overhead")

    checks = {
        # detection + bitwise recovery on every execution path
        "stream_storm_detected":
            stream["corrupted"] >= 1 and stream["detected"] >= 1,
        "stream_recovered_bitwise": stream["recovered_bitwise"],
        "stream_no_failed_blocks": not stream["failed_blocks"],
        "ooc_storm_detected":
            ooc["corrupted"] >= 2 and ooc["detected"] >= 2,
        "ooc_recovered_bitwise": ooc["recovered_bitwise"],
        "ooc_clean_bitwise": ooc["clean_bitwise_equals_oracle"],
        "serve_storm_detected":
            serve["corrupted"] >= 1 and serve["detected"] >= 1
            and serve["stats_recomputed"] >= 1,
        "serve_recovered_bitwise": serve["recovered_bitwise"],
        # the negative control: without verify the SAME storms pass every
        # byte-level check and deliver wrong answers with zero retries
        "off_is_silently_wrong":
            stream["off_silently_wrong"] and ooc["off_silently_wrong"]
            and serve["off_silently_wrong_requests"] >= 1,
        "off_nothing_else_caught_it":
            stream["off_retries"] == 0 and ooc["off_retries"] == 0
            and serve["off_retries"] == 0,
        # zero false positives across the clean sweeps
        "no_false_positives":
            sweep["false_positives"] == 0
            and sweep["verify_failed_events"] == 0
            and stream["clean_false_positives"] == 0
            and ooc["clean_false_positives"] == 0,
        # verification hides under throttled I/O + transform compute
        "overhead_within_10pct":
            overhead["overhead_frac"] < OVERHEAD_BUDGET,
    }
    doc = {
        "quick": quick,
        "config": {"seed": SEED, "impl": IMPL, "fft_len": FFT_LEN,
                   "size_mb": SIZE_MB, "coalesce": COALESCE,
                   "corrupt_rate": CORRUPT_RATE,
                   "clean_seeds": CLEAN_SEEDS,
                   "overhead_budget": OVERHEAD_BUDGET},
        "stream": stream,
        "ooc": ooc,
        "serve": serve,
        "false_positive_sweep": sweep,
        "overhead": overhead,
        "checks": checks,
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = [
        {"name": "verify_stream_storm", "us_per_call": 0.0,
         "derived": f"corrupted={stream['corrupted']} "
                    f"detected={stream['detected']} "
                    f"retries={stream['retries']} "
                    f"bitwise={stream['recovered_bitwise']}"},
        {"name": "verify_ooc_storm", "us_per_call": 0.0,
         "derived": f"corrupted={ooc['corrupted']} "
                    f"detected={ooc['detected']} "
                    f"sites={'+'.join(ooc['detected_sites'])} "
                    f"bitwise={ooc['recovered_bitwise']}"},
        {"name": "verify_serve_storm", "us_per_call": 0.0,
         "derived": f"corrupted={serve['corrupted']} "
                    f"detected={serve['detected']} "
                    f"recomputed={serve['stats_recomputed']} "
                    f"bitwise={serve['recovered_bitwise']}"},
        {"name": "verify_overhead",
         "us_per_call": overhead["wall_abft_s"] * 1e6,
         "derived": f"off={overhead['wall_off_s']}s "
                    f"abft={overhead['wall_abft_s']}s "
                    f"frac={overhead['overhead_frac']}"},
        {"name": "verify_checks", "us_per_call": 0.0,
         "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                             for k, ok in checks.items())},
    ]
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
