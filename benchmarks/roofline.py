"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) single-pod cell:

    compute term    = HLO_FLOPs_per_device   / 197e12 FLOP/s   (bf16 MXU)
    memory term     = HLO_bytes_per_device   / 819e9  B/s      (HBM)
    collective term = coll_bytes_per_device  / 50e9   B/s      (ICI links)

(cost_analysis and the HLO collective parse are per-device — calibrated in
launch/dryrun.py — so the spec's global/(chips*peak) form reduces to these.)
FLOPs/bytes come from the unrolled-depth-extrapolated cost pass because
XLA's cost analysis ignores while-loop trip counts (models/scanning.py).

MODEL_FLOPS uses the spec's convention: 6*N*D train / 2*N*D prefill /
2*N*B decode, N = active params (MoE: routed top-k + shared expert), D =
global tokens; divided by 256 chips to match the per-device HLO numbers.
The ratio MODEL_FLOPS/HLO_FLOPs exposes remat recompute, attention flops,
dispatch overhead, and — dominant for small-head archs — attention compute
replicated over the model axis when head counts don't divide it.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.specs import SHAPES

PEAK_FLOPS = 197e12   # bf16 per chip (v5e)
HBM_BW = 819e9        # B/s per chip
ICI_BW = 50e9         # B/s per link
CHIPS_SINGLE_POD = 256

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def model_flops_per_device(arch: str, shape: str, chips: int = CHIPS_SINGLE_POD):
    cfg = get_config(arch)
    case = SHAPES[shape]
    n = cfg.n_active_params()
    if case.mode == "train":
        toks = case.global_batch * case.seq_len
        total = 6.0 * n * toks
    elif case.mode == "prefill":
        toks = case.global_batch * case.seq_len
        total = 2.0 * n * toks
    else:  # decode: one token per sequence
        total = 2.0 * n * case.global_batch
    return total / chips


def load_cell(arch: str, shape: str, mesh: str = "single_pod") -> dict | None:
    p = RESULTS / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def analyze_cell(rec: dict) -> dict | None:
    """Three roofline terms + bottleneck + MFU-proxy for one dry-run record.

    Metric hygiene (models/scanning.py): flops + collective bytes come from
    the full-unroll extrapolation and are exact; the scanned pass (loop
    bodies counted once) is a hard floor, so every extrapolated metric is
    clamped to it — this also de-noises decode cells where tiny per-layer
    deltas can go negative. Memory is reported as [lb, ub]: lb from the
    layers-only unroll (inner-scan bodies once), ub from the full unroll
    (fusion-subsumed slices overcount); the geometric mean is the point
    estimate used for the bottleneck call.
    """
    if rec.get("skipped") or not rec.get("ok"):
        return None
    cost = rec.get("cost") or rec.get("cost_lb")
    if cost is None:
        return None
    floor = rec.get("cost_scanned", {})

    def met(key, source=cost):
        return max(source.get(key, 0.0), floor.get(key, 0.0), 0.0)

    flops = met("flops")
    bytes_ub = met("bytes_accessed")
    bytes_lb = (met("bytes_accessed", rec["cost_lb"])
                if "cost_lb" in rec else bytes_ub)
    bytes_lb = min(bytes_lb, bytes_ub)
    bytes_mid = (bytes_lb * bytes_ub) ** 0.5 if bytes_lb else bytes_ub
    coll = met("collective_bytes")

    t_compute = flops / PEAK_FLOPS
    t_mem_lb, t_mem_ub = bytes_lb / HBM_BW, bytes_ub / HBM_BW
    t_memory = bytes_mid / HBM_BW
    t_collective = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"])
    t_bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": t_compute, "memory_s": t_memory,
        "memory_s_lb": t_mem_lb, "memory_s_ub": t_mem_ub,
        "collective_s": t_collective,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        # roofline fraction: useful-model-time / bound-time
        "roofline_frac": (mf / PEAK_FLOPS) / t_bound if t_bound else 0.0,
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
    }


def table(mesh: str = "single_pod"):
    rows = []
    for arch in [a.strip() for a in _ARCHS]:
        for shape in SHAPES:
            rec = load_cell(arch, shape, mesh)
            if rec is None:
                continue
            if rec.get("skipped"):
                rows.append({"arch": arch, "shape": shape, "skipped": True,
                             "reason": rec.get("reason", "")})
                continue
            a = analyze_cell(rec)
            if a:
                rows.append(a)
            else:
                rows.append({"arch": arch, "shape": shape,
                             "failed": rec.get("error", "no cost pass")})
    return rows


from repro.configs import ARCHS as _ARCHS  # noqa: E402


def run(quick: bool = False):
    out = []
    for r in table():
        if r.get("skipped"):
            out.append({"name": f"roofline_{r['arch']}_{r['shape']}",
                        "us_per_call": 0.0, "derived": "SKIP (long_500k rule)"})
            continue
        if r.get("failed"):
            out.append({"name": f"roofline_{r['arch']}_{r['shape']}",
                        "us_per_call": 0.0, "derived": f"FAIL {r['failed']}"})
            continue
        out.append({
            "name": f"roofline_{r['arch']}_{r['shape']}",
            "us_per_call": max(r["compute_s"], r["memory_s"],
                               r["collective_s"]) * 1e6,
            "derived": (f"bound={r['bottleneck']} "
                        f"frac={r['roofline_frac']:.3f} "
                        f"useful={r['useful_ratio']:.3f} "
                        f"c/m/x={r['compute_s']:.4f}/{r['memory_s']:.4f}/"
                        f"{r['collective_s']:.4f}s"),
        })
    if not out:
        out.append({"name": "roofline", "us_per_call": 0.0,
                    "derived": "no dry-run results yet (run repro.launch.sweep)"})
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
