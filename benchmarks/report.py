"""Generate the EXPERIMENTS.md tables from results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report [--mesh single_pod]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.roofline import (CHIPS_SINGLE_POD, analyze_cell, load_cell,
                                 model_flops_per_device)
from repro.configs import ARCHS
from repro.launch.specs import SHAPES

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun"


def dryrun_table(mesh: str) -> str:
    lines = [
        f"| arch | shape | status | temp GiB/dev | args GiB/dev | compile s |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load_cell(arch, shape, mesh)
            if rec is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | |")
                continue
            if rec.get("skipped"):
                lines.append(f"| {arch} | {shape} | skip (long_500k rule) | | | |")
                continue
            if not rec.get("ok"):
                lines.append(f"| {arch} | {shape} | **FAIL** {rec.get('error','')[:60]} | | | |")
                continue
            m = rec["memory"]
            lines.append(
                f"| {arch} | {shape} | ok | "
                f"{m['temp_bytes'] / 2**30:.2f} | "
                f"{m['argument_bytes'] / 2**30:.2f} | "
                f"{rec.get('compile_s', '')} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute s | memory s [lb,ub] | collective s | bound | "
        "MODEL/HLO flops | roofline frac | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    hints = {
        ("collective",): "cut FSDP gathers / EP a2a / topology reshape",
        ("memory",): "fuse, bf16 intermediates, smaller remat window",
        ("compute",): "shard replicated attention heads / pad to axis",
    }
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load_cell(arch, shape, "single_pod")
            if rec is None or rec.get("skipped") or not rec.get("ok"):
                continue
            a = analyze_cell(rec)
            if not a:
                continue
            fix = hints[(a["bottleneck"],)]
            lines.append(
                f"| {arch} | {shape} | {a['compute_s']:.4f} | "
                f"{a['memory_s']:.4f} [{a['memory_s_lb']:.4f},"
                f"{a['memory_s_ub']:.4f}] | {a['collective_s']:.4f} | "
                f"{a['bottleneck']} | {a['useful_ratio']:.3f} | "
                f"{a['roofline_frac']:.4f} | {fix} |")
    return "\n".join(lines)


def pick_hillclimb_cells() -> list[dict]:
    """worst roofline frac / most collective-bound / paper-representative."""
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load_cell(arch, shape, "single_pod")
            if rec is None or rec.get("skipped") or not rec.get("ok"):
                continue
            a = analyze_cell(rec)
            if a:
                cells.append(a)
    if not cells:
        return []
    worst = min(cells, key=lambda a: a["roofline_frac"])
    coll = max(cells, key=lambda a: a["collective_s"]
               / max(a["compute_s"] + a["memory_s"], 1e-12))
    return [dict(worst, why="worst roofline fraction"),
            dict(coll, why="most collective-bound")]


def main(argv=None):
    ap = argparse.ArgumentParser()
    args = ap.parse_args(argv)
    print("## Dry-run (single_pod, 16x16 = 256 chips)\n")
    print(dryrun_table("single_pod"))
    print("\n## Dry-run (multi_pod, 2x16x16 = 512 chips)\n")
    print(dryrun_table("multi_pod"))
    print("\n## Roofline (single_pod)\n")
    print(roofline_table())
    print("\n## Suggested hillclimb cells\n")
    for c in pick_hillclimb_cells():
        print(f"- {c['arch']} x {c['shape']}: {c['why']} "
              f"(frac={c['roofline_frac']:.4f}, bound={c['bottleneck']})")


if __name__ == "__main__":
    main()
