"""End-to-end pipeline benchmark: serial map-only vs the overlapped stream.

The paper's Hadoop layer wins by overlapping map waves with I/O; the
stream executor (core/pipeline/stream.py) makes that overlap explicit —
prefetch readers, coalesced async device batches, writeback workers. This
benchmark runs the SAME block store through three configurations and
records the trajectory in BENCH_pipeline.json:

  * ``serial`` — the synchronous per-block map loop (one worker: read ->
    decode -> H2D -> execute -> sync -> D2H -> encode -> write, nothing
    overlapped). This is the acceptance baseline: the pipelined mode must
    beat its throughput strictly.
  * ``pipelined`` — the stream executor: coalesce=4, inflight=3,
    4 readers / 4 writers.
  * ``maponly_threaded`` — the classic thread-pool map-only job (reported
    for context, not gated: on a many-core host with a hot page cache it
    approximates a parallel memcpy farm; the stream executor's advantages
    — bounded staging memory, one dispatcher feeding the device window,
    coalesced launches — matter on real accelerators where per-thread
    dispatch serializes on the device anyway).

Per-mode metrics: throughput (input MB/s of job wall), per-stage clock
totals (read/h2d/compute/d2h/write), ``overlap_efficiency`` = max(stage
totals)/wall (1.0 = wall collapsed onto the slowest stage, a perfectly
hidden pipeline) and ``overlap_x`` = sum(stage totals)/wall (> 1 proves
compute and I/O genuinely ran concurrently: wall < sum of stage times).
Outputs of all modes must be bitwise identical — coalesced batches and the
remainder tail must not change a single bit.

Both paths are warmed up on a small store first so plan trace+compile time
(benchmarked separately in BENCH_fft.json) doesn't pollute the comparison.
impl="ref" keeps the leaf transform identical-and-cheap on the CPU CI
container — this benchmark measures orchestration, not the kernels.

I/O model: CI scratch space is effectively tmpfs, where a block "read" is
a page-cache memcpy — there is no latency for a pipeline to hide, and on
a 2-core runner a single sequential loop is already near memory-bandwidth
optimal (the paper's regime is the opposite: spinning-disk HDFS at
~100-250 MB/s per spindle against a fast device). `ThrottledStore`
restores that regime deterministically: every block read/write sleeps
bytes / DISK_MB_S, identically for every mode. The sleep stands in for
real device/disk latency, so the gate measures exactly what the tentpole
claims — the stream executor hides I/O latency behind compute and the
serial loop cannot. ``disk_sim_mb_s`` in the JSON records the model.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

from benchmarks.common import make_signal_store
from repro.core.pipeline import JobConfig
from repro.core.pipeline.testing import DISK_MB_S, ThrottledStore
from repro.launch.fft_job import run_job
import repro.fft as fft_api

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"

# per-transition manifest fsyncs + atomic block writes hit the filesystem
# hard; on slow/virtual filesystems (9p, overlay) fsync latency noise
# swamps the orchestration signal this benchmark measures. Prefer tmpfs —
# but only when it can actually hold the working set (Docker's default
# /dev/shm is 64MB; a full run needs input + per-mode outputs + merges).


def _scratch() -> Path | None:
    shm = Path("/dev/shm")
    if not shm.is_dir():
        return None
    try:
        st = os.statvfs(shm)
    except OSError:
        return None
    return shm if st.f_bavail * st.f_frsize >= 2 << 30 else None


_SCRATCH = _scratch()

FFT_LEN = 1024
SEGMENTS_PER_BLOCK = 512  # 4 MB blocks
COALESCE = 4
INFLIGHT = 3
IMPL = "ref"
# ThrottledStore / DISK_MB_S: the shared deterministic disk model
# (repro/core/pipeline/testing.py) — same 250 MB/s spindle as before.

MODES = {
    # speculation off for stable timing; it is covered by the test suite
    "serial": dict(pipelined=False,
                   cfg=JobConfig(workers=1, speculation=False)),
    "pipelined": dict(pipelined=True,
                      cfg=JobConfig(readers=4, writers=4, coalesce=COALESCE,
                                    inflight=INFLIGHT, speculation=False,
                                    poll_interval_s=0.005)),
    "maponly_threaded": dict(pipelined=False,
                             cfg=JobConfig(workers=4, speculation=False)),
}


def _run_mode(store, work: Path, mode: str) -> dict:
    out_dir = work / f"out_{mode}"
    if out_dir.exists():
        shutil.rmtree(out_dir)  # fresh manifest: re-run every block
    t0 = time.monotonic()
    job, stats, stage_s = run_job(store, out_dir, fft_len=FFT_LEN, impl=IMPL,
                                  **MODES[mode])
    wall = time.monotonic() - t0
    merged = work / f"merged_{mode}.bin"
    job.merge(merged)
    stage_total = sum(stage_s.values())
    max_stage = max(stage_s.values()) if stage_s else 0.0
    return {
        "wall_s": wall,
        "throughput_mb_s": store.total_bytes / (1 << 20) / wall,
        "stage_s": {k: round(v, 4) for k, v in stage_s.items()},
        "stage_total_s": round(stage_total, 4),
        "overlap_efficiency": round(max_stage / wall, 4) if wall else None,
        "overlap_x": round(stage_total / wall, 4) if wall else None,
        "batches": stats.batches,
        "coalesced_blocks": stats.coalesced_blocks,
        "blocks": stats.blocks_done,
        "merged": merged,
    }


def run(quick: bool = False):
    size_mb = 64 if quick else 128
    iters = 2 if quick else 3
    fft_api.clear_plan_cache()
    with tempfile.TemporaryDirectory(dir=_SCRATCH) as tmp:
        work = Path(tmp)
        # warmup: compile both paths' plans (serial per-block shape +
        # coalesced full-batch shape) on a store of exactly one full batch
        warm_store, _ = make_signal_store(
            work / "warm_in", size_mb=COALESCE * 4, fft_len=FFT_LEN,
            segments_per_block=SEGMENTS_PER_BLOCK)
        warm_store = ThrottledStore.open(warm_store.root)
        for mode in MODES:
            _run_mode(warm_store, work / "warm", mode)

        store, _ = make_signal_store(work / "in", size_mb=size_mb,
                                     fft_len=FFT_LEN,
                                     segments_per_block=SEGMENTS_PER_BLOCK)
        store = ThrottledStore.open(store.root)
        results = {}
        for mode in MODES:
            best = None
            for _ in range(iters):
                r = _run_mode(store, work, mode)
                if best is None or r["wall_s"] < best["wall_s"]:
                    best = r
            results[mode] = best
        merged = {m: results[m].pop("merged").read_bytes() for m in results}
        identical = all(v == merged["serial"] for v in merged.values())

    ser, pipe = results["serial"], results["pipelined"]
    checks = {
        # acceptance: coalesced+overlapped beats the serial map loop
        "pipelined_throughput_gt_serial":
            pipe["throughput_mb_s"] > ser["throughput_mb_s"],
        # acceptance: wall < sum of stage clocks == genuine overlap
        "pipelined_stages_overlap": pipe["overlap_x"] is not None
            and pipe["overlap_x"] > 1.0,
        # the coalesced batches + remainder tail change nothing, bitwise
        "outputs_bitwise_identical": identical,
    }
    doc = {
        "quick": quick,
        "config": {"size_mb": size_mb, "fft_len": FFT_LEN,
                   "segments_per_block": SEGMENTS_PER_BLOCK,
                   "coalesce": COALESCE, "inflight": INFLIGHT, "impl": IMPL,
                   "disk_sim_mb_s": DISK_MB_S},
        **results,
        "speedup_vs_serial_x": round(
            pipe["throughput_mb_s"] / ser["throughput_mb_s"], 3),
        "checks": checks,
        "plan_cache": fft_api.cache_info(),
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = []
    for mode, r in results.items():
        rows.append({
            "name": f"pipeline_{mode}",
            "us_per_call": r["wall_s"] * 1e6,
            "derived": (f"{r['throughput_mb_s']:.1f}MB/s "
                        f"overlap_x={r['overlap_x']} "
                        f"overlap_eff={r['overlap_efficiency']} "
                        f"batches={r['batches']}"),
        })
    rows.append({"name": "pipeline_checks", "us_per_call": 0.0,
                 "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                                     for k, ok in checks.items())})
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
