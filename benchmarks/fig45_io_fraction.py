"""Paper Figures 4/5: percent of time in I/O vs FFT calculation.

Paper: CPU pipeline ~70-75% I/O; GPU pipeline ~92-95% I/O (the faster the
compute, the more I/O dominates — the Amdahl argument driving the whole
design). Reproduced through the block pipeline with per-phase timers.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

from benchmarks.common import make_signal_store
from benchmarks.fig2_total_time import run_pipeline
from repro.core.amdahl import fit_parallel_fraction

FFT_LEN = 1024


def run(quick: bool = False):
    size = 8 if quick else 24
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        store, _ = make_signal_store(Path(tmp) / "in", size_mb=size,
                                     fft_len=FFT_LEN)
        for impl, fig, paper in (("ref", "fig4", "70-75%"),
                                 ("matfft", "fig5", "92-95%")):
            r = run_pipeline(store, Path(tmp) / f"out_{impl}", impl, FFT_LEN)
            measured = r["io_s"] + r["fft_s"]
            io_pct = 100 * r["io_s"] / measured
            p = fit_parallel_fraction(r["io_s"], r["fft_s"])
            rows.append({
                "name": f"{fig}_io_fraction_{impl}",
                "us_per_call": r["total_s"] * 1e6,
                "derived": f"io={io_pct:.1f}% fft={100 - io_pct:.1f}% "
                           f"amdahl_P={p:.3f} (paper: io {paper})"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
