"""Chaos gate: seeded fault schedules over the full pipelined FFT job.

The resilience layer's acceptance property (DESIGN.md §10) is that a
deterministic storm of injected failures — bad block reads, corrupt
replicas, decode/launch/realize/writeback faults — changes NOTHING about
the job's output, only its attempt counts. This benchmark proves it and
records the trajectory in BENCH_chaos.json:

  * **Chaos parity** — one pipelined job runs fault-free, then the SAME
    store re-runs under a seeded `FaultPlan` (≥3 distinct injection
    sites, ≥10% of blocks scheduled to fault, plus two physically
    corrupted primary replicas). Gates: the merged outputs are bitwise
    identical, no block exhausts its retry budget, the injector actually
    fired, and the corrupted replicas were served via deep-verified
    fallback AND repaired on disk (`StoreStats`).
  * **Graceful degradation** — a distributed plan on an 8-device host
    mesh loses two devices (`mesh.device` rules via
    `FaultInjector.apply_device_loss`); `plan(..., fallback="degrade")`
    must complete by re-planning on the shrunk healthy mesh instead of
    raising, produce a numerically correct spectrum, and record a
    "plan_downgrade" resilience event.
  * **Corrupt-storm negative control** — the one storm this layer can
    NOT absorb: ``kind="corrupt"`` rules perturb realized values after
    every byte check has passed. Without ABFT verification the job
    "succeeds" with silently wrong bytes and ZERO retries (proof the
    CRC/replica machinery is blind to it); with ``verify="abft"`` the
    same storm is detected and the output recovers bitwise. The full
    defense gate is benchmarks/bench_verify.py (BENCH_verify.json).

Wall times for the fault-free vs chaos runs are recorded un-gated (the
chaos overhead is retry work by design, not a regression signal). The
schedule is a pure function of SEED — rerunning this benchmark anywhere
replays byte-for-byte the same faults.
"""

from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import shutil  # noqa: E402
import tempfile  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402

from benchmarks.common import make_signal_store  # noqa: E402
from repro.core.pipeline import (JobConfig, MapOnlyJob,  # noqa: E402
                                 SegmentFFTTransform)
from repro.core.resilience import (FaultInjector, FaultPlan,  # noqa: E402
                                   FaultRule, clear_events, events)
from repro.core.resilience import meshstate  # noqa: E402
import repro.fft as fft_api  # noqa: E402

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_chaos.json"

FFT_LEN = 512
SEGMENTS_PER_BLOCK = 256  # 1 MB blocks
SIZE_MB = 16              # -> 16 blocks
SEED = 1407               # the chaos schedule is a pure function of this
RATE = 0.25               # per (site, block) fault probability
IMPL = "ref"              # orchestration under fault, not kernels
# the seeded draw covers the per-block sites; replica faults fall back to
# a healthy copy (replication=2) instead of failing the block, so the
# worst per-block failure count is bounded by the other four sites
DRAW_SITES = ("blockstore.read", "blockstore.replica", "blockstore.write",
              "stream.decode", "stream.writeback")
# explicit group-site rules: one hit fails a whole coalesced batch, so
# they are scheduled deterministically rather than drawn per block
GROUP_RULES = (FaultRule("stream.launch", 2), FaultRule("stream.realize", 3))
COALESCE = 4
# budget: worst case a block eats one fault per drawn failing site (4)
# plus both group hits landing in its batch
MAX_RETRIES = 8


def _run_job(store, out_dir: Path, injector,
             verify: str = "off") -> tuple[dict, bytes, float]:
    if out_dir.exists():
        shutil.rmtree(out_dir)  # fresh manifest: re-run every block
    cfg = JobConfig(readers=2, writers=2, coalesce=COALESCE, inflight=2,
                    speculation=False, poll_interval_s=0.005,
                    max_retries=MAX_RETRIES, injector=injector)
    store.injector = injector
    t0 = time.monotonic()
    job = MapOnlyJob(store, out_dir, config=cfg, pipelined=True,
                     transform=SegmentFFTTransform(FFT_LEN, impl=IMPL,
                                                   verify=verify))
    stats = job.run()
    wall = time.monotonic() - t0
    merged = out_dir.parent / f"{out_dir.name}_merged.bin"
    job.merge(merged)
    return stats, merged.read_bytes(), wall


def _chaos_plan(num_blocks: int) -> FaultPlan:
    drawn = FaultPlan.random(SEED, num_blocks, sites=DRAW_SITES, rate=RATE)
    return FaultPlan(drawn.rules + GROUP_RULES, meta=dict(drawn.meta))


def _degrade_scenario() -> dict:
    """Distributed plan loses 2/8 devices; degrade must re-plan, not raise."""
    import jax
    from repro import compat

    n = 1 << 12
    rng = np.random.default_rng(SEED)
    xr = rng.standard_normal(n).astype(np.float32)
    xi = rng.standard_normal(n).astype(np.float32)
    ref = np.fft.fft(xr + 1j * xi)

    mesh = compat.make_mesh((len(jax.devices()),), ("x",))
    plan_kw = dict(kind="c2c", n=n, mesh=mesh, placement="distributed")
    fft_api.plan(**plan_kw)  # healthy-mesh plan now stale on device loss

    inj = FaultInjector(FaultPlan.random(SEED, 0, rate=0.0,
                                         device_loss=(6, 7)))
    clear_events()
    try:
        lost = inj.apply_device_loss(mesh)
        t0 = time.monotonic()
        p = fft_api.plan(**plan_kw, fallback="degrade")
        yr, yi = p.execute(xr, xi)
        wall = time.monotonic() - t0
        got = np.asarray(yr) + 1j * np.asarray(yi)
        downgrades = events("plan_downgrade")
    finally:
        meshstate.restore_devices()
    err = float(np.max(np.abs(got - ref)) / np.max(np.abs(ref)))
    return {
        "mesh_devices": int(mesh.devices.size),
        "lost_devices": len(lost),
        "degraded_devices": (int(p.mesh.devices.size)
                             if p.mesh is not None else 0),
        "degraded_placement": p.placement,
        "replan_wall_s": round(wall, 4),
        "rel_err": err,
        "downgrade_events": downgrades,
        "completed": True,
    }


def _corrupt_scenario(work: Path) -> dict:
    """The negative control: silent value corruption vs the byte checks.

    Same store, same seeded ``kind="corrupt"`` storm at the post-realize
    checkpoint, run twice — verify off (the storm must slip through every
    CRC with zero retries) and verify="abft" (the checksum row must catch
    it and the retry path must restore the clean bytes)."""
    store, _ = make_signal_store(work / "in", size_mb=SIZE_MB // 2,
                                 fft_len=FFT_LEN,
                                 segments_per_block=SEGMENTS_PER_BLOCK)
    num_blocks = len(store.blocks)
    storm = FaultPlan.random(SEED, num_blocks, sites=("stream.realize",),
                             rate=0.5, kind="corrupt")

    _, clean_bytes, _ = _run_job(store, work / "clean", None)

    inj_off = FaultInjector(storm)
    stats_off, off_bytes, _ = _run_job(store, work / "corrupt_off", inj_off)

    clear_events()
    inj = FaultInjector(storm)
    stats_abft, abft_bytes, _ = _run_job(store, work / "corrupt_abft", inj,
                                         verify="abft")
    return {
        "blocks": num_blocks,
        "corrupt_rules": len(storm.rules),
        "off_corrupted": inj_off.total_corrupted,
        "off_retries": stats_off.retries,
        "off_silently_wrong": off_bytes != clean_bytes,
        "abft_corrupted": inj.total_corrupted,
        "abft_detected": len(events("verify_failed")),
        "abft_retries": stats_abft.retries,
        "abft_recovered_bitwise": abft_bytes == clean_bytes,
    }


def run(quick: bool = False):
    fft_api.clear_plan_cache()
    with tempfile.TemporaryDirectory() as tmp:
        work = Path(tmp)
        store, _ = make_signal_store(work / "in", size_mb=SIZE_MB,
                                     fft_len=FFT_LEN,
                                     segments_per_block=SEGMENTS_PER_BLOCK,
                                     replication=2)
        num_blocks = len(store.blocks)

        base_stats, base_bytes, base_wall = _run_job(
            store, work / "out_clean", injector=None)

        # physical damage on top of the injected schedule: two primaries
        # rot on disk, so the chaos run must survive REAL corruption too
        store.corrupt_block(0, replica=0)
        store.corrupt_block(1, replica=0)

        plan = _chaos_plan(num_blocks)
        injector = FaultInjector(plan)
        chaos_stats, chaos_bytes, chaos_wall = _run_job(
            store, work / "out_chaos", injector=injector)

        corrupt = _corrupt_scenario(work / "corrupt")

    raising = [r for r in plan.rules if r.site != "mesh.device"]
    faulted_blocks = {r.index for r in raising if r.index is not None}
    degrade = _degrade_scenario()

    checks = {
        # acceptance: chaos changes attempt counts, never output bits
        "chaos_output_bitwise_identical": chaos_bytes == base_bytes,
        "chaos_distinct_sites_ge_3":
            len({r.site for r in raising}) >= 3,
        "chaos_block_fault_rate_ge_10pct":
            len(faulted_blocks) >= max(1, num_blocks // 10),
        "chaos_faults_fired": injector.total_fired >= len(faulted_blocks),
        "chaos_attempts_within_budget":
            chaos_stats.attempts <= num_blocks * MAX_RETRIES,
        "chaos_no_failed_blocks": not chaos_stats.failed_blocks,
        # the corrupted primaries were served from replica 1 AND healed
        "repair_heals_corrupt_replicas":
            store.stats.fallback_reads >= 2 and store.stats.repairs >= 2,
        # acceptance: device loss degrades to a working re-plan
        "degrade_replan_completed": degrade["completed"],
        "degrade_output_correct": degrade["rel_err"] < 1e-4,
        "degrade_event_recorded": len(degrade["downgrade_events"]) >= 1,
        # negative control: value corruption passes every byte check
        # silently; only the ABFT invariants (DESIGN.md §13) catch it
        "corrupt_silent_without_verify":
            corrupt["off_corrupted"] >= 1 and corrupt["off_retries"] == 0
            and corrupt["off_silently_wrong"],
        "corrupt_caught_with_verify":
            corrupt["abft_detected"] >= 1
            and corrupt["abft_recovered_bitwise"],
    }
    doc = {
        "quick": quick,
        "config": {"size_mb": SIZE_MB, "blocks": num_blocks,
                   "fft_len": FFT_LEN, "seed": SEED, "rate": RATE,
                   "draw_sites": DRAW_SITES, "coalesce": COALESCE,
                   "max_retries": MAX_RETRIES, "impl": IMPL},
        "schedule": {"rules": len(plan.rules),
                     "distinct_sites": sorted({r.site for r in raising}),
                     "faulted_blocks": sorted(faulted_blocks),
                     "block_fault_rate": round(
                         len(faulted_blocks) / num_blocks, 3)},
        "fault_free": {"wall_s": round(base_wall, 4),
                       "attempts": base_stats.attempts,
                       "retries": base_stats.retries},
        "chaos": {"wall_s": round(chaos_wall, 4),
                  "attempts": chaos_stats.attempts,
                  "retries": chaos_stats.retries,
                  "failed_blocks": chaos_stats.failed_blocks,
                  "injector": injector.summary(),
                  "store": store.stats.as_dict()},
        "corrupt_control": corrupt,
        "degrade": degrade,
        "checks": checks,
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = [
        {"name": "chaos_fault_free", "us_per_call": base_wall * 1e6,
         "derived": f"attempts={base_stats.attempts} "
                    f"retries={base_stats.retries}"},
        {"name": "chaos_injected", "us_per_call": chaos_wall * 1e6,
         "derived": f"attempts={chaos_stats.attempts} "
                    f"retries={chaos_stats.retries} "
                    f"fired={injector.total_fired} "
                    f"repairs={store.stats.repairs}"},
        {"name": "chaos_corrupt_control", "us_per_call": 0.0,
         "derived": f"off_wrong={corrupt['off_silently_wrong']} "
                    f"off_retries={corrupt['off_retries']} "
                    f"abft_detected={corrupt['abft_detected']} "
                    f"abft_bitwise={corrupt['abft_recovered_bitwise']}"},
        {"name": "chaos_degrade", "us_per_call": degrade["replan_wall_s"]
            * 1e6,
         "derived": f"devices={degrade['mesh_devices']}->"
                    f"{degrade['degraded_devices']} "
                    f"placement={degrade['degraded_placement']} "
                    f"rel_err={degrade['rel_err']:.2e}"},
        {"name": "chaos_checks", "us_per_call": 0.0,
         "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                             for k, ok in checks.items())},
    ]
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
