"""Measuring autotuner + 3-D pencil acceptance gate (BENCH_tune.json).

Three claims, each on a deterministic substrate (PR-3 precedent: CI has
no real interconnect or spindle, so the gates run on the seeded models
and the raw container walls are recorded un-gated):

  * **Tuned <= default** — `tune()` with the deterministic two-resource
    event-sim measurer (ICI link + MXU, the bench_distributed constants)
    must pick knobs whose modeled wall is <= the analytic default's wall
    for the distributed pencil, and `tune_out_of_core()` on the
    ThrottledStore disk model (250 MB/s spindle + per-job overhead) must
    pick a panel_scale no slower than the default factorization. Both
    are structural — the default is always candidate 0 of the sweep —
    so a regression here means the sweep lost the default or the ranking
    broke.
  * **Wisdom round-trip** — a SECOND process re-planning the same spec
    against the shared wisdom file must report `wisdom_hit` with ZERO
    measurements and the IDENTICAL winning knobs: plan selection is a
    pure lookup, FFTW-wisdom style.
  * **3-D pencil** — the (4, 2)-mesh pencil volume must be bitwise-equal
    to the LOCAL fftn oracle under BOTH exchange engines, run exactly
    ``ndim-1 == 2`` exchange legs, and its per-leg collective-byte
    accounting must sum to the totals the cost model gates on.
"""

from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402

import repro.fft as fft_api  # noqa: E402
from repro import compat  # noqa: E402
from repro.fft import tuner  # noqa: E402

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_tune.json"

SHAPE3 = (16, 32, 64)   # 3-D pencil volume for the bitwise gate
SHAPE2 = (64, 256)      # distributed 2-D spec the tuner sweeps
BT = 2                  # matched kernel tile (bitwise vs local)

ICI_BPS = 50e9          # bench_distributed's event-sim constants
MACS_PS = 2e13
RING_LAT_S = 1e-6
A2A_LAT_S = 1e-6
DISK_BPS = 250e6        # core.pipeline.testing.DISK_MB_S


def event_sim_measurer(plan, cfg):
    """Deterministic two-resource schedule wall for a distributed plan:
    leaf GEMM time + the exchange bytes the pipeline cannot hide + a
    launch-latency charge per collective round (chunked engines pay
    D-1 ppermute rounds per chunk per leg; monolithic pays one
    all_to_all per leg)."""
    comp = plan.gemm_macs / MACS_PS
    exposed = plan.exposed_collective_bytes / ICI_BPS
    legs = getattr(plan.dist, "n_exchanges", 1) if plan.dist else 0
    ov = plan.spec.overlap
    if ov == "off" or not legs:
        lat = legs * A2A_LAT_S
    else:
        ring = max(getattr(plan.dist, "grid", (plan.dist.d,)))
        lat = legs * int(ov) * (ring - 1) * RING_LAT_S
    extra = 0.0
    if plan.spec.layout == "copy":
        extra = plan.hbm_bytes / (8 * MACS_PS)  # materialized transposes
    return comp + exposed + lat + extra


_CHILD = r"""
import json, os, sys
import repro.fft as fft_api
from repro.fft import tuner

wp, payload = sys.argv[1], json.loads(sys.argv[2])
cfg = tuner.TuneConfig(measurer="analytic")
p = fft_api.plan(kind="c2c", shape=tuple(payload["shape"]),
                 batch_shape=tuple(payload["batch_shape"]),
                 tune=True, wisdom_path=wp, tune_config=cfg)
stats = tuner.tune_stats()
print(json.dumps({
    "measurements": stats["measurements"],
    "wisdom_hits": stats["wisdom_hits"],
    "knobs": {"layout": p.spec.layout, "overlap": p.spec.overlap,
              "batch_tile": p.spec.batch_tile},
    "cache_wisdom_hits": fft_api.cache_info()["wisdom_hits"],
}))
"""


def run(quick: bool = False):
    d = jax.device_count()
    fft_api.clear_plan_cache()
    tuner.reset_tune_stats()
    tmp = Path(tempfile.mkdtemp(prefix="repro_tune_bench_"))
    wp = str(tmp / "wisdom.json")

    # ---- gate (a): tuned <= default on the event-sim model -----------
    mesh = compat.make_mesh((d,), ("data",))
    cfg = tuner.TuneConfig(measurer=event_sim_measurer)
    knobs, rep = tuner.tune(
        kind="c2c", shape=(16 * d, 256), mesh=mesh, axes=("data",),
        num_devices=d, placement="distributed",
        wisdom_path=str(tmp / "dist.json"), config=cfg)
    default_wall = rep.candidates[0]["measured_s"]
    tuned_wall = min(c["measured_s"] for c in rep.candidates)
    tuned_le_default = tuned_wall <= default_wall

    scale, orep = tuner.tune_out_of_core(
        1 << 24, 1 << 22, wisdom_path=str(tmp / "dist.json"))
    ooc_default = next(c["measured_s"] for c in orep.candidates
                       if c["knobs"]["panel_scale"] == 1)
    ooc_tuned = min(c["measured_s"] for c in orep.candidates)
    ooc_le_default = ooc_tuned <= ooc_default

    # ---- gate (b): wisdom round-trip across processes ----------------
    payload = json.dumps({"shape": SHAPE2, "batch_shape": [8]})
    env = dict(os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
    outs = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD, wp, payload],
            capture_output=True, text=True, env=env, check=True)
        outs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    first, second = outs
    round_trip = (first["measurements"] > 0
                  and second["measurements"] == 0
                  and second["wisdom_hits"] == 1
                  and second["cache_wisdom_hits"] == 1
                  and second["knobs"] == first["knobs"])

    # ---- gate (c): 3-D pencil bitwise vs local fftn ------------------
    mesh3 = compat.make_mesh((4, d // 4), ("data", "model")) \
        if d >= 8 else None
    pencil_checks = {}
    if mesh3 is not None:
        rng = np.random.default_rng(0)
        xr, xi = (rng.standard_normal(SHAPE3).astype(np.float32)
                  for _ in range(2))
        local = fft_api.plan(kind="c2c", shape=SHAPE3, batch_tile=BT,
                             placement="local")
        want = [np.asarray(a) for a in local.execute(xr, xi)]
        for overlap in ("off", 2):
            p = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh3,
                             placement="distributed", batch_tile=BT,
                             overlap=overlap)
            got = p.execute(xr, xi)
            pencil_checks[f"bitwise_overlap_{overlap}"] = all(
                np.asarray(g).tobytes() == w.tobytes()
                for g, w in zip(got, want))
        p3 = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh3,
                          placement="distributed", overlap="off")
        legs = p3.per_leg_collective_bytes
        pencil_checks["n_exchanges_is_ndim_minus_1"] = (
            p3.dist.n_exchanges == len(SHAPE3) - 1)
        pencil_checks["per_leg_bytes_sum"] = (
            len(legs) == p3.dist.n_exchanges
            and sum(legs) == p3.collective_bytes)

    checks = {
        "tuned_le_default": tuned_le_default,
        "ooc_tuned_le_default": ooc_le_default,
        "wisdom_round_trip": round_trip,
        **pencil_checks,
    }
    doc = {
        "quick": quick,
        "config": {"devices": d, "shape3": SHAPE3, "shape2": SHAPE2,
                   "ici_bps": ICI_BPS, "macs_ps": MACS_PS,
                   "disk_bps": DISK_BPS},
        "tuned": {"knobs": knobs, "wall_s": tuned_wall,
                  "default_wall_s": default_wall,
                  "candidates": len(rep.candidates),
                  "disagreement": rep.disagreement},
        "ooc": {"panel_scale": scale, "wall_s": ooc_tuned,
                "default_wall_s": ooc_default},
        "wisdom": {"first": first, "second": second},
        "tune_stats": tuner.tune_stats(),
        "checks": checks,
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = [
        {"name": "tune_dist_default", "us_per_call": default_wall * 1e6,
         "derived": f"D={d} analytic-default knobs"},
        {"name": "tune_dist_tuned", "us_per_call": tuned_wall * 1e6,
         "derived": f"winner={knobs}"},
        {"name": "tune_ooc", "us_per_call": ooc_tuned * 1e6,
         "derived": f"panel_scale={scale} default={ooc_default * 1e6:.1f}us"},
        {"name": "tune_wisdom", "us_per_call": 0.0,
         "derived": (f"first_meas={first['measurements']} "
                     f"second_meas={second['measurements']} "
                     f"hit={second['wisdom_hits'] == 1}")},
        {"name": "tune_checks", "us_per_call": 0.0,
         "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                             for k, ok in checks.items())},
    ]
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
