"""2-D transform gates: transpose-free axis-pass chain vs the naive
fft-rows -> materialized-transpose -> fft-rows baseline (BENCH_fft2.json).

Three acceptance properties of the axis-generic core (DESIGN.md §9):

  1. **Bytes** — the zero-copy 2-D plan's analytic HBM byte counter is
     STRICTLY below the naive baseline's at every gated shape. The naive
     baseline is the same plan with layout="copy": each non-contiguous
     axis pays a materialized swapaxes round-trip before and after its
     row-major pass (plan.fftn_hbm_bytes counts both layouts); zero_copy
     runs every non-contiguous axis as ONE column-strided kernel pass.
     The rfft2 plan must additionally undercut the c2c zero-copy plan
     (the packed-real halving).
  2. **Bitwise vs the naive baseline** — executed zero_copy output ==
     executed copy output bit for bit on random inputs: the column kernel
     issues exactly the GEMMs the transposed row kernel issues, per row.
  3. **Parity vs numpy** — np.fft.fft2/rfft2 parity, two regimes:
     bitwise at f32-representable inputs (scaled origin impulses: every
     spectrum value is exactly representable and exactly computed by both
     sides), and f32 round-off tolerance on random inputs (numpy's f64
     pocketfft twiddles legitimately round differently in the last ulp at
     non-trivial bins, so random-input parity is a tolerance check by
     construction — same honesty rule as bench_distributed.py).

Wall clocks are recorded for the trajectory but NOT gated (interpret-mode
CPU, as everywhere else in this repo's benches).
"""

from __future__ import annotations

import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft_api  # noqa: E402

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_fft2.json"

# (n0, n1): both-leaf, and a long contiguous axis (level-1 pass A) where
# the naive baseline's extra transposes hurt most
SIZES = [(128, 128), (64, 4096)]
QUICK_SIZES = [(64, 128)]
IMPULSES = [1.0, 3.0, -2.5, 0.09375]  # exactly-representable scales
TOL = 5e-6


def _bitwise(a, b) -> bool:
    return bool((np.asarray(a[0]) == np.asarray(b[0])).all()
                and (np.asarray(a[1]) == np.asarray(b[1])).all())


def _rel_err(got, want) -> float:
    g = np.asarray(got[0]) + 1j * np.asarray(got[1])
    return float(np.abs(g - want).max() / (np.abs(want).max() or 1.0))


def bench_shape(n0: int, n1: int, iters: int) -> dict:
    rng = np.random.default_rng(0)
    shape = (n0, n1)
    xr = jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    xi = jnp.asarray(rng.standard_normal(shape).astype(np.float32))

    p_zc = fft_api.plan(kind="c2c", shape=shape, interpret=True)
    p_naive = fft_api.plan(kind="c2c", shape=shape, layout="copy",
                           interpret=True)
    p_r = fft_api.plan(kind="r2c", shape=shape, interpret=True)

    zc = p_zc.execute(xr, xi)
    naive = p_naive.execute(xr, xi)
    want = np.fft.fft2(np.asarray(xr) + 1j * np.asarray(xi))
    sp = p_r.execute_real(xr)
    want_r = np.fft.rfft2(np.asarray(xr))

    # f32-representable family: scaled origin impulses — the full 2-D
    # spectrum is the constant `a`, exact on both sides, compared bitwise
    impulse_bitwise = True
    for a in IMPULSES:
        d = np.zeros(shape, np.float32)
        d[0, 0] = a
        wd = np.fft.fft2(d.astype(np.float64))
        got = p_zc.execute(jnp.asarray(d), jnp.zeros(shape, jnp.float32))
        impulse_bitwise &= _bitwise(
            got, (wd.real.astype(np.float32), wd.imag.astype(np.float32)))
        wdr = np.fft.rfft2(d.astype(np.float64))
        got_r = p_r.execute_real(jnp.asarray(d))
        impulse_bitwise &= _bitwise(
            got_r,
            (wdr.real.astype(np.float32), wdr.imag.astype(np.float32)))

    def wall(fn):
        fn()  # warm (trace+compile already paid above, keep honest)
        best = float("inf")
        for _ in range(iters):
            t0 = time.monotonic()
            jax.block_until_ready(fn())
            best = min(best, time.monotonic() - t0)
        return best

    return {
        "shape": list(shape),
        "hbm_bytes": {
            "zero_copy": p_zc.hbm_bytes_per_row,
            "naive": p_naive.hbm_bytes_per_row,
            "rfft2": p_r.hbm_bytes_per_row,
            "ratio": p_zc.hbm_bytes_per_row / p_naive.hbm_bytes_per_row,
        },
        "zero_copy_bitwise_vs_naive": _bitwise(zc, naive),
        "fft2_oracle_err": _rel_err(zc, want),
        "rfft2_oracle_err": _rel_err(sp, want_r),
        "impulse_bitwise_vs_numpy": impulse_bitwise,
        "wall_s": {
            "zero_copy": wall(lambda: p_zc.execute(xr, xi)),
            "naive": wall(lambda: p_naive.execute(xr, xi)),
            "rfft2": wall(lambda: p_r.execute_real(xr)),
        },
        "traces": {"zero_copy": p_zc.trace_counts,
                   "naive": p_naive.trace_counts,
                   "rfft2": p_r.trace_counts},
    }


def run(quick: bool = False):
    sizes = QUICK_SIZES if quick else SIZES
    iters = 2 if quick else 3
    recs = [bench_shape(n0, n1, iters) for n0, n1 in sizes]

    checks = {
        # acceptance: strictly fewer HBM bytes than the naive transpose
        # baseline at every shape; rfft2 undercuts c2c zero-copy too
        "transpose_free_fewer_bytes": all(
            r["hbm_bytes"]["zero_copy"] < r["hbm_bytes"]["naive"]
            for r in recs),
        "rfft2_fewer_bytes_than_c2c": all(
            r["hbm_bytes"]["rfft2"] < r["hbm_bytes"]["zero_copy"]
            for r in recs),
        # acceptance: same GEMMs -> bitwise-equal output planes
        "zero_copy_bitwise_vs_naive": all(
            r["zero_copy_bitwise_vs_naive"] for r in recs),
        # acceptance: numpy parity (see module docstring for the split)
        "impulse_bitwise_vs_numpy": all(
            r["impulse_bitwise_vs_numpy"] for r in recs),
        "fft2_oracle_close": all(r["fft2_oracle_err"] < TOL for r in recs),
        "rfft2_oracle_close": all(r["rfft2_oracle_err"] < TOL for r in recs),
        # zero retrace on the repeat executes above
        "plan_cache_no_retrace": all(
            v["forward"] == 1
            for r in recs for v in r["traces"].values()),
    }
    OUT_PATH.write_text(json.dumps(
        {"quick": quick, "checks": checks, "shapes": recs}, indent=1))

    rows = []
    for r in recs:
        n0, n1 = r["shape"]
        hb = r["hbm_bytes"]
        rows.append({
            "name": f"fft2_{n0}x{n1}_zero_copy",
            "us_per_call": r["wall_s"]["zero_copy"] * 1e6,
            "derived": (f"bytes={hb['zero_copy']} "
                        f"vs naive={hb['naive']} "
                        f"(x{hb['ratio']:.3f})"),
        })
        rows.append({
            "name": f"fft2_{n0}x{n1}_naive",
            "us_per_call": r["wall_s"]["naive"] * 1e6,
            "derived": (f"bitwise_eq={r['zero_copy_bitwise_vs_naive']} "
                        f"oracle_err={r['fft2_oracle_err']:.1e}"),
        })
        rows.append({
            "name": f"fft2_{n0}x{n1}_rfft2",
            "us_per_call": r["wall_s"]["rfft2"] * 1e6,
            "derived": (f"bytes={hb['rfft2']} "
                        f"oracle_err={r['rfft2_oracle_err']:.1e} "
                        f"impulse_bitwise={r['impulse_bitwise_vs_numpy']}"),
        })
    rows.append({"name": "fft2_checks", "us_per_call": 0.0,
                 "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                                     for k, ok in checks.items())})
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
