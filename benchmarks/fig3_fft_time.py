"""Paper Figure 3: FFT CALCULATION time only (I/O excluded).

Paper: the GPU's batched CUFFT cut pure FFT time ~5x vs the CPU library.
Container analogue: pure compute time of each kernel impl over an in-memory
batch, per FFT length. Also reports the MXU-vs-VPU formulation comparison
(matfft vs stockham) that motivates the TPU adaptation (DESIGN.md §2).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import timeit
import repro.fft as fft_api

BATCH_ELEMS = 1 << 21  # ~2M complex samples in memory


def run(quick: bool = False):
    sizes = [1024] if quick else [256, 1024, 4096]
    elems = BATCH_ELEMS // (4 if quick else 1)
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        b = elems // n
        xr = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
        xi = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
        times = {}
        for impl in ("ref", "matfft", "stockham"):
            p = fft_api.plan(kind="c2c", n=n, batch_shape=(b,), impl=impl)

            def call(p=p):
                yr, yi = p.execute(xr, xi)
                yr.block_until_ready()
            t = timeit(call, warmup=1, iters=3)
            times[impl] = t
            rows.append({"name": f"fig3_fft_{impl}_n{n}",
                         "us_per_call": t * 1e6,
                         "derived": f"batch={b} "
                                    f"gflops={5 * b * n * np.log2(n) / t / 1e9:.2f}"})
        rows.append({"name": f"fig3_speedup_n{n}", "us_per_call": 0.0,
                     "derived": f"accel_vs_lib={times['ref'] / times['matfft']:.2f}x "
                                f"mxu_vs_vpu_formulation={times['stockham'] / times['matfft']:.2f}x "
                                f"(paper: ~5x gpu vs cpu)"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
