"""Out-of-core FFT benchmark + acceptance gate: BENCH_outofcore.json.

The paper's headline scenario is a transform whose operand does not fit
in memory (>1TB across the cluster); `core/fft/outofcore.py` streams the
two-pass four-step through a `BlockStore` under a caller working-set
budget. This gate proves the three claims at directly-verifiable sizes
and models the terabyte-class point analytically:

  * ``streamed`` — a 2^22 (quick) / 2^24 (full) point c2c run against a
    `ThrottledStore` (the shared deterministic 250 MB/s disk model, same
    spindle as bench_pipeline) with budget << operand. The merged
    spectrum must be BITWISE identical to `reference_out_of_core`'s
    in-memory oracle — which executes the same panel-shaped cached plans
    and the same twiddle helper, so any drift is a real streaming bug,
    not rounding. ``overlap_x`` = sum of per-stage clocks / wall (> 1
    proves the streamed passes overlap I/O with compute even while
    throttled).
  * ``resume`` — a deterministic `FaultInjector` schedule kills one
    pass-1 job's shuffle scatter past its retry budget (the crash);
    re-planning over the same work_dir must re-run ONLY the lost job
    (resumed pass-1 attempts < pass1_jobs) and still merge bitwise
    identical output.
  * ``terabyte_model`` — the 2^34-point factorization (128 GiB operand)
    under a 1 GiB budget: the analytic io_bytes / shuffle_bytes /
    working_set record plus disk-model seconds. No storage is touched at
    this size; the streamed path is exactly the code gated above.

impl="ref" on BOTH sides: the oracle must launch the identical
executables as the streamed passes (batch shape and impl both change
last-bit rounding).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.fft.outofcore import reference_out_of_core
from repro.core.pipeline import JobConfig
from repro.core.pipeline.blockstore import BlockStore
from repro.core.pipeline.testing import DISK_MB_S, ThrottledStore
from repro.core.resilience import FaultInjector, FaultPlan, FaultRule
import repro.fft as fft_api

OUT_PATH = Path(__file__).resolve().parent.parent / "BENCH_outofcore.json"

IMPL = "ref"
TERA_LOG2_N = 34
TERA_BUDGET = 1 << 30  # 1 GiB working-set cap for the 128 GiB operand


def _scratch() -> Path | None:
    shm = Path("/dev/shm")
    if not shm.is_dir():
        return None
    try:
        st = os.statvfs(shm)
    except OSError:
        return None
    return shm if st.f_bavail * st.f_frsize >= 2 << 30 else None


_SCRATCH = _scratch()


def _ingest(root: Path, sig: np.ndarray, block_bytes: int) -> ThrottledStore:
    store = ThrottledStore(root, block_bytes=block_bytes)
    store.put_bytes(sig.tobytes())
    return store


def _streamed(work: Path, sig: np.ndarray, n: int, budget: int,
              oracle: bytes) -> dict:
    f = fft_api.factor_out_of_core(n, budget)
    block_bytes = min(f.pass1_panel_bytes, 1 << 20)
    store = _ingest(work / "in", sig, block_bytes)
    plan = fft_api.plan(kind="c2c", n=n, placement="out_of_core",
                        store=store, work_dir=work / "ooc", impl=IMPL,
                        budget_bytes=budget)
    t0 = time.monotonic()
    stats = plan.execute()
    wall = time.monotonic() - t0
    merged = work / "merged.bin"
    plan.merge(merged)
    stage_total = sum(sum(s.stage_s.values())
                      for s in (stats.pass1, stats.pass2))
    d = stats.as_dict()
    return {
        "factors": f.as_dict(),
        "block_bytes": block_bytes,
        "budget_bytes": budget,
        "operand_over_budget_x": round(f.operand_bytes / budget, 2),
        "wall_s": round(wall, 4),
        "throughput_mb_s": round(f.operand_bytes / (1 << 20) / wall, 2),
        "overlap_x": round(stage_total / wall, 4) if wall else None,
        "stats": d,
        "io_measured_eq_model": d["io"]["total"] == f.io_bytes,
        "bitwise": merged.read_bytes() == oracle,
    }


def _resume(work: Path, sig: np.ndarray, n: int, budget: int,
            oracle: bytes) -> dict:
    """Crash mid-shuffle (a deterministic fault exhausts one pass-1 job's
    retries), then resume over the same work_dir with a clean injector."""
    f = fft_api.factor_out_of_core(n, budget)
    block_bytes = min(f.pass1_panel_bytes, 1 << 20)
    store = _ingest(work / "in", sig, block_bytes)
    victim = f.pass1_jobs // 2
    inj = FaultInjector(FaultPlan((
        FaultRule(site="ooc.shuffle", index=victim * f.pass1_jobs + victim,
                  calls=(1, 2, 3, 4)),)))
    cfg = JobConfig(readers=2, writers=2, inflight=2, speculation=False,
                    max_retries=3, injector=inj)
    plan = fft_api.plan(kind="c2c", n=n, placement="out_of_core",
                        store=store, work_dir=work / "ooc", impl=IMPL,
                        budget_bytes=budget, job_config=cfg)
    crashed = False
    try:
        plan.execute()  # pass-2 guard refuses the incomplete shuffle
    except RuntimeError:
        crashed = True
    # the resumed run: same work_dir, no injector — a new invocation
    plan2 = fft_api.plan(kind="c2c", n=n, placement="out_of_core",
                         store=store, work_dir=work / "ooc", impl=IMPL,
                         budget_bytes=budget)
    stats = plan2.execute()
    merged = work / "merged.bin"
    plan2.merge(merged)
    return {
        "pass1_jobs": f.pass1_jobs,
        "crashed_as_scheduled": crashed,
        "resumed_pass1_attempts": stats.pass1_attempts,
        "resumed_pass2_attempts": stats.pass2_attempts,
        "pass1_work_preserved":
            crashed and 0 < stats.pass1_attempts < f.pass1_jobs,
        "bitwise": merged.read_bytes() == oracle,
    }


def _terabyte_model() -> dict:
    f = fft_api.factor_out_of_core(1 << TERA_LOG2_N, TERA_BUDGET)
    return {
        **f.as_dict(),
        "disk_model_mb_s": DISK_MB_S,
        "disk_model_s": round(f.io_bytes / (DISK_MB_S * (1 << 20)), 1),
    }


def run(quick: bool = False):
    log2_n = 22 if quick else 24
    n = 1 << log2_n
    budget = (8 * n) // 16  # operand/16: working set far below the data
    fft_api.clear_plan_cache()
    rng = np.random.default_rng(7)
    sig = rng.standard_normal((n, 2)).astype(np.float32)
    oracle = reference_out_of_core(sig, fft_api.factor_out_of_core(n, budget),
                                   impl=IMPL)

    with tempfile.TemporaryDirectory(dir=_SCRATCH) as tmp:
        work = Path(tmp)
        streamed = _streamed(work / "main", sig, n, budget, oracle)
        shutil.rmtree(work / "main")
        resume = _resume(work / "resume", sig, n, budget, oracle)

    tera = _terabyte_model()
    checks = {
        # acceptance: the streamed transform is the oracle, bit for bit
        "streamed_bitwise_equals_oracle": streamed["bitwise"],
        # measured storage traffic == the analytic 4x-operand model
        "io_measured_eq_model": streamed["io_measured_eq_model"],
        # the enforced working set honors the budget, which is far
        # below the operand (this is what "out of core" means)
        "working_set_within_budget":
            streamed["factors"]["working_set_bytes"] <= budget,
        "budget_far_below_operand":
            streamed["operand_over_budget_x"] >= 8,
        # crash mid-shuffle: resume redoes only the lost pass-1 job and
        # the spectrum is still bitwise identical
        "resume_preserves_pass1_work": resume["pass1_work_preserved"],
        "resume_bitwise_equals_oracle": resume["bitwise"],
        # terabyte point: 128 GiB operand streams under a 1 GiB budget
        "terabyte_fits_budget":
            tera["working_set_bytes"] <= TERA_BUDGET
            and tera["operand_bytes"] >= 128 * TERA_BUDGET,
    }
    doc = {
        "quick": quick,
        "config": {"log2_n": log2_n, "budget_bytes": budget, "impl": IMPL,
                   "disk_sim_mb_s": DISK_MB_S},
        "streamed": streamed,
        "resume": resume,
        "terabyte_model": tera,
        "checks": checks,
        "plan_cache": fft_api.cache_info(),
    }
    OUT_PATH.write_text(json.dumps(doc, indent=1))

    rows = [
        {"name": f"outofcore_2^{log2_n}",
         "us_per_call": streamed["wall_s"] * 1e6,
         "derived": (f"{streamed['throughput_mb_s']}MB/s "
                     f"overlap_x={streamed['overlap_x']} "
                     f"operand/budget={streamed['operand_over_budget_x']}x "
                     f"bitwise={streamed['bitwise']}")},
        {"name": "outofcore_resume", "us_per_call": 0.0,
         "derived": (f"resumed_p1={resume['resumed_pass1_attempts']}/"
                     f"{resume['pass1_jobs']} "
                     f"bitwise={resume['bitwise']}")},
        {"name": f"outofcore_2^{TERA_LOG2_N}_model", "us_per_call": 0.0,
         "derived": (f"operand={tera['operand_bytes'] >> 30}GiB "
                     f"ws={tera['working_set_bytes'] >> 20}MiB "
                     f"io={tera['io_bytes'] >> 30}GiB "
                     f"disk_model={tera['disk_model_s']}s")},
        {"name": "outofcore_checks", "us_per_call": 0.0,
         "derived": " ".join(f"{k}={'PASS' if ok else 'FAIL'}"
                             for k, ok in checks.items())},
    ]
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    for row in run(quick=args.quick):
        print(f"{row['name']},{row['us_per_call']:.1f},\"{row['derived']}\"")
    checks = json.loads(OUT_PATH.read_text())["checks"]
    if not all(checks.values()):
        print(f"FAIL: {checks}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
