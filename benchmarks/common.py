"""Shared benchmark utilities: timing + the paper's workload generator."""

from __future__ import annotations

import time
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core.pipeline import BlockStore
from repro.core.pipeline.records import segment_block_bytes


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(iters):
        t0 = time.monotonic()
        fn()
        samples.append(time.monotonic() - t0)
    return float(np.median(samples))


def make_signal_store(root: Path, *, size_mb: int, fft_len: int,
                      segments_per_block: int = 1024, seed: int = 0,
                      replication: int = 1) -> tuple[BlockStore, np.ndarray]:
    """Interleaved-complex signal file split into blocks (paper's setup)."""
    n_seg = size_mb * (1 << 20) // (8 * fft_len)
    rng = np.random.default_rng(seed)
    sig = rng.standard_normal((n_seg, fft_len, 2)).astype(np.float32)
    store = BlockStore(root, block_bytes=segment_block_bytes(
        fft_len, min(segments_per_block, n_seg)), replication=replication)
    store.put_bytes(sig.tobytes())
    return store, sig


def block_until_ready(x):
    if isinstance(x, tuple):
        for e in x:
            e.block_until_ready()
    else:
        x.block_until_ready()
