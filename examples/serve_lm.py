"""Batched serving example: prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-1b]

Uses the reduced config of any assigned architecture — including the
SWA ring-cache (gemma3/danube/mixtral), SSM-state (rwkv6/zamba2) and
enc-dec (whisper) cache layouts.
"""

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models.transformer import TransformerLM
from repro.serve import ServeEngine
from repro.sharding.rules import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, 64, cfg.d_model)), jnp.float32)
    if cfg.num_prefix_embeds:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_prefix_embeds,
                                 cfg.d_model)), jnp.float32)

    engine = ServeEngine(model)
    t0 = time.monotonic()
    out = engine.generate(params, batch, args.new_tokens)
    dt = time.monotonic() - t0
    print(f"arch={args.arch} generated {tuple(out.shape)} in {dt:.1f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequences:", np.asarray(out)[:2, :10])


if __name__ == "__main__":
    main()
