"""Quickstart: the paper's pipeline in 40 lines.

Split a signal into HDFS-style blocks, run the map-only batched-FFT job
(the Hadoop+CUFFT flow of Figure 1), merge, and verify against numpy.

The FFT itself goes through the `repro.fft` plan-and-execute facade: one
`plan(...)` call resolves the whole strategy (placement, layout, rfft
packing) and returns a cached `ExecutablePlan` — every same-shaped block
reuses the compiled callable, the paper's `cufftPlanMany` amortization.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 block_of_segments, segments_of_block)
from repro.core.pipeline.records import segment_block_bytes
import repro.fft as fft_api


def main():
    fft_len, n_segments = 1024, 512
    rng = np.random.default_rng(0)
    signal = rng.standard_normal((n_segments, fft_len, 2)).astype(np.float32)

    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        # 1. copy-in: split into blocks (one block = one map record)
        store = BlockStore(tmp / "in", block_bytes=segment_block_bytes(
            fft_len, 64), replication=2)
        store.put_bytes(signal.tobytes())
        print(f"split {signal.nbytes / 2**20:.1f} MiB into "
              f"{len(store.blocks)} blocks")

        # 2. map-only job: batched FFT per block, zero reducers. The plan
        # is built once per block shape and cached process-wide.
        def map_fn(data, idx):
            re, im = segments_of_block(data, fft_len)
            p = fft_api.plan(kind="c2c", n=fft_len,
                             batch_shape=re.shape[:-1])
            yr, yi = p.execute(jnp.asarray(re), jnp.asarray(im))
            return block_of_segments(np.asarray(yr), np.asarray(yi))

        job = MapOnlyJob(store, tmp / "out", map_fn, JobConfig(workers=4))
        stats = job.run()
        info = fft_api.cache_info()
        print(f"map tasks: {stats.blocks_done} done, "
              f"{stats.attempts} attempts, {stats.wall_s:.2f}s; "
              f"plan cache: {info['misses']} built / {info['hits']} reused")

        # 3. getmerge + verify
        job.merge(tmp / "merged.bin")
        got = np.frombuffer((tmp / "merged.bin").read_bytes(), np.float32)
        got = got.reshape(-1, fft_len, 2)
        want = np.fft.fft(signal[..., 0] + 1j * signal[..., 1], axis=-1)
        err = np.abs((got[..., 0] + 1j * got[..., 1]) - want).max()
        print(f"max abs error vs numpy: {err:.2e}")
        assert err < 1e-2 * np.abs(want).max()
        print("OK")


if __name__ == "__main__":
    main()
