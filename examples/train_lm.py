"""End-to-end training driver: ~100M-param qwen2-family model, few hundred
steps on synthetic data, with checkpoints and auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

``--tiny`` drops to the smoke-test size (CI-friendly, ~2 min on CPU).
The ~100M configuration is the assignment's "train a ~100M model" driver;
on this 1-core CPU container it is slow but runs — the production path for
real hardware is launch/train.py + the dry-run's sharding configs.
"""

import argparse
import dataclasses
import tempfile
from pathlib import Path

import jax

from repro.configs import get_config
from repro.data import TokenPipeline, synthetic_corpus
from repro.models.transformer import TransformerLM
from repro.train.trainer import Trainer, TrainerConfig


def config_100m():
    """qwen2-family, ~100M params (12L, d=512, ff=2048, 32k vocab)."""
    return dataclasses.replace(
        get_config("qwen2-0.5b"),
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=2,
        head_dim=64, d_ff=2048, vocab_size=32768,
        dtype="float32", remat="none",
        attn_q_chunk=256, attn_kv_chunk=128, loss_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").reduced() if args.tiny else config_100m()
    if args.tiny:
        args.steps = min(args.steps, 40)
    model = TransformerLM(cfg)
    n_params = cfg.n_params()
    print(f"model: {cfg.name}-derived, {n_params / 1e6:.1f}M params")

    workdir = Path(args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_"))
    store = synthetic_corpus(workdir / "corpus", vocab_size=cfg.vocab_size,
                             n_tokens=2_000_000)
    pipe = TokenPipeline(store, batch=args.batch, seq=args.seq)

    tc = TrainerConfig(base_lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, ckpt_dir=str(workdir / "ckpt"),
                       ckpt_every=max(args.steps // 4, 10), log_every=10)
    trainer = Trainer(model, tc)
    state = trainer.restore_or_init(jax.random.PRNGKey(0))
    start = int(state["step"])
    if start:
        print(f"auto-resumed from step {start}")
    state, history = trainer.run(state, iter(pipe),
                                 steps=args.steps - start)
    first, last = history[0], history[-1]
    print(f"step {first['step']}: loss {first['loss']:.3f}  ->  "
          f"step {last['step']}: loss {last['loss']:.3f}")
    assert last["loss"] < first["loss"] or start > 0
    print(f"checkpoints in {workdir / 'ckpt'}  (re-run to resume)")


if __name__ == "__main__":
    main()
