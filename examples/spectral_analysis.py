"""Spectral analysis end-to-end: the signal analyst's workflow at scale.

The paper's motivating user is "the signal analyst" running spectral
analysis over huge capture files. This example runs the whole stack:

  1. synthesize a multi-tone capture with a transient chirp;
  2. block-split it (BlockStore) and run the MAP-ONLY job computing a
     power spectrogram per block (framed STFT -> batched MXU FFT kernel);
  3. merge spectrogram blocks and locate the tones + the chirp window;
  4. fault-tolerance demo: corrupt a replica mid-store and let the job
     fall back; inject one flaky task and watch the retry.

    PYTHONPATH=src python examples/spectral_analysis.py
"""

import tempfile
from pathlib import Path

import numpy as np
import jax.numpy as jnp

import repro.fft as fft_api
from repro.core.pipeline import BlockStore, JobConfig, MapOnlyJob
from repro.core.spectral import power_spectrogram

SR = 16_000           # sample rate
FRAME, HOP = 512, 256
TONES_HZ = (440.0, 1_250.0, 3_000.0)
CHIRP_AT = 0.5        # fraction of the file where the chirp lives


def synth_capture(seconds: float, seed: int = 0) -> np.ndarray:
    t = np.arange(int(seconds * SR)) / SR
    rng = np.random.default_rng(seed)
    x = 0.05 * rng.standard_normal(t.size)
    for hz in TONES_HZ:
        x += np.sin(2 * np.pi * hz * t)
    mid = int(CHIRP_AT * t.size)
    w = np.arange(SR // 2) / SR
    x[mid:mid + SR // 2] += 2.0 * np.sin(2 * np.pi * (2000 + 6000 * w) * w * SR)
    return x.astype(np.float32)


def main():
    x = synth_capture(seconds=8.0)

    # inspect the r2c plan every map task's stft will cache-hit: the full
    # strategy (rfft packing, fused untangle, byte/flop budget) is resolved
    # before any data moves
    frames_per_block = 1 + (SR - FRAME) // HOP
    p = fft_api.plan(kind="r2c", n=FRAME, batch_shape=(frames_per_block,))
    print(f"r2c plan: n={p.n} x{frames_per_block} frames/block, "
          f"fused_untangle={p.fused_untangle}, "
          f"{p.hbm_bytes_per_row} HBM bytes/frame, "
          f"{p.flops / 1e6:.2f} MFLOP/block")

    with tempfile.TemporaryDirectory() as tmp:
        tmp = Path(tmp)
        store = BlockStore(tmp / "in", block_bytes=4 * SR, replication=2)  # 1s blocks
        store.put_bytes(x.tobytes())
        print(f"capture: {x.size / SR:.0f}s at {SR} Hz -> "
              f"{len(store.blocks)} one-second blocks")

        # fault injection: damage a primary replica before the job runs
        store.corrupt_block(2, replica=0)
        flaky = {"left": 1}

        def map_fn(data, idx):
            if idx == 4 and flaky["left"]:
                flaky["left"] -= 1
                raise RuntimeError("injected task failure")
            samples = np.frombuffer(data, np.float32)
            ps = power_spectrogram(jnp.asarray(samples), FRAME, HOP)
            return np.asarray(ps, np.float32).tobytes()

        job = MapOnlyJob(store, tmp / "out", map_fn, JobConfig(workers=4))
        stats = job.run()
        print(f"map tasks: {stats.blocks_done} done, retries={stats.retries} "
              f"(1 injected failure + replica fallback exercised)")

        job.merge(tmp / "spectrogram.bin")
        n_bins = FRAME // 2 + 1
        spec = np.frombuffer((tmp / "spectrogram.bin").read_bytes(),
                             np.float32).reshape(-1, n_bins)
        print(f"spectrogram: {spec.shape[0]} frames x {n_bins} bins")

        # locate the tones
        mean_power = spec.mean(axis=0)
        found = np.sort(np.argsort(mean_power)[-3:]) * SR / FRAME
        print("tone bins found:", [f"{f:.0f} Hz" for f in found],
              "expected:", [f"{f:.0f} Hz" for f in TONES_HZ])
        # locate the chirp (frame of peak wideband energy)
        wideband = spec[:, n_bins // 2:].sum(axis=1)
        frames_per_block = spec.shape[0] / len(store.blocks)
        chirp_s = wideband.argmax() / frames_per_block
        print(f"chirp located at ~{chirp_s:.1f}s (expected ~{8 * CHIRP_AT:.1f}s)")
        for f, e in zip(found, sorted(TONES_HZ)):
            assert abs(f - e) < SR / FRAME + 1
        print("OK")


if __name__ == "__main__":
    main()
