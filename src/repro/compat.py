"""Version compatibility shims for the installed JAX.

The codebase targets the current `jax.shard_map` API (with ``check_vma``);
older releases only ship `jax.experimental.shard_map.shard_map` (with the
equivalent flag spelled ``check_rep``). Everything that shards goes through
this one wrapper so the rest of the tree can use the modern spelling.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` with fallback to the experimental module."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """`jax.make_mesh` with fallback to a manual device reshape."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    import numpy as np
    from jax.sharding import Mesh
    devs = np.asarray(jax.devices()).reshape(axis_shapes)
    return Mesh(devs, axis_names)
