"""Optimizers (from scratch — no optax in this environment).

All optimizers are pure pytree transforms: ``init(params) -> state`` and
``update(grads, state, params, lr) -> (new_params, new_state)``. Optimizer
state inherits the parameter sharding (ZeRO-1 falls out of FSDP'd params:
the moments are sharded exactly like the params they track).

Adafactor is provided for the very large assigned configs (mixtral-8x22b,
llama4-scout): factored second moments cut optimizer HBM from 2x to ~0.02x
of the (already FSDP-sharded) parameter bytes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (params, state)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------


def sgd(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        new = jax.tree.map(
            lambda p, m: p - lr * (m + weight_decay * p), params, mu)
        return new, {"mu": mu, "step": state["step"] + 1}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"mu": z, "nu": jax.tree.map(jnp.copy, z),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            new_p = p.astype(jnp.float32) - lr * (
                mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["mu"])
        flat_v = tdef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        mu = tdef.unflatten([o[1] for o in out])
        nu = tdef.unflatten([o[2] for o in out])
        return new_p, {"mu": mu, "nu": nu, "step": step}

    return Optimizer(init, update)


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), no momentum.

    >=2D params keep row/col factored statistics over the last two dims;
    <2D params fall back to full second moments.
    """

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"stats": jax.tree.map(one, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def one(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = vr.mean(axis=-1, keepdims=True)
                u = g * jax.lax.rsqrt(vr[..., None] / jnp.maximum(denom[..., None], eps))
                u = u * jax.lax.rsqrt(vc[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            new_p = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["stats"])
        out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = tdef.unflatten([o[0] for o in out])
        stats = tdef.unflatten([o[1] for o in out])
        return new_p, {"stats": stats, "step": step}

    return Optimizer(init, update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}


def get_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)
