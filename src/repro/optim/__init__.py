from repro.optim.optimizers import (Optimizer, adamw, adafactor, sgd,
                                    clip_by_global_norm)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
from repro.optim.compression import compress_int8, decompress_int8

__all__ = ["Optimizer", "adamw", "adafactor", "sgd", "clip_by_global_norm",
           "cosine_schedule", "linear_warmup_cosine", "compress_int8",
           "decompress_int8"]
