"""Error-feedback int8 gradient compression for the DP all-reduce.

Distributed-optimization trick (DESIGN.md §7): before the data-parallel
all-reduce, each gradient leaf is quantized to int8 with a per-leaf scale;
the quantization residual is kept locally and added back into the next
step's gradient (error feedback — Karimireddy et al. 2019 — which keeps
SGD-style convergence despite biased quantization). Cuts DP all-reduce
bytes 4x vs f32 / 2x vs bf16.

Used via ``train_step(..., grad_compression=True)``: the psum runs on the
int8-decoded values (XLA all-reduces the decoded f32; on real hardware the
int8 payload + custom reduction would use ~1/4 the ICI bytes — the roofline
collective term in EXPERIMENTS.md §Perf quantifies the modeled saving).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g, error):
    """Quantize g + error -> (int8 payload, scale, new_error)."""
    g = g.astype(jnp.float32) + error
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    decoded = q.astype(jnp.float32) * scale
    return q, scale, g - decoded


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, errors):
    """Apply error-feedback compression leafwise.

    Returns (decoded grads, new errors). The decoded grads are what enters
    the all-reduce; the errors stay device-local.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    dec, errs = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress_int8(g, e)
        dec.append(decompress_int8(q, s).astype(g.dtype))
        errs.append(ne)
    return tdef.unflatten(dec), tdef.unflatten(errs)
