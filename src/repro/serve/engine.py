"""Batched serving: prefill + greedy decode over jit'd step functions."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerLM


class ServeEngine:
    def __init__(self, model: TransformerLM):
        self.model = model
        self._prefill = jax.jit(model.prefill, static_argnames=("cache_len",))
        self._decode = jax.jit(model.decode_step, donate_argnums=1)

    def generate(self, params, batch, max_new_tokens: int):
        """Greedy continuation of batch["tokens"] (B, S)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        p = self.model.cfg.num_prefix_embeds
        cache_len = p + s + max_new_tokens
        logits, caches = self._prefill(params, batch, cache_len=cache_len)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(tokens.dtype)
        out.append(tok)
        for t in range(max_new_tokens - 1):
            logits, caches = self._decode(params, caches, tok,
                                          jnp.int32(p + s + t))
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(tokens.dtype)
            out.append(tok)
        return jnp.concatenate(out, axis=1)


def greedy_generate(model, params, batch, max_new_tokens: int):
    return ServeEngine(model).generate(params, batch, max_new_tokens)
