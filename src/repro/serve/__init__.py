from repro.serve.engine import ServeEngine, greedy_generate
from repro.serve.fft_service import (
    DeadlineExceeded,
    FftService,
    FftTicket,
    RequestFailed,
    ServiceClosed,
    ServiceError,
    ServiceOverload,
    ServiceStats,
)

__all__ = [
    "DeadlineExceeded",
    "FftService",
    "FftTicket",
    "RequestFailed",
    "ServeEngine",
    "ServiceClosed",
    "ServiceError",
    "ServiceOverload",
    "ServiceStats",
    "greedy_generate",
]
