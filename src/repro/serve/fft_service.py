"""FFT-as-a-service: a fault-tolerant dynamic-batching front-end.

The paper's pitch is turning batch FFT into something analysts treat as an
interactive service on cheap, failure-prone servers; the engine underneath
this module is already a serving backend — a process-level plan cache
(zero retrace on repeat execute) and async coalesced dispatch
(core/pipeline/stream.py). `FftService` is the missing front-end, built to
stay *correct and bounded under overload and faults*:

  admit    `submit()` runs admission control synchronously on the caller
           thread: a bounded queue (occupancy cap — reject with
           `ServiceOverload(reason="queue_full")`, never unbounded
           growth), plus optional per-spec token-bucket rate limiting and
           per-spec inflight caps. Every rejection is a structured error
           on the returned ticket; nothing blocks, nothing is dropped
           silently.
  batch    ONE batcher thread drains the queue and groups requests by
           their resolved `FftSpec` cache key (the resolved spec modulo
           batch rows), launching coalesced `execute_async` batches. Plan
           reuse follows stream.py's 2-plan full/tail trick, generalized:
           per spec key every launch uses either the FULL plan
           (`coalesce x rows`, short groups zero-padded up to it) or the
           SINGLE plan (one request, taken when the queue is idle) — so a
           key touches at most two cache entries no matter how traffic
           fragments.
  deadline per-request deadlines resolved against the injectable
           `RetryPolicy` clock at admit and enforced end-to-end: late
           requests are shed BEFORE launch (and swept while queued), and
           a result that realizes past its deadline is degraded to a
           `DeadlineExceeded` carrying the queue/batch/execute breakdown.
  execute  launches go through `repro.fft.plan(...)` — the service never
           holds executables of its own, the plan cache is the warm path
           — inside a bounded in-flight window (semaphore released at
           realization, exactly the stream executor's discipline).
           Writeback workers realize results, slice rows back per
           request, and resolve tickets.
  degrade  on sustained overload (consecutive queue-full rejections) the
           batcher sheds queued load by policy — "oldest_deadline" (the
           requests least likely to make it) or "smallest_batch" (the
           spec groups that coalesce worst) — completing victims with
           `ServiceOverload(reason="shed")` and logging a
           `service_degrade` event. On `meshstate` device loss the next
           launches re-plan via `plan(..., fallback="degrade")` and the
           epoch change is logged as a `service_degrade` event too.

Failure semantics: the fault sites `serve.admit` / `serve.batch` /
`serve.execute` (appended to `repro.core.resilience.faults.SITES`) thread
`FaultInjector` through all three stages; batch failures re-enter each
member into the retry path under the service's ONE `RetryPolicy` until
attempts/deadline are spent, then resolve as `RequestFailed` chaining the
last cause. An unexpected batcher crash fails only the requests it held
and recovers to an empty-but-serving state (`service_crash_recovered`
event); `close(drain=True)` launches everything still queued and joins
every thread, leaving the process at idle. Gated end to end by
benchmarks/bench_serve.py (BENCH_serve.json): under an open-loop overload
with a 25% seeded fault storm, every admitted request returns a
bitwise-correct result or a classified structured error.
"""

from __future__ import annotations

import math
import queue
import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.resilience import verify as abft
from repro.core.resilience.events import record_event
from repro.core.resilience.faults import (corrupt_salt, maybe_fire,
                                          perturb_array)
from repro.core.resilience.retry import RetryPolicy
from repro.fft import spec as spec_mod

SHED_POLICIES = ("oldest_deadline", "smallest_batch")


# ---------------------------------------------------------------------------
# error taxonomy: every client-visible failure is one of these, each
# carrying enough structure for dashboards/tests to classify without
# parsing message text (DESIGN.md §12)


class ServiceError(Exception):
    """Base class for every structured service-side failure."""

    stage = "service"

    def as_dict(self) -> dict:
        return {"error": type(self).__name__, "stage": self.stage,
                "message": str(self)}


class ServiceOverload(ServiceError):
    """Admission control rejected (or shed) the request.

    ``reason``: "queue_full" | "rate_limit" | "inflight_cap" | "shed".
    """

    stage = "admit"

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"service overloaded ({reason})"
                         + (f": {detail}" if detail else ""))
        self.reason = reason

    def as_dict(self) -> dict:
        return {**super().as_dict(), "reason": self.reason}


class ServiceClosed(ServiceError):
    """The service is shut (or shutting) down; the request was not run."""

    stage = "admit"


class DeadlineExceeded(ServiceError):
    """The request missed its deadline; carries the end-to-end breakdown.

    ``queue_s`` covers submit -> group formation, ``batch_s`` group
    formation -> launch (host gather + dispatch), ``execute_s`` launch ->
    realization (0.0 when the request was shed before launching — the
    normal case, late work never reaches the device). ``stage`` names
    where the deadline tripped: "queue" | "execute".
    """

    def __init__(self, deadline_s: float, queue_s: float,
                 batch_s: float = 0.0, execute_s: float = 0.0,
                 stage: str = "queue"):
        super().__init__(
            f"deadline {deadline_s * 1e3:.1f} ms exceeded at {stage} "
            f"(queue {queue_s * 1e3:.1f} ms, batch {batch_s * 1e3:.1f} ms, "
            f"execute {execute_s * 1e3:.1f} ms)")
        self.deadline_s = deadline_s
        self.queue_s = queue_s
        self.batch_s = batch_s
        self.execute_s = execute_s
        self.stage = stage

    def as_dict(self) -> dict:
        return {**super().as_dict(), "deadline_s": self.deadline_s,
                "queue_s": self.queue_s, "batch_s": self.batch_s,
                "execute_s": self.execute_s}


class RequestFailed(ServiceError):
    """The request's retry budget is spent; chains the last cause."""

    def __init__(self, stage: str, attempts: int, cause: BaseException):
        super().__init__(
            f"request failed at {stage} after {attempts} attempt(s): "
            f"{cause!r}")
        self.stage = stage
        self.attempts = attempts
        self.__cause__ = cause

    def as_dict(self) -> dict:
        return {**super().as_dict(), "attempts": self.attempts,
                "cause": repr(self.__cause__)}


# ---------------------------------------------------------------------------


class FftTicket:
    """Client handle for one submitted request (a tiny settable future).

    Resolved exactly once, with either ``value`` (planar result arrays)
    or ``error`` (a classified exception — usually a `ServiceError`).
    """

    def __init__(self, seq: int, kind: str, shape: tuple, rows: int,
                 deadline_s: float | None):
        self.seq = seq
        self.kind = kind
        self.shape = shape
        self.rows = rows
        self.deadline_s = deadline_s
        self.value = None
        self.error: BaseException | None = None
        self.attempts = 0
        #: total batch rows of the launch that produced the result (the
        #: full coalesced size or this request's own rows for a singleton
        #: launch). CPU FFT backends pick summation strategies by batch
        #: size, so a fault-free oracle must replay THIS size to compare
        #: bitwise — row position and co-batched content provably don't
        #: affect a row's result, but the launch size does.
        self.batch_rows: int | None = None
        self._occupies = False   # holds an admission slot until resolved
        self._energy: float | None = None  # input energy (verify modes)
        self._corrupt_hit = False          # quarantined at least once
        self.timings: dict = {}   # queue_s / batch_s / execute_s / total_s
        self._event = threading.Event()
        # internal routing state (service-owned, not part of the API)
        self._key = None
        self._operands: tuple = ()
        self._squeeze = False
        self._deadline_at: float | None = None
        self._t_submit = 0.0
        self._t_formed = 0.0
        self._t_launch = 0.0

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: float | None = None):
        """Block for the outcome; returns the planar arrays or raises the
        classified error."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.seq} still pending")
        if self.error is not None:
            raise self.error
        return self.value


@dataclass
class ServiceStats:
    """Thread-safe service counters; snapshot() adds latency percentiles."""

    submitted: int = 0
    admitted: int = 0
    rejected: dict = field(default_factory=dict)  # reason -> count
    completed: int = 0
    failed: int = 0
    deadline_exceeded: int = 0
    shed: int = 0
    retries: int = 0
    batches: int = 0
    batched_requests: int = 0
    padded_rows: int = 0
    max_queued: int = 0
    degrade_events: int = 0
    crash_recoveries: int = 0
    corruption_detected: int = 0    # verify checks that tripped
    corruption_recomputed: int = 0  # quarantined requests later completed

    def __post_init__(self):
        self._lock = threading.Lock()
        self._latencies: list[float] = []

    def bump(self, name: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + by)

    def reject(self, reason: str) -> None:
        with self._lock:
            self.rejected[reason] = self.rejected.get(reason, 0) + 1

    def saw_queue(self, depth: int) -> None:
        with self._lock:
            if depth > self.max_queued:
                self.max_queued = depth

    def record_latency(self, total_s: float) -> None:
        with self._lock:
            self._latencies.append(total_s)

    @staticmethod
    def _pct(sorted_vals: list[float], q: float) -> float:
        if not sorted_vals:
            return 0.0
        i = min(len(sorted_vals) - 1, int(math.ceil(q * len(sorted_vals))) - 1)
        return sorted_vals[max(i, 0)]

    def snapshot(self) -> dict:
        with self._lock:
            lat = sorted(self._latencies)
            doc = {k: v for k, v in self.__dict__.items()
                   if not k.startswith("_")}
            doc["rejected"] = dict(self.rejected)
        doc["rejected_total"] = sum(doc["rejected"].values())
        doc["latency"] = {
            "count": len(lat),
            "p50_ms": round(self._pct(lat, 0.50) * 1e3, 3),
            "p99_ms": round(self._pct(lat, 0.99) * 1e3, 3),
            "max_ms": round((lat[-1] if lat else 0.0) * 1e3, 3),
        }
        if self.batches:
            doc["mean_requests_per_launch"] = round(
                self.batched_requests / self.batches, 3)
        return doc


class _TokenBucket:
    """Per-spec admission rate limiter on the service's injectable clock."""

    def __init__(self, rate: float, burst: float, clock):
        self.rate = float(rate)
        self.burst = float(burst)
        self.clock = clock
        self.tokens = float(burst)
        self.t = clock()

    def try_take(self) -> bool:
        now = self.clock()
        self.tokens = min(self.burst,
                          self.tokens + (now - self.t) * self.rate)
        self.t = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclass
class _Group:
    """One forming/launched batch: same spec key, FIFO tickets."""

    key: object
    tickets: list
    # ABFT state for verify="abft" launches: the checksum row appended at
    # gather is `verify_weights @ rows[:verify_rows]`; writeback replays
    # the combination on the realized output
    verify_weights: object = None
    verify_rows: int = 0


class FftService:
    """The planned engine behind a bounded, deadline-aware request front.

    Args:
      impl/interpret/layout: forwarded to every `repro.fft.plan` call.
      mesh/placement: optional mesh for segmented/distributed specs;
        placement defaults to "auto" (mesh-free requests resolve local).
      queue_depth: admission bound — a submit is rejected with
        `ServiceOverload(reason="queue_full")` once this many admitted
        requests are outstanding (queued, batching, in flight, or
        retrying — a request holds its slot from admission to
        resolution), so total service occupancy is hard-bounded by
        ``queue_depth``, retries included.
      coalesce: requests per full batch (the dynamic batcher's target).
      max_inflight: launched-but-unrealized batch window (semaphore
        released at realization — the only sync point).
      max_batch_delay_s: how long a short group may wait for company
        before launching as a padded tail.
      default_deadline_s: deadline applied when submit passes none.
      per_spec_qps / per_spec_burst: token-bucket admission per spec key
        (None disables); per_spec_inflight: cap of admitted-incomplete
        requests per spec key (None disables).
      shed_policy: "oldest_deadline" | "smallest_batch" — victim order
        under sustained overload.
      shed_after: consecutive queue-full rejections that trigger a shed;
        shed_fraction: fraction of queued requests shed per trigger.
      retry: the service's ONE `RetryPolicy` (attempts/backoff/clock);
        its clock also times deadlines and latency stats.
      degrade: pass fallback="degrade" to every plan call (re-plans on
        mesh loss instead of raising); injector: `FaultInjector` wired to
        the serve.* sites.
      verify: "off" | "parseval" | "abft" — ABFT silent-corruption
        defense (DESIGN.md §13). "parseval" checks every request's
        output energy against its input energy recorded at admission
        (per-request quarantine); "abft" instead appends one linearity
        checksum row to every
        launch (riding the full-plan padding trick, so a spec key still
        touches at most two plan-cache entries). A failed check raises
        `SilentCorruption`, quarantines the unit (the single request for
        an energy miss, the whole batch for a checksum miss — linearity
        cannot name the culprit row) and recomputes it through the ONE
        retry path; `corruption_detected` / `corruption_recomputed`
        count the round trips.
    """

    def __init__(self, *, impl: str = "matfft", interpret=None,
                 layout: str = "zero_copy", mesh=None,
                 placement: str = "auto", queue_depth: int = 256,
                 coalesce: int = 4, max_inflight: int = 4, writers: int = 2,
                 max_batch_delay_s: float = 0.002,
                 default_deadline_s: float | None = None,
                 per_spec_qps: float | None = None,
                 per_spec_burst: float | None = None,
                 per_spec_inflight: int | None = None,
                 shed_policy: str = "oldest_deadline", shed_after: int = 8,
                 shed_fraction: float = 0.25,
                 retry: RetryPolicy | None = None, degrade: bool = True,
                 injector=None, poll_interval_s: float = 0.001,
                 verify: str = "off", start: bool = True):
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        if coalesce < 1:
            raise ValueError(f"coalesce must be >= 1, got {coalesce}")
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"unknown shed_policy {shed_policy!r}; "
                             f"expected one of {SHED_POLICIES}")
        self.impl = impl
        self.interpret = interpret
        self.layout = layout
        self.mesh = mesh
        self.placement = placement
        self.queue_depth = queue_depth
        self.coalesce = coalesce
        self.max_inflight = max(max_inflight, 1)
        self.max_batch_delay_s = max_batch_delay_s
        self.default_deadline_s = default_deadline_s
        self.per_spec_qps = per_spec_qps
        self.per_spec_burst = (per_spec_burst if per_spec_burst is not None
                               else 2.0 * coalesce)
        self.per_spec_inflight = per_spec_inflight
        self.shed_policy = shed_policy
        self.shed_after = max(shed_after, 1)
        self.shed_fraction = shed_fraction
        self.policy = retry or RetryPolicy()
        self.degrade = degrade
        self.injector = injector
        self.poll_interval_s = poll_interval_s
        self.verify = abft.check_mode(verify)
        self.stats = ServiceStats()
        self._clock = self.policy.clock

        self._admit_lock = threading.Lock()
        self._seq = 0
        self._occupancy = 0          # admitted requests awaiting launch
        self._overload_strikes = 0   # consecutive queue-full rejections
        self._shed_requested = threading.Event()
        self._buckets: dict = {}     # spec key -> _TokenBucket
        self._spec_inflight: dict = {}  # spec key -> admitted-incomplete

        self._queue: queue.SimpleQueue = queue.SimpleQueue()
        self._events: queue.SimpleQueue = queue.SimpleQueue()
        self._pending: dict = {}     # spec key -> deque[FftTicket]
        self._inflight = threading.BoundedSemaphore(self.max_inflight)
        self._outstanding = 0        # launched batches not yet resolved
        self._outstanding_lock = threading.Lock()
        self._closing = threading.Event()   # drain mode: flush then exit
        self._stopped = threading.Event()   # hard stop (close(drain=False))
        self._mesh_epoch = None
        self._batcher: threading.Thread | None = None
        self._writers = ThreadPoolExecutor(max_workers=max(writers, 1))
        if start:
            self.start()

    # ------------------------------------------------------------- lifecycle

    def start(self) -> None:
        if self._batcher is not None and self._batcher.is_alive():
            return
        if self._closing.is_set():
            raise ServiceClosed("service has been closed")
        self._batcher = threading.Thread(
            target=self._batch_loop, name="fft-service-batcher", daemon=True)
        self._batcher.start()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=exc == (None, None, None))

    def idle(self) -> bool:
        """True when nothing is queued, pending, or in flight."""
        with self._outstanding_lock:
            outstanding = self._outstanding
        with self._admit_lock:
            occupancy = self._occupancy
        return occupancy == 0 and outstanding == 0

    def close(self, drain: bool = True, timeout: float | None = 30.0) -> None:
        """Stop admitting; drain (launch everything queued, wait for every
        outcome) or cancel pending with `ServiceClosed`. Idempotent."""
        self._closing.set()
        if not drain:
            self._stopped.set()
        if self._batcher is not None:
            # start() was never called (start=False tests): resolve the
            # queue here so close() leaves no ticket forever-pending
            self._batcher.join(timeout=timeout)
        else:
            self._stopped.set()
            self._flush_cancelled()
        self._writers.shutdown(wait=True)
        if self._batcher is None or not self._batcher.is_alive():
            self._flush_cancelled()

    def _flush_cancelled(self) -> None:
        """Resolve everything still queued/pending after a hard stop."""
        while True:
            try:
                t = self._queue.get_nowait()
            except queue.Empty:
                break
            if isinstance(t, FftTicket):
                self._complete(t, error=ServiceClosed(
                    "service closed before the request launched"))
        for dq in self._pending.values():
            while dq:
                self._complete(dq.popleft(), error=ServiceClosed(
                    "service closed before the request launched"))

    # ------------------------------------------------------------- admission

    def submit(self, kind: str, *operands, shape=None,
               deadline_s: float | None = None) -> FftTicket:
        """Submit one transform; never blocks, always returns a ticket.

        kind="c2c" takes planar ``(xr, xi)``; kind="r2c" takes real
        ``(x,)``. The trailing ``shape`` axes (default: the last axis) are
        the transform; leading axes collapse into batch rows. Rejections
        resolve the ticket immediately with a structured error.
        """
        now = self._clock()
        with self._admit_lock:
            seq = self._seq
            self._seq += 1
        dl = deadline_s if deadline_s is not None else self.default_deadline_s
        ops, shape_t, rows, squeeze = self._normalize_operands(
            kind, operands, shape)
        ticket = FftTicket(seq, kind, shape_t, rows, dl)
        ticket._operands = ops
        ticket._squeeze = squeeze
        if self.verify == "parseval":
            # the Parseval baseline: input energy measured at the trust
            # boundary, before the request ever touches service state.
            # abft mode skips this — the checksum row is the (stronger)
            # invariant and the per-request energy passes were the
            # dominant verification cost.
            ticket._energy = abft.energy(*ops)
        ticket._t_submit = now
        ticket._deadline_at = None if dl is None else now + dl
        # spec-key resolution validates the transform up front (pow2 axes,
        # placement feasibility) — a bad spec is a synchronous ValueError,
        # a client bug rather than a service condition
        ticket._key = self._spec_key(kind, shape_t, rows)
        self.stats.bump("submitted")

        if self._closing.is_set():
            return self._reject(ticket, ServiceClosed(
                "service is shutting down"), reason="closed")
        try:
            maybe_fire(self.injector, "serve.admit", seq)
        except IOError as e:
            self.stats.reject("admit_fault")
            return self._reject(ticket, RequestFailed("admit", 1, e),
                                reason=None)
        with self._admit_lock:
            if self._occupancy >= self.queue_depth:
                self._overload_strikes += 1
                if self._overload_strikes >= self.shed_after:
                    self._shed_requested.set()
                err = ServiceOverload(
                    "queue_full",
                    f"{self._occupancy} queued >= depth {self.queue_depth}")
                reject = err
            elif not self._admit_spec(ticket._key):
                reject = self._spec_rejection(ticket._key)
            else:
                self._overload_strikes = 0
                self._occupancy += 1
                ticket._occupies = True
                self._spec_inflight[ticket._key] = (
                    self._spec_inflight.get(ticket._key, 0) + 1)
                self.stats.saw_queue(self._occupancy)
                reject = None
        if reject is not None:
            return self._reject(ticket, reject, reason=reject.reason)
        self.stats.bump("admitted")
        self._queue.put(ticket)
        return ticket

    def _reject(self, ticket: FftTicket, err: ServiceError,
                reason: str | None) -> FftTicket:
        if reason is not None:
            self.stats.reject(reason)
        ticket.error = err
        ticket._event.set()
        return ticket

    def _admit_spec(self, key) -> bool:
        """Per-spec admission (called under _admit_lock)."""
        if (self.per_spec_inflight is not None
                and self._spec_inflight.get(key, 0) >= self.per_spec_inflight):
            return False
        if self.per_spec_qps is not None:
            bucket = self._buckets.get(key)
            if bucket is None:
                bucket = self._buckets[key] = _TokenBucket(
                    self.per_spec_qps, self.per_spec_burst, self._clock)
            if not bucket.try_take():
                return False
        return True

    def _spec_rejection(self, key) -> ServiceOverload:
        if (self.per_spec_inflight is not None
                and self._spec_inflight.get(key, 0) >= self.per_spec_inflight):
            return ServiceOverload(
                "inflight_cap",
                f"{self._spec_inflight.get(key, 0)} inflight for this spec")
        return ServiceOverload("rate_limit",
                               f"{self.per_spec_qps}/s token bucket empty")

    @staticmethod
    def _normalize_operands(kind, operands, shape):
        if kind not in ("c2c", "r2c"):
            raise ValueError(f"kind must be 'c2c' or 'r2c', got {kind!r}")
        want = 2 if kind == "c2c" else 1
        if len(operands) != want:
            raise ValueError(
                f"kind={kind!r} takes {want} operand(s) "
                f"({'xr, xi' if want == 2 else 'x'}), got {len(operands)}")
        ops = tuple(np.ascontiguousarray(o, dtype=np.float32)
                    for o in operands)
        if any(o.shape != ops[0].shape for o in ops[1:]):
            raise ValueError(
                f"operand shapes differ: {[o.shape for o in ops]}")
        full = ops[0].shape
        if shape is None:
            if not full:
                raise ValueError("operands must have at least one axis")
            shape_t = (int(full[-1]),)
        else:
            shape_t = ((int(shape),) if isinstance(shape, int)
                       else tuple(int(d) for d in shape))
        if len(shape_t) > len(full) or tuple(full[-len(shape_t):]) != shape_t:
            raise ValueError(
                f"trailing operand axes {full} do not match transform "
                f"shape {shape_t}")
        rows = int(math.prod(full[:-len(shape_t)] or (1,)))
        squeeze = len(full) == len(shape_t)
        ops = tuple(o.reshape(rows, *shape_t) for o in ops)
        return ops, shape_t, rows, squeeze

    def _spec_key(self, kind: str, shape: tuple, rows: int):
        """The resolved `FftSpec` cache key modulo batch rows — requests
        that share it can share a plan at any coalesced batch size."""
        num_devices = (int(self.mesh.devices.size)
                       if self.mesh is not None else None)
        resolved = spec_mod.resolve(
            kind=kind, shape=shape, batch_shape=(rows,),
            placement=self.placement, layout=self.layout, impl=self.impl,
            interpret=self.interpret, num_devices=num_devices,
            verify=self.verify)
        return replace(resolved, batch_shape=(rows,), placement="auto")

    # --------------------------------------------------------------- batcher

    def _plan(self, key, total_rows: int):
        import repro.fft as fft_api
        return fft_api.plan(
            kind=key.kind, shape=key.shape, batch_shape=(total_rows,),
            impl=self.impl, interpret=self.interpret, layout=self.layout,
            mesh=self.mesh, placement=self.placement,
            fallback="degrade" if self.degrade else "error",
            verify=self.verify)

    def warmup(self, profile) -> dict:
        """Pre-plan + pre-trace every batch size a hot spec can hit.

        ``profile`` is an iterable of ``{"kind", "shape", "rows"}`` dicts
        (or ``(kind, shape, rows)`` tuples) describing expected traffic.
        For each record this plans BOTH sizes the batcher can dispatch —
        the singleton (``rows`` + the ABFT checksum row if enabled) and
        the full coalesced batch (``coalesce * rows`` + checksum) — and
        runs zeros through each plan once so the jitted executable is
        traced. After warmup, the first real request for a profiled spec
        causes ZERO plan-cache misses and zero retraces.

        Returns a summary: specs seen, plans warmed, and the cache_info
        snapshot afterwards.
        """
        import jax

        import repro.fft as fft_api
        extra = 1 if self.verify == "abft" else 0
        specs = plans = 0
        for rec in profile:
            if isinstance(rec, dict):
                kind = rec.get("kind", "c2c")
                shape = rec["shape"]
                rows = int(rec.get("rows", 1))
            else:
                kind, shape, rows = rec
                rows = int(rows)
            shape_t = ((int(shape),) if isinstance(shape, int)
                       else tuple(int(d) for d in shape))
            key = self._spec_key(kind, shape_t, rows)
            specs += 1
            for total in sorted({rows + extra,
                                 self.coalesce * rows + extra}):
                p = self._plan(key, total)
                ops = [np.zeros((total, *key.shape), np.float32)
                       for _ in range(1 if kind == "r2c" else 2)]
                out = (p.execute_real(*ops) if kind == "r2c"
                       else p.execute(*ops))
                jax.block_until_ready(out)
                plans += 1
        return {"specs": specs, "plans": plans,
                "cache_info": fft_api.cache_info()}

    def _batch_loop(self) -> None:
        while True:
            try:
                if self._step():
                    return
            except Exception as e:  # crash containment: fail only what we
                # hold, recover to an empty-but-serving state
                self.stats.bump("crash_recoveries")
                record_event("service_crash_recovered", error=repr(e))

    def _step(self) -> bool:
        """One batcher iteration; True = drained and done, exit the loop."""
        self._drain_events()
        self._check_mesh_epoch()
        self._sweep_deadlines()
        if self._shed_requested.is_set():
            self._shed_requested.clear()
            self._shed()
        if self._stopped.is_set():
            self._flush_cancelled()
            return self._quiesced()
        # move newly admitted tickets into their spec groups
        moved = 0
        while True:
            try:
                t = self._queue.get(
                    timeout=0 if moved else self.poll_interval_s)
            except queue.Empty:
                break
            if isinstance(t, FftTicket):
                self._pending.setdefault(t._key, deque()).append(t)
                moved += 1
        now = self._clock()
        draining = self._closing.is_set()
        for key in list(self._pending):
            dq = self._pending.get(key)
            if not dq:
                self._pending.pop(key, None)
                continue
            while len(dq) >= self.coalesce:
                self._launch(_Group(key, [dq.popleft()
                                          for _ in range(self.coalesce)]))
            if dq and (draining
                       or now - dq[0]._t_submit >= self.max_batch_delay_s):
                self._launch(_Group(key, list(dq)))
                dq.clear()
        if draining:
            return self._quiesced()
        return False

    def _quiesced(self) -> bool:
        with self._outstanding_lock:
            outstanding = self._outstanding
        return (outstanding == 0 and self._events.empty()
                and not any(self._pending.values()) and self._queue.empty())

    def _drain_events(self) -> None:
        while True:
            try:
                kind, payload = self._events.get_nowait()
            except queue.Empty:
                return
            if kind == "retry":
                for t in payload:
                    self._pending.setdefault(t._key, deque()).appendleft(t)

    def _check_mesh_epoch(self) -> None:
        if self.mesh is None:
            return
        from repro.core.resilience import meshstate
        epoch = meshstate.epoch()
        if self._mesh_epoch is None:
            self._mesh_epoch = epoch
        elif epoch != self._mesh_epoch:
            self._mesh_epoch = epoch
            self.stats.bump("degrade_events")
            record_event(
                "service_degrade", reason="device_loss", epoch=epoch,
                action=("replan_fallback_degrade" if self.degrade
                        else "none"))

    def _sweep_deadlines(self) -> None:
        now = self._clock()
        for dq in self._pending.values():
            kept = [t for t in dq if not self._shed_if_late(t, now)]
            if len(kept) != len(dq):
                dq.clear()
                dq.extend(kept)

    def _shed_if_late(self, t: FftTicket, now: float) -> bool:
        if t._deadline_at is None or now < t._deadline_at:
            return False
        self.stats.bump("deadline_exceeded")
        self._complete(t, error=DeadlineExceeded(
            t.deadline_s, queue_s=now - t._t_submit, stage="queue"))
        return True

    def _shed(self) -> None:
        """Sustained overload: drop queued requests by policy."""
        self._drain_events()
        while True:  # pull everything admitted so victims see the whole set
            try:
                t = self._queue.get_nowait()
            except queue.Empty:
                break
            if isinstance(t, FftTicket):
                self._pending.setdefault(t._key, deque()).append(t)
        total = sum(len(dq) for dq in self._pending.values())
        if total == 0:
            return
        n_shed = max(1, int(math.ceil(self.shed_fraction * total)))
        victims: list[FftTicket] = []
        if self.shed_policy == "oldest_deadline":
            flat = [t for dq in self._pending.values() for t in dq]
            flat.sort(key=lambda t: (t._deadline_at is None,
                                     t._deadline_at or 0.0, t.seq))
            victims = flat[:n_shed]
        else:  # smallest_batch: break the worst-coalescing groups first
            for key in sorted(self._pending,
                              key=lambda k: len(self._pending[k])):
                for t in self._pending[key]:
                    if len(victims) >= n_shed:
                        break
                    victims.append(t)
                if len(victims) >= n_shed:
                    break
        chosen = {id(t) for t in victims}
        for dq in self._pending.values():
            kept = [t for t in dq if id(t) not in chosen]
            dq.clear()
            dq.extend(kept)
        for t in victims:
            self.stats.bump("shed")
            self._complete(t, error=ServiceOverload(
                "shed", f"load shed ({self.shed_policy})"))
        self.stats.bump("degrade_events")
        record_event("service_degrade", reason="overload",
                     policy=self.shed_policy, shed=len(victims),
                     queued=total)

    # --------------------------------------------------------------- launch

    def _launch(self, group: _Group) -> None:
        now = self._clock()
        group.tickets = [t for t in group.tickets
                         if not self._shed_if_late(t, now)]
        if not group.tickets:
            return
        for t in group.tickets:
            t._t_formed = now
            t.attempts += 1
        while not self._inflight.acquire(timeout=self.poll_interval_s):
            self._drain_events()
            if self._stopped.is_set():
                for t in group.tickets:
                    self._complete(t, error=ServiceClosed(
                        "service closed before the request launched"))
                return
        try:
            if self.injector is not None:
                self.injector.fire_group(
                    "serve.batch", [t.seq for t in group.tickets])
            handle, pad_rows = self._gather_and_launch(group)
        except BaseException as e:
            self._inflight.release()
            self._fail_group(group, e, stage="batch")
            return
        self.stats.bump("batches")
        self.stats.bump("batched_requests", len(group.tickets))
        self.stats.bump("padded_rows", pad_rows)
        with self._outstanding_lock:
            self._outstanding += 1
        self._writers.submit(self._writeback, group, handle)

    def _gather_and_launch(self, group: _Group):
        """Host gather into one batch + async dispatch; the 2-plan trick:
        a singleton group runs the SINGLE-request plan, anything larger
        pads up to the FULL ``coalesce x rows`` batch."""
        key = group.key
        rows = group.tickets[0].rows
        n_ops = len(group.tickets[0]._operands)
        extra = 1 if self.verify == "abft" else 0
        if len(group.tickets) == 1 and not extra:
            total = rows
            ops = group.tickets[0]._operands
        else:
            total = rows if len(group.tickets) == 1 \
                else self.coalesce * rows
            ops = []
            for i in range(n_ops):
                buf = np.zeros((total + extra, *key.shape), np.float32)
                r0 = 0
                for t in group.tickets:
                    buf[r0:r0 + rows] = t._operands[i]
                    r0 += rows
                ops.append(buf)
            if extra:
                # one linearity checksum row rides the batch: its
                # transform must equal the same weighted combination of
                # the rows' transforms (weights recomputable at
                # writeback from `total` alone — no state to thread)
                w = abft.checksum_weights(total, seed=total)
                for buf in ops:
                    buf[total] = (w @ buf[:total].reshape(
                        total, -1)).reshape(key.shape)
                group.verify_weights = w
                group.verify_rows = total
        pad_rows = total - rows * len(group.tickets)
        plan = self._plan(key, total + extra)
        t0 = self._clock()
        out = plan.execute_async(*ops)
        for t in group.tickets:
            t._t_launch = t0
            t.batch_rows = total + extra
        return out, pad_rows

    def _writeback(self, group: _Group, handle) -> None:
        try:
            self._writeback_inner(group, handle)
        finally:
            # decrement AFTER any retry events are queued, so the drain
            # exit condition can't observe outstanding == 0 with retries
            # still unrouted
            with self._outstanding_lock:
                self._outstanding -= 1

    def _corrupt_host(self, host, group: _Group):
        """Seeded silent-corruption checkpoint: perturb a hit ticket's
        realized rows AFTER every integrity/fault hook has run — only the
        ABFT invariants stand between this and the client."""
        if self.injector is None:
            return host
        rows = group.tickets[0].rows
        out = list(host)
        r0 = 0
        for t in group.tickets:
            scale = self.injector.corrupt_scale("serve.execute", t.seq)
            if scale is not None:
                for k, a in enumerate(out):
                    if not a.flags.writeable:
                        a = out[k] = np.array(a, copy=True)
                    perturb_array(a[r0:r0 + rows], scale,
                                  corrupt_salt("serve.execute", t.seq, k))
            r0 += rows
        return tuple(out)

    def _verify_group(self, host, group: _Group) -> None:
        """The batch-level linearity check; a miss quarantines the WHOLE
        group (the checksum residual cannot name the culprit row)."""
        if group.verify_weights is None:
            return
        abft.check_checksum(
            host, group.verify_weights, int(math.prod(group.key.shape)),
            "f32", site="serve.execute", index=group.tickets[0].seq,
            seqs=[t.seq for t in group.tickets])

    def _verify_member(self, t: FftTicket, value) -> None:
        """Per-request Parseval: output energy vs the energy recorded at
        admission; a miss quarantines just this request."""
        if t._energy is None:
            return
        n = int(math.prod(t.shape))
        if t.kind == "r2c":
            e_out = abft.energy_onesided(value[0], value[1], n)
        else:
            e_out = abft.energy(*value)
        abft.check_parseval(t._energy, e_out, n, "f32",
                            site="serve.execute", index=t.seq)

    def _writeback_inner(self, group: _Group, handle) -> None:
        try:
            try:
                host = tuple(np.asarray(a) for a in handle)  # realization
            finally:
                self._inflight.release()
            if self.injector is not None:
                self.injector.fire_group(
                    "serve.execute", [t.seq for t in group.tickets])
            host = self._corrupt_host(host, group)
            self._verify_group(host, group)
        except BaseException as e:
            self._fail_group(group, e, stage="execute")
            return
        now = self._clock()
        rows = group.tickets[0].rows
        r0 = 0
        for t in group.tickets:
            value = tuple(a[r0] if t._squeeze else a[r0:r0 + rows]
                          for a in host)
            r0 += rows
            try:
                self._verify_member(t, value)
            except abft.SilentCorruption as e:
                self._fail_group(_Group(group.key, [t]), e, stage="execute")
                continue
            t.timings = {
                "queue_s": t._t_formed - t._t_submit,
                "batch_s": t._t_launch - t._t_formed,
                "execute_s": now - t._t_launch,
                "total_s": now - t._t_submit,
            }
            if t._deadline_at is not None and now > t._deadline_at:
                # end-to-end enforcement: a result realized too late is a
                # deadline miss, even though the math is done
                self.stats.bump("deadline_exceeded")
                self._complete(t, error=DeadlineExceeded(
                    t.deadline_s, stage="execute", **{
                        k: v for k, v in t.timings.items() if k != "total_s"}))
            else:
                self.stats.record_latency(t.timings["total_s"])
                self._complete(t, value=value)

    def _fail_group(self, group: _Group, err: BaseException,
                    stage: str) -> None:
        """Batch failure: admit each member into the retry path or fail it.

        Runs on the batcher (pre-launch faults) or a writeback worker;
        retryable members are routed back to the batcher via the events
        queue so pending state stays single-threaded.
        """
        retry: list[FftTicket] = []
        now = self._clock()
        if isinstance(err, abft.SilentCorruption):
            self.stats.bump("corruption_detected")
            for t in group.tickets:
                t._corrupt_hit = True
        for t in group.tickets:
            elapsed = now - t._t_submit
            late = t._deadline_at is not None and now >= t._deadline_at
            if (not late
                    and self.policy.should_retry(t.attempts, elapsed, err)):
                self.stats.bump("retries")
                retry.append(t)
            elif late:
                self.stats.bump("deadline_exceeded")
                self._complete(t, error=DeadlineExceeded(
                    t.deadline_s, queue_s=t._t_formed - t._t_submit,
                    batch_s=now - t._t_formed, stage=stage))
            else:
                self._complete(t, error=RequestFailed(stage, t.attempts, err))
        if retry:
            self._events.put(("retry", retry))

    # ------------------------------------------------------------ completion

    def _complete(self, t: FftTicket, value=None,
                  error: BaseException | None = None) -> None:
        if t._event.is_set():
            return
        t.value = value
        t.error = error
        if t._occupies:
            t._occupies = False
            with self._admit_lock:
                self._occupancy -= 1
                left = self._spec_inflight.get(t._key, 0) - 1
                if left > 0:
                    self._spec_inflight[t._key] = left
                else:
                    self._spec_inflight.pop(t._key, None)
        if error is None:
            self.stats.bump("completed")
            if t._corrupt_hit:
                self.stats.bump("corruption_recomputed")
        elif isinstance(error, RequestFailed):
            self.stats.bump("failed")
        t._event.set()
