"""Synthetic open-loop load generation for `FftService`.

Shared by the chaos-under-load gate (benchmarks/bench_serve.py) and the
`python -m repro.launch.fft_serve` CLI so both drive the service the same
way: N client threads submit a deterministic mixed-spec request stream
(request seq -> seeded RNG -> operands, so a fault-free oracle can
recompute any request's expected output bit-for-bit), open-loop — clients
never wait for results before submitting the next request, which is what
makes offered load exceed capacity and actually exercises admission
control instead of self-throttling around it.

Outcome classification is the contract the gate asserts: every submitted
request ends in exactly one bucket — ``ok`` (with a bitwise-checkable
result), a named rejection (``queue_full``/``rate_limit``/
``inflight_cap``/``admit_fault``/``closed``), ``shed``, ``deadline``, or
``failed`` — anything else (timeout waiting on a ticket) is a silent
drop and fails the gate.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.serve.fft_service import (DeadlineExceeded, FftService,
                                     RequestFailed, ServiceClosed,
                                     ServiceOverload)


@dataclass(frozen=True)
class RequestShape:
    """One entry of the workload mix: a transform kind/shape/batch rows."""

    kind: str     # "c2c" | "r2c"
    n: int        # 1-D transform length (pow2)
    rows: int     # batch rows per request

    @property
    def label(self) -> str:
        return f"{self.kind}-n{self.n}-r{self.rows}"


# mixed n, c2c + r2c — three spec keys so the batcher has real grouping
# work but enough same-key traffic to coalesce
DEFAULT_MIX = (
    RequestShape("c2c", 256, 2),
    RequestShape("c2c", 512, 4),
    RequestShape("r2c", 512, 2),
)


def request_operands(seed: int, rid: int, shape: RequestShape) -> tuple:
    """Deterministic operands for request ``rid`` — the oracle recomputes
    these independently, so results can be checked bit-for-bit."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, rid]))
    dims = (shape.rows, shape.n)
    if shape.kind == "c2c":
        return (rng.standard_normal(dims, dtype=np.float32),
                rng.standard_normal(dims, dtype=np.float32))
    return (rng.standard_normal(dims, dtype=np.float32),)


def pick_shape(seed: int, rid: int, mix) -> RequestShape:
    rng = np.random.default_rng(np.random.SeedSequence([seed, rid, 7]))
    return mix[int(rng.integers(len(mix)))]


@dataclass
class SubmittedRequest:
    rid: int
    shape: RequestShape
    ticket: object
    t_submit: float


def drive(service: FftService, *, num_requests: int, clients: int = 3,
          seed: int = 0, mix=DEFAULT_MIX, qps: float | None = None,
          deadline_s: float | None = None,
          duration_s: float | None = None) -> list:
    """Open-loop drive: ``clients`` threads split the request ids and
    submit flat-out (or paced to ``qps`` aggregate when given) without
    waiting on results. Returns every `SubmittedRequest` in rid order.

    ``duration_s`` caps wall time: pacing stops issuing new requests once
    exceeded (the request count is the primary knob; the cap guards CI).
    """
    records: list = [None] * num_requests
    interval = (clients / qps) if qps else 0.0
    t_start = time.monotonic()

    def client(cid: int) -> None:
        for rid in range(cid, num_requests, clients):
            if duration_s and time.monotonic() - t_start > duration_s:
                break
            shape = pick_shape(seed, rid, mix)
            ops = request_operands(seed, rid, shape)
            ticket = service.submit(shape.kind, *ops,
                                    deadline_s=deadline_s)
            records[rid] = SubmittedRequest(rid, shape, ticket,
                                            time.monotonic())
            if interval:
                time.sleep(interval)

    threads = [threading.Thread(target=client, args=(cid,), daemon=True)
               for cid in range(max(clients, 1))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return [r for r in records if r is not None]


def classify(rec: SubmittedRequest, timeout: float = 60.0) -> str:
    """Wait for the outcome and name its bucket (see module docstring)."""
    if not rec.ticket.wait(timeout):
        return "silent_drop"   # a pending ticket after drain = a lost request
    err = rec.ticket.error
    if err is None:
        return "ok"
    if isinstance(err, ServiceOverload):
        return "shed" if err.reason == "shed" else err.reason
    if isinstance(err, DeadlineExceeded):
        return "deadline"
    if isinstance(err, ServiceClosed):
        return "closed"
    if isinstance(err, RequestFailed):
        return "admit_fault" if err.stage == "admit" else "failed"
    return f"unclassified:{type(err).__name__}"


def oracle(shape: RequestShape, ops: tuple, impl: str = "ref",
           batch_rows: int | None = None) -> tuple:
    """Fault-free reference: the request executed ALONE, zero-padded to
    ``batch_rows`` (the launch size the service used — see
    `FftTicket.batch_rows`).

    Row position and co-batched content don't change a row's result, but
    CPU FFT backends pick summation strategies by total batch size, so
    bitwise comparison must replay the same size. Shares the service's
    plan cache by design (same resolved spec -> same cached plan)."""
    import repro.fft as fft_api
    total = batch_rows or shape.rows
    padded = []
    for op in ops:
        buf = np.zeros((total, shape.n), np.float32)
        buf[:shape.rows] = op
        padded.append(buf)
    plan = fft_api.plan(kind=shape.kind, n=shape.n,
                        batch_shape=(total,), impl=impl)
    if shape.kind == "c2c":
        out = plan.execute(*padded)
    else:
        out = plan.execute_real(*padded)
    return tuple(np.asarray(a)[:shape.rows] for a in out)


def bitwise_equal(got: tuple, want: tuple) -> bool:
    return (len(got) == len(want)
            and all(np.array_equal(np.asarray(g), w)
                    for g, w in zip(got, want)))
