"""Training loop: pjit'd step, ZeRO-sharded optimizer, fault tolerance.

The step function is a single donated-state pjit program:

    state = {params, opt_state, step[, errors]}
    train_step(state, batch) -> (state, metrics)

Parallelism comes entirely from shardings (sharding/rules.py): batch DP over
('pod','data'), tensor parallel over 'model', params+optimizer FSDP over
'data' (ZeRO-3 params / ZeRO-1 moments). Gradient all-reduces are implicit
in pjit (reduce-scatter + all-gather for FSDP'd params).

Fault tolerance (DESIGN.md §7): async keep-N checkpoints, auto-resume from
the newest committed step, and mesh-shape-agnostic restore (checkpoints are
global arrays; restore device_puts onto the *current* mesh's shardings, so
an elastic restart on a different data-parallel width just works —
exercised in tests/test_trainer.py::test_elastic_reshard).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.models.transformer import TransformerLM
from repro.optim.compression import compress_tree, init_error_state
from repro.optim.optimizers import clip_by_global_norm, get_optimizer
from repro.optim.schedules import linear_warmup_cosine
from repro.sharding.rules import (ShardingRules, abstract_params,
                                  init_params, param_shardings, resolve_pspec)


@dataclass
class TrainerConfig:
    optimizer: str = "adamw"
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    grad_accum: int = 1
    grad_compression: bool = False
    weight_decay: float = 0.1
    ckpt_dir: str | None = None
    ckpt_every: int = 100
    keep_n: int = 3
    log_every: int = 10


def make_train_step(model: TransformerLM, tc: TrainerConfig):
    """Build the pure step function (pjit-ready; also used by the dry-run)."""
    opt_kw = {}
    if tc.optimizer in ("adamw", "adafactor"):
        opt_kw["weight_decay"] = tc.weight_decay
    opt = get_optimizer(tc.optimizer, **opt_kw)
    lr_fn = linear_warmup_cosine(tc.base_lr, tc.warmup_steps, tc.total_steps)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params = state["params"]
        if tc.grad_accum > 1:
            # microbatch scan: batch leaves are (accum, mb, ...)
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zero), batch)
            loss = loss / tc.grad_accum
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        new_state = dict(state)
        if tc.grad_compression:
            grads, new_state["errors"] = compress_tree(grads, state["errors"])
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt_state"], params, lr)
        new_state.update(params=new_params, opt_state=new_opt,
                         step=state["step"] + 1)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return opt, train_step


def state_shardings(model: TransformerLM, state, rules: ShardingRules,
                    mesh: Mesh):
    """Shardings for the full train state.

    Params use the rules; every non-param leaf is sharded like the param of
    identical shape (adamw moments, compression errors => ZeRO-1 for free),
    else replicated (adafactor's factored stats are tiny; step scalar).
    """
    pshard = param_shardings(model.param_specs(), rules, mesh)
    flat_p = {tuple(x.shape): s for x, s in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(pshard))}
    rep = NamedSharding(mesh, P())

    def pick(x):
        return flat_p.get(tuple(x.shape), rep)

    sh = {k: jax.tree.map(pick, v) for k, v in state.items() if k != "params"}
    sh["params"] = pshard
    return sh


class Trainer:
    def __init__(self, model: TransformerLM, tc: TrainerConfig,
                 mesh: Mesh | None = None,
                 rules: ShardingRules | None = None):
        self.model = model
        self.tc = tc
        self.mesh = mesh
        self.rules = rules or ShardingRules.default()
        self.opt, self._step_fn = make_train_step(model, tc)
        self.ckpt = (CheckpointManager(tc.ckpt_dir, tc.keep_n)
                     if tc.ckpt_dir else None)
        self._jit_step = None

    # ------------------------------------------------------------------
    def init_state(self, key):
        params = init_params(self.model.param_specs(), key)
        state = {"params": params, "opt_state": self.opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if self.tc.grad_compression:
            state["errors"] = init_error_state(params)
        return state

    def state_shardings(self, state):
        if self.mesh is None:
            return None
        return state_shardings(self.model, state, self.rules, self.mesh)

    def restore_or_init(self, key):
        state = self.init_state(key)
        if self.ckpt is not None:
            latest = self.ckpt.latest()
            if latest is not None:
                shardings = self.state_shardings(state)
                _, state = self.ckpt.restore_latest(
                    jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state),
                    shardings)
        return state

    # ------------------------------------------------------------------
    def run(self, state, data_iter, steps: int, batch_shardings=None):
        """Train ``steps`` steps; returns (state, list of metrics dicts)."""
        tc = self.tc
        step_fn = jax.jit(self._step_fn, donate_argnums=0)
        history = []
        t0 = time.monotonic()
        for i, batch in enumerate(data_iter):
            if i >= steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            step = int(state["step"])
            if step % tc.log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.monotonic() - t0
                history.append(m)
            if self.ckpt is not None and step % tc.ckpt_every == 0:
                self.ckpt.save_async(step, state)
        if self.ckpt is not None:
            self.ckpt.save_async(int(state["step"]), state)
            self.ckpt.wait()
        return state, history
