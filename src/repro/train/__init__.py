from repro.train.trainer import Trainer, TrainerConfig, make_train_step

__all__ = ["Trainer", "TrainerConfig", "make_train_step"]
