"""Cross-device (level 2) four-step FFT via shard_map + collectives.

This implements the paper's §VI future work ("paralleling an FFT across a
server cluster ... using RDMA") TPU-natively: the Hadoop cluster becomes a
mesh axis (or a flattened tuple of axes, up to the full 512-chip multi-pod
mesh), HDFS block exchange becomes an on-device collective over ICI, and
each "map task" runs the level-0/1 MXU kernels of repro/fft/executors.py
on its local shard.

Data layout (N = N1 * N2 global points, D devices, planar re/im):

  input   x[i], i = i1*N2 + i2, sharded contiguously: device d owns
          i in [d*N/D, (d+1)*N/D)  == rows i1 in [d*N1/D, ...) of (N1, N2)
  xchg #1 split i2, concat i1   -> (N1, N2/D)   full columns on-device
  pass 1  local FFT over i1 (length N1, batched N2/D)  + on-the-fly twiddle
  xchg #2 split o1, concat i2   -> (N2, N1/D)   full rows on-device
  pass 2  local FFT over i2 (length N2, batched N1/D), stored o2-major
  xchg #3 (natural_order only) split o2, concat o1 -> contiguous output
          shard, already o2-major — no transpose epilogue

Two exchange engines implement each cross-device transpose (DESIGN.md §8):

  overlap=None ("off")   one monolithic `lax.all_to_all` per exchange —
                         the measured baseline; every collective byte sits
                         exposed on the critical path.
  overlap=k (chunks)     the exchange is split into k column slabs, each
                         rotated through the mesh as D-1 direct
                         `lax.ppermute` rounds (double-buffered: slab c+1
                         is in flight while slab c — already assembled —
                         runs its local `fft_cols` + twiddle). By the last
                         round only the final slab's FFT is non-hidden, so
                         all but 1/k of the collective bytes can hide
                         behind MXU compute (`exposed_collective_bytes`).

Both engines are bitwise-identical transforms: the exchange is pure data
movement, and the per-slab kernels compute each column with exactly the
same GEMMs as the monolithic call (benchmarks/bench_distributed.py gates
on this).

Constraints: N, N1, N2 powers of two with D | N1 and D | N2 (hence N >= D^2)
— the standard constraint of transpose-based distributed FFTs, validated up
front by `repro.fft.spec` so it surfaces as a plan-time ValueError. With the
512-chip mesh the minimum distributed transform is 2^18 points. Chunked
overlap additionally needs chunks | N1/D and chunks | N2/D.

Twiddle note: W_N^{i2*o1} exponents reach N1*N2 ~ 2^40+, far beyond f32
integer precision. Since N is a power of two, `(i2 * o1) mod N` is computed
exactly in uint32 wrap-around arithmetic (mod 2^32 then mask), keeping the
twiddle angles exact for any N <= 2^32.

`build_distributed` is the strategy builder the `repro.fft` planner
consumes (the planner owns the single jit); `distributed_fft` remains as
the historical entry point, now a thin wrapper over the facade.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.fft import executors as fft_ex
from repro.kernels.fft import plan as fft_plan

# overlap="auto" heuristic bounds (DESIGN.md §8): below AUTO_MIN_N the
# per-round ppermute latency exceeds the compute the pipeline could hide
# (slab GEMMs can't cover a round); above RING_MAX_D the direct ring's
# D-1 rounds per slab degenerate into a latency ladder of tiny pieces.
OVERLAP_AUTO_MIN_N = 1 << 26
OVERLAP_RING_MAX_D = 64
OVERLAP_AUTO_CHUNKS = 4


@dataclass(frozen=True)
class DistPlan:
    n: int
    d: int           # number of devices along the FFT axes
    n1: int          # pass-1 transform length (columns)
    n2: int          # pass-2 transform length (rows)
    natural_order: bool = True  # False skips exchange #3 (TRANSPOSED_OUT)
    chunks: int | None = None   # ppermute pipeline slabs; None = all_to_all

    @property
    def n_exchanges(self) -> int:
        """Cross-device transposes executed: transposed-out skips #3."""
        return 3 if self.natural_order else 2

    @property
    def bytes_per_exchange_per_device(self) -> int:
        """Planar f32 payload each device moves in ONE exchange."""
        return 2 * 4 * self.n // self.d

    @property
    def per_leg_bytes_per_device(self) -> tuple:
        """Per-exchange-leg payload (uniform legs), tuner-facing — same
        shape of accounting as PencilPlan.per_leg_bytes_per_device."""
        return (self.bytes_per_exchange_per_device,) * self.n_exchanges

    @property
    def per_leg_exposed_bytes_per_device(self) -> tuple:
        """Structurally exposed (fill/drain) payload per leg."""
        return tuple(b // (self.chunks or 1)
                     for b in self.per_leg_bytes_per_device)

    @property
    def collective_bytes_per_device(self) -> int:
        """Planar f32 payload each device exchanges across the whole
        transform — n_exchanges legs, so transposed-out plans report one
        exchange fewer (previously this over-reported by one a2a)."""
        return self.n_exchanges * self.bytes_per_exchange_per_device

    @property
    def exposed_collective_bytes_per_device(self) -> int:
        """Bytes per device that CANNOT overlap compute: the pipeline's
        fill/drain slab per exchange. chunks=None (or 1) exposes every
        byte; k slabs expose 1/k of each leg. Full hiding of the rest
        additionally needs per-round compute >= per-round transfer time —
        the bench's event model accounts for that; this is the structural
        lower bound."""
        return self.collective_bytes_per_device // (self.chunks or 1)


def plan_distributed(n: int, num_devices: int, *, natural_order: bool = True,
                     chunks: int | None = None) -> DistPlan:
    p = fft_plan.log2i(n)
    pd = fft_plan.log2i(num_devices)
    if p < 2 * pd:
        raise ValueError(
            f"distributed FFT needs n >= D^2 (n=2^{p}, D=2^{pd}); "
            f"use segmented_fft for batches of smaller transforms")
    a = min(max(p // 2, pd), p - pd)  # log2(n1), clamped so D | n1 and D | n2
    return DistPlan(n=n, d=num_devices, n1=1 << a, n2=1 << (p - a),
                    natural_order=bool(natural_order), chunks=chunks)


@dataclass(frozen=True)
class PencilPlan:
    """Cross-device plan for an N-D pencil-decomposed transform.

    Input (n0, ..., n_{nd-1}) shards its leading nd-1 axes over a device
    grid (2-D: the flattened mesh, grid=(D,); 3-D: one mesh axis per
    sharded axis, grid=(d0, d1)); each device FFTs its local rows of the
    contiguous last axis, then ``ndim-1`` re-pencil exchange legs each
    re-shard one transformed axis and un-shard the next axis to transform
    — (arXiv:2202.12756's slab/pencil structure on our existing exchange
    engines). For 2-D that is the familiar ONE exchange vs three for the
    1-D distributed four-step; 3-D volumes run two legs.
    """

    shape: tuple      # (n0, ..., n_{nd-1}) global volume
    d: int            # total devices along the FFT axes
    grid: tuple = None  # devices per exchange leg k (shards axis k)
    chunks: int | None = None  # ppermute pipeline slabs; None = all_to_all

    def __post_init__(self):
        if self.grid is None:  # legacy 2-D callers: one flattened ring
            object.__setattr__(self, "grid", (self.d,))

    @property
    def n(self) -> int:
        return math.prod(self.shape)

    @property
    def n_exchanges(self) -> int:
        return len(self.shape) - 1

    @property
    def bytes_per_exchange_per_device(self) -> int:
        """Planar f32 payload each device moves in ONE exchange leg (every
        leg re-pencils the full local volume, so legs are equal-sized)."""
        return 2 * 4 * self.n // self.d

    @property
    def per_leg_bytes_per_device(self) -> tuple:
        """Per-exchange-leg payload, leg order = transform order (axis
        nd-2 first, axis 0 last) — what the tuner ranks against."""
        return (self.bytes_per_exchange_per_device,) * self.n_exchanges

    @property
    def collective_bytes_per_device(self) -> int:
        return self.n_exchanges * self.bytes_per_exchange_per_device

    @property
    def per_leg_exposed_bytes_per_device(self) -> tuple:
        """Structurally exposed (fill/drain) payload per leg."""
        return tuple(b // (self.chunks or 1)
                     for b in self.per_leg_bytes_per_device)

    @property
    def exposed_collective_bytes_per_device(self) -> int:
        """Fill/drain slab per exchange (see DistPlan's twin property)."""
        return self.collective_bytes_per_device // (self.chunks or 1)


def pencil_grid(shape, num_devices: int, axis_sizes=None) -> tuple:
    """Device-grid factors for the pencil legs of an N-D ``shape``.

    2-D pencils flatten every mesh axis into one exchange ring (grid=(D,),
    the PR-5 layout). 3-D volumes shard BOTH leading axes, one mesh axis
    each — the caller must supply the per-mesh-axis sizes (in spec.axes
    order) so the grid matches the mesh's actual structure.
    """
    nd = len(shape)
    if nd == 2:
        return (int(num_devices),)
    if axis_sizes is None:
        raise ValueError(
            f"{nd}-D pencil volumes shard the {nd - 1} leading axes over a "
            f"device grid: plan with a mesh (its axes become the grid, "
            f"e.g. a (4, 2) mesh for shape={shape})")
    grid = tuple(int(g) for g in axis_sizes)
    if len(grid) != nd - 1:
        raise ValueError(
            f"{nd}-D pencil needs exactly {nd - 1} mesh axes (one "
            f"device-grid factor per sharded leading axis of "
            f"shape={shape}); got {len(grid)} axes of sizes {grid}")
    return grid


def pencil_r2c_half(shape, grid, impl: str):
    """The packed half-width pencil shape for a real-input transform, or
    None when the flop-halved path cannot apply (tiny last axis, non-GEMM
    impl, or a final exchange leg that cannot split the half width).

    The r2c pencil rides the rfftn packing: the contiguous pass transforms
    n_last/2 packed complex points, every exchange leg moves the half
    width, and ONE N-D untangle on the global result recovers the real
    spectrum — flop- and byte-halved end to end (DESIGN.md §14).
    """
    shape = tuple(int(d) for d in shape)
    m = shape[-1] // 2
    if impl != "matfft" or shape[-1] < 4:
        return None
    half = (*shape[:-1], m)
    grid = tuple(int(g) for g in grid)
    for k, g in enumerate(grid):  # every leg must split the half volume
        if half[k] % g or half[k + 1] % g:
            return None
    return half


def plan_pencil(shape, num_devices: int, *, grid=None,
                chunks: int | None = None) -> PencilPlan:
    shape = tuple(int(d) for d in shape)
    if len(shape) < 2:
        raise ValueError(f"pencil decomposition needs >= 2 axes, "
                         f"got shape={shape}")
    fft_plan.log2i(num_devices)
    if grid is None:
        grid = pencil_grid(shape, num_devices)
    grid = tuple(int(g) for g in grid)
    if math.prod(grid) != num_devices:
        raise ValueError(
            f"pencil device grid {grid} must multiply to the device count "
            f"D={num_devices}")
    for g in grid:
        fft_plan.log2i(g)
    for k, g in enumerate(grid):
        # leg k shards axis k on input and splits axis k+1 on exchange
        if shape[k] % g or shape[k + 1] % g:
            raise ValueError(
                f"pencil decomposition needs grid[{k}]={g} to divide both "
                f"axis {k} (the input shard) and axis {k + 1} (the "
                f"exchange split) of shape={shape}")
    return PencilPlan(shape=shape, d=num_devices, grid=grid, chunks=chunks)


def _resolve_overlap_knob(n_total: int, num_devices: int, slab_widths,
                          overlap, widths_desc: str) -> int | None:
    """Shared ``overlap`` knob parser for both exchange engines.

    "off"/None -> None. "auto" -> OVERLAP_AUTO_CHUNKS when the ring
    pipeline can plausibly pay for itself (n_total >= OVERLAP_AUTO_MIN_N,
    ring size <= OVERLAP_RING_MAX_D, slabs at least 2 wide), else None.
    An explicit int is validated — chunks must divide every per-device
    slab width so each ppermute round rotates equal pieces — and is
    honoured even where "auto" would decline (user override).
    """
    if overlap is None or overlap == "off":
        return None
    min_w = min(slab_widths)
    if overlap == "auto":
        if (n_total < OVERLAP_AUTO_MIN_N
                or num_devices > OVERLAP_RING_MAX_D or min_w < 2):
            return None
        return min(OVERLAP_AUTO_CHUNKS, min_w)
    if isinstance(overlap, bool) or not isinstance(overlap, int):
        raise ValueError(
            f"overlap must be 'auto', 'off', or a chunk count (int); "
            f"got {overlap!r}")
    if overlap < 1 or any(w % overlap for w in slab_widths):
        raise ValueError(
            f"overlap={overlap} chunks must divide {widths_desc} so "
            f"every ppermute round rotates equal slabs")
    return overlap


def resolve_overlap_pencil(shape, num_devices: int, overlap, *,
                           grid=None) -> int | None:
    """Resolve the ``overlap`` knob for the pencil exchanges: chunks must
    divide every per-leg per-device slab width shape[k+1]/grid[k] (for
    2-D that is the familiar n1/D of the ONE exchange)."""
    shape = tuple(int(d) for d in shape)
    plan = plan_pencil(shape, num_devices, grid=grid)
    widths = tuple(shape[k + 1] // g for k, g in enumerate(plan.grid))
    return _resolve_overlap_knob(
        plan.n, max(plan.grid), widths, overlap,
        f"every per-leg exchange slab width "
        f"{'n1/D=%d' % widths[0] if len(widths) == 1 else widths} "
        f"(shape={shape}, grid={plan.grid})")


def resolve_overlap(n: int, num_devices: int, overlap) -> int | None:
    """Resolve the ``overlap`` knob for the 1-D engine: chunks must
    divide both per-device slab widths n1/D and n2/D."""
    if overlap is None or overlap == "off":
        return None
    plan = plan_distributed(n, num_devices)
    n1l, n2l = plan.n1 // plan.d, plan.n2 // plan.d
    return _resolve_overlap_knob(
        n, num_devices, (n1l, n2l), overlap,
        f"both per-device slab widths n1/D={n1l} and n2/D={n2l} "
        f"(n={n}, D={num_devices})")


def _axis_size(mesh: Mesh, axis_names) -> int:
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    return math.prod(mesh.shape[a] for a in axis_names)


def _zeros_planar(shape):
    return (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32))


def _ring(d: int, ax, didx, take, place, bufs):
    """One slab exchange: D-1 direct ppermute rounds + the local piece.

    Round r rotates by r — device ``didx`` sends ``take((didx+r)%D)`` and
    receives source (didx-r)%D's piece, placed by ``place``. The rounds
    carry independent data (no chained buffer), so the scheduler can run
    them concurrently with each other and with the previous slab's FFT.
    Shared by BOTH overlapped engines (1-D three-exchange and 2-D pencil).
    """
    bufs = place(bufs, take(didx), didx)
    for r in range(1, d):
        perm = [(s, (s + r) % d) for s in range(d)]
        pr, pi = take((didx + r) % d)
        rr = lax.ppermute(pr, ax, perm)
        ri = lax.ppermute(pi, ax, perm)
        bufs = place(bufs, (rr, ri), (didx - r) % d)
    return bufs


def _twiddle(i2g: jnp.ndarray, o1: jnp.ndarray, n: int):
    """Planar W_n^{i2g*o1} with exact pow2 modular exponent (see header)."""
    m = (i2g.astype(jnp.uint32)[:, None] * o1.astype(jnp.uint32)[None, :])
    m = m & jnp.uint32(n - 1)
    ang = (-2.0 * math.pi / n) * m.astype(jnp.float32)
    return jnp.cos(ang), jnp.sin(ang)


def build_distributed(n: int, mesh: Mesh, axis_names=("data", "model"), *,
                      impl: str = "matfft", natural_order: bool = True,
                      fuse_twiddle: bool = False,
                      interpret: bool | None = None,
                      layout: str = "zero_copy",
                      overlap: int | None = None):
    """Build the shard_map'd cross-device four-step for a length-n signal.

    ``overlap`` is the RESOLVED chunk count (see `resolve_overlap`; the
    planner resolves "auto"). Returns the shard-mapped function over
    planar (n,) global arrays; the caller (the planner) wraps it in ONE
    `jax.jit` and caches it.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    d = _axis_size(mesh, axis_names)
    plan = plan_distributed(n, d, natural_order=natural_order,
                            chunks=overlap)
    n1, n2 = plan.n1, plan.n2
    n1l, n2l = n1 // d, n2 // d
    ax = tuple(axis_names)
    if overlap is not None and (n1l % overlap or n2l % overlap):
        raise ValueError(
            f"overlap={overlap} does not divide slab widths "
            f"n1/D={n1l}, n2/D={n2l}")

    def pass1(ar, ai, row0, rows):
        """Local pass 1 on an assembled (n1, rows) column slab whose first
        global row (i2) is ``row0``: FFT + the W_n^{i2*o1} twiddle, fused
        into the kernel epilogue when the leaf allows it."""
        can_fuse = (fuse_twiddle and impl == "matfft"
                    and fft_plan.make_plan(n1).levels == 1)
        if can_fuse:
            row_off = row0.astype(jnp.int32).reshape(1)
            return fft_ex.fft_cols(ar, ai, impl=impl, interpret=interpret,
                                   global_twiddle=(n, row_off),
                                   layout=layout)
        ar, ai = fft_ex.fft_cols(ar, ai, impl=impl, interpret=interpret,
                                 layout=layout)
        # ar: (rows, n1), row j = global i2 row0 + j, cols = o1
        i2g = row0.astype(jnp.uint32) + jnp.arange(rows, dtype=jnp.uint32)
        tw_r, tw_i = _twiddle(i2g, jnp.arange(n1, dtype=jnp.uint32), n)
        return ar * tw_r - ai * tw_i, ar * tw_i + ai * tw_r

    def pass2(br, bi, out_major, col_offset=0, ncols=None):
        """Local pass 2 on (n2, n1l): FFT each length-n2 column. The
        o2-major ("col") store is what exchange #3 consumes directly, so
        the old `cr.T.reshape(-1)` HBM transpose epilogue is folded into
        the kernel's out_major store."""
        return fft_ex.fft_cols(br, bi, impl=impl, interpret=interpret,
                               layout=layout, out_major=out_major,
                               col_offset=col_offset, ncols=ncols)

    def local_monolithic(xr_loc, xi_loc):
        # Device-local shard: contiguous rows of the (n1, n2) matrix.
        didx = lax.axis_index(ax)

        def a2a(a):  # global transpose: split cols, concat rows
            return lax.all_to_all(a, ax, split_axis=1, concat_axis=0,
                                  tiled=True)

        # ---- xchg #1: (n1l, n2) -> (n1, n2l): full columns arrive ----
        ar = a2a(xr_loc.reshape(n1l, n2))
        ai = a2a(xi_loc.reshape(n1l, n2))

        # ---- pass 1: FFT columns (length n1), batched over n2l ----
        # fft_cols folds the local transpose into the kernel's BlockSpec:
        # with layout="zero_copy" the (n1, n2l) shard is read column-strided
        # and the (n2l, n1) result written row-major, no `.T` copy in HBM.
        br, bi = pass1(ar, ai, didx * n2l, n2l)

        # ---- xchg #2: (n2l, n1) -> (n2, n1l): full rows arrive ----
        br, bi = a2a(br), a2a(bi)

        if not natural_order:
            # ---- pass 2, row-major out: (n1l, n2) = [o1_loc, o2] ----
            cr, ci = pass2(br, bi, "row")
            return cr.reshape(-1), ci.reshape(-1)

        # ---- pass 2, o2-major out: (n2, n1l) = [o2, o1_loc] ----
        cr, ci = pass2(br, bi, "col")

        # ---- xchg #3: split o2 rows, concat o1 cols -> (n2l, n1) ----
        # the received layout IS the o2-major output shard: flatten free.
        def a2a_t(a):
            return lax.all_to_all(a, ax, split_axis=0, concat_axis=1,
                                  tiled=True)

        cr, ci = a2a_t(cr), a2a_t(ci)
        return cr.reshape(-1), ci.reshape(-1)

    def local_overlapped(xr_loc, xi_loc):
        k = overlap
        n2c, n1c = n2l // k, n1l // k
        didx = lax.axis_index(ax)
        xr2 = xr_loc.reshape(n1l, n2)
        xi2 = xi_loc.reshape(n1l, n2)
        zeros = _zeros_planar

        def ring(take, place, bufs):  # the shared rotation schedule
            return _ring(d, ax, didx, take, place, bufs)

        # ---- xchg #1 slab c: global columns didx*n2l + c-slab ----
        def take1(c):
            def take(dest):
                start = dest * n2l + c * n2c
                return (lax.dynamic_slice(xr2, (0, start), (n1l, n2c)),
                        lax.dynamic_slice(xi2, (0, start), (n1l, n2c)))
            return take

        def place1(bufs, piece, s):
            # source s owns global rows [s*n1l, (s+1)*n1l)
            return (lax.dynamic_update_slice(bufs[0], piece[0],
                                             (s * n1l, 0)),
                    lax.dynamic_update_slice(bufs[1], piece[1],
                                             (s * n1l, 0)))

        # ---- xchg #2 slab c: pass-1 rows c-slab into the (n2, n1l)
        # accumulator (row i2 = s*n2l + c*n2c + j for source s) ----
        def take2(br, bi):
            def take(dest):
                return (lax.dynamic_slice(br, (0, dest * n1l), (n2c, n1l)),
                        lax.dynamic_slice(bi, (0, dest * n1l), (n2c, n1l)))
            return take

        def place2(c):
            def place(bufs, piece, s):
                at = (s * n2l + c * n2c, 0)
                return (lax.dynamic_update_slice(bufs[0], piece[0], at),
                        lax.dynamic_update_slice(bufs[1], piece[1], at))
            return place

        # Software pipeline over slabs (double buffer): slab c+1's rounds
        # are issued before slab c's FFT, so its transfers have a full
        # kernel's worth of compute to hide behind; slab c's pass-1 output
        # immediately feeds its xchg #2 rounds, which hide behind slab
        # c+1's FFT. Only slab 0's arrival and the final slab's FFT are
        # structurally exposed.
        arrived = [None] * k
        arrived[0] = ring(take1(0), place1, zeros((n1, n2c)))
        acc2 = zeros((n2, n1l))
        for c in range(k):
            if c + 1 < k:
                arrived[c + 1] = ring(take1(c + 1), place1,
                                      zeros((n1, n2c)))
            br, bi = pass1(*arrived[c], didx * n2l + c * n2c, n2c)
            acc2 = ring(take2(br, bi), place2(c), acc2)
        a2r, a2i = acc2

        if not natural_order:
            cr, ci = pass2(a2r, a2i, "row")
            return cr.reshape(-1), ci.reshape(-1)

        # ---- pass 2 slab j (columns j-slab of (n2, n1l), read in place
        # via the kernel's col_offset — no retile) + xchg #3 slab j ----
        def take3(cr, ci):
            def take(dest):
                return (lax.dynamic_slice(cr, (dest * n2l, 0), (n2l, n1c)),
                        lax.dynamic_slice(ci, (dest * n2l, 0), (n2l, n1c)))
            return take

        def place3(j):
            def place(bufs, piece, s):
                at = (0, s * n1l + j * n1c)
                return (lax.dynamic_update_slice(bufs[0], piece[0], at),
                        lax.dynamic_update_slice(bufs[1], piece[1], at))
            return place

        out = zeros((n2l, n1))
        for j in range(k):
            cr, ci = pass2(a2r, a2i, "col", col_offset=j * n1c, ncols=n1c)
            out = ring(take3(cr, ci), place3(j), out)
        outr, outi = out
        return outr.reshape(-1), outi.reshape(-1)

    local = local_monolithic if overlap is None else local_overlapped
    spec = P(ax)
    # check_vma=False: pallas_call out_shapes do not carry vma metadata.
    return compat.shard_map(local, mesh=mesh, in_specs=(spec, spec),
                            out_specs=(spec, spec), check_vma=False)


def _pencil_groups(shape, mesh: Mesh, axis_names):
    """Mesh-axis group per exchange leg + the resulting device grid.

    2-D: every mesh axis flattens into ONE exchange ring (PR-5 layout).
    3-D: exactly one mesh axis per sharded leading axis — leg k rotates
    over its own sub-ring while the other grid axis stays put, so the two
    legs' collectives are independent D_k-way transposes.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    names = tuple(axis_names)
    nd = len(shape)
    if nd == 2:
        groups = (names,)
    else:
        if len(names) != nd - 1:
            raise ValueError(
                f"{nd}-D pencil needs exactly {nd - 1} mesh axes (one "
                f"device-grid axis per sharded leading axis of "
                f"shape={tuple(shape)}); got axes {names}")
        groups = tuple((a,) for a in names)
    grid = tuple(_axis_size(mesh, g) for g in groups)
    return groups, grid


def _pencil_legs(shape, grid, groups, *, impl, interpret, layout,
                 batch_tile, overlap):
    """Build the exchange-legs closure shared by the c2c and r2c pencils.

    Input: device-local planar arrays of shape ``loc0`` = per-axis
    ``shape[i]/grid[i]`` for the sharded leading axes, full last axis —
    already transformed along the contiguous axis by the caller. Runs
    legs k = nd-2 .. 0 (exactly local fftn's axis order, so the composed
    transform is bitwise-equal to the local oracle): exchange leg k
    re-shards transformed axis k+1 over grid[k] and assembles full axis
    k, then the axis-k pass runs on the shared axis-pass kernel with a
    column-major store. Each leg uses the monolithic all_to_all or the
    chunked ppermute ring (both bitwise-identical: the slab kernels issue
    exactly the monolithic GEMMs via col_offset/ncols).
    """
    shape = tuple(int(x) for x in shape)
    nd = len(shape)
    loc0 = tuple(shape[i] // grid[i] for i in range(nd - 1)) + (shape[-1],)

    def axis_k_pass(ar, ai, S, k, col_offset=0, ncols=None):
        """Transform axis k of the local planar volume S via the shared
        axis-pass primitive ((B, L, C) view, col-major store), reshaped
        back to volume form (a slab pass narrows axis k+1 to the slab)."""
        B, L, C = math.prod(S[:k]), S[k], math.prod(S[k + 1:])
        nc = C - col_offset if ncols is None else ncols
        yr, yi = fft_ex.axis_pass(ar, ai, (B, L, C), out_major="col",
                                  impl=impl, interpret=interpret,
                                  col_tile=batch_tile, layout=layout,
                                  col_offset=col_offset, ncols=nc)
        rest = math.prod(S[k + 2:])
        out_shape = (*S[:k], L, nc // rest, *S[k + 2:])
        return yr.reshape(out_shape), yi.reshape(out_shape)

    def monolithic_leg(ar, ai, S, k):
        g = groups[k]

        def a2a(a):  # re-pencil: split transformed axis k+1, concat axis k
            return lax.all_to_all(a, g, split_axis=k + 1, concat_axis=k,
                                  tiled=True)

        ar, ai = a2a(ar), a2a(ai)
        S = list(S)
        S[k + 1] //= grid[k]
        S[k] *= grid[k]
        S = tuple(S)
        ar, ai = axis_k_pass(ar, ai, S, k)
        return ar, ai, S

    def overlapped_leg(ar, ai, S, k):
        kc = overlap
        dk, g = grid[k], groups[k]
        didx = lax.axis_index(g)
        w = shape[k + 1] // dk      # per-dest slab width on axis k+1
        wc = w // kc
        accS = list(S)
        accS[k] = S[k] * dk         # full transformed axis k assembles
        accS[k + 1] = w
        accS = tuple(accS)
        rest = math.prod(accS[k + 2:])

        def ring(take, place, bufs):  # the shared rotation schedule
            return _ring(dk, g, didx, take, place, bufs)

        # xchg slab c: sub-ring member ``dest``'s global axis-(k+1)
        # columns [dest*w + c*wc, ... + wc) of this leg's input
        def take(c):
            def take_(dest):
                start = [0] * nd
                start[k + 1] = dest * w + c * wc
                sizes = list(S)
                sizes[k + 1] = wc
                return (lax.dynamic_slice(ar, tuple(start), tuple(sizes)),
                        lax.dynamic_slice(ai, tuple(start), tuple(sizes)))
            return take_

        def place(c):
            def place_(bufs, piece, s):
                # source s owns axis-k block [s*S[k], (s+1)*S[k])
                at = [0] * nd
                at[k] = s * S[k]
                at[k + 1] = c * wc
                at = tuple(at)
                return (lax.dynamic_update_slice(bufs[0], piece[0], at),
                        lax.dynamic_update_slice(bufs[1], piece[1], at))
            return place_

        # Software pipeline (double buffer): slab c+1's ppermute rounds
        # are issued before slab c's axis pass, so the transfer has a
        # full kernel's worth of MXU compute to hide behind. The pass
        # reads the accumulator SNAPSHOT taken before ring c+1 merges
        # in (slab c's columns are already final there) — reading the
        # merged value instead would add a ring(c+1) -> fft(c) dataflow
        # edge and re-expose one slab per exchange. The kernel fetches
        # only the slab's columns via its col_offset BlockSpec, so every
        # slab issues exactly the monolithic GEMMs (bitwise-gated).
        acc = ring(take(0), place(0), _zeros_planar(accS))
        out = _zeros_planar(accS)
        for c in range(kc):
            cur = acc
            if c + 1 < kc:
                acc = ring(take(c + 1), place(c + 1), acc)
            cr, ci = axis_k_pass(cur[0], cur[1], accS, k,
                                 col_offset=c * wc * rest,
                                 ncols=wc * rest)
            at = [0] * nd
            at[k + 1] = c * wc
            out = (lax.dynamic_update_slice(out[0], cr, tuple(at)),
                   lax.dynamic_update_slice(out[1], ci, tuple(at)))
        return out[0], out[1], accS

    leg = monolithic_leg if overlap is None else overlapped_leg

    def legs(ar, ai):
        S = loc0
        for k in range(nd - 2, -1, -1):
            ar, ai, S = leg(ar, ai, S, k)
        return ar, ai

    return legs, loc0


def build_pencil(shape, mesh: Mesh, axis_names=("data", "model"), *,
                 impl: str = "matfft", interpret: bool | None = None,
                 layout: str = "zero_copy", batch_tile: int | None = None,
                 overlap: int | None = None):
    """Build the shard_map'd N-D pencil transform for an (n0, .., nk) volume.

    Data layout (device grid per `_pencil_groups`, planar re/im):

      input   leading axes sharded over the grid (2-D: rows over D; 3-D:
              axis 0 over d0, axis 1 over d1), last axis contiguous
      pass    local FFT of each row (contiguous axis, level 0/1 kernels)
      legs    ndim-1 re-pencil exchanges, axis nd-2 down to axis 0: each
              leg re-shards the just-transformed axis and assembles the
              next, then FFTs it via the shared axis-pass kernel with a
              column-major store (all_to_all or the chunked ppermute ring)

    The output is the full natural-order N-D spectrum with the SAME grid
    rotated one axis right (out_specs P(None, *groups)) — the standard
    pencil re-distribution. Both exchange engines are bitwise-identical
    transforms, same as the 1-D engines, and the leg order matches local
    `fftn` exactly so the composed result is bitwise vs the local oracle.

    ``overlap`` is the RESOLVED chunk count (`resolve_overlap_pencil`).
    Returns the shard-mapped function over planar global volumes; the
    caller (the planner) wraps it in ONE `jax.jit` and caches it.
    """
    shape = tuple(int(x) for x in shape)
    groups, grid = _pencil_groups(shape, mesh, axis_names)
    d = math.prod(grid)
    plan_pencil(shape, d, grid=grid, chunks=overlap)  # validate
    if overlap is not None:
        widths = [shape[k + 1] // grid[k] for k in range(len(shape) - 1)]
        if any(w % overlap for w in widths):
            raise ValueError(
                f"overlap={overlap} does not divide every exchange slab "
                f"width {widths} (shape={shape}, grid={grid})")
    legs, _ = _pencil_legs(shape, grid, groups, impl=impl,
                           interpret=interpret, layout=layout,
                           batch_tile=batch_tile, overlap=overlap)

    def local(xr_loc, xi_loc):
        # contiguous-axis pass on the local shard (leading axes = batch)
        ar, ai = fft_ex.fft(xr_loc, xi_loc, impl=impl, interpret=interpret,
                            batch_tile=batch_tile, layout=layout)
        return legs(ar, ai)

    in_spec = P(*groups, None)    # leading axes sharded over the grid
    out_spec = P(None, *groups)   # grid rotated one axis right
    # check_vma=False: pallas_call out_shapes do not carry vma metadata.
    return compat.shard_map(local, mesh=mesh, in_specs=(in_spec, in_spec),
                            out_specs=(out_spec, out_spec), check_vma=False)


def build_pencil_r2c(shape, mesh: Mesh, axis_names=("data", "model"), *,
                     impl: str = "matfft", interpret: bool | None = None,
                     layout: str = "zero_copy",
                     batch_tile: int | None = None,
                     overlap: int | None = None):
    """Flop-halved real-input pencil: the rfftn packing, distributed.

    The local contiguous pass consumes each real row as n_last/2 packed
    complex points (`executors.rfft_pack_pass` — literally the same
    kernels as the local rfftn fast path), then the SAME exchange legs as
    `build_pencil` run on the half-width volume, halving every leg's
    collective bytes and every axis pass's GEMMs. The result is the RAW
    packed half spectrum, grid-rotated like the c2c pencil; the caller
    (the planner) applies the ONE N-D untangle on the global array —
    outside the shard_map, exactly where local rfftn applies it, so the
    composed transform is bitwise-equal to the local `rfftn` oracle.

    Only valid when `pencil_r2c_half(shape, grid, impl)` is non-None;
    ``overlap`` is resolved against the HALF shape. Returns the
    shard-mapped function real (n0, .., n_last) -> planar half volumes.
    """
    shape = tuple(int(x) for x in shape)
    groups, grid = _pencil_groups(shape, mesh, axis_names)
    d = math.prod(grid)
    half = pencil_r2c_half(shape, grid, impl)
    if half is None:
        raise ValueError(
            f"no flop-halved r2c pencil for shape={shape}, grid={grid}, "
            f"impl={impl!r} (see pencil_r2c_half)")
    plan_pencil(half, d, grid=grid, chunks=overlap)  # validate
    legs, loc0 = _pencil_legs(half, grid, groups, impl=impl,
                              interpret=interpret, layout=layout,
                              batch_tile=batch_tile, overlap=overlap)
    n_last = shape[-1]

    def local(x_loc):
        rows2 = math.prod(loc0[:-1])
        zr, zi = fft_ex.rfft_pack_pass(
            x_loc.reshape(rows2, n_last), n_last, impl=impl,
            interpret=interpret, batch_tile=batch_tile, layout=layout)
        return legs(zr.reshape(loc0), zi.reshape(loc0))

    in_spec = P(*groups, None)
    out_spec = P(None, *groups)
    # check_vma=False: pallas_call out_shapes do not carry vma metadata.
    return compat.shard_map(local, mesh=mesh, in_specs=(in_spec,),
                            out_specs=(out_spec, out_spec), check_vma=False)


def distributed_fft(xr: jnp.ndarray, xi: jnp.ndarray, mesh: Mesh,
                    axis_names=("data", "model"), *, impl: str = "matfft",
                    natural_order: bool = True, fuse_twiddle: bool = False,
                    interpret: bool | None = None,
                    layout: str = "zero_copy", overlap="auto"):
    """Forward FFT of a single length-n planar signal sharded over ``mesh``.

    Args:
      xr, xi: (n,) float32 planes (global arrays; pjit/shard_map shards them
        along the flattened ``axis_names``).
      natural_order: if False, skip exchange #3 and return the transform
        in transposed (o1-major) block order — FFTW's TRANSPOSED_OUT, useful
        when a subsequent pointwise op + inverse FFT follows (convolution).
      layout: "zero_copy" folds the local `.T` at each pass boundary into
        the column-strided Pallas kernel (fft_cols) — the exchange already
        did the cross-device transpose, so no device-local transposed copy
        is materialized either; "copy" keeps the legacy materialized
        transposes (measured baseline).
      overlap: "auto" | "off" | int chunk count — "off" keeps the three
        monolithic all_to_alls; a chunk count pipelines each exchange as
        ppermute slab rounds hidden behind the local FFTs (DESIGN.md §8).
    Returns planar (n,) arrays, sharded like the input.

    Thin wrapper over `repro.fft.plan(placement="distributed")`: repeat
    calls with the same spec hit the plan cache and reuse the compiled
    callable.
    """
    import repro.fft as fft_api
    p = fft_api.plan(kind="c2c", n=xr.shape[-1], batch_shape=(), mesh=mesh,
                     placement="distributed", axes=axis_names, impl=impl,
                     natural_order=natural_order, fuse_twiddle=fuse_twiddle,
                     interpret=interpret, layout=layout, overlap=overlap)
    return p.execute(xr, xi)


def distributed_ifft(xr, xi, mesh, axis_names=("data", "model"), **kw):
    """Inverse FFT, sharded like distributed_fft.

    Routes through the cached plan's `execute_inverse` (the conjugation
    identity lives inside the plan's own jit), so an inverse call is ONE
    facade round-trip instead of re-entering `distributed_fft` with
    negated planes and paying plan resolution + dispatch twice.

    Behavior change vs the pre-facade wrapper: `natural_order=False` now
    fails fast with NotImplementedError (execute_inverse's plan-level
    rule) instead of silently returning the inverse in transposed block
    order — the old behavior inverted a round-tripped TRANSPOSED_OUT
    spectrum incorrectly, since the conjugation identity needs the
    forward's natural output order. Plan the inverse leg with
    natural_order=True.
    """
    import repro.fft as fft_api
    p = fft_api.plan(kind="c2c", n=xr.shape[-1], batch_shape=(), mesh=mesh,
                     placement="distributed", axes=axis_names, **kw)
    return p.execute_inverse(xr, xi)
