"""Cross-device (level 2) four-step FFT via shard_map + all_to_all.

This implements the paper's §VI future work ("paralleling an FFT across a
server cluster ... using RDMA") TPU-natively: the Hadoop cluster becomes a
mesh axis (or a flattened tuple of axes, up to the full 512-chip multi-pod
mesh), HDFS block exchange becomes `jax.lax.all_to_all` over ICI, and each
"map task" runs the level-0/1 MXU kernels of repro/fft/executors.py on its
local shard.

Data layout (N = N1 * N2 global points, D devices, planar re/im):

  input   x[i], i = i1*N2 + i2, sharded contiguously: device d owns
          i in [d*N/D, (d+1)*N/D)  == rows i1 in [d*N1/D, ...) of (N1, N2)
  a2a #1  split i2, concat i1   -> (N1, N2/D)   full columns on-device
  pass 1  local FFT over i1 (length N1, batched N2/D)  + on-the-fly twiddle
  a2a #2  split o1, concat i2   -> (N2, N1/D)   full rows on-device
  pass 2  local FFT over i2 (length N2, batched N1/D)
  a2a #3  (natural_order only) split o2, concat o1 -> contiguous output shard

Constraints: N, N1, N2 powers of two with D | N1 and D | N2 (hence N >= D^2)
— the standard constraint of transpose-based distributed FFTs, validated up
front by `repro.fft.spec` so it surfaces as a plan-time ValueError. With the
512-chip mesh the minimum distributed transform is 2^18 points.

Twiddle note: W_N^{i2*o1} exponents reach N1*N2 ~ 2^40+, far beyond f32
integer precision. Since N is a power of two, `(i2 * o1) mod N` is computed
exactly in uint32 wrap-around arithmetic (mod 2^32 then mask), keeping the
twiddle angles exact for any N <= 2^32.

`build_distributed` is the strategy builder the `repro.fft` planner
consumes (the planner owns the single jit); `distributed_fft` remains as
the historical entry point, now a thin wrapper over the facade.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.fft import executors as fft_ex
from repro.kernels.fft import plan as fft_plan


@dataclass(frozen=True)
class DistPlan:
    n: int
    d: int           # number of devices along the FFT axes
    n1: int          # pass-1 transform length (columns)
    n2: int          # pass-2 transform length (rows)

    @property
    def collective_bytes_per_device(self) -> int:
        """Planar f32 payload each device exchanges per all_to_all."""
        return 2 * 4 * self.n // self.d


def plan_distributed(n: int, num_devices: int) -> DistPlan:
    p = fft_plan.log2i(n)
    pd = fft_plan.log2i(num_devices)
    if p < 2 * pd:
        raise ValueError(
            f"distributed FFT needs n >= D^2 (n=2^{p}, D=2^{pd}); "
            f"use segmented_fft for batches of smaller transforms")
    a = min(max(p // 2, pd), p - pd)  # log2(n1), clamped so D | n1 and D | n2
    return DistPlan(n=n, d=num_devices, n1=1 << a, n2=1 << (p - a))


def _axis_size(mesh: Mesh, axis_names) -> int:
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    return math.prod(mesh.shape[a] for a in axis_names)


def _twiddle(i2g: jnp.ndarray, o1: jnp.ndarray, n: int):
    """Planar W_n^{i2g*o1} with exact pow2 modular exponent (see header)."""
    m = (i2g.astype(jnp.uint32)[:, None] * o1.astype(jnp.uint32)[None, :])
    m = m & jnp.uint32(n - 1)
    ang = (-2.0 * math.pi / n) * m.astype(jnp.float32)
    return jnp.cos(ang), jnp.sin(ang)


def build_distributed(n: int, mesh: Mesh, axis_names=("data", "model"), *,
                      impl: str = "matfft", natural_order: bool = True,
                      fuse_twiddle: bool = False,
                      interpret: bool | None = None,
                      layout: str = "zero_copy"):
    """Build the shard_map'd cross-device four-step for a length-n signal.

    Returns the shard-mapped function over planar (n,) global arrays; the
    caller (the planner) wraps it in ONE `jax.jit` and caches it.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    d = _axis_size(mesh, axis_names)
    plan = plan_distributed(n, d)
    n1, n2 = plan.n1, plan.n2
    n1l, n2l = n1 // d, n2 // d
    ax = tuple(axis_names)

    def local(xr_loc, xi_loc):
        # Device-local shard: contiguous rows of the (n1, n2) matrix.
        didx = lax.axis_index(ax)

        def a2a(a):  # global transpose: split cols, concat rows
            return lax.all_to_all(a, ax, split_axis=1, concat_axis=0,
                                  tiled=True)

        # ---- a2a #1: (n1l, n2) -> (n1, n2l): full columns arrive ----
        ar = a2a(xr_loc.reshape(n1l, n2))
        ai = a2a(xi_loc.reshape(n1l, n2))

        # ---- pass 1: FFT columns (length n1), batched over n2l ----
        # fft_cols folds the local transpose into the kernel's BlockSpec:
        # with layout="zero_copy" the (n1, n2l) shard is read column-strided
        # and the (n2l, n1) result written row-major, no `.T` copy in HBM.
        can_fuse = (fuse_twiddle and impl == "matfft"
                    and fft_plan.make_plan(n1).levels == 1)
        if can_fuse:
            # twiddle W_n^{i2_global*o1} fused into the kernel epilogue:
            # rows of this batch are i2-local, so the kernel's global row
            # offset is didx*n2l; the table is never materialized in HBM
            row_off = (didx * n2l).astype(jnp.int32).reshape(1)
            br, bi = fft_ex.fft_cols(ar, ai, impl=impl, interpret=interpret,
                                     global_twiddle=(n, row_off),
                                     layout=layout)
        else:
            ar, ai = fft_ex.fft_cols(ar, ai, impl=impl, interpret=interpret,
                                     layout=layout)
            # ar: (n2l, n1), rows = local i2, cols = o1
            # ---- twiddle W_n^{i2_global * o1}, computed on the fly ----
            i2g = didx * n2l + jnp.arange(n2l, dtype=jnp.uint32)
            tw_r, tw_i = _twiddle(i2g, jnp.arange(n1, dtype=jnp.uint32), n)
            br = ar * tw_r - ai * tw_i
            bi = ar * tw_i + ai * tw_r

        # ---- a2a #2: (n2l, n1) -> (n2, n1l): full rows arrive ----
        br, bi = a2a(br), a2a(bi)

        # ---- pass 2: FFT rows (length n2), batched over n1l ----
        cr, ci = fft_ex.fft_cols(br, bi, impl=impl, interpret=interpret,
                                 layout=layout)
        # cr: (n1l, n2), rows = local o1, cols = o2

        if not natural_order:
            return cr.reshape(-1), ci.reshape(-1)

        # ---- a2a #3: (n1l, n2) -> (n1, n2l), then o2-major flatten ----
        cr, ci = a2a(cr), a2a(ci)
        # (n1, n2l)[o1, o2_loc] -> out[o2*n1 + o1]: transpose then flatten.
        return cr.T.reshape(-1), ci.T.reshape(-1)

    spec = P(ax)
    # check_vma=False: pallas_call out_shapes do not carry vma metadata.
    return compat.shard_map(local, mesh=mesh, in_specs=(spec, spec),
                            out_specs=(spec, spec), check_vma=False)


def distributed_fft(xr: jnp.ndarray, xi: jnp.ndarray, mesh: Mesh,
                    axis_names=("data", "model"), *, impl: str = "matfft",
                    natural_order: bool = True, fuse_twiddle: bool = False,
                    interpret: bool | None = None,
                    layout: str = "zero_copy"):
    """Forward FFT of a single length-n planar signal sharded over ``mesh``.

    Args:
      xr, xi: (n,) float32 planes (global arrays; pjit/shard_map shards them
        along the flattened ``axis_names``).
      natural_order: if False, skip all_to_all #3 and return the transform
        in transposed (o1-major) block order — FFTW's TRANSPOSED_OUT, useful
        when a subsequent pointwise op + inverse FFT follows (convolution).
      layout: "zero_copy" folds the local `.T` at each pass boundary into
        the column-strided Pallas kernel (fft_cols) — the all_to_all
        already did the cross-device transpose, so no device-local
        transposed copy is materialized either; "copy" keeps the legacy
        materialized transposes (measured baseline).
    Returns planar (n,) arrays, sharded like the input.

    Thin wrapper over `repro.fft.plan(placement="distributed")`: repeat
    calls with the same spec hit the plan cache and reuse the compiled
    callable.
    """
    import repro.fft as fft_api
    p = fft_api.plan(kind="c2c", n=xr.shape[-1], batch_shape=(), mesh=mesh,
                     placement="distributed", axes=axis_names, impl=impl,
                     natural_order=natural_order, fuse_twiddle=fuse_twiddle,
                     interpret=interpret, layout=layout)
    return p.execute(xr, xi)


def distributed_ifft(xr, xi, mesh, axis_names=("data", "model"), **kw):
    """Inverse via conjugation identity, sharded like distributed_fft."""
    n = xr.shape[-1]
    yr, yi = distributed_fft(xr, -xi, mesh, axis_names, **kw)
    return yr / n, -yi / n
