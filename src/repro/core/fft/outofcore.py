"""Out-of-core four-step FFT: transforms larger than memory, streamed
through a `BlockStore` (the paper's >1TB headline scenario; EFFT,
arXiv:1409.5757).

The four-step factorization IS an out-of-core algorithm — under the
standard permuted-layout contract that saves the extra corner-turn
shuffles (FFTW-MPI's TRANSPOSED_IN/TRANSPOSED_OUT; the out-of-core
analogue of this repo's distributed natural_order=False convention):

  * the stored operand ``s`` is the signal in DECIMATED (corner-turned)
    layout — interpreting ``s`` as the row-major (n2, n1) matrix
    ``M[j2, j1] = s[j2*n1 + j1]``, the natural-order signal is
    ``x[j1*n2 + j2] = M[j2, j1]`` (i.e. ``x = T(s)`` with
    ``T(v) = v.reshape(n2, n1).T.ravel()``);
  * the emitted spectrum is in TRANSPOSED order:
    ``out[k1*n2 + k2] = X[k1 + n1*k2]`` where ``X = DFT_n(x)`` — the
    same operator again: ``out = T(X)``.

  A natural-layout operand costs exactly one extra storage shuffle each
  way (the pass-1 scatter with the FFT/twiddle skipped); it is NOT
  bundled here, because the decimated contract is what end-to-end
  spectral pipelines (filter in spectral order, transform back) want.

The algebra behind the two passes — split k = k1 + n1*k2, j = j1*n2 + j2
(k1, j1 in [0, n1)); then W_n^{j*k} factors with no cross term:

    X[k1 + n1*k2] = sum_{j2} W_n2^{j2*k2} * ( W_n^{j2*k1} * P[j2, k1] )
    P[j2, k1]     = sum_{j1} W_n1^{j1*k1} * M[j2, j1]

which streams in exactly two bounded passes plus ONE storage transpose:

  pass 1    each job reads t2 contiguous rows of M (one panel of
            t2*n1 complex samples), runs a batched length-n1 FFT through
            the cached plan, applies the global twiddle W_n^{j2*k1} in
            the same streamed job, and scatters the panel back as
            (t1, t2) tiles in k1-major order — the transposed-shuffle
            write. Job c is journaled DONE only after ALL of its tiles
            are atomically on disk, so a crash mid-shuffle re-runs only
            the incomplete jobs.
  pass 2    job r gathers its row-of-tiles into a (t1, n2) panel (tile
            CRCs verified against the shuffle journal), runs a batched
            length-n2 FFT, and writes one final offset-named output
            block: out[k1*n2 + k2] = X[k1 + n1*k2]. In-memory check:
            np.fft.fft(s.reshape(n2, n1).T.ravel()).reshape(n2, n1).T.

Memory never exceeds a bounded working set: the factorization picks the
panel widths t2 (pass 1) and t1 (pass 2) so that `WS_PANELS` concurrent
panels (prefetch + staging + inflight window + writeback) fit the caller's
``budget_bytes``; the stream executor's bounded queues enforce the bound
structurally. Both passes run through `StreamExecutor`
(core/pipeline/stream.py) — prefetch readers, async cached-plan launches,
writeback workers — under the shared `Manifest` journal (crash-resume, one
manifest per phase) and `RetryPolicy`/`FaultInjector` resilience wiring
(sites ``ooc.shuffle`` and ``ooc.pass2`` cover the new failure domains).

The analytic cost model extends the planner's: ``passes`` (2),
``io_bytes`` (4 x operand: read + shuffle-write + shuffle-read + write),
``shuffle_bytes`` (2 x operand), and ``working_set_bytes`` (the enforced
peak). benchmarks/bench_outofcore.py gates a 2^34-point transform on the
deterministic disk model and bitwise parity at directly-verifiable sizes.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from dataclasses import dataclass, field, replace
from pathlib import Path

import numpy as np

from repro.core.pipeline.blockstore import BlockStore, _atomic_write, _crc
from repro.core.pipeline.maponly import (FAILED, PENDING, JobConfig,
                                         JobStats, Manifest)
from repro.core.pipeline.records import block_of_segments
from repro.core.pipeline.stream import Decoded, StagingPool, StreamExecutor, \
    StreamTransform
from repro.core.resilience import verify as abft
from repro.core.resilience.faults import maybe_corrupt, maybe_fire
from repro.kernels.fft import plan as kplan

_C64 = 8  # bytes per interleaved complex64 sample

# concurrent panels the streamed passes can hold at once: reader prefetch
# + gathered staging + the inflight launch window + a writeback copy. The
# factorization sizes panels so WS_PANELS of them fit the budget; the
# executor's bounded queues make the bound structural, not advisory.
WS_PANELS = 4


def _near_square_split(n: int) -> tuple[int, int]:
    """n = n1 * n2, both pow2, near-square, each within the single-device
    plan maximum (MAX_LEAF**2 — the pass lengths run device-local)."""
    if not kplan.is_pow2(n) or n < 4:
        raise ValueError(f"out-of-core transform length must be a power of "
                         f"two >= 4, got n={n}")
    p = kplan.log2i(n)
    n1 = 1 << (p // 2)
    n2 = 1 << (p - p // 2)  # n2 >= n1
    max_local = kplan.MAX_LEAF ** 2
    if n2 > max_local:
        raise ValueError(
            f"out-of-core split n={n} needs pass lengths n1={n1}, n2={n2}, "
            f"but each pass runs a device-local plan capped at "
            f"MAX_LEAF**2={max_local}")
    return n1, n2


def _pow2_floor(x: int) -> int:
    return 1 << (x.bit_length() - 1) if x >= 1 else 0


@dataclass(frozen=True)
class OocPlan:
    """The pure out-of-core factorization + analytic cost model.

    Computable without a store (the dry-run path models 2^34+ transforms
    this way); `OutOfCorePlan` binds one to a concrete `BlockStore`.
    """

    n: int              # total transform points
    n1: int             # pass-1 FFT length (stored rows of M are length n1)
    n2: int             # pass-2 FFT length
    t2: int             # pass-1 panel height: stored rows per streamed job
    t1: int             # pass-2 panel height: spectrum rows per job
    budget_bytes: int   # caller's working-set cap the panels were sized to

    # ---------------- geometry ----------------
    @property
    def operand_bytes(self) -> int:
        return _C64 * self.n

    @property
    def pass1_jobs(self) -> int:
        return self.n2 // self.t2

    @property
    def pass2_jobs(self) -> int:
        return self.n1 // self.t1

    @property
    def pass1_panel_bytes(self) -> int:
        return _C64 * self.n1 * self.t2

    @property
    def pass2_panel_bytes(self) -> int:
        return _C64 * self.n2 * self.t1

    @property
    def tile_bytes(self) -> int:
        return _C64 * self.t1 * self.t2

    @property
    def tiles(self) -> int:
        return self.pass1_jobs * self.pass2_jobs

    # ---------------- analytic cost model ----------------
    @property
    def passes(self) -> int:
        return 2

    @property
    def io_bytes(self) -> int:
        """Total storage traffic: read input + write tiles + read tiles +
        write output — each exactly one operand, the four-step minimum."""
        return 4 * self.operand_bytes

    @property
    def shuffle_bytes(self) -> int:
        """Bytes crossing the transpose shuffle (tile write + read back)."""
        return 2 * self.operand_bytes

    @property
    def working_set_bytes(self) -> int:
        """The enforced peak host working set (WS_PANELS bounded panels)."""
        return WS_PANELS * max(self.pass1_panel_bytes, self.pass2_panel_bytes)

    @property
    def flops(self) -> float:
        """5 n log2 n, same convention as `ExecutablePlan.flops`."""
        return 5.0 * self.n * math.log2(self.n)

    def as_dict(self) -> dict:
        return {"n": self.n, "n1": self.n1, "n2": self.n2,
                "t1": self.t1, "t2": self.t2,
                "budget_bytes": self.budget_bytes,
                "operand_bytes": self.operand_bytes,
                "pass1_jobs": self.pass1_jobs, "pass2_jobs": self.pass2_jobs,
                "tiles": self.tiles, "tile_bytes": self.tile_bytes,
                "passes": self.passes, "io_bytes": self.io_bytes,
                "shuffle_bytes": self.shuffle_bytes,
                "working_set_bytes": self.working_set_bytes}


def factor_out_of_core(n: int, budget_bytes: int,
                       block_bytes: int | None = None,
                       panel_scale: int = 1) -> OocPlan:
    """Factor n = n1 * n2 and size the streaming panels against the budget.

    The memory-budget rule: WS_PANELS concurrent panels must fit, so
    t2 (pass-1 stored rows/job) is the largest power of two with
    WS_PANELS * 8*n1*t2 <= budget_bytes, and t1 (pass-2 spectrum
    rows/job) likewise against 8*n2*t1. When the operand store's
    ``block_bytes`` is given, t2 additionally aligns so each pass-1
    panel is a whole number of store blocks (jobs read block-granular,
    never split a block).

    ``panel_scale`` (pow2 >= 1) shrinks BOTH panel heights by that
    factor below the budget-maximal choice — the autotuner's OOC knob:
    smaller panels trade per-job overhead for earlier first-byte and a
    smaller resident set (repro.fft.tuner measures the trade on the
    deterministic disk model; panel_scale=1 is the analytic default).
    """
    scale = int(panel_scale)
    if scale < 1 or scale & (scale - 1):
        raise ValueError(
            f"panel_scale must be a power of two >= 1, got {panel_scale}")
    n1, n2 = _near_square_split(n)
    row_bytes = _C64 * n1
    t2 = _pow2_floor(min(budget_bytes // (WS_PANELS * row_bytes),
                         n2)) // scale
    if block_bytes is not None and t2 >= 1 \
            and (row_bytes * t2) % block_bytes:
        # a panel is row_bytes * 2^k: if the largest affordable k fails,
        # every smaller one has fewer factors of two and fails harder
        raise ValueError(
            f"store block_bytes={block_bytes} does not tile the pass-1 "
            f"panel ({row_bytes * t2} B = {t2} rows of {row_bytes} B); "
            f"ingest with a block size that divides the panel")
    t1 = _pow2_floor(min(budget_bytes // (WS_PANELS * _C64 * n2),
                         n1)) // scale
    if t2 < 1 or t1 < 1:
        if scale > 1:
            raise ValueError(
                f"panel_scale={scale} shrinks the streaming panels below "
                f"one row for n={n} under budget_bytes={budget_bytes}; "
                f"use a smaller scale")
        need = WS_PANELS * _C64 * max(n1, n2)
        raise ValueError(
            f"memory budget {budget_bytes} B cannot hold even one "
            f"single-column working set for n={n} (needs >= {need} B = "
            f"{WS_PANELS} panels of one length-{max(n1, n2)} line); raise "
            f"budget_bytes or shrink n")
    return OocPlan(n=n, n1=n1, n2=n2, t2=t2, t1=t1,
                   budget_bytes=budget_bytes)


# ---------------------------------------------------------------------------
# twiddle: W_n^{j2*k1} with exponents reduced mod n in EXACT integer
# arithmetic (uint64 products stay exact up to n = 2^34 and far beyond),
# then float64 angles -> float32 factors. Both the streamed pass and the
# in-memory reference call THIS function with the same global j2 indices,
# which is what makes streamed-vs-oracle comparisons bitwise.


def _twiddle_rows(j2_start: int, rows: int, n1: int,
                  n: int) -> tuple[np.ndarray, np.ndarray]:
    j2 = np.arange(j2_start, j2_start + rows, dtype=np.uint64)[:, None]
    k1 = np.arange(n1, dtype=np.uint64)[None, :]
    e = (j2 * k1) % np.uint64(n)  # exact: j2*k1 < n2*n1 = n <= 2^63
    ang = (-2.0 * np.pi / n) * e.astype(np.float64)
    return (np.cos(ang).astype(np.float32),
            np.sin(ang).astype(np.float32))


def _apply_twiddle(yr: np.ndarray, yi: np.ndarray, j2_start: int,
                   n: int) -> tuple[np.ndarray, np.ndarray]:
    """(yr + i*yi)[j2_local, k1] * W_n^{(j2_start+j2_local)*k1}, float32.

    Plain elementwise numpy (two mults + add/sub per plane, each correctly
    rounded) so the streamed chunks and the full-matrix oracle reduce to
    the identical per-element operation sequence — the bitwise invariant.
    """
    wr, wi = _twiddle_rows(j2_start, yr.shape[0], yr.shape[1], n)
    return yr * wr - yi * wi, yr * wi + yi * wr


# ---------------------------------------------------------------------------
# the shuffle journal: an append-only JSONL record of every pass-1 job's
# tile CRCs, fsync'd BEFORE the job can be journaled DONE in the phase-1
# manifest. DONE in the manifest therefore implies the job's tile integrity
# metadata is durable — pass 2 verifies every tile read against it.


class TileJournal:
    """Append-only (torn-tail tolerant) CRC journal for shuffle tiles.

    Under ``verify`` modes each record also carries the per-tile ENERGY
    (float64 sum of squares) measured just before the bytes were CRC'd —
    the ABFT side-channel: a CRC only proves the bytes on disk are the
    bytes that were written, the journaled energy lets pass 2 prove the
    values are the values pass 1 computed.
    """

    def __init__(self, path: os.PathLike):
        self.path = Path(path)
        self._lock = threading.Lock()
        self._crcs: dict[str, str] = {}
        self._energies: dict[str, float] = {}
        if self.path.exists():
            for line in self.path.read_text().splitlines():
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    break  # torn tail from a crash mid-append
                self._crcs.update(rec.get("crcs", {}))
                self._energies.update(rec.get("energies", {}))

    def record(self, job: int, crcs: dict[str, str],
               energies: dict[str, float] | None = None) -> None:
        rec: dict = {"job": job, "crcs": crcs}
        if energies:
            rec["energies"] = energies
        with self._lock:
            with open(self.path, "a") as f:
                f.write(json.dumps(rec) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._crcs.update(crcs)
            if energies:
                self._energies.update(energies)

    def crc(self, name: str) -> str | None:
        with self._lock:
            return self._crcs.get(name)

    def energy(self, name: str) -> float | None:
        with self._lock:
            return self._energies.get(name)


def _tile_name(r: int, c: int) -> str:
    return f"tile_{r:06d}_{c:06d}.bin"


class _IoCounter:
    """Thread-safe measured storage-traffic counters (vs the analytic
    model's `io_bytes`; reported by `OocStats.io`)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counts = {"input_read": 0, "shuffle_write": 0,
                       "shuffle_read": 0, "output_write": 0}

    def add(self, key: str, nbytes: int) -> None:
        with self._lock:
            self.counts[key] += nbytes

    def as_dict(self) -> dict:
        with self._lock:
            d = dict(self.counts)
        d["total"] = sum(d.values())
        return d


# ---------------------------------------------------------------------------
# phase-1 plumbing: a panel-granular reader over the operand store + the
# transposed-shuffle scatter writer


class _Pass1Store:
    """Presents the operand `BlockStore` re-blocked at pass-1 panel
    granularity for `StreamExecutor` (which only needs `read_block` +
    `write_output_block`): job c reads the blocks spanning stored rows
    [c*t2, (c+1)*t2) of M and the "output write" scatters the twiddled
    panel into (t1, t2) tiles in k1-major order — the transpose
    shuffle."""

    def __init__(self, store: BlockStore, f: OocPlan, journal: TileJournal,
                 io: _IoCounter, injector=None, verify: str = "off"):
        self.store = store
        self.f = f
        self.journal = journal
        self.io = io
        self.injector = injector
        self.verify = abft.check_mode(verify)
        panel = f.pass1_panel_bytes
        if store.total_bytes != f.operand_bytes:
            raise ValueError(
                f"store holds {store.total_bytes} B but the plan transforms "
                f"n={f.n} points = {f.operand_bytes} B")
        if panel % store.block_bytes:
            raise ValueError(
                f"pass-1 panel ({panel} B) is not a whole number of store "
                f"blocks ({store.block_bytes} B); re-ingest or re-factor")
        self.blocks_per_job = panel // store.block_bytes

    def read_block(self, index: int) -> bytes:
        g = self.blocks_per_job
        parts = [self.store.read_block(i)
                 for i in range(index * g, (index + 1) * g)]
        data = parts[0] if g == 1 else b"".join(parts)
        self.io.add("input_read", len(data))
        return data

    def write_output_block(self, out_dir: os.PathLike, index: int,
                           data: bytes) -> None:
        """The transposed-shuffle write: panel -> R tiles, k1-major order,
        each atomic; the job's CRC record is fsync-durable before return
        (and therefore before the manifest can mark the job DONE)."""
        f = self.f
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        panel = np.frombuffer(data, np.float32).reshape(f.t2, f.n1, 2)
        crcs = {}
        energies: dict[str, float] = {}
        e_panel = abft.energy(panel) if self.verify != "off" else None
        for r in range(f.pass2_jobs):
            tid = r * f.pass1_jobs + index
            maybe_fire(self.injector, "ooc.shuffle", tid)
            tile = np.ascontiguousarray(
                panel[:, r * f.t1:(r + 1) * f.t1].transpose(1, 0, 2))
            # silent-corruption checkpoint: a hit perturbs the tile BEFORE
            # the CRC is taken, so the journal faithfully records the
            # corrupt bytes — only the energy invariant below can tell
            (tile,), _ = maybe_corrupt(self.injector, "ooc.shuffle", tid,
                                       [tile])
            blob = tile.tobytes()
            name = _tile_name(r, index)
            _atomic_write(out / name, blob)
            crcs[name] = _crc(blob)
            if self.verify != "off":
                energies[name] = abft.energy(tile)
            self.io.add("shuffle_write", len(blob))
        if self.verify != "off":
            # scatter is a pure rearrangement: the tiles' energies must
            # resum to the panel's (float64, positive terms — no
            # cancellation), so the tolerance is summation-order noise,
            # far tighter than the FFT Parseval bound
            e_tiles = math.fsum(energies.values())
            tol = 1e-9 * (e_panel + 1e-30)
            if abs(e_tiles - e_panel) > tol:
                raise abft.fail("ooc.shuffle", index, check="scatter_energy",
                                expected=e_panel, got=e_tiles, tol=tol)
        self.journal.record(index, crcs, energies or None)


class _Pass1Transform(StreamTransform):
    """Streamed pass 1: batched length-n1 FFT of a stored-row panel
    through the cached plan (exactly the full-panel plan — panels are
    uniform, so stream.py's two-plans-per-job guarantee collapses to
    one), twiddled in the same streamed job, encoded for the shuffle
    scatter."""

    def __init__(self, f: OocPlan, impl: str, verify: str = "off"):
        self.f = f
        self.impl = impl
        self.verify = abft.check_mode(verify)
        self._pool: StagingPool | None = None

    def open(self, pool_capacity: int, stop: threading.Event) -> None:
        self._pool = StagingPool(pool_capacity, stop)

    def close(self) -> None:
        self._pool = None

    def decode(self, data: bytes, index: int) -> Decoded:
        inter = np.frombuffer(data, np.float32).reshape(self.f.t2,
                                                        self.f.n1, 2)
        e_in = abft.energy(inter) if self.verify != "off" else None
        return Decoded(index, (inter[..., 0], inter[..., 1]),
                       rows=self.f.t2, key=None,  # one job per launch
                       energy=e_in)

    def gather(self, group):
        (d,) = group
        shape = (self.f.t2, self.f.n1)
        if self._pool is not None:
            re_b, im_b = self._pool.acquire(shape)
        else:  # transform used outside an executor (tests)
            re_b, im_b = (np.empty(shape, np.float32) for _ in range(2))
        try:
            np.copyto(re_b, d.arrays[0])
            np.copyto(im_b, d.arrays[1])
        except BaseException:
            self.discard((re_b, im_b))
            raise
        return re_b, im_b

    def launch(self, batch):
        import repro.fft as fft_api
        re_b, im_b = batch
        p = fft_api.plan(kind="c2c", n=self.f.n1,
                         batch_shape=(self.f.t2,), impl=self.impl,
                         verify=self.verify)
        return p.execute_async(re_b, im_b, donate=True), batch

    def realize(self, handle):
        (yr, yi), batch = handle
        try:
            return np.asarray(yr), np.asarray(yi)
        finally:
            self.discard(batch)  # unconditional: no leaked staging

    def discard(self, batch) -> None:
        if self._pool is not None:
            self._pool.release(batch[0].shape, batch)

    def verify_member(self, host, row0: int, d: Decoded) -> None:
        # Parseval over the realized panel: the pre-twiddle FFT output
        # must carry n1 x the input energy recorded at decode
        if self.verify == "off" or d.energy is None:
            return
        yr, yi = host
        abft.check_parseval(d.energy, abft.energy(yr, yi), self.f.n1,
                            "f32", site="ooc.pass1", index=d.index)

    def encode(self, host, row0: int, d: Decoded) -> bytes:
        # the global twiddle W_n^{j2*k1}, applied in the same streamed job
        # (no extra storage pass; j2 offset comes from the job index)
        yr, yi = host
        tr, ti = _apply_twiddle(yr, yi, d.index * self.f.t2, self.f.n)
        return block_of_segments(tr, ti)


# ---------------------------------------------------------------------------
# phase-2 plumbing: row-of-tiles gather + final offset-named output writes


class _Pass2Store:
    """Job r's "block" is its row of C shuffle tiles, CRC-verified against
    the journal and assembled into one (t1, n2) panel; the output side
    writes the final spectrum block at offset r * t1*n2*8 (offset-named,
    so the standard offset-ordered getmerge concatenation applies)."""

    def __init__(self, inter_dir: os.PathLike, f: OocPlan,
                 journal: TileJournal, io: _IoCounter, injector=None,
                 verify: str = "off"):
        self.inter = Path(inter_dir)
        self.f = f
        self.journal = journal
        self.io = io
        self.injector = injector
        self.verify = abft.check_mode(verify)

    def read_block(self, index: int) -> bytes:
        f = self.f
        tiles = []
        for c in range(f.pass1_jobs):
            maybe_fire(self.injector, "ooc.pass2",
                       index * f.pass1_jobs + c)
            name = _tile_name(index, c)
            blob = (self.inter / name).read_bytes()
            want = self.journal.crc(name)
            if want is not None and _crc(blob) != want:
                raise IOError(
                    f"shuffle tile {name} failed its journaled CRC "
                    f"(pass-2 job {index})")
            self.io.add("shuffle_read", len(blob))
            tile = np.frombuffer(blob, np.float32).reshape(f.t1, f.t2, 2)
            if self.verify != "off":
                # re-measure the ABFT side-channel: the tile's energy must
                # match what pass 1 journaled (same values, same float64
                # reduction — summation-order noise only)
                want_e = self.journal.energy(name)
                if want_e is not None:
                    got_e = abft.energy(tile)
                    tol = 1e-9 * (want_e + 1e-30)
                    if abs(got_e - want_e) > tol:
                        raise abft.fail("ooc.pass2", index,
                                        check="tile_energy", tile=name,
                                        expected=want_e, got=got_e, tol=tol)
            tiles.append(tile)
        return np.concatenate(tiles, axis=1).tobytes()

    def write_output_block(self, out_dir: os.PathLike, index: int,
                           data: bytes) -> None:
        maybe_fire(self.injector, "blockstore.write", index)
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        offset = index * self.f.pass2_panel_bytes
        _atomic_write(out / f"block_{offset:016d}.bin", data)
        self.io.add("output_write", len(data))


class _Pass2Transform(StreamTransform):
    """Streamed pass 2: batched length-n2 FFT of each (t1, n2) panel; the
    result rows ARE final spectrum rows (transposed order), no twiddle."""

    def __init__(self, f: OocPlan, impl: str, verify: str = "off"):
        self.f = f
        self.impl = impl
        self.verify = abft.check_mode(verify)
        self._pool: StagingPool | None = None

    def open(self, pool_capacity: int, stop: threading.Event) -> None:
        self._pool = StagingPool(pool_capacity, stop)

    def close(self) -> None:
        self._pool = None

    def decode(self, data: bytes, index: int) -> Decoded:
        inter = np.frombuffer(data, np.float32).reshape(self.f.t1,
                                                        self.f.n2, 2)
        e_in = abft.energy(inter) if self.verify != "off" else None
        return Decoded(index, (inter[..., 0], inter[..., 1]),
                       rows=self.f.t1, key=None, energy=e_in)

    def gather(self, group):
        (d,) = group
        shape = (self.f.t1, self.f.n2)
        if self._pool is not None:
            re_b, im_b = self._pool.acquire(shape)
        else:
            re_b, im_b = (np.empty(shape, np.float32) for _ in range(2))
        try:
            np.copyto(re_b, d.arrays[0])
            np.copyto(im_b, d.arrays[1])
        except BaseException:
            self.discard((re_b, im_b))
            raise
        return re_b, im_b

    def launch(self, batch):
        import repro.fft as fft_api
        re_b, im_b = batch
        p = fft_api.plan(kind="c2c", n=self.f.n2,
                         batch_shape=(self.f.t1,), impl=self.impl,
                         verify=self.verify)
        return p.execute_async(re_b, im_b, donate=True), batch

    def realize(self, handle):
        (yr, yi), batch = handle
        try:
            return np.asarray(yr), np.asarray(yi)
        finally:
            self.discard(batch)

    def discard(self, batch) -> None:
        if self._pool is not None:
            self._pool.release(batch[0].shape, batch)

    def verify_member(self, host, row0: int, d: Decoded) -> None:
        if self.verify == "off" or d.energy is None:
            return
        yr, yi = host
        abft.check_parseval(d.energy, abft.energy(yr, yi), self.f.n2,
                            "f32", site="ooc.pass2", index=d.index)

    def encode(self, host, row0: int, d: Decoded) -> bytes:
        return block_of_segments(*host)


# ---------------------------------------------------------------------------


@dataclass
class OocStats:
    """Per-run observability: phase stats + measured I/O vs the model."""

    pass1: JobStats | None = None
    pass2: JobStats | None = None
    pass1_attempts: int = 0  # attempts THIS run (0 on a post-pass-1 resume)
    pass2_attempts: int = 0
    wall_s: float = 0.0
    io: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        def job(s):
            return None if s is None else {
                "blocks_done": s.blocks_done, "attempts": s.attempts,
                "retries": s.retries, "batches": s.batches,
                "stage_s": {k: round(v, 4) for k, v in s.stage_s.items()},
                "wall_s": round(s.wall_s, 4)}
        return {"pass1": job(self.pass1), "pass2": job(self.pass2),
                "pass1_attempts": self.pass1_attempts,
                "pass2_attempts": self.pass2_attempts,
                "wall_s": round(self.wall_s, 4), "io": self.io}


class OutOfCorePlan:
    """An executable out-of-core transform bound to a `BlockStore`.

    Build via ``repro.fft.plan(kind="c2c", n=..., placement="out_of_core",
    store=..., work_dir=..., budget_bytes=...)``. Not process-cached (it
    carries live store/directory state); the per-pass FFT plans it launches
    ARE the cached `ExecutablePlan`s, so repeat jobs retrace nothing.

    Layout under ``work_dir``:
      tiles/                 the shuffle tiles (intermediate, 1 operand)
      out/                   final offset-named spectrum blocks
      pass1_manifest.json    phase-1 job journal (crash-resume)
      pass2_manifest.json    phase-2 job journal
      tiles.jsonl            append-only tile CRC journal
    """

    def __init__(self, factors: OocPlan, store: BlockStore,
                 work_dir: os.PathLike, impl: str = "ref",
                 config: JobConfig | None = None, verify: str = "off"):
        self.factors = factors
        self.store = store
        self.impl = impl
        # "abft" on the out-of-core path adds nothing over "parseval":
        # panels launch as single uniform jobs (no coalesced groups to
        # disambiguate), so both modes run the energy-invariant chain —
        # decode energy -> realize Parseval -> scatter conservation ->
        # journaled tile energies -> pass-2 re-checks
        self.verify = abft.check_mode(verify)
        self.work_dir = Path(work_dir)
        self.work_dir.mkdir(parents=True, exist_ok=True)
        self.tiles_dir = self.work_dir / "tiles"
        self.out_dir = self.work_dir / "out"
        cfg = config or JobConfig(
            readers=2, writers=2, coalesce=1, inflight=2, speculation=False)
        # coalesce is forced to 1: each job is already a full-panel batch,
        # and the working-set bound assumes one panel per pipeline slot
        self.cfg = replace(cfg, coalesce=1)
        self.injector = self.cfg.injector
        self.journal = TileJournal(self.work_dir / "tiles.jsonl")
        self.io = _IoCounter()

    # convenience mirrors of the factorization's cost model
    @property
    def n(self) -> int:
        return self.factors.n

    @property
    def passes(self) -> int:
        return self.factors.passes

    @property
    def io_bytes(self) -> int:
        return self.factors.io_bytes

    @property
    def shuffle_bytes(self) -> int:
        return self.factors.shuffle_bytes

    @property
    def working_set_bytes(self) -> int:
        return self.factors.working_set_bytes

    @property
    def operand_bytes(self) -> int:
        return self.factors.operand_bytes

    @property
    def flops(self) -> float:
        return self.factors.flops

    # ------------------------------------------------------------------
    def _run_phase(self, which: int) -> JobStats:
        f = self.factors
        if which == 1:
            store = _Pass1Store(self.store, f, self.journal, self.io,
                                self.injector, verify=self.verify)
            transform = _Pass1Transform(f, self.impl, verify=self.verify)
            manifest = Manifest(self.work_dir / "pass1_manifest.json",
                                f.pass1_jobs)
            out_dir = self.tiles_dir
        else:
            store = _Pass2Store(self.tiles_dir, f, self.journal, self.io,
                                self.injector, verify=self.verify)
            transform = _Pass2Transform(f, self.impl, verify=self.verify)
            manifest = Manifest(self.work_dir / "pass2_manifest.json",
                                f.pass2_jobs)
            out_dir = self.out_dir
        # a resumed run is a NEW job invocation: blocks journaled FAILED
        # (retry budget exhausted in a previous run) get a fresh budget —
        # only DONE is durable across runs (RUNNING already demotes to
        # PENDING inside Manifest's crash replay)
        for i, t in manifest.tasks.items():
            if t.status == FAILED:
                manifest.update(i, status=PENDING, error=None)
        stats = JobStats()
        StreamExecutor(store, out_dir, transform, self.cfg, manifest,
                       stats).run()
        return stats

    def run_pass1(self) -> JobStats:
        """Phase 1 + shuffle only (checkpointable; resume re-runs nothing
        once every job is journaled DONE)."""
        return self._run_phase(1)

    def run_pass2(self) -> JobStats:
        """Phase 2 only; requires the shuffle to be complete."""
        m1 = Manifest(self.work_dir / "pass1_manifest.json",
                      self.factors.pass1_jobs)
        incomplete = self.factors.pass1_jobs - len(m1.done())
        m1.close()
        if incomplete:
            raise RuntimeError(
                f"pass 2 needs a complete shuffle: {incomplete} pass-1 "
                f"job(s) not DONE in {self.work_dir / 'pass1_manifest.json'}"
                f"; run run_pass1()/execute() first")
        return self._run_phase(2)

    def execute(self) -> OocStats:
        """Run (or resume) the full transform. Each phase's `Manifest`
        replays its journal first, so a crash mid-shuffle re-runs only the
        pass-1 jobs whose tiles never all landed, and a crash mid-pass-2
        re-runs only unfinished pass-2 jobs — completed pass-1 work is
        never redone."""
        t0 = time.monotonic()
        s = OocStats()
        s.pass1 = self.run_pass1()
        s.pass1_attempts = s.pass1.attempts
        s.pass2 = self.run_pass2()
        s.pass2_attempts = s.pass2.attempts
        s.wall_s = time.monotonic() - t0
        s.io = self.io.as_dict()
        return s

    def merge(self, dest: os.PathLike) -> int:
        """Offset-ordered concat of the final spectrum blocks (getmerge)."""
        f = self.factors
        expect = [f"block_{r * f.pass2_panel_bytes:016d}.bin"
                  for r in range(f.pass2_jobs)]
        missing = [n for n in expect if not (self.out_dir / n).exists()]
        if missing:
            raise IOError(f"merge: {len(missing)} output blocks missing "
                          f"(first: {missing[0]}); run execute() first")
        total = 0
        with open(dest, "wb") as out:
            for name in expect:
                data = (self.out_dir / name).read_bytes()
                out.write(data)
                total += len(data)
        return total


def plan_out_of_core(n: int, store: BlockStore, work_dir: os.PathLike,
                     budget_bytes: int, impl: str = "ref",
                     config: JobConfig | None = None,
                     verify: str = "off",
                     panel_scale: int = 1) -> OutOfCorePlan:
    """Factor + bind: the `placement="out_of_core"` entry point."""
    factors = factor_out_of_core(n, budget_bytes,
                                 block_bytes=store.block_bytes,
                                 panel_scale=panel_scale)
    return OutOfCorePlan(factors, store, work_dir, impl=impl, config=config,
                         verify=verify)


# ---------------------------------------------------------------------------
# layout helpers + the in-memory oracle


def corner_turn(v: np.ndarray, factors: OocPlan) -> np.ndarray:
    """The layout operator T: decimated storage order <-> natural order.

    T maps the stored operand to the natural-order signal AND the
    natural-order spectrum to the emitted (transposed-order) output —
    ``out == T(np.fft.fft(T(s)))``. In-memory only (tests / the bench's
    numpy cross-check at verifiable sizes); ``v`` is (n,) complex-like or
    (n, k) with trailing component axes carried along.
    """
    f = factors
    return np.ascontiguousarray(
        v.reshape(f.n2, f.n1, *v.shape[1:]).swapaxes(0, 1)).reshape(v.shape)


def reference_out_of_core(sig: np.ndarray, factors: OocPlan,
                          impl: str = "ref") -> bytes:
    """In-memory oracle: the SAME decomposition as the streamed path —
    same panel-shaped cached plans (bit-for-bit launches: a (t2, n1)
    batch here and in pass 1 is the same executable), same twiddle
    helper, same encode — on interleaved (n, 2) float32, without the
    storage round-trips. Returns merged output bytes in the transposed
    spectral order out[k1*n2 + k2]; the streamed result must match it
    BITWISE."""
    import repro.fft as fft_api
    f = factors
    m = sig.reshape(f.n2, f.n1, 2)
    p1 = fft_api.plan(kind="c2c", n=f.n1, batch_shape=(f.t2,), impl=impl)
    tr = np.empty((f.n2, f.n1), np.float32)
    ti = np.empty((f.n2, f.n1), np.float32)
    for c in range(f.pass1_jobs):
        rows = slice(c * f.t2, (c + 1) * f.t2)
        yr, yi = p1.execute(np.ascontiguousarray(m[rows, :, 0]),
                            np.ascontiguousarray(m[rows, :, 1]))
        tr[rows], ti[rows] = _apply_twiddle(
            np.asarray(yr), np.asarray(yi), c * f.t2, f.n)
    tr = np.ascontiguousarray(tr.T)  # the shuffle: (n1, n2), k1-major
    ti = np.ascontiguousarray(ti.T)
    p2 = fft_api.plan(kind="c2c", n=f.n2, batch_shape=(f.t1,), impl=impl)
    parts = []
    for r in range(f.pass2_jobs):
        rows = slice(r * f.t1, (r + 1) * f.t1)
        zr, zi = p2.execute(np.ascontiguousarray(tr[rows]),
                            np.ascontiguousarray(ti[rows]))
        parts.append(block_of_segments(np.asarray(zr), np.asarray(zi)))
    return b"".join(parts)
