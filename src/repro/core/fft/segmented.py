"""Segmented (map-only) batched FFT — the paper's actual regime.

The paper never computes a transform longer than one block: a 1 TB file is
a *batch* of independent FFT-size segments, and each 512 MB block is FFT'd
in place by one map task with zero inter-task communication (numReducers=0).

The TPU-native translation: shard the segment batch across the mesh and run
the level-0/1 kernels per shard. There are NO collectives in this path —
`out_shardings == in_shardings` — which is the whole point of the paper's
map-only design, and what the dry-run verifies (the compiled HLO for this
op contains zero collective ops; see tests/test_distributed_fft.py).

`build_segmented` is the strategy builder the `repro.fft` planner consumes:
it returns the shard_map'd kernel plus the jit shardings, and the planner
owns the jit — so the compiled callable lives in the process-level plan
cache instead of being rebuilt per call. `segmented_fft` remains as the
historical entry point, now a thin wrapper that builds-and-executes a plan.
"""

from __future__ import annotations

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.fft import executors as fft_ex


def build_segmented(mesh: Mesh, batch_axes, *, kind: str = "c2c",
                    shape=None, impl: str = "matfft",
                    interpret: bool | None = None,
                    layout: str = "zero_copy"):
    """Build the map-only shard_map kernel for a (batch, *shape) segment
    batch.

    Returns ``(inner, in_shardings, out_shardings)``; the caller (the
    planner) wraps ``inner`` in ONE `jax.jit` and caches it. kind="c2c"
    maps planar (xr, xi) -> (yr, yi); kind="r2c" maps real x -> the planar
    one-sided spectrum, still with zero collectives. ``shape`` is the
    per-segment transform shape (None = 1-D over the last axis); 2-D
    segments — batches of images — shard exactly like 1-D ones: only the
    batch axis is split, each device runs the N-D axis passes locally.
    """
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    ndim = 1 if shape is None else len(shape)
    spec = P(batch_axes, *([None] * ndim))
    sharding = NamedSharding(mesh, spec)

    if kind == "c2c":
        if ndim == 1:
            def f(xr, xi):
                return fft_ex.fft(xr, xi, impl=impl, interpret=interpret,
                                  layout=layout)
        else:
            def f(xr, xi):
                return fft_ex.fftn(xr, xi, shape, impl=impl,
                                   interpret=interpret, layout=layout)
        in_specs, out_specs = (spec, spec), (spec, spec)
        in_sh, out_sh = (sharding, sharding), (sharding, sharding)
    elif kind == "r2c":
        if ndim == 1:
            def f(x):
                return fft_ex.rfft(x, impl=impl, interpret=interpret,
                                   layout=layout)
        else:
            def f(x):
                return fft_ex.rfftn(x, shape, impl=impl,
                                    interpret=interpret, layout=layout)
        in_specs, out_specs = (spec,), (spec, spec)
        in_sh, out_sh = (sharding,), (sharding, sharding)
    else:
        raise ValueError(f"unknown kind {kind!r} for segmented placement")

    # shard_map (not bare pjit): XLA cannot partition through an opaque
    # pallas_call, so auto-sharding would insert all-gathers — the exact
    # failure mode the paper's map-only design exists to avoid. shard_map
    # pins one program instance per shard; the compiled HLO has zero
    # collectives (asserted in tests).
    inner = compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    return inner, in_sh, out_sh


def segmented_fft(xr, xi, mesh: Mesh, batch_axes=("pod", "data", "model"), *,
                  impl: str = "matfft", interpret: bool | None = None,
                  layout: str = "zero_copy"):
    """Batched FFT of (batch, n) planar arrays, batch sharded over the mesh.

    Each device transforms its own rows — one "map task" per shard, no
    reduce phase. Lengths up to MAX_LEAF**2 per segment (level-1 local
    four-step, zero-copy by default); longer single transforms need
    distributed placement.

    Thin wrapper over `repro.fft.plan(placement="segmented")`: repeat calls
    with the same batch/length/mesh hit the plan cache and reuse the
    compiled callable.
    """
    import repro.fft as fft_api
    p = fft_api.plan(kind="c2c", n=xr.shape[-1], batch_shape=xr.shape[:-1],
                     mesh=mesh, placement="segmented", axes=batch_axes,
                     impl=impl, interpret=interpret, layout=layout)
    return p.execute(xr, xi)
