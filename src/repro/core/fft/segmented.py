"""Segmented (map-only) batched FFT — the paper's actual regime.

The paper never computes a transform longer than one block: a 1 TB file is
a *batch* of independent FFT-size segments, and each 512 MB block is FFT'd
in place by one map task with zero inter-task communication (numReducers=0).

The TPU-native translation: shard the segment batch across the mesh and run
the level-0/1 kernels per shard. There are NO collectives in this path —
`out_shardings == in_shardings` — which is the whole point of the paper's
map-only design, and what the dry-run verifies (the compiled HLO for this
op contains zero collective ops; see tests/test_distributed_fft.py).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.kernels.fft import ops as fft_ops


def segmented_fft(xr, xi, mesh: Mesh, batch_axes=("pod", "data", "model"), *,
                  impl: str = "matfft", interpret: bool | None = None,
                  layout: str = "zero_copy"):
    """Batched FFT of (batch, n) planar arrays, batch sharded over the mesh.

    Each device transforms its own rows — one "map task" per shard, no
    reduce phase. Lengths up to MAX_LEAF**2 per segment (level-1 local
    four-step, zero-copy by default); longer single transforms need
    distributed_fft.
    """
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    spec = P(batch_axes, None)
    sharding = NamedSharding(mesh, spec)

    def f(xr, xi):
        return fft_ops.fft(xr, xi, impl=impl, interpret=interpret,
                           layout=layout)

    # shard_map (not bare pjit): XLA cannot partition through an opaque
    # pallas_call, so auto-sharding would insert all-gathers — the exact
    # failure mode the paper's map-only design exists to avoid. shard_map
    # pins one program instance per shard; the compiled HLO has zero
    # collectives (asserted in tests).
    inner = compat.shard_map(f, mesh=mesh, in_specs=(spec, spec),
                             out_specs=(spec, spec), check_vma=False)
    return jax.jit(inner, in_shardings=(sharding, sharding),
                   out_shardings=(sharding, sharding))(xr, xi)
