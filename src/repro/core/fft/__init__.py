from repro.core.fft.distributed import distributed_fft, plan_distributed
from repro.core.fft.segmented import segmented_fft

__all__ = ["distributed_fft", "plan_distributed", "segmented_fft"]
