"""Spectral ops built on the FFT stack: STFT, FFT convolution, SpectralMixer.

These are the framework-level consumers of the paper's technique:
  * ``stft`` — the signal-analyst workload the paper targets (spectrograms
    over huge capture files), and the real math behind whisper's log-mel
    frontend (which the assigned config stubs at the embedding level);
  * ``fft_conv`` — long causal convolution via FFT (only valid for
    time-INVARIANT kernels; RWKV6/Mamba2 decays are data-dependent, hence
    inapplicable there — DESIGN.md §5);
  * ``fft_conv2d`` — 2-D FFT convolution for image filtering, the first
    consumer of the axis-generic ``shape=(n0, n1)`` plans (DESIGN.md §9);
  * ``SpectralMixer`` — FNet-style token mixing, the optional beyond-paper
    integration of the FFT into transformer blocks (ablation in examples/).

Every transform goes through the `repro.fft` plan-and-execute facade
(DESIGN.md §6): the r2c/c2c plans behind a given frame/pad length are
resolved and compiled once in the process-level plan cache, so a
spectrogram job over thousands of identical blocks pays plan construction
exactly once — the paper's amortized-`cufftPlanMany` property.
"""

from __future__ import annotations

import functools
import math

import jax.numpy as jnp
import numpy as np

import repro.fft as fft_api


@functools.lru_cache(maxsize=None)
def _hann(frame: int) -> np.ndarray:
    return (0.5 - 0.5 * np.cos(2 * math.pi * np.arange(frame) / frame)).astype(np.float32)


def frame_signal(x: jnp.ndarray, frame: int, hop: int) -> jnp.ndarray:
    """(..., t) -> (..., n_frames, frame) by strided framing (drop tail)."""
    t = x.shape[-1]
    n_frames = 1 + (t - frame) // hop
    idx = jnp.arange(n_frames)[:, None] * hop + jnp.arange(frame)[None, :]
    return x[..., idx]


def stft(x: jnp.ndarray, frame: int = 1024, hop: int = 512, *,
         window: bool = True, impl: str = "matfft",
         interpret: bool | None = None):
    """Short-time Fourier transform -> planar (..., n_frames, frame//2+1).

    Frames are real, so this rides the rfft fast path: half-length packed
    transform + fused untangle, ~half the flops/bytes of fft()+slice.
    """
    frames = frame_signal(x.astype(jnp.float32), frame, hop)
    if window:
        frames = frames * jnp.asarray(_hann(frame))
    p = fft_api.plan(kind="r2c", n=frame, batch_shape=frames.shape[:-1],
                     impl=impl, interpret=interpret)
    return p.execute_real(frames)


def power_spectrogram(x, frame=1024, hop=512, **kw):
    sr, si = stft(x, frame, hop, **kw)
    return sr * sr + si * si


def _next_pow2(n: int) -> int:
    return 1 << max(1, (n - 1).bit_length())


def fft_conv(x: jnp.ndarray, kernel: jnp.ndarray, *, impl: str = "matfft",
             interpret: bool | None = None) -> jnp.ndarray:
    """Causal 1-D convolution of (..., t) with (t_k,) via FFT, O(t log t).

    Zero-padded to the next power of two >= t + t_k so the circular
    convolution equals the linear one on the first t samples.
    """
    t = x.shape[-1]
    tk = kernel.shape[-1]
    n = _next_pow2(t + tk)
    xp = jnp.pad(x.astype(jnp.float32), [(0, 0)] * (x.ndim - 1) + [(0, n - t)])
    kp = jnp.pad(kernel.astype(jnp.float32), (0, n - tk))
    # Both operands are real: multiply one-sided rfft spectra (conjugate
    # symmetry survives the product) and invert with the r2c plan's
    # inverse — every transform runs at half length.
    px = fft_api.plan(kind="r2c", n=n, batch_shape=xp.shape[:-1],
                      impl=impl, interpret=interpret)
    pk = fft_api.plan(kind="r2c", n=n, batch_shape=kp.shape[:-1],
                      impl=impl, interpret=interpret)
    xr, xi = px.execute_real(xp)
    kr, ki = pk.execute_real(kp)
    pr = xr * kr - xi * ki
    pi = xr * ki + xi * kr
    yr = px.execute_inverse(pr, pi)
    return yr[..., :t]


def fft_conv2d(x: jnp.ndarray, kernel: jnp.ndarray, *, impl: str = "matfft",
               interpret: bool | None = None) -> jnp.ndarray:
    """2-D convolution of (..., h, w) images with a (kh, kw) filter via the
    2-D FFT plans — the paper's image-filtering workload (arXiv:1505.08019)
    on the axis-generic transform core, O(hw log hw).

    Both operands are real, so both transforms ride the r2c fast path
    (packed contiguous axis, deferred N-D untangle): multiply the
    one-sided 2-D spectra — conjugate symmetry survives the pointwise
    product — and invert with the r2c plan's inverse. Zero-padded to the
    next powers of two >= h + kh, w + kw so the circular convolution
    equals the linear one on the leading h x w window (the "causal"
    top-left alignment, matching `fft_conv`).
    """
    h, w = x.shape[-2:]
    kh, kw = kernel.shape[-2:]
    n0, n1 = _next_pow2(h + kh), _next_pow2(w + kw)
    xp = jnp.pad(x.astype(jnp.float32),
                 [(0, 0)] * (x.ndim - 2) + [(0, n0 - h), (0, n1 - w)])
    kp = jnp.pad(kernel.astype(jnp.float32),
                 [(0, 0)] * (kernel.ndim - 2) + [(0, n0 - kh), (0, n1 - kw)])
    px = fft_api.plan(kind="r2c", shape=(n0, n1), batch_shape=xp.shape[:-2],
                      impl=impl, interpret=interpret)
    pk = fft_api.plan(kind="r2c", shape=(n0, n1), batch_shape=kp.shape[:-2],
                      impl=impl, interpret=interpret)
    xr, xi = px.execute_real(xp)
    kr, ki = pk.execute_real(kp)
    pr = xr * kr - xi * ki
    pi = xr * ki + xi * kr
    yr = px.execute_inverse(pr, pi)
    return yr[..., :h, :w]


def spectral_mixer(x: jnp.ndarray, *, impl: str = "matfft",
                   interpret: bool | None = None) -> jnp.ndarray:
    """FNet token mixing: Re(FFT_seq(FFT_hidden(x))) for (..., seq, d).

    Requires seq and d to be powers of two in kernel mode; callers pad.
    """
    z = jnp.zeros_like(x)
    p_hidden = fft_api.plan(kind="c2c", n=x.shape[-1],
                            batch_shape=x.shape[:-1], impl=impl,
                            interpret=interpret)
    hr, hi = p_hidden.execute(x, z)  # over d
    hr = jnp.swapaxes(hr, -1, -2)
    hi = jnp.swapaxes(hi, -1, -2)
    p_seq = fft_api.plan(kind="c2c", n=hr.shape[-1],
                         batch_shape=hr.shape[:-1], impl=impl,
                         interpret=interpret)
    sr, _ = p_seq.execute(hr, hi)  # over seq
    return jnp.swapaxes(sr, -1, -2)
