"""Record layout: one block == one record == a batch of FFT segments.

The paper's custom InputFormat hands a whole HDFS block to a map task as a
single Record; inside the task the block is reinterpreted as a batch of
FFT-size segments ("the partitioning of FFT segments can be done inside
memory using CUFFT's batched FFT plan"). These helpers do exactly that
reinterpretation, for the paper's interleaved complex64 sample layout.
"""

from __future__ import annotations

import numpy as np


def segments_of_block(data: bytes, fft_len: int) -> tuple[np.ndarray, np.ndarray]:
    """bytes -> planar (nseg, fft_len) float32 re/im.

    Layout: interleaved single-precision complex (re0, im0, re1, im1, ...),
    the JCUFFT/CUFFT default the paper uses. The block must contain a whole
    number of segments (the splitter guarantees block_bytes % (8*fft_len)==0).
    """
    flat = np.frombuffer(data, dtype=np.float32)
    seg_floats = 2 * fft_len
    if flat.size % seg_floats:
        raise ValueError(
            f"block of {flat.size} floats is not a whole number of "
            f"{fft_len}-point complex segments")
    inter = flat.reshape(-1, fft_len, 2)
    return np.ascontiguousarray(inter[..., 0]), np.ascontiguousarray(inter[..., 1])


def block_of_segments(re: np.ndarray, im: np.ndarray) -> bytes:
    """planar (nseg, fft_len) -> interleaved complex64 bytes."""
    inter = np.stack([re, im], axis=-1).astype(np.float32)
    return inter.tobytes()


def segment_block_bytes(fft_len: int, segments_per_block: int) -> int:
    """Block size holding exactly ``segments_per_block`` complex64 segments."""
    return 8 * fft_len * segments_per_block
