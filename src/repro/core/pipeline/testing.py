"""Deterministic storage models for benchmarks and tests.

CI scratch space is effectively tmpfs, where a block "read" is a
page-cache memcpy — there is no latency for a pipeline to hide. The
paper's regime is the opposite: spinning-disk HDFS at ~100-250 MB/s per
spindle against a fast device. `ThrottledStore` restores that regime
deterministically: every block read/write sleeps bytes / disk_mb_s,
identically for every execution mode, so overlap gates measure exactly
what they claim (the stream executor hides I/O latency behind compute;
a serial loop cannot). The sleep releases the GIL, so it is hideable by
overlap — exactly like real disk waits — and deterministic across runs
and runners.

Shared here (instead of copy-pasted per benchmark) so bench_pipeline,
bench_outofcore, bench_chaos, and the test suite model the same disk.
"""

from __future__ import annotations

import time

from repro.core.pipeline.blockstore import BlockStore

DISK_MB_S = 250  # modeled per-spindle disk bandwidth (paper-era HDFS)


class ThrottledStore(BlockStore):
    """Benchmark/test store modeling paper-era disk latency: every block
    read/write sleeps nbytes / (disk_mb_s MB/s) on top of the tmpfs
    access. Subclass or assign ``disk_mb_s`` to model other spindles."""

    disk_mb_s: float = DISK_MB_S

    def read_block(self, index: int, verify: bool = True) -> bytes:
        data = super().read_block(index, verify)
        time.sleep(len(data) / (self.disk_mb_s * (1 << 20)))
        return data

    def write_output_block(self, out_dir, index: int, data) -> None:
        time.sleep(len(data) / (self.disk_mb_s * (1 << 20)))
        super().write_output_block(out_dir, index, data)
