"""Map-only job runner: Hadoop's task tracker, minus the reduce phase.

Faithful pieces (paper §III):
  * zero reducers — each map attempt writes its output block directly to the
    output directory, named by input offset, so getmerge is order-correct;
  * one block per task, batched FFT inside the task.

Large-scale-runnability pieces (Hadoop semantics the paper relies on
implicitly, implemented explicitly here):
  * crash-consistent job manifest: every state transition is journaled; a
    restarted job re-runs only non-DONE blocks (checkpoint/restart);
  * bounded retries per block with failure isolation (one poisoned block
    cannot take down the job until its retry budget is spent);
  * speculative execution: when a running attempt exceeds
    ``straggler_factor`` x the median completed-task latency, a duplicate
    attempt is launched; block writes are atomic + idempotent so whichever
    attempt finishes first wins and the loser's write is a harmless replace;
  * worker pool == "servers": thread workers model the paper's S servers
    (JAX jit'd compute releases the GIL, so threads genuinely overlap I/O
    with compute the way Hadoop overlaps map waves).
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from pathlib import Path
from statistics import median
from typing import Callable

from repro.core.pipeline.blockstore import BlockStore

PENDING, RUNNING, DONE, FAILED = "PENDING", "RUNNING", "DONE", "FAILED"


@dataclass
class JobConfig:
    workers: int = 4
    max_retries: int = 3
    straggler_factor: float = 3.0
    speculation: bool = True
    min_completed_for_speculation: int = 3
    poll_interval_s: float = 0.02


@dataclass
class TaskState:
    index: int
    status: str = PENDING
    attempts: int = 0
    started_at: float | None = None
    finished_at: float | None = None
    speculated: bool = False
    error: str | None = None


class Manifest:
    """Crash-consistent per-block task journal (atomic JSON rewrites)."""

    def __init__(self, path: Path, num_blocks: int):
        self.path = Path(path)
        self._lock = threading.Lock()
        if self.path.exists():
            doc = json.loads(self.path.read_text())
            self.tasks = {int(k): TaskState(**v) for k, v in doc.items()}
            for t in self.tasks.values():  # RUNNING at crash time -> retry
                if t.status == RUNNING:
                    t.status = PENDING
        else:
            self.tasks = {i: TaskState(i) for i in range(num_blocks)}
            self._flush()

    def _flush(self) -> None:
        doc = {k: vars(v) for k, v in self.tasks.items()}
        fd, tmp = tempfile.mkstemp(dir=self.path.parent, prefix=".mtmp_")
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)

    def update(self, index: int, **fields) -> None:
        with self._lock:
            t = self.tasks[index]
            for k, v in fields.items():
                setattr(t, k, v)
            self._flush()

    def pending(self) -> list[int]:
        return [i for i, t in self.tasks.items() if t.status == PENDING]

    def done(self) -> list[int]:
        return [i for i, t in self.tasks.items() if t.status == DONE]


@dataclass
class JobStats:
    blocks_done: int = 0
    attempts: int = 0
    retries: int = 0
    speculative_launches: int = 0
    speculative_wins: int = 0
    wall_s: float = 0.0
    task_seconds: list[float] = field(default_factory=list)


class MapOnlyJob:
    """Runs ``map_fn(block_bytes, index) -> bytes`` over every store block."""

    def __init__(self, store: BlockStore, out_dir: os.PathLike,
                 map_fn: Callable[[bytes, int], bytes],
                 config: JobConfig | None = None,
                 job_dir: os.PathLike | None = None):
        self.store = store
        self.out_dir = Path(out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.map_fn = map_fn
        self.cfg = config or JobConfig()
        job_dir = Path(job_dir) if job_dir else self.out_dir
        job_dir.mkdir(parents=True, exist_ok=True)
        self.manifest = Manifest(job_dir / "job_manifest.json",
                                 len(store.blocks))
        self.stats = JobStats()
        self._done_latencies: list[float] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _attempt(self, index: int) -> tuple[int, float]:
        t0 = time.monotonic()
        data = self.store.read_block(index)
        out = self.map_fn(data, index)
        self.store.write_output_block(self.out_dir, index, out)
        return index, time.monotonic() - t0

    def run(self) -> JobStats:
        cfg = self.cfg
        t_start = time.monotonic()
        todo = self.manifest.pending()
        inflight: dict[Future, tuple[int, float, bool]] = {}
        speculated: set[int] = set()
        completed: set[int] = set(self.manifest.done())

        with ThreadPoolExecutor(max_workers=cfg.workers) as pool:

            def launch(i: int, is_spec: bool) -> None:
                self.manifest.update(i, status=RUNNING,
                                     started_at=time.monotonic(),
                                     speculated=is_spec)
                fut = pool.submit(self._attempt, i)
                inflight[fut] = (i, time.monotonic(), is_spec)
                self.stats.attempts += 1
                if is_spec:
                    self.stats.speculative_launches += 1

            for i in todo:
                launch(i, False)

            while inflight:
                done_futs, _ = wait(list(inflight), timeout=cfg.poll_interval_s,
                                    return_when=FIRST_COMPLETED)
                now = time.monotonic()

                # --- straggler speculation ---
                if (cfg.speculation
                        and len(self._done_latencies)
                        >= cfg.min_completed_for_speculation):
                    med = median(self._done_latencies)
                    for fut, (i, started, is_spec) in list(inflight.items()):
                        if (not is_spec and i not in speculated
                                and i not in completed
                                and now - started > cfg.straggler_factor * med):
                            speculated.add(i)
                            launch(i, True)

                for fut in done_futs:
                    i, started, is_spec = inflight.pop(fut)
                    if i in completed:
                        continue  # a twin already won; idempotent write
                    err = fut.exception()
                    if err is None:
                        _, dt = fut.result()
                        completed.add(i)
                        self._done_latencies.append(dt)
                        self.stats.task_seconds.append(dt)
                        self.stats.blocks_done += 1
                        if is_spec:
                            self.stats.speculative_wins += 1
                        self.manifest.update(i, status=DONE,
                                             finished_at=time.monotonic())
                    else:
                        st = self.manifest.tasks[i]
                        attempts = st.attempts + 1
                        if attempts >= cfg.max_retries:
                            self.manifest.update(i, status=FAILED,
                                                 attempts=attempts,
                                                 error=repr(err))
                            raise RuntimeError(
                                f"block {i} failed {attempts} times"
                            ) from err
                        self.stats.retries += 1
                        self.manifest.update(i, status=PENDING,
                                             attempts=attempts,
                                             error=repr(err))
                        launch(i, False)

        self.stats.wall_s = time.monotonic() - t_start
        return self.stats

    def merge(self, dest: os.PathLike) -> int:
        return self.store.getmerge(self.out_dir, dest)
