"""Map-only job runner: Hadoop's task tracker, minus the reduce phase.

Faithful pieces (paper §III):
  * zero reducers — each map attempt writes its output block directly to the
    output directory, named by input offset, so getmerge is order-correct;
  * one block per task, batched FFT inside the task.

Large-scale-runnability pieces (Hadoop semantics the paper relies on
implicitly, implemented explicitly here):
  * crash-consistent job manifest: every state transition is journaled; a
    restarted job re-runs only non-DONE blocks (checkpoint/restart);
  * bounded retries per block with failure isolation (one poisoned block
    cannot take down the job until its retry budget is spent);
  * speculative execution: when a running attempt exceeds
    ``straggler_factor`` x the median completed-task latency, a duplicate
    attempt is launched; block writes are atomic + idempotent so whichever
    attempt finishes first wins and the loser's write is a harmless replace;
  * worker pool == "servers": thread workers model the paper's S servers
    (JAX jit'd compute releases the GIL, so threads genuinely overlap I/O
    with compute the way Hadoop overlaps map waves).
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from pathlib import Path
from statistics import median
from typing import Callable

from repro.core.pipeline.blockstore import BlockStore
from repro.core.resilience.faults import maybe_corrupt_bytes, maybe_fire
from repro.core.resilience.retry import RetryPolicy

PENDING, RUNNING, DONE, FAILED = "PENDING", "RUNNING", "DONE", "FAILED"


@dataclass
class JobConfig:
    workers: int = 4
    max_retries: int = 3  # legacy knob: feeds the default RetryPolicy
    straggler_factor: float = 3.0
    speculation: bool = True
    min_completed_for_speculation: int = 3
    poll_interval_s: float = 0.02
    # --- streaming path knobs (MapOnlyJob(pipelined=True) / stream.py) ---
    readers: int = 2      # prefetch/decode threads
    writers: int = 2      # writeback (D2H + encode + write) threads
    coalesce: int = 1     # same-shaped blocks fused into one device batch
    inflight: int = 2     # launched-but-unrealized batch window
    # --- resilience (core/resilience; DESIGN.md §10) ---
    # ONE retry policy for both execution paths. None = the legacy
    # immediate-retry behaviour bounded by max_retries; pass a RetryPolicy
    # for backoff + per-block deadlines. Backoff sleeps run on the
    # coordinator/dispatcher thread through policy.sleep (injectable).
    retry: RetryPolicy | None = None
    injector: object = None  # FaultInjector for deterministic chaos runs
    # ABFT hook for the serial path (DESIGN.md §13): called as
    # verify_fn(block_bytes_in, out_bytes, index) after the map function
    # (and after the corruption checkpoint); raise SilentCorruption to
    # quarantine the attempt back into the retry budget. None = no check.
    verify_fn: Callable | None = None

    def retry_policy(self) -> RetryPolicy:
        return self.retry or RetryPolicy(max_attempts=self.max_retries)


@dataclass
class TaskState:
    index: int
    status: str = PENDING
    attempts: int = 0
    started_at: float | None = None
    finished_at: float | None = None
    speculated: bool = False
    error: str | None = None


class Manifest:
    """Crash-consistent per-block task journal (append-only, O(1)/transition).

    Layout: line-delimited JSON — one ``snapshot`` record (the full task
    table) followed by one ``update`` line per state transition. A
    transition appends + fsyncs ~100 bytes instead of rewriting the whole
    table (the seed behaviour was O(blocks) bytes per transition, so
    O(blocks²) per job — measurable manifest stalls past a few thousand
    blocks). Crash-restart semantics are unchanged: on open the journal is
    replayed in order (a torn final line from a crash mid-append is
    dropped; every earlier line was fsync-durable), RUNNING tasks demote to
    PENDING, and the journal is compacted back to a single fresh snapshot.
    Legacy single-object manifests (the pre-journal format) replay too.
    """

    def __init__(self, path: Path, num_blocks: int):
        self.path = Path(path)
        self._lock = threading.Lock()
        self._fh = None
        self.appends = 0  # transitions journaled by THIS process (stats)
        if self.path.exists():
            self.tasks = self._replay(self.path)
            for t in self.tasks.values():  # RUNNING at crash time -> retry
                if t.status == RUNNING:
                    t.status = PENDING
        else:
            self.tasks = {i: TaskState(i) for i in range(num_blocks)}
        self._compact()

    @staticmethod
    def _replay(path: Path) -> dict[int, TaskState]:
        tasks: dict[int, TaskState] = {}
        for line in path.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail from a crash mid-append; rest is durable
            if rec.get("type") == "update":
                t = tasks[rec["index"]]
                for k, v in rec["fields"].items():
                    setattr(t, k, v)
            elif rec.get("type") == "snapshot":
                tasks = {t["index"]: TaskState(**t) for t in rec["tasks"]}
            else:  # legacy format: one JSON object {index: task_fields}
                tasks = {int(k): TaskState(**v) for k, v in rec.items()}
        return tasks

    def _compact(self) -> None:
        """Rewrite as snapshot-only (atomic), then reopen for appending."""
        if self._fh is not None:
            self._fh.close()
        snap = json.dumps({"type": "snapshot",
                           "tasks": [vars(t) for t in self.tasks.values()]})
        fd, tmp = tempfile.mkstemp(dir=self.path.parent, prefix=".mtmp_")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(snap + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            # crash-mid-compact: the journal at self.path is untouched
            # (os.replace is all-or-nothing), so a reopen replays the SAME
            # task states; just don't leak the tmp snapshot
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self._fh = open(self.path, "a")

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def update(self, index: int, **fields) -> None:
        with self._lock:
            t = self.tasks[index]
            for k, v in fields.items():
                setattr(t, k, v)
            if self._fh is None:  # reopened after close(): keep appending
                self._fh = open(self.path, "a")
            self._fh.write(json.dumps(
                {"type": "update", "index": index, "fields": fields}) + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self.appends += 1

    def pending(self) -> list[int]:
        return [i for i, t in self.tasks.items() if t.status == PENDING]

    def done(self) -> list[int]:
        return [i for i, t in self.tasks.items() if t.status == DONE]


@dataclass
class JobStats:
    blocks_done: int = 0
    attempts: int = 0
    retries: int = 0
    speculative_launches: int = 0
    speculative_wins: int = 0
    wall_s: float = 0.0
    task_seconds: list[float] = field(default_factory=list)
    # streaming path: per-stage clock totals (read/h2d/compute/d2h/write)
    # and coalescing counters; empty/zero on the serial path
    stage_s: dict[str, float] = field(default_factory=dict)
    batches: int = 0
    coalesced_blocks: int = 0
    # blocks whose retry budget was exhausted this run: one structured
    # {"index", "attempts", "error"} record each (the RuntimeError the job
    # raises chains the last underlying exception as __cause__)
    failed_blocks: list[dict] = field(default_factory=list)


class MapOnlyJob:
    """Runs ``map_fn(block_bytes, index) -> bytes`` over every store block."""

    def __init__(self, store: BlockStore, out_dir: os.PathLike,
                 map_fn: Callable[[bytes, int], bytes] | None = None,
                 config: JobConfig | None = None,
                 job_dir: os.PathLike | None = None,
                 pipelined: bool = False, transform=None):
        if map_fn is None and transform is None:
            raise ValueError("need map_fn (serial / pipelined) or "
                             "transform (pipelined)")
        if transform is not None and not pipelined:
            raise ValueError("transform= requires pipelined=True")
        self.store = store
        self.out_dir = Path(out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.map_fn = map_fn
        self.pipelined = pipelined
        self.transform = transform
        self.cfg = config or JobConfig()
        job_dir = Path(job_dir) if job_dir else self.out_dir
        job_dir.mkdir(parents=True, exist_ok=True)
        self.manifest = Manifest(job_dir / "job_manifest.json",
                                 len(store.blocks))
        self.stats = JobStats()
        self._done_latencies: list[float] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _attempt(self, index: int) -> tuple[int, float]:
        t0 = time.monotonic()
        maybe_fire(self.cfg.injector, "maponly.attempt", index)
        data = self.store.read_block(index)
        out = self.map_fn(data, index)
        # silent-corruption checkpoint: past the CRC-verified read and the
        # map function, so only the ABFT verify hook below can see it
        out = maybe_corrupt_bytes(self.cfg.injector, "maponly.attempt",
                                  index, out)
        if self.cfg.verify_fn is not None:
            self.cfg.verify_fn(data, out, index)
        self.store.write_output_block(self.out_dir, index, out)
        return index, time.monotonic() - t0

    def run(self) -> JobStats:
        if self.pipelined:
            # the overlapped stream executor (stream.py): same manifest /
            # retry / speculation semantics, staged instead of lump-serial
            from repro.core.pipeline.stream import (MapFnTransform,
                                                    StreamExecutor)
            transform = self.transform or MapFnTransform(self.map_fn)
            return StreamExecutor(self.store, self.out_dir, transform,
                                  self.cfg, self.manifest, self.stats).run()
        cfg = self.cfg
        t_start = time.monotonic()
        try:
            return self._run_serial(cfg, t_start)
        finally:
            self.manifest.close()  # fd hygiene; reopens on next update

    def _run_serial(self, cfg: JobConfig, t_start: float) -> JobStats:
        todo = self.manifest.pending()
        inflight: dict[Future, tuple[int, float, bool]] = {}
        speculated: set[int] = set()
        completed: set[int] = set(self.manifest.done())
        policy = cfg.retry_policy()
        # per-block deadline clock + jitter chain (policy state); attempt
        # COUNTS stay in the manifest so they survive crash-restarts
        first_started: dict[int, float] = {}
        retry_states: dict = {}

        with ThreadPoolExecutor(max_workers=cfg.workers) as pool:

            def launch(i: int, is_spec: bool) -> None:
                first_started.setdefault(i, time.monotonic())
                self.manifest.update(i, status=RUNNING,
                                     started_at=time.monotonic(),
                                     speculated=is_spec)
                fut = pool.submit(self._attempt, i)
                inflight[fut] = (i, time.monotonic(), is_spec)
                self.stats.attempts += 1
                if is_spec:
                    self.stats.speculative_launches += 1

            for i in todo:
                launch(i, False)

            while inflight:
                done_futs, _ = wait(list(inflight), timeout=cfg.poll_interval_s,
                                    return_when=FIRST_COMPLETED)
                now = time.monotonic()

                # --- straggler speculation ---
                if (cfg.speculation
                        and len(self._done_latencies)
                        >= cfg.min_completed_for_speculation):
                    med = median(self._done_latencies)
                    for fut, (i, started, is_spec) in list(inflight.items()):
                        if (not is_spec and i not in speculated
                                and i not in completed
                                and now - started > cfg.straggler_factor * med):
                            speculated.add(i)
                            launch(i, True)

                for fut in done_futs:
                    i, started, is_spec = inflight.pop(fut)
                    if i in completed:
                        continue  # a twin already won; idempotent write
                    err = fut.exception()
                    if err is None:
                        _, dt = fut.result()
                        completed.add(i)
                        self._done_latencies.append(dt)
                        self.stats.task_seconds.append(dt)
                        self.stats.blocks_done += 1
                        if is_spec:
                            self.stats.speculative_wins += 1
                        self.manifest.update(i, status=DONE,
                                             finished_at=time.monotonic())
                    else:
                        st = self.manifest.tasks[i]
                        attempts = st.attempts + 1
                        elapsed = now - first_started.get(i, now)
                        if not policy.should_retry(attempts, elapsed, err):
                            self.manifest.update(i, status=FAILED,
                                                 attempts=attempts,
                                                 error=repr(err))
                            self.stats.failed_blocks.append(
                                {"index": i, "attempts": attempts,
                                 "error": repr(err)})
                            raise RuntimeError(
                                f"block {i} failed {attempts} times"
                            ) from err
                        self.stats.retries += 1
                        self.manifest.update(i, status=PENDING,
                                             attempts=attempts,
                                             error=repr(err))
                        retry_states.setdefault(
                            i, policy.new_state()).backoff()
                        launch(i, False)

        self.stats.wall_s = time.monotonic() - t_start
        return self.stats

    def merge(self, dest: os.PathLike) -> int:
        return self.store.getmerge(self.out_dir, dest)
