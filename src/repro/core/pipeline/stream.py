"""Streaming overlapped block pipeline: the paper's map-wave/I/O overlap,
made explicit instead of emergent.

The serial `MapOnlyJob` path runs read -> decode -> H2D -> execute ->
block_until_ready -> D2H -> encode -> write per block, so the device idles
during every byte of I/O and each block pays a full dispatch round-trip.
This module restructures the job as a staged stream (EFFT, arXiv:1409.5757
— double-buffered streaming hides disk/transfer behind compute; and
arXiv:2202.12756 — batch many transforms per launch):

  read     reader threads: block I/O + crc verify + zero-copy decode
           (strided views over the block bytes). The bounded decoded
           queue is the prefetch back-pressure — readers block when the
           device side lags, capping host memory however far I/O could
           run ahead.
  h2d      the single dispatcher coalesces up to `coalesce` same-shaped
           blocks into ONE device batch (the `cufftPlanMany` amortization:
           one cached plan at batch coalesce x segments_per_block, plus one
           remainder-tail plan), gathering them into reusable preallocated
           staging buffers (`StagingPool`) that feed the async launch.
  compute  `plan.execute_async` — unrealized device arrays, NO
           block_until_ready anywhere in the hot path. The dispatcher keeps
           at most `inflight` launched batches outstanding (a semaphore
           released by the writeback stage once a batch's D2H completes):
           when the window is full, dispatch stalls until the OLDEST
           in-flight batch realizes — that window boundary is the only
           sync point in the pipeline.
  d2h      writeback workers realize device results (np.asarray) while the
           dispatcher is already launching later batches.
  write    same workers: per-block encode + atomic offset-named writes.

Retry / speculation / manifest semantics match `MapOnlyJob`: every
transition journaled (RUNNING at dispatch into the pipeline, DONE after
the block's output write, PENDING again on retry), bounded per-block retry
budgets, and straggler speculation — a block whose attempt exceeds
``straggler_factor`` x the median completed latency is re-injected as a
duplicate attempt; atomic idempotent writes make whichever finishes first
the winner. `MapOnlyJob(pipelined=True)` routes here.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from dataclasses import dataclass
from statistics import median
from typing import Any, Callable

import numpy as np

from repro.core.pipeline.blockstore import BlockStore
from repro.core.pipeline.maponly import (DONE, FAILED, PENDING, RUNNING,
                                         JobConfig, JobStats, Manifest)
from repro.core.pipeline.records import block_of_segments
from repro.core.resilience import verify as abft
from repro.core.resilience.faults import (corrupt_salt, maybe_fire,
                                          perturb_array)

STAGES = ("read", "h2d", "compute", "d2h", "write")


class _Stop(Exception):
    """Internal: pipeline is shutting down (fatal error elsewhere)."""


class StagingPool:
    """Bounded pool of reusable host staging buffers, keyed by shape.

    Holds the preallocated batch buffers the dispatcher gathers into
    (`SegmentFFTTransform.gather`). ``acquire`` blocks when ``capacity``
    buffer sets are outstanding, bounding staging memory at
    O(capacity x batch) regardless of input size; a set is released back
    only once its batch has been realized (device provably done), which is
    what makes input donation / zero-copy host aliasing safe.
    """

    def __init__(self, capacity: int, stop: threading.Event):
        self.capacity = capacity
        self._stop = stop
        self._cv = threading.Condition()
        self._free: dict[tuple, list] = {}
        self._outstanding = 0

    def acquire(self, shape: tuple, count: int = 2):
        """Return ``count`` float32 arrays of ``shape`` (re/im planes)."""
        with self._cv:
            while self._outstanding >= self.capacity:
                if self._stop.is_set():
                    raise _Stop
                self._cv.wait(timeout=0.05)
            self._outstanding += 1
            free = self._free.get(shape)
            if free:
                return free.pop()
        try:
            return tuple(np.empty(shape, np.float32) for _ in range(count))
        except BaseException:  # allocation failed: give the slot back
            with self._cv:
                self._outstanding -= 1
                self._cv.notify()
            raise

    def release(self, shape: tuple, bufs) -> None:
        with self._cv:
            self._outstanding -= 1
            self._free.setdefault(shape, []).append(bufs)
            self._cv.notify()

    def wake_all(self) -> None:
        with self._cv:
            self._cv.notify_all()


@dataclass
class Decoded:
    """One decoded block waiting in the dispatcher's coalesce group.

    ``arrays`` must be cheap views (the block bytes themselves are the
    prefetch memory); pooled staging is acquired in ``gather``, never
    here, so dropping a Decoded needs no cleanup.
    """
    index: int
    arrays: tuple          # host views consumed by gather()/launch()
    rows: int              # batch rows this block contributes
    key: Any               # coalesce group key (None = never coalesce)
    energy: float | None = None  # input energy at decode (CRC-clean
    #                              bytes), consumed by the Parseval check


class StreamTransform:
    """decode / launch / realize / encode hooks for `StreamExecutor`.

    ``launch`` must be asynchronous (return unrealized device values);
    ``realize`` is the only place a sync may happen. Blocks whose ``key``
    matches are coalesced into one ``launch`` group, so all hooks must be
    thread-safe: decode runs on reader threads, launch on the dispatcher,
    realize/encode on writeback workers.
    """

    def open(self, pool_capacity: int, stop: threading.Event) -> None:
        """Called once before streaming starts (allocate staging here)."""

    def decode(self, data: bytes, index: int) -> Decoded:
        raise NotImplementedError

    def gather(self, group: list[Decoded]):
        """Host-side batch assembly (the h2d stage clock). After this
        returns, the group's staging buffers may be reused."""
        return group

    def launch(self, batch):
        raise NotImplementedError

    def realize(self, handle):
        raise NotImplementedError

    def discard(self, batch) -> None:
        """Release a gathered batch that will never launch (failure path);
        must be safe to call on any successful `gather` result."""

    def close(self) -> None:
        """Called once when streaming ends (release pools/executors)."""

    def verify_group(self, host, group: list[Decoded]) -> None:
        """ABFT invariants over a whole realized batch (e.g. the linearity
        checksum row). Runs on writeback workers AFTER the corruption
        checkpoint; raising `SilentCorruption` quarantines every member
        back into the retry path."""

    def verify_member(self, host, row0: int, d: Decoded) -> None:
        """Per-block invariant (e.g. Parseval vs the energy recorded at
        decode). Raising quarantines just this member."""

    def encode(self, host, row0: int, d: Decoded) -> bytes:
        raise NotImplementedError


class MapFnTransform(StreamTransform):
    """Adapter: a classic ``map_fn(bytes, index) -> bytes`` map task.

    No coalescing (opaque bytes have no batchable shape). ``launch``
    submits ``map_fn`` to a small compute pool and returns the future, so
    the dispatcher never blocks on a map task — read/compute/write all
    overlap, and a hung ``map_fn`` still leaves the dispatcher free to
    speculate a twin attempt (matching the serial path's semantics).
    ``realize`` (the writeback stage) is where the future resolves.

    Known limit: a PERMANENTLY hung ``map_fn`` strands its (non-daemon)
    pool thread — ``run()`` still returns via the twin and ``close()``
    won't block (``shutdown(wait=False)``), but interpreter exit joins
    the stuck thread. Twin rescue also has a capacity bound: each hung
    attempt pins one inflight-window slot and one writeback worker until
    shutdown, so the stream survives up to min(inflight, writers) - 1
    SIMULTANEOUSLY hung blocks — the analogue of the serial path, which
    survives hung < workers (and, worse, never returns from ``run()``
    when they persist, blocked in pool shutdown). Size ``inflight`` /
    ``writers`` above the expected straggler count; a truly hung task
    needs a process-level timeout either way.
    """

    def __init__(self, map_fn: Callable[[bytes, int], bytes]):
        self.map_fn = map_fn
        self._pool: ThreadPoolExecutor | None = None
        self._stop: threading.Event | None = None

    def open(self, pool_capacity: int, stop: threading.Event) -> None:
        self._pool = ThreadPoolExecutor(max_workers=pool_capacity)
        self._stop = stop

    def close(self) -> None:
        if self._pool is not None:
            # wait=False: a genuinely hung map task must not hang close
            self._pool.shutdown(wait=False)
            self._pool = None

    def decode(self, data: bytes, index: int) -> Decoded:
        return Decoded(index=index, arrays=(data,), rows=1, key=None)

    def launch(self, batch):
        (d,) = batch
        if self._pool is None:  # transform used outside an executor
            return self.map_fn(d.arrays[0], d.index)
        return self._pool.submit(self.map_fn, d.arrays[0], d.index)

    def realize(self, handle):
        if isinstance(handle, Future):
            # stop-aware wait: when the job shuts down (e.g. a twin won
            # and the hung primary is abandoned) writeback must not block
            # shutdown on a future that will never resolve
            while True:
                try:
                    return handle.result(timeout=0.1)
                except FuturesTimeout:
                    if self._stop is not None and self._stop.is_set():
                        raise _Stop
        return handle

    def encode(self, host, row0: int, d: Decoded) -> bytes:
        return host


class SegmentFFTTransform(StreamTransform):
    """The paper's workload: each block is a batch of complex FFT segments.

    decode is zero-copy (strided re/im views of the raw block bytes);
    gather deinterleaves the whole group straight INTO a preallocated
    reusable batch staging buffer (`np.concatenate(..., out=)` — exactly
    one host copy per plane, the same copy the serial path pays for
    `ascontiguousarray`); launch fires the cached plan's `execute_async`
    on that buffer. Same-shaped groups reuse exactly one plan; the
    remainder tail keys a second — the plan-cache key includes
    `batch_shape`, so coalescing changes it by design (DESIGN.md §7).

    A staging buffer returns to the pool only in `realize`, i.e. after the
    device is provably done with it — this is what makes `donate=True`
    (and JAX CPU's zero-copy host-buffer aliasing) safe: the memory is
    never rewritten while a launched batch may still read or own it.

    ``verify`` (DESIGN.md §13): "parseval" records each block's input
    energy at decode (the bytes are CRC-clean there) and checks the
    realized spectrum's energy against it per member — detection
    localizes to one block, so only that block retries. "abft" instead
    appends ONE seeded checksum row to every gathered batch — its
    transform must equal the weighted combination of the batch rows'
    transforms (linearity), checked group-wide before encode; it catches
    corruption the energy check cannot (e.g. permutations) at the cost
    of group-granular quarantine. The extra row rides the same two plans
    per key (full -> rows+1, tail -> tail+1), so the <=2-plans-per-key
    coalescing property is preserved.
    """

    def __init__(self, fft_len: int, impl: str = "matfft",
                 donate: bool = True, verify: str = "off"):
        self.fft_len = fft_len
        self.impl = impl
        self.donate = donate
        self.verify = abft.check_mode(verify)
        self._pool: StagingPool | None = None

    def open(self, pool_capacity: int, stop: threading.Event) -> None:
        self._pool = StagingPool(pool_capacity, stop)

    def decode(self, data: bytes, index: int) -> Decoded:
        flat = np.frombuffer(data, dtype=np.float32)
        if flat.size % (2 * self.fft_len):
            raise ValueError(
                f"block {index}: {flat.size} floats is not a whole number "
                f"of {self.fft_len}-point complex segments")
        inter = flat.reshape(-1, self.fft_len, 2)
        shape = inter.shape[:2]
        # views, not copies: the block bytes waiting in the decode queue
        # ARE the prefetch buffer; the deinterleave happens in gather
        # decode energy feeds the per-member Parseval check; in abft mode
        # the group checksum row is the (stronger) invariant, so skip the
        # per-member energy passes entirely — they were the dominant
        # verification cost (one full read of every plane, twice)
        e_in = abft.energy(flat) if self.verify == "parseval" else None
        return Decoded(index, (inter[..., 0], inter[..., 1]),
                       rows=shape[0], key=shape, energy=e_in)

    def gather(self, group: list[Decoded]):
        rows = sum(d.rows for d in group)
        extra = 1 if self.verify == "abft" else 0
        shape = (rows + extra, self.fft_len)
        if self._pool is not None:
            re_b, im_b = self._pool.acquire(shape)
        else:  # transform used outside an executor (tests)
            re_b = np.empty(shape, np.float32)
            im_b = np.empty(shape, np.float32)
        try:
            np.concatenate([d.arrays[0] for d in group], axis=0,
                           out=re_b[:rows])
            np.concatenate([d.arrays[1] for d in group], axis=0,
                           out=im_b[:rows])
            if extra:
                w = abft.checksum_weights(rows, seed=rows)
                re_b[rows] = w @ re_b[:rows]
                im_b[rows] = w @ im_b[:rows]
        except BaseException:  # never leak the acquired set
            self.discard((re_b, im_b))
            raise
        return re_b, im_b

    def launch(self, batch):
        import repro.fft as fft_api
        re_b, im_b = batch
        p = fft_api.plan(kind="c2c", n=self.fft_len,
                         batch_shape=re_b.shape[:-1], impl=self.impl,
                         verify=self.verify)
        return p.execute_async(re_b, im_b, donate=self.donate), batch

    def realize(self, handle):
        (yr, yi), batch = handle
        try:
            return np.asarray(yr), np.asarray(yi)  # D2H: the window sync
        finally:
            # async dispatch surfaces device errors HERE, so the release
            # must be unconditional or each transient failure leaks a set
            # until the pool starves the dispatcher
            self.discard(batch)

    def discard(self, batch) -> None:
        if self._pool is not None:  # device done -> staging reusable
            self._pool.release(batch[0].shape, batch)

    def verify_group(self, host, group: list[Decoded]) -> None:
        if self.verify != "abft":
            return
        rows = sum(d.rows for d in group)
        w = abft.checksum_weights(rows, seed=rows)
        abft.check_checksum(host, w, self.fft_len, site="stream.realize",
                            index=group[0].index,
                            blocks=[d.index for d in group])

    def verify_member(self, host, row0: int, d: Decoded) -> None:
        if self.verify == "off" or d.energy is None:
            return
        yr, yi = host
        e_out = abft.energy(yr[row0:row0 + d.rows], yi[row0:row0 + d.rows])
        abft.check_parseval(d.energy, e_out, self.fft_len,
                            site="stream.realize", index=d.index)

    def encode(self, host, row0: int, d: Decoded) -> bytes:
        yr, yi = host
        return block_of_segments(yr[row0:row0 + d.rows],
                                 yi[row0:row0 + d.rows])


class StreamExecutor:
    """Runs a `StreamTransform` over every store block, overlapped.

    Shares `Manifest` + `JobStats` with `MapOnlyJob` so the pipelined path
    is a drop-in: same crash-restart, retry-budget and speculation
    semantics, plus per-stage clocks in ``stats.stage_s``.
    """

    def __init__(self, store: BlockStore, out_dir, transform: StreamTransform,
                 cfg: JobConfig, manifest: Manifest, stats: JobStats):
        self.store = store
        self.out_dir = out_dir
        self.transform = transform
        self.cfg = cfg
        self.manifest = manifest
        self.stats = stats
        self._stats_lock = threading.Lock()
        self._stop = threading.Event()
        self._todo: queue.SimpleQueue = queue.SimpleQueue()
        # bounded: decoded blocks waiting for the dispatcher ARE the
        # prefetch window; readers block here when the device side lags,
        # so host memory stays O(queue x block) for any input size
        self._decoded: queue.Queue = queue.Queue(
            maxsize=2 * max(cfg.coalesce, 1) + max(cfg.readers, 1))
        self._events: queue.SimpleQueue = queue.SimpleQueue()
        self._inflight = threading.Semaphore(max(cfg.inflight, 1))
        # per-block processing start (set by the reader that picks the
        # block up). Latency medians and straggler ages are measured from
        # HERE, not from enqueue time — every block is enqueued at t=0, so
        # enqueue-based clocks grow with elapsed time and would both
        # inflate the median and mark merely-queued blocks as stragglers.
        self._started: dict[int, float] = {}
        # resilience: the shared retry policy + optional fault injector
        # (DESIGN.md §10). _first_started feeds the policy's per-block
        # deadline and is never popped on retry (unlike _started, whose
        # clock restarts so straggler detection stays per-attempt).
        self._policy = cfg.retry_policy()
        self._injector = cfg.injector
        self._retry_states: dict = {}
        self._first_started: dict[int, float] = {}

    # ------------------------------------------------------------------
    def _add_stage(self, stage: str, dt: float) -> None:
        with self._stats_lock:
            self.stats.stage_s[stage] = self.stats.stage_s.get(stage, 0.) + dt

    def _put_decoded(self, item) -> None:
        while not self._stop.is_set():
            try:
                self._decoded.put(item, timeout=0.05)
                return
            except queue.Full:  # prefetch window full: back-pressure
                continue

    def _reader(self) -> None:
        while True:
            item = self._todo.get()
            if item is None or self._stop.is_set():
                return
            index, is_spec = item
            # a speculative twin keeps the primary's clock (setdefault);
            # retries clear the entry first, so their clock restarts
            self._started.setdefault(index, time.monotonic())
            try:
                t0 = time.monotonic()
                data = self.store.read_block(index)
                maybe_fire(self._injector, "stream.decode", index)
                d = self.transform.decode(data, index)
                self._add_stage("read", time.monotonic() - t0)
                self._put_decoded(("ok", index, is_spec, d))
            except _Stop:
                return
            except BaseException as e:
                self._put_decoded(("err", index, is_spec, e))

    def _corrupt_host(self, host, group: list[tuple[Decoded, bool]]):
        """Post-realize corruption checkpoint (``kind="corrupt"`` rules at
        stream.realize): silently perturb a scheduled member's rows of the
        realized host arrays. Runs AFTER the CRC-verified read and the
        device sync — only the verify hooks below can catch it."""
        host = list(host) if isinstance(host, (tuple, list)) else [host]
        row0 = 0
        for d, _ in group:
            scale = self._injector.corrupt_scale("stream.realize", d.index)
            if scale is not None:
                for k in range(len(host)):
                    a = host[k]
                    if isinstance(a, (bytes, bytearray)):
                        if len(a) % 4 or not a:
                            continue  # opaque map output; nothing to flip
                        arr = np.frombuffer(a, dtype=np.float32).copy()
                        perturb_array(arr, scale,
                                      corrupt_salt("stream.realize",
                                                   d.index, k))
                        host[k] = arr.tobytes()
                        continue
                    if not a.flags.writeable:  # realized outputs often are
                        a = host[k] = np.array(a, copy=True)
                    perturb_array(a[row0:row0 + d.rows], scale,
                                  corrupt_salt("stream.realize", d.index, k))
            row0 += d.rows
        return host[0] if len(host) == 1 else tuple(host)

    def _writeback(self, handle, group: list[tuple[Decoded, bool]]) -> None:
        try:
            t0 = time.monotonic()
            try:
                host = self.transform.realize(handle)
            finally:
                # the window boundary: oldest batch realized -> next launch
                self._inflight.release()
            self._add_stage("d2h", time.monotonic() - t0)
            # fires only after realize: the staging set is back in the
            # pool (realize's finally), so an injected fault here cannot
            # leak pool capacity and starve the dispatcher
            if self._injector is not None:
                self._injector.fire_group(
                    "stream.realize", [d.index for d, _ in group])
                host = self._corrupt_host(host, group)
            # group invariant (abft checksum row): a failure here cannot
            # name the culprit, so the whole group quarantines and retries
            self.transform.verify_group(host, [d for d, _ in group])
        except BaseException as e:
            for d, is_spec in group:
                self._events.put(("err", d.index, is_spec, e))
            return
        row0 = 0
        t_done = time.monotonic()
        for d, is_spec in group:
            try:
                t0 = time.monotonic()
                maybe_fire(self._injector, "stream.writeback", d.index)
                # per-member invariant (Parseval): quarantines just this
                # block back into the retry path — recompute-on-detect
                self.transform.verify_member(host, row0, d)
                out = self.transform.encode(host, row0, d)
                self.store.write_output_block(self.out_dir, d.index, out)
                self._add_stage("write", time.monotonic() - t0)
                self._events.put(("done", d.index, is_spec, t_done))
            except BaseException as e:
                self._events.put(("err", d.index, is_spec, e))
            row0 += d.rows

    # ------------------------------------------------------------------
    def run(self) -> JobStats:
        cfg = self.cfg
        t_start = time.monotonic()
        for s in STAGES:
            self.stats.stage_s.setdefault(s, 0.0)

        todo = self.manifest.pending()
        total_left = len(todo)
        if total_left == 0:
            self.manifest.close()  # fd hygiene; reopens on next update
            self.stats.wall_s = time.monotonic() - t_start
            return self.stats

        coalesce = max(cfg.coalesce, 1)
        # batch staging sets: the inflight window plus slack for a batch
        # being gathered while another retires (double-buffering rule)
        self.transform.open(max(cfg.inflight, 1) + 2, self._stop)

        speculated: set[int] = set()
        completed: set[int] = set()
        decode_pending = 0  # enqueued to readers, not yet taken by us
        latencies: list[float] = []
        fatal: list[BaseException] = []

        readers = [threading.Thread(target=self._reader, daemon=True)
                   for _ in range(max(cfg.readers, 1))]
        for r in readers:
            r.start()
        writers = ThreadPoolExecutor(max_workers=max(cfg.writers, 1))

        def enqueue(i: int, is_spec: bool) -> None:
            nonlocal decode_pending
            self.manifest.update(i, status=RUNNING,
                                 started_at=time.monotonic(),
                                 speculated=is_spec)
            if not is_spec:  # retry: restart the block's clock when a
                self._started.pop(i, None)  # reader picks it up again
            self._first_started.setdefault(i, time.monotonic())
            decode_pending += 1
            self.stats.attempts += 1
            if is_spec:
                self.stats.speculative_launches += 1
            self._todo.put((i, is_spec))

        def on_failure(i: int, is_spec: bool, err: BaseException) -> None:
            if i in completed or fatal:
                return
            st = self.manifest.tasks[i]
            attempts = st.attempts + 1
            now = time.monotonic()
            elapsed = now - self._first_started.get(i, now)
            if not self._policy.should_retry(attempts, elapsed, err):
                self.manifest.update(i, status=FAILED, attempts=attempts,
                                     error=repr(err))
                self.stats.failed_blocks.append(
                    {"index": i, "attempts": attempts, "error": repr(err)})
                fatal.append(RuntimeError(
                    f"block {i} failed {attempts} times"))
                fatal[-1].__cause__ = err
                self._stop.set()
                return
            self.stats.retries += 1
            self.manifest.update(i, status=PENDING, attempts=attempts,
                                 error=repr(err))
            # backoff before relaunch; default policy has zero base delay,
            # so legacy jobs keep their immediate-retry behaviour
            self._retry_states.setdefault(
                i, self._policy.new_state()).backoff()
            enqueue(i, False)

        def on_done(i: int, is_spec: bool, t_done: float) -> None:
            nonlocal total_left
            if i in completed:
                return  # a speculative twin already won; idempotent write
            completed.add(i)
            total_left -= 1
            dt = t_done - self._started.get(i, t_done)
            latencies.append(dt)
            self.stats.task_seconds.append(dt)
            self.stats.blocks_done += 1
            if is_spec:
                self.stats.speculative_wins += 1
            self.manifest.update(i, status=DONE,
                                 finished_at=time.monotonic())

        def drain_events(block: bool = False) -> None:
            while True:
                try:
                    ev = self._events.get(
                        block=block, timeout=cfg.poll_interval_s)
                except queue.Empty:
                    return
                block = False
                kind, i, is_spec, payload = ev
                if kind == "done":
                    on_done(i, is_spec, payload)
                else:
                    on_failure(i, is_spec, payload)

        def maybe_speculate() -> None:
            if (not cfg.speculation
                    or len(latencies) < cfg.min_completed_for_speculation):
                return
            med = median(latencies)
            now = time.monotonic()
            # only blocks a reader has actually STARTED can be stragglers;
            # blocks still queued are waiting on back-pressure, not stuck
            for i, started in list(self._started.items()):
                if (i not in completed and i not in speculated
                        and now - started > cfg.straggler_factor * med):
                    speculated.add(i)
                    enqueue(i, True)

        def dispatch(group: list[tuple[Decoded, bool]]) -> None:
            # h2d + launch; window back-pressure lives in the semaphore
            while not self._inflight.acquire(timeout=cfg.poll_interval_s):
                drain_events()  # keep completions flowing while we wait
                if self._stop.is_set():
                    return
            batch = None
            try:
                if self._injector is not None:
                    self._injector.fire_group(
                        "stream.launch", [d.index for d, _ in group])
                t0 = time.monotonic()
                batch = self.transform.gather([d for d, _ in group])
                self._add_stage("h2d", time.monotonic() - t0)
                t0 = time.monotonic()
                handle = self.transform.launch(batch)
                self._add_stage("compute", time.monotonic() - t0)
            except BaseException as e:
                self._inflight.release()
                if batch is not None:  # gathered but never launched
                    self.transform.discard(batch)
                for d, is_spec in group:
                    on_failure(d.index, is_spec, e)
                return
            self.stats.batches += 1
            self.stats.coalesced_blocks += max(len(group) - 1, 0)
            writers.submit(self._writeback, handle, group)

        try:
            for i in todo:
                enqueue(i, False)

            group: list[tuple[Decoded, bool]] = []
            while total_left > 0 and not self._stop.is_set():
                drain_events()
                maybe_speculate()
                try:
                    kind, i, is_spec, payload = self._decoded.get(
                        timeout=cfg.poll_interval_s)
                except queue.Empty:
                    if group and decode_pending == 0:
                        dispatch(group)
                        group = []
                    continue
                decode_pending -= 1
                if kind == "err":
                    on_failure(i, is_spec, payload)
                    continue
                d: Decoded = payload
                if i in completed:  # twin won while we were decoding
                    continue
                if group and (d.key is None or d.key != group[0][0].key
                              or len(group) >= coalesce):
                    dispatch(group)
                    group = []
                group.append((d, is_spec))
                if len(group) >= coalesce or d.key is None or (
                        decode_pending == 0 and self._decoded.empty()):
                    dispatch(group)
                    group = []
            # the loop exits only at total_left == 0 (or stop): any block
            # still in `group` was completed by a speculative twin while
            # its decode waited, so launching the leftovers would only
            # redo finished work — drop them (Decoded holds views, no
            # pooled staging, so dropping needs no cleanup)
        finally:
            try:
                self._stop.set()
                for _ in readers:
                    self._todo.put(None)
                if isinstance(getattr(self.transform, "_pool", None),
                              StagingPool):
                    self.transform._pool.wake_all()
                writers.shutdown(wait=True)
                for r in readers:
                    r.join(timeout=5.0)
                self.transform.close()
                # late finishers (stats/manifest completeness) BEFORE the
                # manifest close below — their updates must not silently
                # reopen the journal fd we are about to release
                drain_events()
            finally:
                self.manifest.close()  # fd hygiene; reopens on next update
        if fatal:
            raise fatal[0]
        self.stats.wall_s = time.monotonic() - t_start
        return self.stats
