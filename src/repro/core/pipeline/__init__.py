from repro.core.pipeline.blockstore import (BlockIntegrityError, BlockStore,
                                            StoreStats)
from repro.core.pipeline.maponly import MapOnlyJob, JobConfig, JobStats
from repro.core.pipeline.records import segments_of_block, block_of_segments
from repro.core.pipeline.stream import (MapFnTransform, SegmentFFTTransform,
                                        StagingPool, StreamExecutor,
                                        StreamTransform)

__all__ = ["BlockIntegrityError", "BlockStore", "MapOnlyJob", "JobConfig",
           "JobStats", "segments_of_block", "block_of_segments", "StoreStats",
           "StreamExecutor", "StreamTransform", "SegmentFFTTransform",
           "MapFnTransform", "StagingPool"]
