from repro.core.pipeline.blockstore import BlockStore
from repro.core.pipeline.maponly import MapOnlyJob, JobConfig
from repro.core.pipeline.records import segments_of_block, block_of_segments

__all__ = ["BlockStore", "MapOnlyJob", "JobConfig", "segments_of_block",
           "block_of_segments"]
