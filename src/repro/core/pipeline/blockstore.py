"""BlockStore: the HDFS analogue for the paper's block-granular pipeline.

A *store* is a directory of fixed-size binary blocks plus a JSON manifest.
The design choices mirror the paper directly:

  * fixed ``block_bytes`` (their ``dfs.block.size``; default here is scaled
    down from their 512 MB so tests stay fast, but it is the same knob —
    the paper sets it to the largest buffer the accelerator can take in one
    transfer);
  * one block == one record == one map task (their custom InputFormat);
  * blocks are named by byte offset so a final merge is a simple
    offset-ordered concatenation (their ``hdfs -getmerge``);
  * block writes are atomic (write-tmp, fsync, rename), which makes map
    attempts idempotent — the property Hadoop's speculative execution
    relies on, and ours does too (maponly.py);
  * optional replication: ``replication=r`` keeps r copies of each block;
    reads fall back to a replica when the primary is missing/corrupt
    (checksum mismatch), simulating HDFS datanode failure.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

MANIFEST = "manifest.json"


def _sha(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()[:16]


def _atomic_write(path: Path, data: bytes) -> None:
    tmp_fd, tmp_name = tempfile.mkstemp(dir=path.parent, prefix=".tmp_")
    try:
        with os.fdopen(tmp_fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, path)  # atomic; last writer wins, all identical
    except BaseException:
        if os.path.exists(tmp_name):
            os.unlink(tmp_name)
        raise


@dataclass
class BlockInfo:
    index: int
    offset: int
    nbytes: int
    checksum: str

    def name(self, replica: int = 0) -> str:
        suffix = "" if replica == 0 else f".rep{replica}"
        return f"block_{self.offset:016d}.bin{suffix}"


@dataclass
class BlockStore:
    root: Path
    block_bytes: int = 1 << 20
    replication: int = 1
    blocks: list[BlockInfo] = field(default_factory=list)
    total_bytes: int = 0

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ---------------- ingest ----------------
    def put_bytes(self, data: bytes) -> None:
        """Split ``data`` into blocks (the HDFS copy-in step)."""
        self.blocks = []
        self.total_bytes = len(data)
        for off in range(0, len(data), self.block_bytes):
            chunk = data[off:off + self.block_bytes]
            info = BlockInfo(index=len(self.blocks), offset=off,
                             nbytes=len(chunk), checksum=_sha(chunk))
            for r in range(self.replication):
                _atomic_write(self.root / info.name(r), chunk)
            self.blocks.append(info)
        self._save_manifest()

    def put_array(self, arr: np.ndarray) -> None:
        self.put_bytes(np.ascontiguousarray(arr).tobytes())

    def _save_manifest(self) -> None:
        doc = {
            "block_bytes": self.block_bytes,
            "total_bytes": self.total_bytes,
            "replication": self.replication,
            "blocks": [vars(b) for b in self.blocks],
        }
        _atomic_write(self.root / MANIFEST, json.dumps(doc, indent=1).encode())

    @classmethod
    def open(cls, root: os.PathLike) -> "BlockStore":
        root = Path(root)
        doc = json.loads((root / MANIFEST).read_text())
        store = cls(root=root, block_bytes=doc["block_bytes"],
                    replication=doc.get("replication", 1))
        store.total_bytes = doc["total_bytes"]
        store.blocks = [BlockInfo(**b) for b in doc["blocks"]]
        return store

    # ---------------- reads (with replica fallback) ----------------
    def read_block(self, index: int, verify: bool = True) -> bytes:
        info = self.blocks[index]
        last_err: Exception | None = None
        for r in range(max(self.replication, 1)):
            path = self.root / info.name(r)
            try:
                data = path.read_bytes()
                if verify and _sha(data) != info.checksum:
                    raise IOError(f"checksum mismatch on {path.name}")
                return data
            except (IOError, OSError) as e:  # missing or corrupt replica
                last_err = e
        raise IOError(f"block {index}: all replicas failed") from last_err

    def corrupt_block(self, index: int, replica: int = 0) -> None:
        """Test hook: damage one replica (simulated datanode failure)."""
        path = self.root / self.blocks[index].name(replica)
        path.write_bytes(b"\x00CORRUPT" * 4)

    # ---------------- output side ----------------
    def write_output_block(self, out_dir: os.PathLike, index: int,
                           data: bytes) -> None:
        """Map-task output write: atomic, named by offset (mergeable)."""
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        _atomic_write(out / self.blocks[index].name(), data)

    def getmerge(self, out_dir: os.PathLike, dest: os.PathLike) -> int:
        """The paper's ``hdfs -getmerge``: offset-ordered concat to one file."""
        out = Path(out_dir)
        names = sorted(p.name for p in out.glob("block_*.bin"))
        expect = [b.name() for b in self.blocks]
        if names != expect:
            missing = sorted(set(expect) - set(names))
            raise IOError(f"getmerge: missing {len(missing)} output blocks: "
                          f"{missing[:3]}...")
        total = 0
        with open(dest, "wb") as f:
            for name in names:  # lexicographic == offset order (zero-padded)
                data = (out / name).read_bytes()
                f.write(data)
                total += len(data)
        return total
