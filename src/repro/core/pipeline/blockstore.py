"""BlockStore: the HDFS analogue for the paper's block-granular pipeline.

A *store* is a directory of fixed-size binary blocks plus a JSON manifest.
The design choices mirror the paper directly:

  * fixed ``block_bytes`` (their ``dfs.block.size``; default here is scaled
    down from their 512 MB so tests stay fast, but it is the same knob —
    the paper sets it to the largest buffer the accelerator can take in one
    transfer);
  * one block == one record == one map task (their custom InputFormat);
  * blocks are named by byte offset so a final merge is a simple
    offset-ordered concatenation (their ``hdfs -getmerge``);
  * block writes are atomic (write-tmp, fsync, rename), which makes map
    attempts idempotent — the property Hadoop's speculative execution
    relies on, and ours does too (maponly.py);
  * optional replication: ``replication=r`` keeps r copies of each block;
    reads fall back to a replica when the primary is missing/corrupt
    (checksum mismatch), simulating HDFS datanode failure — and a
    successful deep-verified fallback opportunistically repairs the
    damaged copies (`repair_block`, HDFS's re-replication analogue).

Replica iteration runs under the shared `RetryPolicy`
(core/resilience/retry.py) and every read/write is a named fault-injection
site (core/resilience/faults.py), so chaos runs can prove the fallback +
repair behaviour deterministically (DESIGN.md §10).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
import zlib
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.resilience.faults import maybe_fire
from repro.core.resilience.retry import RetryPolicy

MANIFEST = "manifest.json"
MERGE_CHUNK = 4 << 20  # getmerge streams block files in bounded chunks


class BlockIntegrityError(IOError):
    """A block-granular integrity failure (checksum mismatch, missing or
    unreadable block), carrying WHICH block: ``index`` (store block index,
    when known) and ``block`` (the offending file name). Subclasses
    ``IOError`` so every retry policy and replica loop still classifies it
    as retryable I/O; raisers chain the underlying error (``from err``,
    the PR-6 convention) so the root cause stays on the traceback."""

    def __init__(self, msg: str, *, index: int | None = None,
                 block: str | None = None):
        super().__init__(msg)
        self.index = index
        self.block = block


def _sha(data) -> str:
    return hashlib.sha256(data).hexdigest()[:16]


def _crc(data) -> str:
    # the cheap per-read block check (HDFS's own choice); SHA-256 stays in
    # the manifest as the replica-repair ground truth. DESIGN.md §7 has
    # the honest micro-benchmark: the split is architectural — raw crc32
    # speed depends on the zlib build (SIMD crc vs SHA-NI sha256)
    return f"{zlib.crc32(data) & 0xffffffff:08x}"


def _atomic_write(path: Path, data) -> None:
    tmp_fd, tmp_name = tempfile.mkstemp(dir=path.parent, prefix=".tmp_")
    try:
        with os.fdopen(tmp_fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, path)  # atomic; last writer wins, all identical
    except BaseException:
        if os.path.exists(tmp_name):
            os.unlink(tmp_name)
        raise


class StoreStats:
    """Thread-safe read-path counters (reader threads hit these
    concurrently): replica fallbacks served and replica copies repaired."""

    def __init__(self):
        self._lock = threading.Lock()
        self.fallback_reads = 0
        self.repairs = 0

    def bump(self, name: str, k: int = 1) -> None:
        with self._lock:
            setattr(self, name, getattr(self, name) + k)

    def as_dict(self) -> dict:
        with self._lock:
            return {"fallback_reads": self.fallback_reads,
                    "repairs": self.repairs}


@dataclass
class BlockInfo:
    index: int
    offset: int
    nbytes: int
    checksum: str  # SHA-256 (truncated): replica-repair ground truth
    crc32: str = ""  # cheap hot-path read check ("" on legacy manifests)

    def name(self, replica: int = 0) -> str:
        suffix = "" if replica == 0 else f".rep{replica}"
        return f"block_{self.offset:016d}.bin{suffix}"


@dataclass
class BlockStore:
    root: Path
    block_bytes: int = 1 << 20
    replication: int = 1
    blocks: list[BlockInfo] = field(default_factory=list)
    total_bytes: int = 0
    # resilience wiring (never serialized into the manifest): a
    # FaultInjector for chaos runs, an override RetryPolicy for the
    # replica loop, and the fallback/repair counters
    injector: object = field(default=None, repr=False, compare=False)
    retry: RetryPolicy | None = field(default=None, repr=False, compare=False)
    stats: StoreStats = field(default_factory=StoreStats, repr=False,
                              compare=False)

    def __post_init__(self):
        self.root = Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ---------------- ingest ----------------
    def _append_block(self, offset: int, chunk) -> None:
        info = BlockInfo(index=len(self.blocks), offset=offset,
                         nbytes=len(chunk), checksum=_sha(chunk),
                         crc32=_crc(chunk))
        for r in range(self.replication):
            _atomic_write(self.root / info.name(r), chunk)
        self.blocks.append(info)

    def put_bytes(self, data) -> None:
        """Split ``data`` into blocks (the HDFS copy-in step).

        Accepts any buffer (bytes, bytearray, numpy view); slicing goes
        through a ``memoryview`` so no chunk copy is ever materialized —
        the seed doubled peak ingest memory by slicing ``bytes`` directly.
        """
        self.blocks = []
        mv = memoryview(data).cast("B")
        self.total_bytes = mv.nbytes
        for off in range(0, mv.nbytes, self.block_bytes):
            self._append_block(off, mv[off:off + self.block_bytes])
        self._save_manifest()

    def put_file(self, path: os.PathLike) -> None:
        """Streaming ingest: split a file into blocks reading one block at
        a time, so copy-in never holds the whole input in memory. A
        mid-stream read or write failure surfaces as a structured
        `BlockIntegrityError` naming the block being ingested (chained
        ``from`` the underlying OS error)."""
        self.blocks = []
        self.total_bytes = 0
        with open(path, "rb") as f:
            while True:
                index = len(self.blocks)
                try:
                    chunk = f.read(self.block_bytes)
                    if not chunk:
                        break
                    self._append_block(self.total_bytes, chunk)
                except OSError as err:
                    raise BlockIntegrityError(
                        f"put_file: ingest of block {index} (offset "
                        f"{self.total_bytes}) from {path} failed",
                        index=index,
                        block=f"block_{self.total_bytes:016d}.bin",
                    ) from err
                self.total_bytes += len(chunk)
        self._save_manifest()

    def put_array(self, arr: np.ndarray) -> None:
        self.put_bytes(np.ascontiguousarray(arr).view(np.uint8).reshape(-1))

    def _save_manifest(self) -> None:
        doc = {
            "block_bytes": self.block_bytes,
            "total_bytes": self.total_bytes,
            "replication": self.replication,
            "blocks": [vars(b) for b in self.blocks],
        }
        _atomic_write(self.root / MANIFEST, json.dumps(doc, indent=1).encode())

    @classmethod
    def open(cls, root: os.PathLike) -> "BlockStore":
        root = Path(root)
        doc = json.loads((root / MANIFEST).read_text())
        store = cls(root=root, block_bytes=doc["block_bytes"],
                    replication=doc.get("replication", 1))
        store.total_bytes = doc["total_bytes"]
        store.blocks = [BlockInfo(**b) for b in doc["blocks"]]
        return store

    # ---------------- reads (with replica fallback) ----------------
    def _verify(self, data, info: BlockInfo, deep: bool) -> bool:
        """Hot path: crc32. ``deep`` (replica fallback / repair) or legacy
        manifests without a crc: full SHA-256 against the ground truth."""
        if deep or not info.crc32:
            return _sha(data) == info.checksum
        return _crc(data) == info.crc32

    def _replica_policy(self) -> RetryPolicy:
        """The replica loop as a retry policy: attempt r = replica r,
        immediate (no backoff — the next replica is a different disk)."""
        return self.retry or RetryPolicy(
            max_attempts=max(self.replication, 1),
            retryable=(IOError, OSError))

    def read_block(self, index: int, verify: bool = True) -> bytes:
        info = self.blocks[index]
        maybe_fire(self.injector, "blockstore.read", index)

        def attempt(r: int) -> tuple[int, bytes]:
            if r == 0:
                maybe_fire(self.injector, "blockstore.replica", index)
            path = self.root / info.name(r)
            data = path.read_bytes()
            # primary read pays only the cheap crc; a fallback replica
            # is about to become the new source of truth, so it must
            # match the cryptographic checksum before being served
            if verify and not self._verify(data, info, deep=r > 0):
                raise BlockIntegrityError(
                    f"checksum mismatch on {path.name}",
                    index=index, block=path.name)
            return r, data

        try:
            r, data = self._replica_policy().call(attempt)
        except (IOError, OSError) as e:  # every replica missing or corrupt
            raise BlockIntegrityError(
                f"block {index}: all replicas failed",
                index=index, block=info.name()) from e
        if r > 0:
            # served from a fallback replica: the primary (and any earlier
            # copy) is broken — repair it now from the verified data, or
            # it stays damaged until the LAST replica rots and the block
            # is gone for good
            self.stats.bump("fallback_reads")
            if verify:
                self.repair_block(index, data)
        return data

    def repair_block(self, index: int, data: bytes | None = None) -> int:
        """Opportunistic replica repair: atomically rewrite every damaged
        or missing copy of block ``index`` from a deep-verified good one.

        ``data`` (when given) must match the manifest's SHA-256 ground
        truth; otherwise the first replica that does is the source.
        Returns the number of copies rewritten (0 = all were healthy).
        Atomic per copy, so concurrent readers only ever see the old or
        the repaired bytes, and repeated repairs are idempotent.
        """
        info = self.blocks[index]
        if data is None:
            for r in range(max(self.replication, 1)):
                try:
                    cand = (self.root / info.name(r)).read_bytes()
                except OSError:
                    continue
                if _sha(cand) == info.checksum:
                    data = cand
                    break
            if data is None:
                raise IOError(
                    f"block {index}: no intact replica to repair from")
        elif _sha(data) != info.checksum:
            raise ValueError(
                f"block {index}: repair source fails the SHA-256 ground "
                f"truth; refusing to propagate corruption")
        repaired = 0
        for r in range(max(self.replication, 1)):
            path = self.root / info.name(r)
            try:
                if _sha(path.read_bytes()) == info.checksum:
                    continue  # this copy is healthy
            except OSError:
                pass  # missing: rewrite below
            _atomic_write(path, data)
            repaired += 1
        if repaired:
            self.stats.bump("repairs", repaired)
        return repaired

    def corrupt_block(self, index: int, replica: int = 0) -> None:
        """Test hook: damage one replica (simulated datanode failure)."""
        path = self.root / self.blocks[index].name(replica)
        path.write_bytes(b"\x00CORRUPT" * 4)

    # ---------------- output side ----------------
    def write_output_block(self, out_dir: os.PathLike, index: int,
                           data: bytes) -> None:
        """Map-task output write: atomic, named by offset (mergeable)."""
        maybe_fire(self.injector, "blockstore.write", index)
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        _atomic_write(out / self.blocks[index].name(), data)

    def getmerge(self, out_dir: os.PathLike, dest: os.PathLike) -> int:
        """The paper's ``hdfs -getmerge``: offset-ordered concat to one file."""
        out = Path(out_dir)
        names = sorted(p.name for p in out.glob("block_*.bin"))
        expect = [b.name() for b in self.blocks]
        if names != expect:
            missing = sorted(set(expect) - set(names))
            first = missing[0] if missing else names[0]
            raise BlockIntegrityError(
                f"getmerge: missing {len(missing)} output blocks "
                f"(first: {first})",
                index=expect.index(first) if first in expect else None,
                block=first)
        total = 0
        with open(dest, "wb") as f:
            for i, name in enumerate(names):  # lexicographic == offset order
                try:
                    with open(out / name, "rb") as src:  # bounded stream
                        while True:
                            chunk = src.read(MERGE_CHUNK)
                            if not chunk:
                                break
                            f.write(chunk)
                            total += len(chunk)
                except OSError as err:
                    # a block that listed but fails mid-stream (vanished,
                    # truncated device, I/O error): name it, chain it
                    raise BlockIntegrityError(
                        f"getmerge: output block {name} (index {i}) "
                        f"failed mid-stream", index=i, block=name) from err
        return total
