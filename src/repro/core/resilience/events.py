"""In-process resilience event log.

A tiny append-only registry the degradation machinery writes to and the
chaos gate asserts on: planner downgrades (`repro.fft.plan(...,
fallback="degrade")`), simulated device loss/restore (`meshstate`). Kept
separate from Python logging so tests and benchmarks can make *structural*
assertions ("exactly one downgrade event, from distributed to local")
instead of grepping log text; every record is also mirrored to the
``repro.resilience`` logger at WARNING for human eyes.
"""

from __future__ import annotations

import logging
import threading
import time

log = logging.getLogger("repro.resilience")

_LOCK = threading.Lock()
_EVENTS: list[dict] = []


def record_event(kind: str, **fields) -> dict:
    """Append one event ``{"kind": kind, "t": wall_time, **fields}``."""
    ev = {"kind": kind, "t": time.time(), **fields}
    with _LOCK:
        _EVENTS.append(ev)
    log.warning("resilience event: %s %s", kind, fields)
    return ev


def events(kind: str | None = None) -> list[dict]:
    """Snapshot of recorded events, optionally filtered by kind."""
    with _LOCK:
        snap = list(_EVENTS)
    return snap if kind is None else [e for e in snap if e["kind"] == kind]


def clear_events() -> None:
    """Reset the log (test/benchmark isolation)."""
    with _LOCK:
        _EVENTS.clear()
