"""In-process resilience event log (capped ring buffer).

A tiny keep-latest registry the degradation machinery writes to and the
chaos gate asserts on: planner downgrades (`repro.fft.plan(...,
fallback="degrade")`), simulated device loss/restore (`meshstate`),
service degradation (`repro.serve.fft_service`). Kept separate from
Python logging so tests and benchmarks can make *structural* assertions
("exactly one downgrade event, from distributed to local") instead of
grepping log text; every record is also mirrored to the
``repro.resilience`` logger at WARNING for human eyes.

The buffer is bounded (default 4096 events, `set_capacity` to resize):
a long-running service emitting degrade/retry events forever must not
leak memory, so the oldest events are evicted keep-latest and counted in
`dropped()` — an assertion that needs the full history should either
raise the capacity or snapshot via `events()` as it goes.
"""

from __future__ import annotations

import logging
import threading
import time
from collections import deque

log = logging.getLogger("repro.resilience")

DEFAULT_CAPACITY = 4096

_LOCK = threading.Lock()
_EVENTS: deque[dict] = deque(maxlen=DEFAULT_CAPACITY)
_DROPPED = 0


def record_event(kind: str, **fields) -> dict:
    """Append one event ``{"kind": kind, "t": wall_time, **fields}``.

    When the ring is full the OLDEST event is evicted (keep-latest) and
    the drop counter advances; recording never blocks or grows memory.
    """
    global _DROPPED
    ev = {"kind": kind, "t": time.time(), **fields}
    with _LOCK:
        if len(_EVENTS) == _EVENTS.maxlen:
            _DROPPED += 1
        _EVENTS.append(ev)
    log.warning("resilience event: %s %s", kind, fields)
    return ev


def events(kind: str | None = None) -> list[dict]:
    """Snapshot of retained events (oldest first), optionally filtered."""
    with _LOCK:
        snap = list(_EVENTS)
    return snap if kind is None else [e for e in snap if e["kind"] == kind]


def dropped() -> int:
    """Events evicted from the ring since the last `clear_events()`."""
    with _LOCK:
        return _DROPPED


def capacity() -> int:
    """Current ring size (events retained before keep-latest eviction)."""
    with _LOCK:
        return _EVENTS.maxlen


def set_capacity(size: int) -> None:
    """Resize the ring, keeping the newest events that still fit (evicted
    ones count as dropped)."""
    global _EVENTS, _DROPPED
    if size < 1:
        raise ValueError(f"event-log capacity must be >= 1, got {size}")
    with _LOCK:
        kept = deque(_EVENTS, maxlen=size)
        _DROPPED += len(_EVENTS) - len(kept)
        _EVENTS = kept


def stats() -> dict:
    """``{"retained", "capacity", "dropped"}`` counters for reports."""
    with _LOCK:
        return {"retained": len(_EVENTS), "capacity": _EVENTS.maxlen,
                "dropped": _DROPPED}


def clear_events() -> None:
    """Reset the log and drop counter (test/benchmark isolation)."""
    global _DROPPED
    with _LOCK:
        _EVENTS.clear()
        _DROPPED = 0
