"""One retry/backoff policy for every failure domain.

Before this module, each layer counted failures its own way: `maponly.py`
kept an ad-hoc ``max_retries`` integer, `blockstore.py` looped bare over
replicas, and nothing anywhere bounded *time* (a block that fails slowly
could spin a multi-hour out-of-core job forever). `RetryPolicy` is the one
definition of "try again":

  * bounded attempts (``max_attempts`` — the classic retry budget);
  * exponential backoff with **decorrelated jitter**
    (``sleep = min(cap, uniform(base, 3 * prev))``), which avoids the
    synchronized retry storms plain exponential backoff produces when many
    workers fail on the same shared resource;
  * a per-operation ``deadline_s`` (wall budget across all attempts);
  * explicit ``retryable`` exception classes — anything else fails fast;
  * injectable ``clock``/``sleep``/``seed`` so tests run instantly and
    chaos schedules stay deterministic.

The default policy (``base_delay_s=0``) retries immediately, which is
exactly the pre-existing behaviour of every caller — the policy changes
*where the decision lives*, not what a default-configured job does.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable


@dataclass(frozen=True)
class RetryPolicy:
    """Immutable retry strategy; per-operation bookkeeping lives in
    `RetryState` (``policy.new_state()``), one state per block/op."""

    max_attempts: int = 3
    base_delay_s: float = 0.0      # 0 = retry immediately (legacy default)
    max_delay_s: float = 2.0       # decorrelated-jitter cap
    deadline_s: float | None = None  # wall budget across ALL attempts
    retryable: tuple = (Exception,)
    seed: int = 0                  # jitter RNG seed (deterministic tests)
    sleep: Callable[[float], None] = field(default=time.sleep, repr=False)
    clock: Callable[[], float] = field(default=time.monotonic, repr=False)

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("backoff delays must be >= 0")

    # -------------------------------------------------------------- decide
    def retryable_exc(self, exc: BaseException) -> bool:
        return isinstance(exc, self.retryable)

    def should_retry(self, attempts: int, elapsed: float,
                     exc: BaseException) -> bool:
        """May another attempt launch after ``attempts`` failures?

        ``attempts`` counts FAILED attempts so far (the manifest's
        ``attempts`` field after the current failure is journaled);
        ``elapsed`` is wall time since the op first started.
        """
        if not self.retryable_exc(exc):
            return False
        if attempts >= self.max_attempts:
            return False
        if self.deadline_s is not None and elapsed >= self.deadline_s:
            return False
        return True

    def next_delay(self, prev_delay: float, rng: random.Random) -> float:
        """Decorrelated jitter: ``min(cap, U(base, 3 * prev))``."""
        if self.base_delay_s <= 0 and prev_delay <= 0:
            return 0.0  # immediate-retry policy: never sleep
        lo = self.base_delay_s
        hi = max(3.0 * prev_delay, lo)
        d = rng.uniform(lo, hi) if hi > lo else lo
        return min(self.max_delay_s, d)

    # -------------------------------------------------------------- drive
    def new_state(self) -> "RetryState":
        return RetryState(self)

    def call(self, fn: Callable[[int], object]):
        """Run ``fn(attempt_index)`` (0-based) under this policy.

        The synchronous driver, used where the whole retry loop fits in
        one call frame (e.g. `BlockStore.read_block`, where the attempt
        index selects the replica). Event-driven callers (the job runners,
        whose attempts resolve on other threads) use `should_retry` +
        `RetryState.backoff` directly. Raises the last attempt's exception
        when the budget is spent.
        """
        state = self.new_state()
        while True:
            try:
                return fn(state.attempts)
            except BaseException as exc:
                if not state.admit(exc):
                    raise


class RetryState:
    """Mutable per-operation retry bookkeeping (attempt count, deadline
    clock, jitter chain). Not thread-safe; guard externally if shared."""

    def __init__(self, policy: RetryPolicy):
        self.policy = policy
        self.attempts = 0          # failed attempts recorded so far
        self.last_error: BaseException | None = None
        self.t0 = policy.clock()
        self._rng = random.Random(policy.seed)
        self._prev_delay = policy.base_delay_s

    @property
    def elapsed(self) -> float:
        return self.policy.clock() - self.t0

    def admit(self, exc: BaseException, attempts: int | None = None) -> bool:
        """Record one failed attempt; True = backoff applied, retry now.

        ``attempts`` overrides the internal counter for callers whose
        durable attempt count lives elsewhere (the job manifest survives
        crash-restarts; this state does not).
        """
        self.attempts = self.attempts + 1 if attempts is None else attempts
        self.last_error = exc
        if not self.policy.should_retry(self.attempts, self.elapsed, exc):
            return False
        self.backoff()
        return True

    def backoff(self) -> float:
        """Sleep the next decorrelated-jitter delay; returns it."""
        d = self.policy.next_delay(self._prev_delay, self._rng)
        if d > 0:
            self._prev_delay = d
            self.policy.sleep(d)
        return d
