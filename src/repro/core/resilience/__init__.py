"""`repro.core.resilience` — the cross-cutting resilience layer.

The source paper's entire case for Hadoop over a dedicated supercomputer
is commodity-server fault tolerance: disks corrupt, datanodes die,
stragglers appear, and the job still finishes. The reproduction grew that
behaviour piecemeal (per-block retry and speculation in
`core/pipeline/maponly.py`, replica fallback in `blockstore.py`, the
crash-replayable journal from the stream pipeline); this package makes it
one subsystem that can be *proven* under systematic failure
(DESIGN.md §10):

  * `retry`     — ONE `RetryPolicy` (bounded attempts, exponential backoff
                  with decorrelated jitter, per-op deadline, retryable
                  exception classes, injectable clock/sleep) shared by the
                  map-only job, the stream executor, and the BlockStore
                  replica loop.
  * `faults`    — a deterministic, seeded `FaultPlan`/`FaultInjector` with
                  named injection sites threaded through every failure
                  domain, so chaos runs are exactly reproducible.
  * `meshstate` — the logical device-health registry behind
                  `repro.fft.plan(..., fallback="degrade")`: simulated
                  device loss shrinks or empties the mesh and the planner
                  re-plans distributed -> segmented/local instead of dying.
  * `events`    — the in-process event log (downgrades, device loss,
                  repairs) that tests and the chaos gate assert on.
  * `verify`    — ABFT invariants (Parseval energy, linearity checksum
                  row) with derived per-precision tolerances; a failed
                  check raises `SilentCorruption` (retryable) and the
                  quarantined unit recomputes through the ONE RetryPolicy.
                  Paired with the silent ``kind="corrupt"`` fault rules
                  in `faults` (post-CRC perturbation the byte-integrity
                  layers provably cannot see).

Exercised end to end by `benchmarks/bench_chaos.py` (BENCH_chaos.json,
gated in test.sh/CI) and `tests/test_chaos.py` (`pytest -m chaos`).
"""

from repro.core.resilience.events import clear_events, events, record_event
from repro.core.resilience.events import set_capacity as set_event_capacity
from repro.core.resilience.events import stats as event_stats
from repro.core.resilience.faults import (KINDS, SITES, FaultInjector,
                                          FaultPlan, FaultRule,
                                          InjectedFault, maybe_corrupt,
                                          maybe_fire, perturb_array)
from repro.core.resilience.retry import RetryPolicy, RetryState
from repro.core.resilience.verify import (VERIFY_MODES, SilentCorruption,
                                          check_checksum, check_parseval)

__all__ = [
    "KINDS",
    "SITES",
    "VERIFY_MODES",
    "FaultInjector",
    "FaultPlan",
    "FaultRule",
    "InjectedFault",
    "RetryPolicy",
    "RetryState",
    "SilentCorruption",
    "check_checksum",
    "check_parseval",
    "clear_events",
    "event_stats",
    "events",
    "maybe_corrupt",
    "maybe_fire",
    "perturb_array",
    "record_event",
    "set_event_capacity",
]
