"""Algorithm-based fault tolerance (ABFT) invariants for FFT execution.

CRC/SHA layers catch corruption *at rest*; a bit flip after decode-verify,
inside a launch, or in a realized result produces a bitwise-consistent
wrong answer that no byte check can see. The FFT is uniquely cheap to
defend against this: two O(n) mathematical invariants gate an O(n log n)
transform (DESIGN.md §13):

  * **Parseval** — the unnormalized forward DFT scales energy by exactly
    n: ``sum_k |X[k]|^2 == n * sum_j |x[j]|^2``. Checked in float64 with
    a tolerance derived from the dtype eps and the transform's rounding
    depth (O(log2 n) butterfly stages).
  * **Linearity checksum row** — the DFT is linear, so appending one row
    equal to a seeded random combination of a batch's rows means its
    transform must equal the same combination of the rows' transforms.
    One extra row rides an existing batched launch (the serve/stream
    zero-padded full-plan trick keeps <= 2 plans per key) and localizes
    corruption anywhere in the batch, including rows whose own energy
    check would pass (e.g. an injected permutation).

A failed check raises `SilentCorruption` — an ``IOError`` subclass, so
every existing `RetryPolicy` classifies it retryable and the quarantined
unit re-enters the ONE retry path (recompute); a ``verify_failed`` event
records site/block/detail for the gates in benchmarks/bench_verify.py.
"""

from __future__ import annotations

import numpy as np

from repro.core.resilience.events import record_event

VERIFY_MODES = ("off", "parseval", "abft")

# tolerance safety constant: worst-case relative error of a length-n f32
# FFT grows like O(eps * log2 n) through the butterfly stages; 64x covers
# accumulation across batched rows and the float64 energy reduction
# without ever admitting a norm-relative perturbation (which changes
# energy by O(scale^2), many orders above any eps-scaled bound).
TOLERANCE_SAFETY = 64.0

_EPS = {
    "f32": float(np.finfo(np.float32).eps),
    "f64": float(np.finfo(np.float64).eps),
    "bf16": 2.0 ** -8,
}


class SilentCorruption(IOError):
    """An algorithmic invariant failed on otherwise byte-consistent data.

    ``IOError`` subclass by design: every `RetryPolicy` in the tree
    classifies it retryable, so detection quarantines the unit and the
    existing retry machinery recomputes it.
    """

    def __init__(self, message: str, site: str = "", index=None):
        super().__init__(message)
        self.site = site
        self.index = index


def fail(site: str, index=None, **fields) -> SilentCorruption:
    """Record a ``verify_failed`` event and build the structured error.

    Callers ``raise fail(...)`` so detection telemetry and the exception
    can never disagree.
    """
    record_event("verify_failed", site=site, index=index, **fields)
    detail = ", ".join(f"{k}={v}" for k, v in fields.items())
    return SilentCorruption(
        f"silent corruption detected at {site} (block={index}): {detail}",
        site=site, index=index)


def check_mode(mode: str) -> str:
    if mode not in VERIFY_MODES:
        raise ValueError(
            f"unknown verify mode {mode!r}; expected one of {VERIFY_MODES}")
    return mode


def parseval_rtol(n: int, precision: str = "f32") -> float:
    """Relative tolerance for the energy invariant at transform size n."""
    eps = _EPS.get(precision, _EPS["f32"])
    return TOLERANCE_SAFETY * eps * max(1.0, float(np.log2(max(n, 2))))


def energy(*arrays) -> float:
    """Sum of squares across planar components, accumulated in float64.

    Squares are formed in the operand's own dtype (exact re-computation:
    two energy() calls over the same float32 values produce identical
    squares, so rearrangement checks can use tight tolerances) and only
    the reduction runs in float64 — this avoids materializing a float64
    copy of every operand, which dominated verification wall time.
    """
    total = 0.0
    for a in arrays:
        a = np.asarray(a)
        total += float(np.sum(np.square(a), dtype=np.float64))
    return total


def energy_onesided(re, im, n: int) -> float:
    """Full-spectrum energy from a one-sided r2c result.

    The stored n/2+1 bins imply the conjugate half: DC and Nyquist count
    once, interior bins twice.
    """
    re = np.asarray(re, dtype=np.float64)
    im = np.asarray(im, dtype=np.float64)
    full = np.square(re) + np.square(im)
    e = np.sum(full[..., 1:-1]) * 2.0 + np.sum(full[..., 0]) \
        + np.sum(full[..., -1])
    return float(e)


def check_parseval(e_in: float, e_out: float, n: int,
                   precision: str = "f32", *, site: str, index=None,
                   **fields) -> None:
    """Assert ``e_out == n * e_in`` within the derived tolerance.

    ``e_in`` is input energy, ``e_out`` output (full-spectrum) energy of
    an unnormalized forward transform of length ``n``.
    """
    expect = float(n) * e_in
    tol = parseval_rtol(n, precision) * (abs(expect) + 1e-30)
    err = abs(e_out - expect)
    if err > tol:
        raise fail(site, index, invariant="parseval", n=n,
                   e_in=e_in, e_out=e_out, rel_err=err / (abs(expect) + 1e-30),
                   **fields)


def checksum_weights(rows: int, seed: int = 0) -> np.ndarray:
    """Seeded random combination weights for ``rows`` batch rows.

    Drawn in [0.5, 1.5] so no row is down-weighted to the tolerance
    floor; float32 to match operand dtype. Deterministic (PCG64).
    """
    rng = np.random.default_rng(seed)
    return rng.uniform(0.5, 1.5, size=rows).astype(np.float32)


def add_checksum_row(arrays, weights: np.ndarray):
    """Append ``weights @ a`` as one extra row to each (rows, n) array."""
    out = []
    for a in arrays:
        row = (weights @ a.reshape(len(weights), -1)).reshape(
            (1,) + a.shape[1:]).astype(a.dtype)
        out.append(np.concatenate([a, row], axis=0))
    return out


def abft_rtol(n: int, rows: int, precision: str = "f32") -> float:
    """Relative tolerance for the linearity residual.

    Parseval's per-transform bound, widened by sqrt(rows) for the
    host-side weighted reduction across the batch.
    """
    return parseval_rtol(n, precision) * float(np.sqrt(max(rows, 1) + 1))


def check_checksum(out_arrays, weights: np.ndarray, n: int,
                   precision: str = "f32", *, site: str, index=None,
                   **fields) -> None:
    """Assert each array's last row equals the weighted combination of the
    preceding rows (linearity of the transform), within tolerance."""
    rows = len(weights)
    rtol = abft_rtol(n, rows, precision)
    for a in out_arrays:
        a = np.asarray(a)
        # GEMV in the operand dtype (the checksum row itself was formed by
        # the same-precision combination at gather, so matching precision
        # here adds no detection error); norms accumulate in float64. The
        # float64-everything variant cost a full-batch copy per plane.
        w = weights.astype(a.dtype, copy=False)
        combo = w @ a[:rows].reshape(rows, -1)
        resid = a[rows].reshape(-1) - combo
        ref = float(np.sqrt(np.sum(np.square(combo), dtype=np.float64)))
        err = float(np.sqrt(np.sum(np.square(resid), dtype=np.float64)))
        if err > rtol * (ref + 1e-30):
            raise fail(site, index, invariant="checksum_row", n=n,
                       rows=rows, rel_err=err / (ref + 1e-30), **fields)


# ------------------------------------------------------------- cost model
def verify_flops(mode: str, n: int, rows: int) -> int:
    """Analytic flop count of the verification work itself.

    parseval: square+accumulate over input and output planes (2 planes x
    2 ops x rows x n, both sides). abft replaces the per-member energy
    checks with the checksum row: the input-side combination at gather,
    the output-side combination and residual norms at realize (MAC + norm
    passes over 2 planes each), plus the extra row's own transform —
    which the main cost model already counts because the plan's batch
    really is rows+1.
    """
    check_mode(mode)
    if mode == "off" or rows <= 0:
        return 0
    if mode == "parseval":
        return 8 * rows * n
    return 16 * rows * n


def verify_hbm_bytes(mode: str, n: int, rows: int,
                     bytes_per_el: int = 4) -> int:
    """Extra HBM/host traffic: parseval re-reads input (at decode) and
    output (at realize) planes for the energy reductions; abft re-reads
    input once for the gather-side combination and output once for the
    residual check — two passes either way, abft just spends them on the
    stronger invariant."""
    check_mode(mode)
    if mode == "off" or rows <= 0:
        return 0
    plane = 2 * rows * n * bytes_per_el
    return 2 * plane
