"""Logical device-health registry: graceful mesh degradation.

The distributed engines assume every mesh device answers its collectives;
on a real fleet, chips get cordoned and hosts drop mid-job. JAX gives a
single process no way to *actually* kill one of its own devices, so this
module keeps the process-level fiction the rest of the resilience layer
agrees on: a set of lost device ids plus an epoch counter. Simulated loss
(`lose_devices`, or `FaultInjector.apply_device_loss` for scheduled
chaos) bumps the epoch; `repro.fft.plan(..., fallback="degrade")` checks
`mesh_healthy` before committing to a distributed strategy and re-plans
on a shrunk mesh (`shrunk_mesh`) or mesh-free when devices are gone —
instead of launching collectives that would hang a real cluster.
"""

from __future__ import annotations

import threading

from repro.core.resilience.events import record_event

_LOCK = threading.Lock()
_LOST: set = set()   # jax device ids considered dead
_EPOCH = 0           # bumps on every loss/restore (cache-invalidation tag)


def lose_devices(device_ids) -> None:
    """Mark device ids lost (simulated datanode/chip failure)."""
    global _EPOCH
    ids = {int(d) for d in device_ids}
    if not ids:
        return
    with _LOCK:
        _LOST.update(ids)
        _EPOCH += 1
        epoch = _EPOCH
    record_event("device_loss", device_ids=sorted(ids), epoch=epoch)


def restore_devices(device_ids=None) -> None:
    """Heal device ids (None = all) — test/benchmark teardown."""
    global _EPOCH
    with _LOCK:
        if device_ids is None:
            healed = sorted(_LOST)
            _LOST.clear()
        else:
            healed = sorted(_LOST & {int(d) for d in device_ids})
            _LOST.difference_update(healed)
        if not healed:
            return
        _EPOCH += 1
        epoch = _EPOCH
    record_event("device_restore", device_ids=healed, epoch=epoch)


def lost_devices() -> frozenset:
    with _LOCK:
        return frozenset(_LOST)


def epoch() -> int:
    """Monotonic health-change counter (plan-cache invalidation tag)."""
    with _LOCK:
        return _EPOCH


def healthy_devices(mesh) -> list:
    """The mesh's devices that are not marked lost, in mesh order."""
    lost = lost_devices()
    return [d for d in mesh.devices.flat if d.id not in lost]


def mesh_healthy(mesh) -> bool:
    """True when every device of ``mesh`` still answers."""
    return len(healthy_devices(mesh)) == mesh.devices.size


def shrunk_mesh(mesh):
    """The largest power-of-two 1-D mesh of still-healthy devices, or None.

    Degraded re-planning target: the distributed engines need a pow2
    device count, and a 1-D mesh over the first axis name is the most
    general shape every placement accepts. None when fewer than 2 healthy
    devices remain (degrade goes mesh-free/local instead).
    """
    healthy = healthy_devices(mesh)
    k = 1
    while k * 2 <= len(healthy):
        k *= 2
    if k < 2:
        return None
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(healthy[:k]), (mesh.axis_names[0],))
