"""Deterministic, seeded fault injection across every failure domain.

A chaos run that cannot be replayed is a flake generator, not a test. The
design here makes the *schedule* — which (site, block, call-number)
triples fault — a pure function of the `FaultPlan`, never of thread
timing: the plan is fully materialized up front (explicit rules, or rules
drawn once from a seeded RNG), and the injector counts calls per
``(site, block)`` so "block 3's first pass through realize faults" means
the same thing no matter how readers, the dispatcher, and writeback
workers interleave.

Injection sites (each threaded through its owning layer):

  ==================  =====================================================
  site                fires at
  ==================  =====================================================
  blockstore.read     `BlockStore.read_block` entry (I/O error -> the
                      job-level retry budget)
  blockstore.replica  the PRIMARY replica read inside the fallback loop
                      (exercises replica fallback + opportunistic repair)
  blockstore.write    `BlockStore.write_output_block` entry
  stream.decode       reader thread, before `transform.decode`
  stream.launch       dispatcher, before gather/launch (fires per group
                      member; one hit fails the whole coalesced batch)
  stream.realize      writeback worker, at the realization boundary —
                      AFTER the device sync, so pooled staging is already
                      safely released (simulates D2H/result corruption)
  stream.writeback    writeback worker, before per-block encode + write
  maponly.attempt     serial map-task attempt entry
  mesh.device         not raised: rule ``index`` names a mesh device
                      ordinal to mark lost in `meshstate` (consumed by
                      `FaultInjector.apply_device_loss`; the planner's
                      ``fallback="degrade"`` re-plans around it)
  ooc.shuffle         out-of-core pass-1 transposed-shuffle tile write
                      (core/fft/outofcore.py; index = r*C + c tile id)
  ooc.pass2           out-of-core pass-2 tile read/assemble (index =
                      r*C + c tile id)
  serve.admit         `FftService.submit` admission (index = request seq;
                      the request is rejected with a structured error, it
                      never enters the queue)
  serve.batch         batcher group formation, fired per member BEFORE
                      gather/launch — one hit fails the whole coalesced
                      batch pre-launch, members re-enter the retry path
  serve.execute       writeback realization, fired per member AFTER the
                      device sync (simulates D2H/result corruption; the
                      batch's results are discarded and members retried)
  ==================  =====================================================

All raising sites throw `InjectedFault` (an ``IOError`` subclass, so the
replica loop and every retry policy classify it as retryable I/O).

Rules come in two *kinds*. ``kind="raise"`` (the default, everything
above) throws at the site. ``kind="corrupt"`` never raises: the layer
calls the separate ``corrupt_scale``/``maybe_corrupt`` checkpoint AFTER
its integrity checks have passed (post-CRC realized outputs, journaled
tile payloads, service results) and the injector deterministically
perturbs one element of the data flowing through — a silent wrong answer
that only an algorithmic invariant (core/resilience/verify.py) can
catch. Corrupt checkpoints count calls in their own namespace, so adding
corruption points at a site never shifts the call numbering of existing
raise rules (same append-only stability contract as `SITES`).
"""

from __future__ import annotations

import json
import random
import threading
import zlib
from dataclasses import dataclass, field

import numpy as np

SITES = (
    "blockstore.read",
    "blockstore.replica",
    "blockstore.write",
    "stream.decode",
    "stream.launch",
    "stream.realize",
    "stream.writeback",
    "maponly.attempt",
    "mesh.device",
    # appended AFTER the original nine so seeded FaultPlan.random draws
    # for the pre-existing sites replay identically (same seed, same
    # schedule — the chaos gate's fixed-seed runs stay byte-stable)
    "ooc.shuffle",
    "ooc.pass2",
    # appended after the ooc pair, same append-only contract (asserted by
    # tests/test_resilience.py::test_seeded_schedule_stable_under_append)
    "serve.admit",
    "serve.batch",
    "serve.execute",
)

# sites a seeded random plan draws from by default: the raising, per-block
# sites (mesh.device loss is a state change, scheduled explicitly)
RANDOM_SITES = tuple(s for s in SITES if s != "mesh.device")


class InjectedFault(IOError):
    """A deterministic injected failure (retryable I/O by construction)."""


def _check_site(site: str) -> str:
    if site not in SITES:
        raise ValueError(
            f"unknown fault site {site!r}; expected one of {SITES}")
    return site


KINDS = ("raise", "corrupt")


@dataclass(frozen=True)
class FaultRule:
    """One scheduled fault: fire at ``site`` for block ``index`` on the
    given per-(site, index) ``calls`` (1-based; ``index=None`` matches
    every block, still counted per block).

    ``kind="raise"`` throws `InjectedFault` at the site's ``fire`` call;
    ``kind="corrupt"`` silently perturbs data at the site's
    ``corrupt_scale`` checkpoint instead, by ``scale`` (relative to the
    payload's L2 norm, so the perturbation is above any derived Parseval
    tolerance regardless of transform size)."""

    site: str
    index: int | None = None
    calls: tuple = (1,)
    kind: str = "raise"
    scale: float = 1.0

    def __post_init__(self):
        _check_site(self.site)
        calls = tuple(int(c) for c in self.calls)
        if not calls or min(calls) < 1:
            raise ValueError(f"calls must be 1-based call numbers, "
                             f"got {self.calls}")
        object.__setattr__(self, "calls", calls)
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {KINDS}")
        scale = float(self.scale)
        if not scale > 0.0 or not np.isfinite(scale):
            raise ValueError(f"scale must be finite and > 0, got {self.scale}")
        object.__setattr__(self, "scale", scale)


@dataclass(frozen=True)
class FaultPlan:
    """A fully-materialized fault schedule (a tuple of `FaultRule`s).

    Build explicitly, from a seed (`FaultPlan.random` — same seed, same
    schedule, forever), or from a CLI/launcher spec (`FaultPlan.parse`).
    """

    rules: tuple = ()
    meta: dict = field(default_factory=dict, compare=False)

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in self.rules:
            if not isinstance(r, FaultRule):
                raise TypeError(f"rules must be FaultRule, got {type(r)}")

    @classmethod
    def random(cls, seed: int, num_blocks: int, sites=None,
               rate: float = 0.1, times: int = 1,
               device_loss: tuple = (), kind: str = "raise") -> "FaultPlan":
        """Draw a schedule once from ``seed``: each (site, block) faults
        with probability ``rate`` on its first ``times`` calls.

        Pre-drawing (instead of consulting an RNG at fire time) is what
        makes chaos runs reproducible under free thread interleaving.
        ``device_loss`` ordinals become ``mesh.device`` rules.

        ``kind="corrupt"`` draws the SAME (site, block) hit pattern as a
        raise plan at the same seed (the hit draws share one stream;
        perturbation scales come from a second seeded stream), so a storm
        can be re-run as silent corruption without reshuffling which
        blocks are targeted.
        """
        sites = tuple(_check_site(s) for s in (sites or RANDOM_SITES))
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        if kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r}; expected one of {KINDS}")
        rng = random.Random(seed)
        scale_rng = random.Random(seed ^ 0x5CA1E)
        rules = []
        for site in sites:
            for idx in range(num_blocks):
                if rng.random() < rate:
                    calls = tuple(range(1, times + 1))
                    if kind == "corrupt":
                        rules.append(FaultRule(
                            site, idx, calls, kind="corrupt",
                            scale=scale_rng.uniform(0.25, 4.0)))
                    else:
                        rules.append(FaultRule(site, idx, calls))
        for dev in device_loss:
            rules.append(FaultRule("mesh.device", int(dev)))
        return cls(tuple(rules), meta={
            "seed": seed, "rate": rate, "sites": sites, "times": times,
            "num_blocks": num_blocks, "device_loss": tuple(device_loss),
            "kind": kind})

    @classmethod
    def parse(cls, spec: str, num_blocks: int) -> "FaultPlan":
        """Build a plan from a launcher spec string.

        Two forms:
          * ``"seed=7,rate=0.15,times=1,sites=blockstore.read+stream.decode,
            lose=6+7,kind=corrupt"`` — a seeded random schedule (``sites``
            are ``+``-separated; ``lose`` lists device ordinals to drop;
            ``kind`` defaults to ``raise``);
          * a JSON object (starts with ``{``) or ``@path`` to a JSON file:
            ``{"rules": [{"site": ..., "index": ..., "calls": [1],
            "kind": "corrupt", "scale": 1.5}]}`` and/or the random-plan
            keys ``{"seed", "rate", "sites", "times", "kind"}``.
        """
        spec = spec.strip()
        if spec.startswith("@"):
            spec = open(spec[1:]).read().strip()
        if spec.startswith("{"):
            doc = json.loads(spec)
            rules = tuple(FaultRule(r["site"], r.get("index"),
                                    tuple(r.get("calls", (1,))),
                                    kind=r.get("kind", "raise"),
                                    scale=float(r.get("scale", 1.0)))
                          for r in doc.get("rules", ()))
            if "seed" in doc:
                rnd = cls.random(int(doc["seed"]), num_blocks,
                                 sites=doc.get("sites"),
                                 rate=float(doc.get("rate", 0.1)),
                                 times=int(doc.get("times", 1)),
                                 device_loss=doc.get("device_loss", ()),
                                 kind=doc.get("kind", "raise"))
                rules += rnd.rules
            return cls(rules, meta={"spec": "json"})
        kv = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(
                    f"bad --faults fragment {part!r}: expected key=value "
                    f"pairs (seed=, rate=, times=, sites=a+b, lose=i+j, "
                    f"kind=raise|corrupt)")
            k, v = part.split("=", 1)
            kv[k.strip()] = v.strip()
        unknown = set(kv) - {"seed", "rate", "times", "sites", "lose", "kind"}
        if unknown:
            raise ValueError(f"unknown --faults keys {sorted(unknown)}")
        return cls.random(
            int(kv.get("seed", 0)), num_blocks,
            sites=tuple(kv["sites"].split("+")) if "sites" in kv else None,
            rate=float(kv.get("rate", 0.1)),
            times=int(kv.get("times", 1)),
            device_loss=tuple(int(d) for d in kv["lose"].split("+"))
            if "lose" in kv else (),
            kind=kv.get("kind", "raise"))

    def to_spec(self) -> str:
        """Serialize to a JSON spec string that `parse` round-trips.

        Explicit rules (not the seed) are emitted, so the exact schedule —
        including per-rule corrupt scales — replays bit-identically via
        ``--faults @file.json`` regardless of `parse`'s ``num_blocks``.
        """
        return json.dumps({"rules": [
            {"site": r.site, "index": r.index, "calls": list(r.calls),
             "kind": r.kind, "scale": r.scale}
            for r in self.rules]})

    def device_loss(self) -> tuple:
        """Mesh device ordinals this plan marks lost."""
        return tuple(r.index for r in self.rules
                     if r.site == "mesh.device" and r.index is not None)


class FaultInjector:
    """Thread-safe executor of a `FaultPlan`.

    Layers call ``fire(site, index)`` at their named site; the injector
    counts the call per ``(site, index)`` and raises `InjectedFault` when
    a rule schedules that call number. ``fired``/``calls`` expose exact
    per-site telemetry for the chaos gate's budget assertions.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._calls: dict = {}     # (site, index) -> raise-checkpoint calls
        self._fired: dict = {}     # site -> faults raised
        # corrupt checkpoints count in their own namespace so adding
        # corruption points at a site never shifts raise-rule numbering
        self._corrupt_calls: dict = {}   # (site, index) -> corrupt calls
        self._corrupted: dict = {}       # site -> perturbations applied
        # index rules by site and kind for O(rules-at-site) matching
        self._by_site: dict = {}
        self._corrupt_by_site: dict = {}
        for r in plan.rules:
            if r.kind == "corrupt":
                self._corrupt_by_site.setdefault(r.site, []).append(r)
            else:
                self._by_site.setdefault(r.site, []).append(r)

    def fire(self, site: str, index: int | None = None) -> None:
        """Count one pass of ``index`` through ``site``; raise if scheduled.

        Only ``kind="raise"`` rules match here — corrupt rules are
        consumed by the separate `corrupt_scale` checkpoint.
        """
        _check_site(site)
        with self._lock:
            call_no = self._calls.get((site, index), 0) + 1
            self._calls[(site, index)] = call_no
            hit = any(
                (r.index is None or r.index == index) and call_no in r.calls
                for r in self._by_site.get(site, ()))
            if hit:
                self._fired[site] = self._fired.get(site, 0) + 1
        if hit:
            raise InjectedFault(
                f"injected fault at {site} (block={index}, call={call_no})")

    def corrupt_scale(self, site: str, index: int | None = None):
        """Count one pass of ``index`` through ``site``'s corruption
        checkpoint; return the scheduled perturbation scale (or None).

        Never raises — a hit means the caller must silently perturb the
        payload (see `maybe_corrupt`). Counted separately from `fire`.
        """
        _check_site(site)
        with self._lock:
            call_no = self._corrupt_calls.get((site, index), 0) + 1
            self._corrupt_calls[(site, index)] = call_no
            for r in self._corrupt_by_site.get(site, ()):
                if ((r.index is None or r.index == index)
                        and call_no in r.calls):
                    self._corrupted[site] = self._corrupted.get(site, 0) + 1
                    return r.scale
        return None

    def fire_group(self, site: str, indices) -> None:
        """Fire for every member of a coalesced batch: any scheduled member
        fails the whole group (counted per member, so the schedule stays
        deterministic however blocks happen to be grouped)."""
        for i in indices:
            self.fire(site, i)

    def apply_device_loss(self, mesh) -> tuple:
        """Mark this plan's ``mesh.device`` ordinals lost in `meshstate`.

        Returns the device ids marked. Call once before (or mid-) job; the
        planner's ``fallback="degrade"`` consults the registry.
        """
        ordinals = self.plan.device_loss()
        if not ordinals:
            return ()
        from repro.core.resilience import meshstate
        devices = list(mesh.devices.flat)
        ids = tuple(devices[o].id for o in ordinals if o < len(devices))
        meshstate.lose_devices(ids)
        return ids

    # ------------------------------------------------------------ telemetry
    @property
    def fired(self) -> dict:
        with self._lock:
            return dict(self._fired)

    @property
    def total_fired(self) -> int:
        with self._lock:
            return sum(self._fired.values())

    @property
    def corrupted(self) -> dict:
        with self._lock:
            return dict(self._corrupted)

    @property
    def total_corrupted(self) -> int:
        with self._lock:
            return sum(self._corrupted.values())

    def summary(self) -> dict:
        with self._lock:
            return {"rules": len(self.plan.rules),
                    "fired_by_site": dict(self._fired),
                    "total_fired": sum(self._fired.values()),
                    "corrupted_by_site": dict(self._corrupted),
                    "total_corrupted": sum(self._corrupted.values())}


def maybe_fire(injector, site: str, index: int | None = None) -> None:
    """``injector.fire`` when an injector is wired, no-op otherwise — the
    one-liner every instrumented layer calls so production paths stay
    branch-cheap and injector-free by default."""
    if injector is not None:
        injector.fire(site, index)


def maybe_corrupt_bytes(injector, site: str, index, data: bytes) -> bytes:
    """Byte-payload corruption checkpoint (block codecs are headerless
    interleaved float32, so the flip reinterprets in place). Counts the
    checkpoint whenever an injector is wired; payloads that are not
    f32-aligned pass through untouched."""
    if injector is None:
        return data
    scale = injector.corrupt_scale(site, index)
    if scale is None or not data or len(data) % 4:
        return data
    arr = np.frombuffer(data, dtype=np.float32).copy()
    perturb_array(arr, scale, corrupt_salt(site, index))
    return arr.tobytes()


def perturb_array(a: np.ndarray, scale: float, salt: int) -> np.ndarray:
    """Deterministically spike one element of ``a`` by ``scale`` times its
    L2 norm (plus 1, so zero arrays still move).

    Pure function of (array content, scale, salt) — a corrupt storm
    replays bit-identically. Norm-relative magnitude keeps the energy
    perturbation at O(scale²) of the signal energy independent of length,
    i.e. provably above any n-scaled Parseval tolerance. Copies when the
    input is read-only (realized device outputs often are).
    """
    if a.size == 0:
        return a
    if not a.flags.writeable:
        a = np.array(a, copy=True)
    flat = a.reshape(-1)
    pos = salt % flat.size
    norm = float(np.sqrt(np.sum(np.square(flat, dtype=np.float64))))
    flat[pos] += np.asarray(scale * (1.0 + norm), dtype=a.dtype)
    return a


def corrupt_salt(site: str, index, k: int = 0) -> int:
    """Deterministic element-position salt for `perturb_array` — a pure
    function of (site, block, plane) so replayed storms hit the same
    element every time."""
    return (zlib.crc32(site.encode())
            + 1000003 * (0 if index is None else int(index)) + k)


def maybe_corrupt(injector, site: str, index, arrays):
    """Corruption checkpoint: when a ``kind="corrupt"`` rule is scheduled
    for ``(site, index)``, silently perturb one element of each array and
    return the (possibly copied) arrays plus a hit flag.

    ``arrays`` is a sequence of ndarrays; returns ``(list, corrupted)``.
    Call AFTER the layer's own integrity checks (CRC verify, journal
    record) so the corruption is invisible to everything but the
    algorithmic invariants in core/resilience/verify.py.
    """
    arrays = list(arrays)
    if injector is None:
        return arrays, False
    scale = injector.corrupt_scale(site, index)
    if scale is None:
        return arrays, False
    for k, a in enumerate(arrays):
        arrays[k] = perturb_array(a, scale, corrupt_salt(site, index, k))
    return arrays, True
