"""Deterministic, seeded fault injection across every failure domain.

A chaos run that cannot be replayed is a flake generator, not a test. The
design here makes the *schedule* — which (site, block, call-number)
triples fault — a pure function of the `FaultPlan`, never of thread
timing: the plan is fully materialized up front (explicit rules, or rules
drawn once from a seeded RNG), and the injector counts calls per
``(site, block)`` so "block 3's first pass through realize faults" means
the same thing no matter how readers, the dispatcher, and writeback
workers interleave.

Injection sites (each threaded through its owning layer):

  ==================  =====================================================
  site                fires at
  ==================  =====================================================
  blockstore.read     `BlockStore.read_block` entry (I/O error -> the
                      job-level retry budget)
  blockstore.replica  the PRIMARY replica read inside the fallback loop
                      (exercises replica fallback + opportunistic repair)
  blockstore.write    `BlockStore.write_output_block` entry
  stream.decode       reader thread, before `transform.decode`
  stream.launch       dispatcher, before gather/launch (fires per group
                      member; one hit fails the whole coalesced batch)
  stream.realize      writeback worker, at the realization boundary —
                      AFTER the device sync, so pooled staging is already
                      safely released (simulates D2H/result corruption)
  stream.writeback    writeback worker, before per-block encode + write
  maponly.attempt     serial map-task attempt entry
  mesh.device         not raised: rule ``index`` names a mesh device
                      ordinal to mark lost in `meshstate` (consumed by
                      `FaultInjector.apply_device_loss`; the planner's
                      ``fallback="degrade"`` re-plans around it)
  ooc.shuffle         out-of-core pass-1 transposed-shuffle tile write
                      (core/fft/outofcore.py; index = r*C + c tile id)
  ooc.pass2           out-of-core pass-2 tile read/assemble (index =
                      r*C + c tile id)
  serve.admit         `FftService.submit` admission (index = request seq;
                      the request is rejected with a structured error, it
                      never enters the queue)
  serve.batch         batcher group formation, fired per member BEFORE
                      gather/launch — one hit fails the whole coalesced
                      batch pre-launch, members re-enter the retry path
  serve.execute       writeback realization, fired per member AFTER the
                      device sync (simulates D2H/result corruption; the
                      batch's results are discarded and members retried)
  ==================  =====================================================

All raising sites throw `InjectedFault` (an ``IOError`` subclass, so the
replica loop and every retry policy classify it as retryable I/O).
"""

from __future__ import annotations

import json
import random
import threading
from dataclasses import dataclass, field

SITES = (
    "blockstore.read",
    "blockstore.replica",
    "blockstore.write",
    "stream.decode",
    "stream.launch",
    "stream.realize",
    "stream.writeback",
    "maponly.attempt",
    "mesh.device",
    # appended AFTER the original nine so seeded FaultPlan.random draws
    # for the pre-existing sites replay identically (same seed, same
    # schedule — the chaos gate's fixed-seed runs stay byte-stable)
    "ooc.shuffle",
    "ooc.pass2",
    # appended after the ooc pair, same append-only contract (asserted by
    # tests/test_resilience.py::test_seeded_schedule_stable_under_append)
    "serve.admit",
    "serve.batch",
    "serve.execute",
)

# sites a seeded random plan draws from by default: the raising, per-block
# sites (mesh.device loss is a state change, scheduled explicitly)
RANDOM_SITES = tuple(s for s in SITES if s != "mesh.device")


class InjectedFault(IOError):
    """A deterministic injected failure (retryable I/O by construction)."""


def _check_site(site: str) -> str:
    if site not in SITES:
        raise ValueError(
            f"unknown fault site {site!r}; expected one of {SITES}")
    return site


@dataclass(frozen=True)
class FaultRule:
    """One scheduled fault: fire at ``site`` for block ``index`` on the
    given per-(site, index) ``calls`` (1-based; ``index=None`` matches
    every block, still counted per block)."""

    site: str
    index: int | None = None
    calls: tuple = (1,)

    def __post_init__(self):
        _check_site(self.site)
        calls = tuple(int(c) for c in self.calls)
        if not calls or min(calls) < 1:
            raise ValueError(f"calls must be 1-based call numbers, "
                             f"got {self.calls}")
        object.__setattr__(self, "calls", calls)


@dataclass(frozen=True)
class FaultPlan:
    """A fully-materialized fault schedule (a tuple of `FaultRule`s).

    Build explicitly, from a seed (`FaultPlan.random` — same seed, same
    schedule, forever), or from a CLI/launcher spec (`FaultPlan.parse`).
    """

    rules: tuple = ()
    meta: dict = field(default_factory=dict, compare=False)

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in self.rules:
            if not isinstance(r, FaultRule):
                raise TypeError(f"rules must be FaultRule, got {type(r)}")

    @classmethod
    def random(cls, seed: int, num_blocks: int, sites=None,
               rate: float = 0.1, times: int = 1,
               device_loss: tuple = ()) -> "FaultPlan":
        """Draw a schedule once from ``seed``: each (site, block) faults
        with probability ``rate`` on its first ``times`` calls.

        Pre-drawing (instead of consulting an RNG at fire time) is what
        makes chaos runs reproducible under free thread interleaving.
        ``device_loss`` ordinals become ``mesh.device`` rules.
        """
        sites = tuple(_check_site(s) for s in (sites or RANDOM_SITES))
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        rng = random.Random(seed)
        rules = []
        for site in sites:
            for idx in range(num_blocks):
                if rng.random() < rate:
                    rules.append(FaultRule(site, idx,
                                           tuple(range(1, times + 1))))
        for dev in device_loss:
            rules.append(FaultRule("mesh.device", int(dev)))
        return cls(tuple(rules), meta={
            "seed": seed, "rate": rate, "sites": sites, "times": times,
            "num_blocks": num_blocks, "device_loss": tuple(device_loss)})

    @classmethod
    def parse(cls, spec: str, num_blocks: int) -> "FaultPlan":
        """Build a plan from a launcher spec string.

        Two forms:
          * ``"seed=7,rate=0.15,times=1,sites=blockstore.read+stream.decode,
            lose=6+7"`` — a seeded random schedule (``sites`` are
            ``+``-separated; ``lose`` lists device ordinals to drop);
          * a JSON object (starts with ``{``) or ``@path`` to a JSON file:
            ``{"rules": [{"site": ..., "index": ..., "calls": [1]}]}`` and/
            or the random-plan keys ``{"seed", "rate", "sites", "times"}``.
        """
        spec = spec.strip()
        if spec.startswith("@"):
            spec = open(spec[1:]).read().strip()
        if spec.startswith("{"):
            doc = json.loads(spec)
            rules = tuple(FaultRule(r["site"], r.get("index"),
                                    tuple(r.get("calls", (1,))))
                          for r in doc.get("rules", ()))
            if "seed" in doc:
                rnd = cls.random(int(doc["seed"]), num_blocks,
                                 sites=doc.get("sites"),
                                 rate=float(doc.get("rate", 0.1)),
                                 times=int(doc.get("times", 1)),
                                 device_loss=doc.get("device_loss", ()))
                rules += rnd.rules
            return cls(rules, meta={"spec": "json"})
        kv = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(
                    f"bad --faults fragment {part!r}: expected key=value "
                    f"pairs (seed=, rate=, times=, sites=a+b, lose=i+j)")
            k, v = part.split("=", 1)
            kv[k.strip()] = v.strip()
        unknown = set(kv) - {"seed", "rate", "times", "sites", "lose"}
        if unknown:
            raise ValueError(f"unknown --faults keys {sorted(unknown)}")
        return cls.random(
            int(kv.get("seed", 0)), num_blocks,
            sites=tuple(kv["sites"].split("+")) if "sites" in kv else None,
            rate=float(kv.get("rate", 0.1)),
            times=int(kv.get("times", 1)),
            device_loss=tuple(int(d) for d in kv["lose"].split("+"))
            if "lose" in kv else ())

    def device_loss(self) -> tuple:
        """Mesh device ordinals this plan marks lost."""
        return tuple(r.index for r in self.rules
                     if r.site == "mesh.device" and r.index is not None)


class FaultInjector:
    """Thread-safe executor of a `FaultPlan`.

    Layers call ``fire(site, index)`` at their named site; the injector
    counts the call per ``(site, index)`` and raises `InjectedFault` when
    a rule schedules that call number. ``fired``/``calls`` expose exact
    per-site telemetry for the chaos gate's budget assertions.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._calls: dict = {}     # (site, index) -> call count
        self._fired: dict = {}     # site -> faults raised
        # index rules by site for O(rules-at-site) matching
        self._by_site: dict = {}
        for r in plan.rules:
            self._by_site.setdefault(r.site, []).append(r)

    def fire(self, site: str, index: int | None = None) -> None:
        """Count one pass of ``index`` through ``site``; raise if scheduled."""
        _check_site(site)
        with self._lock:
            call_no = self._calls.get((site, index), 0) + 1
            self._calls[(site, index)] = call_no
            hit = any(
                (r.index is None or r.index == index) and call_no in r.calls
                for r in self._by_site.get(site, ()))
            if hit:
                self._fired[site] = self._fired.get(site, 0) + 1
        if hit:
            raise InjectedFault(
                f"injected fault at {site} (block={index}, call={call_no})")

    def fire_group(self, site: str, indices) -> None:
        """Fire for every member of a coalesced batch: any scheduled member
        fails the whole group (counted per member, so the schedule stays
        deterministic however blocks happen to be grouped)."""
        for i in indices:
            self.fire(site, i)

    def apply_device_loss(self, mesh) -> tuple:
        """Mark this plan's ``mesh.device`` ordinals lost in `meshstate`.

        Returns the device ids marked. Call once before (or mid-) job; the
        planner's ``fallback="degrade"`` consults the registry.
        """
        ordinals = self.plan.device_loss()
        if not ordinals:
            return ()
        from repro.core.resilience import meshstate
        devices = list(mesh.devices.flat)
        ids = tuple(devices[o].id for o in ordinals if o < len(devices))
        meshstate.lose_devices(ids)
        return ids

    # ------------------------------------------------------------ telemetry
    @property
    def fired(self) -> dict:
        with self._lock:
            return dict(self._fired)

    @property
    def total_fired(self) -> int:
        with self._lock:
            return sum(self._fired.values())

    def summary(self) -> dict:
        with self._lock:
            return {"rules": len(self.plan.rules),
                    "fired_by_site": dict(self._fired),
                    "total_fired": sum(self._fired.values())}


def maybe_fire(injector, site: str, index: int | None = None) -> None:
    """``injector.fire`` when an injector is wired, no-op otherwise — the
    one-liner every instrumented layer calls so production paths stay
    branch-cheap and injector-free by default."""
    if injector is not None:
        injector.fire(site, index)
