"""The paper's analytical performance models (§I, §IV).

Two models appear in the paper:
  1. Amdahl's Argument: S(N) = 1 / ((1-P) + P/N), with P fit from the
     measured I/O vs compute split (their Figures 4/5 put the serial
     fraction — single-node disk I/O — at 70-75% CPU / 92-95% GPU).
  2. The headline runtime estimate O(n log n / (0.8 * S * C)): work divided
     over S servers x C cores with a 0.8 per-server Hadoop efficiency factor.

Both are implemented exactly as stated so benchmarks/fig6_scaling.py can
overlay model vs measured scaling, plus a TPU-flavored variant where the
efficiency factor is *derived* from the compiled collective/compute ratio
instead of assumed (DESIGN.md §10.4).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


def amdahl_speedup(parallel_fraction: float, n_workers: int) -> float:
    """S(N) = 1 / ((1-P) + P/N)."""
    if not 0.0 <= parallel_fraction <= 1.0:
        raise ValueError("P must be in [0, 1]")
    if n_workers < 1:
        raise ValueError("N must be >= 1")
    return 1.0 / ((1.0 - parallel_fraction) + parallel_fraction / n_workers)


def fit_parallel_fraction(t_serial: float, t_parallel: float) -> float:
    """P from a single-machine decomposition t = t_serial + t_parallel."""
    total = t_serial + t_parallel
    if total <= 0:
        raise ValueError("total time must be positive")
    return t_parallel / total


def paper_runtime_model(n: int, servers: int, cores: int, *,
                        efficiency: float = 0.8,
                        unit_time_s: float = 1.0) -> float:
    """The paper's O(n log n / (0.8*S*C)) with an explicit time constant.

    ``unit_time_s`` is the per-(n log n)-unit time of one core, calibrated
    from a single-machine run; the paper leaves it implicit in big-O.
    """
    if n < 2:
        return 0.0
    work = n * math.log2(n)
    return unit_time_s * work / (efficiency * servers * cores)


def calibrate_unit_time(n: int, measured_s: float, servers: int = 1,
                        cores: int = 1, efficiency: float = 1.0) -> float:
    """Solve the model for unit_time_s given one measured run."""
    work = n * math.log2(n)
    return measured_s * efficiency * servers * cores / work


@dataclass(frozen=True)
class ClusterModel:
    """Convenience bundle: calibrate once, predict many."""
    unit_time_s: float
    efficiency: float = 0.8

    def predict(self, n: int, servers: int, cores: int) -> float:
        return paper_runtime_model(n, servers, cores,
                                   efficiency=self.efficiency,
                                   unit_time_s=self.unit_time_s)

    def speedup(self, n: int, servers: int, cores: int) -> float:
        return self.predict(n, 1, 1) / self.predict(n, servers, cores)
