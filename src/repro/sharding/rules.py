"""Logical-axis sharding rules (MaxText-style), the hillclimb lever.

Every parameter is declared once with *logical* dimension names
(``ParamSpec``); a ``ShardingRules`` table maps logical names to mesh axes.
Changing a rule re-shards the whole model without touching model code —
which is exactly how §Perf iterations flip sharding hypotheses.

Defaults implement:
  * tensor parallelism over ``model`` for heads / d_ff / vocab / experts;
  * FSDP (ZeRO-3 style) over ``data`` for the params' d_model dimension —
    XLA inserts the all-gathers at use sites and reduce-scatters gradients;
  * batch data-parallel over ``('pod', 'data')``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Shape + logical axis names + initializer for one parameter."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


MeshAxes = str | tuple[str, ...] | None


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple, or None=replicated)."""
    rules: dict[str, MeshAxes] = field(default_factory=dict)

    @classmethod
    def default(cls, multi_pod: bool = False) -> "ShardingRules":
        batch: MeshAxes = ("pod", "data") if multi_pod else ("data",)
        return cls(rules={
            # --- activations ---
            "batch": batch,
            "seq": None,            # sequence parallelism off by default
            "act_heads": "model",
            "act_d_ff": "model",
            "act_vocab": "model",
            "cache_batch": batch,
            "cache_seq": None,      # decode caches: seq replicated by default
            "cache_heads": "model",
            "cache_head_dim": "model",  # fallback when kv_heads % model != 0
            # --- params ---
            "d_model": "data",      # FSDP axis
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "d_ff": "model",
            "vocab": "model",
            "experts": None,        # TP-MoE: experts replicated, d_ff split
            "layers": None,
            "ssm_state": None,
            "ssm_heads": "model",
            "conv_width": None,
            "frames": None,
        })

    def with_overrides(self, **kv: MeshAxes) -> "ShardingRules":
        new = dict(self.rules)
        new.update(kv)
        return ShardingRules(rules=new)

    def mesh_axes(self, logical: str | None) -> MeshAxes:
        if logical is None:
            return None
        if logical not in self.rules:
            raise KeyError(f"no sharding rule for logical axis {logical!r}")
        return self.rules[logical]

    def pspec(self, axes: tuple[str | None, ...], mesh: Mesh,
              shape: tuple[int, ...] | None = None) -> P:
        return resolve_pspec(shape or tuple(None for _ in axes), axes,
                             self, mesh)


# ---------------------------------------------------------------------------


def resolve_pspec(shape, axes, rules: ShardingRules, mesh: Mesh) -> P:
    """Greedy dim->mesh-axis assignment with divisibility + no-reuse.

    For each dim (in order), take the rule's mesh axes left-to-right and
    keep every axis that (a) exists in this mesh, (b) is not already used
    by an earlier dim, and (c) keeps the dim evenly divisible. This makes
    fallback chains expressible in the rules themselves — e.g. decode
    caches list both ``cache_heads -> model`` and ``cache_head_dim ->
    model``: whichever dim divides first claims the axis.
    """
    out, used = [], set()
    for dim, a in zip(shape, axes):
        m = rules.mesh_axes(a)
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        chosen, prod = [], 1
        for x in ms:
            if x not in mesh.shape or x in used:
                continue
            if dim is not None and dim % (prod * mesh.shape[x]) != 0:
                continue
            chosen.append(x)
            prod *= mesh.shape[x]
        used.update(chosen)
        out.append(tuple(chosen) if len(chosen) > 1
                   else (chosen[0] if chosen else None))
    return P(*out)


def spec_for(ps: ParamSpec, rules: ShardingRules, mesh: Mesh) -> P:
    return resolve_pspec(ps.shape, ps.axes, rules, mesh)


def param_shardings(specs, rules: ShardingRules, mesh: Mesh):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, spec_for(ps, rules, mesh)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


_ACTIVE_RULES: list[ShardingRules] = []


class use_rules:
    """Context manager installing the rules used by ``constrain``."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()


def constrain(x, axes: tuple[str | None, ...]):
    """with_sharding_constraint by logical axes; no-op without a mesh context.

    Model code calls this on the few activations whose sharding XLA's
    propagation gets wrong (most importantly the (batch, seq, VOCAB) logits,
    which propagation otherwise replicates over 'model' — a ~16x activation
    blowup on the production mesh).
    """
    from jax._src import mesh as mesh_lib
    env = mesh_lib.thread_resources.env.physical_mesh
    if env.empty:
        return x
    rules = _ACTIVE_RULES[-1] if _ACTIVE_RULES else ShardingRules.default(
        multi_pod="pod" in env.shape)
    spec = resolve_pspec(tuple(x.shape), axes, rules, env)
    return jax.lax.with_sharding_constraint(x, NamedSharding(env, spec))


def tree_shardings(shape_tree, axes_tree, rules: ShardingRules, mesh: Mesh):
    """Shardings for an arbitrary pytree of arrays/ShapeDtypeStructs given a
    parallel tree of logical-axis tuples (used for decode caches)."""
    leaves, tdef = jax.tree.flatten(shape_tree)
    axes = tdef.flatten_up_to(axes_tree)
    return tdef.unflatten([
        NamedSharding(mesh, resolve_pspec(tuple(x.shape), ax, rules, mesh))
        for x, ax in zip(leaves, axes)])


def abstract_params(specs, dtype=None):
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, dtype or ps.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _init_one(ps: ParamSpec, key) -> jnp.ndarray:
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, ps.dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, ps.dtype)
    fan_in = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
    std = ps.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, ps.shape, jnp.float32) * std).astype(ps.dtype)


def init_params(specs, key):
    """Materialize real parameters (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(ps, k) for ps, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)
