from repro.sharding.rules import (ParamSpec, ShardingRules, abstract_params,
                                  init_params, param_shardings, spec_for)

__all__ = ["ParamSpec", "ShardingRules", "abstract_params", "init_params",
           "param_shardings", "spec_for"]
