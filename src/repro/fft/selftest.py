"""Smoke target for the plan-and-execute facade.

    PYTHONPATH=src python -m repro.fft.selftest

Plans + executes c2c and r2c at every placement the container can host —
leaf (level 0), four-step (level 1), segmented and distributed over an
8-device CPU mesh — in interpret mode, checks each against the numpy
oracle, and verifies the plan cache never retraces. The distributed case
runs BOTH exchange engines (overlap="off" monolithic all_to_alls and an
overlapped ppermute pipeline) and asserts their outputs are bitwise
identical. The 2-D cases cover local fft2/rfft2 against numpy and the
distributed pencil placement (one exchange leg) in both overlap modes,
with a bitwise cross-check between the local and distributed results
(matched kernel tiles -> identical GEMMs). Exit code 0 = all pass. Wired
into test.sh and the CI workflow as the facade's cheap end-to-end gate.
"""

import os
import sys

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.fft as fft_api  # noqa: E402
from repro import compat  # noqa: E402

TOL = 5e-6


def _rel_err(got_r, got_i, want):
    got = np.asarray(got_r) + 1j * np.asarray(got_i)
    scale = np.abs(want).max() or 1.0
    return float(np.abs(got - want).max() / scale)


def _check(name: str, err: float, plan) -> bool:
    retrace_ok = plan.trace_counts["forward"] == 1
    ok = err < TOL and retrace_ok
    print(f"selftest {name:<24} {'OK' if ok else 'FAIL'} "
          f"(err={err:.2e}, traces={plan.trace_counts['forward']})")
    return ok


def main() -> int:
    rng = np.random.default_rng(0)
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    ok = True

    cases = [
        # (label, n, batch, mesh, placement)
        ("leaf", 1024, (4,), None, "local"),
        ("four_step", 1 << 15, (2,), None, "local"),
        ("segmented", 512, (16,), mesh, "segmented"),
    ]
    for label, n, batch, m, placement in cases:
        xr = rng.standard_normal((*batch, n)).astype(np.float32)
        xi = rng.standard_normal((*batch, n)).astype(np.float32)

        p = fft_api.plan(kind="c2c", n=n, batch_shape=batch, mesh=m,
                         placement=placement, interpret=True)
        yr, yi = p.execute(jnp.asarray(xr), jnp.asarray(xi))
        p.execute(jnp.asarray(xr), jnp.asarray(xi))  # must not retrace
        ok &= _check(f"c2c/{label}", _rel_err(yr, yi, np.fft.fft(xr + 1j * xi)),
                     p)

        # r2c at the same placement; four_step = the level-1 half-length
        # regime (n such that n//2 > MAX_LEAF exercises the host untangle)
        rn = 2 * n if label == "four_step" else n
        x = rng.standard_normal((*batch, rn)).astype(np.float32)
        pr = fft_api.plan(kind="r2c", n=rn, batch_shape=batch, mesh=m,
                          placement=placement, interpret=True)
        sr, si = pr.execute_real(jnp.asarray(x))
        pr.execute_real(jnp.asarray(x))
        ok &= _check(f"r2c/{label}", _rel_err(sr, si, np.fft.rfft(x)), pr)

    # distributed: cross-device four-step, both exchange engines. The
    # overlapped ppermute pipeline must match the monolithic all_to_all
    # path bit for bit — same kernels, the exchange is pure data movement.
    nd = 4096
    xr = rng.standard_normal(nd).astype(np.float32)
    xi = rng.standard_normal(nd).astype(np.float32)
    want = np.fft.fft(xr + 1j * xi)
    p_off = fft_api.plan(kind="c2c", n=nd, mesh=mesh,
                         placement="distributed", overlap="off",
                         interpret=True)
    yr0, yi0 = p_off.execute(jnp.asarray(xr), jnp.asarray(xi))
    p_off.execute(jnp.asarray(xr), jnp.asarray(xi))
    ok &= _check("c2c/dist_off", _rel_err(yr0, yi0, want), p_off)

    p_on = fft_api.plan(kind="c2c", n=nd, mesh=mesh,
                        placement="distributed", overlap=4, interpret=True)
    yr1, yi1 = p_on.execute(jnp.asarray(xr), jnp.asarray(xi))
    p_on.execute(jnp.asarray(xr), jnp.asarray(xi))
    ok &= _check("c2c/dist_overlap4", _rel_err(yr1, yi1, want), p_on)
    bitwise = bool((np.asarray(yr1) == np.asarray(yr0)).all()
                   and (np.asarray(yi1) == np.asarray(yi0)).all())
    print(f"selftest dist overlap==off bitwise     "
          f"{'OK' if bitwise else 'FAIL'} "
          f"(exposed {p_on.exposed_collective_bytes} of "
          f"{p_on.collective_bytes} collective bytes)")
    ok &= bitwise

    # ---- 2-D: local c2c + r2c against numpy ----
    n0, n1 = 64, 64
    ir = rng.standard_normal((n0, n1)).astype(np.float32)
    ii = rng.standard_normal((n0, n1)).astype(np.float32)
    want2 = np.fft.fft2(ir + 1j * ii)
    # batch_tile = n1/D matches the distributed shard's kernel tiles, so
    # the local and pencil results below are bitwise-comparable
    bt = n1 // jax.device_count()
    p2 = fft_api.plan(kind="c2c", shape=(n0, n1), interpret=True,
                      batch_tile=bt)
    lr, li = p2.execute(jnp.asarray(ir), jnp.asarray(ii))
    p2.execute(jnp.asarray(ir), jnp.asarray(ii))
    ok &= _check("c2c/fft2_local", _rel_err(lr, li, want2), p2)

    p2r = fft_api.plan(kind="r2c", shape=(n0, n1), interpret=True)
    sr2, si2 = p2r.execute_real(jnp.asarray(ir))
    p2r.execute_real(jnp.asarray(ir))
    ok &= _check("r2c/rfft2_local", _rel_err(sr2, si2, np.fft.rfft2(ir)),
                 p2r)

    # ---- 2-D: distributed pencil (ONE exchange leg), both engines ----
    p2_off = fft_api.plan(kind="c2c", shape=(n0, n1), mesh=mesh,
                          placement="distributed", overlap="off",
                          interpret=True, batch_tile=bt)
    dr, di = p2_off.execute(jnp.asarray(ir), jnp.asarray(ii))
    p2_off.execute(jnp.asarray(ir), jnp.asarray(ii))
    ok &= _check("c2c/pencil_off", _rel_err(dr, di, want2), p2_off)
    one_leg = p2_off.dist.n_exchanges == 1
    print(f"selftest pencil exchange legs         "
          f"{'OK' if one_leg else 'FAIL'} "
          f"({p2_off.dist.n_exchanges} leg, "
          f"{p2_off.collective_bytes} collective bytes)")
    ok &= one_leg

    p2_on = fft_api.plan(kind="c2c", shape=(n0, n1), mesh=mesh,
                         placement="distributed", overlap=4,
                         interpret=True, batch_tile=bt)
    er2, ei2 = p2_on.execute(jnp.asarray(ir), jnp.asarray(ii))
    p2_on.execute(jnp.asarray(ir), jnp.asarray(ii))
    ok &= _check("c2c/pencil_overlap4", _rel_err(er2, ei2, want2), p2_on)
    bitwise2 = bool((np.asarray(er2) == np.asarray(dr)).all()
                    and (np.asarray(ei2) == np.asarray(di)).all())
    print(f"selftest pencil overlap==off bitwise   "
          f"{'OK' if bitwise2 else 'FAIL'}")
    ok &= bitwise2
    bitwise_ld = bool((np.asarray(dr) == np.asarray(lr)).all()
                      and (np.asarray(di) == np.asarray(li)).all())
    print(f"selftest pencil==local bitwise         "
          f"{'OK' if bitwise_ld else 'FAIL'} (matched tiles)")
    ok &= bitwise_ld

    # ---- r2c pencil: packed half-width volume through the exchange ----
    pr2 = fft_api.plan(kind="r2c", shape=(n0, 4 * n1), mesh=mesh,
                       placement="distributed", overlap="off",
                       interpret=True)
    xrr = rng.standard_normal((n0, 4 * n1)).astype(np.float32)
    hr, hi = pr2.execute_real(jnp.asarray(xrr))
    pr2.execute_real(jnp.asarray(xrr))
    ok &= _check("r2c/pencil", _rel_err(hr, hi, np.fft.rfft2(xrr)), pr2)

    # ---- 3-D pencil: one mesh axis per sharded axis, TWO exchange legs
    d = jax.device_count()
    if d >= 8 and d % 4 == 0:
        mesh3 = compat.make_mesh((4, d // 4), ("data", "model"))
        s3 = (16, 32, 64)
        vr = rng.standard_normal(s3).astype(np.float32)
        vi = rng.standard_normal(s3).astype(np.float32)
        p3 = fft_api.plan(kind="c2c", shape=s3, mesh=mesh3,
                          placement="distributed", overlap="off",
                          interpret=True)
        wr, wi = p3.execute(jnp.asarray(vr), jnp.asarray(vi))
        p3.execute(jnp.asarray(vr), jnp.asarray(vi))
        ok &= _check("c2c/pencil3d",
                     _rel_err(wr, wi, np.fft.fftn(vr + 1j * vi)), p3)
        two_legs = p3.dist.n_exchanges == 2
        print(f"selftest pencil3d exchange legs       "
              f"{'OK' if two_legs else 'FAIL'} "
              f"({p3.dist.n_exchanges} legs, per-leg "
              f"{list(p3.per_leg_collective_bytes)} bytes)")
        ok &= two_legs

    info = fft_api.cache_info()
    print(f"selftest plan cache: {info['misses']} built, "
          f"{info['hits']} hits")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
