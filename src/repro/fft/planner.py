"""`repro.fft.plan` — cached executable plans (the `cufftPlanMany` analogue).

The paper builds one batched CUFFT plan per block size and reuses it across
every 512 MB map task; this module is the TPU translation. `plan(...)`
resolves the full strategy up front (spec.py), then returns a frozen
`ExecutablePlan` from a process-level cache keyed on the resolved spec +
mesh — so the jit'd callable and twiddle tables behind a given spec are
built exactly once, and repeat `execute` calls on the same spec trigger
zero retraces (`plan.trace_count` stays at 1; asserted in
tests/test_fft_plan_api.py and reported by benchmarks/bench_fft.py).

An `ExecutablePlan` carries:

  * the resolved `FftSpec` and the level-0/1 factorization (`plan.leaf`)
    plus, for distributed placement, the cross-device `DistPlan`;
  * the analytic cost model: `flops`, `gemm_macs`, `hbm_bytes` (folding the
    roofline byte counters `fft_hbm_bytes`/`rfft_hbm_bytes`), and
    `collective_bytes` for the distributed all_to_alls;
  * `execute(xr, xi)` / `execute_real(x)` / `execute_inverse(...)`,
    backed by lazily-built, id-stable jit'd callables. When called under an
    outer trace (e.g. from a deprecated `ops.*` shim inside `jax.jit`) the
    raw function is inlined instead, so plans stay transparent to jaxpr
    inspection and to the caller's own compilation cache.
"""

from __future__ import annotations

import math
import threading

import jax
import jax.numpy as jnp

from repro.fft import executors
from repro.fft import spec as spec_mod
from repro.fft.spec import FftSpec
from repro.kernels.fft import plan as kplan

_F32 = 4  # bytes per planar float32 element

_PLAN_CACHE: dict = {}
# wisdom_hits counts tuner wisdom-file lookups that skipped measurement
# (tuner.py). A wisdom hit that still BUILDS a new ExecutablePlan is a
# plan-cache miss — the two counters answer different questions ("did we
# re-measure?" vs "did we re-trace?") and are never conflated.
_CACHE_INFO = {"hits": 0, "misses": 0, "invalidations": 0,
               "wisdom_hits": 0}
# map-only jobs plan() from ThreadPoolExecutor workers (core/pipeline):
# the check-then-act on the cache must be atomic or the first same-shaped
# blocks each build (and later compile) their own plan
_CACHE_LOCK = threading.Lock()


def _is_tracer(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


class ExecutablePlan:
    """Frozen plan: resolved strategy + cost model + cached executables.

    Construct via `repro.fft.plan(...)`, never directly — the module-level
    cache is what makes repeat plans free.
    """

    def __init__(self, spec: FftSpec, mesh):
        object.__setattr__(self, "_frozen", False)
        self.spec = spec
        self.mesh = mesh
        # RLock: _build_inverse runs under it and re-enters via _forward()
        self._build_lock = threading.RLock()
        # r2c fast path packs n reals as n/2 complex on the contiguous
        # axis (DESIGN.md §4; deferred N-D untangle for ndim > 1)
        self._fast_r2c = (spec.kind == "r2c" and spec.impl == "matfft"
                          and spec.shape[-1] >= 4
                          and spec.placement != "distributed")
        # flop-halved distributed r2c: the packed half-width pencil
        # (DESIGN.md §14); set below when the grid admits it
        self._fast_r2c_pencil = False
        #: cross-device plan (distributed placement only)
        self.dist = None
        if spec.placement == "distributed":
            num_devices = math.prod(mesh.shape[a] for a in spec.axes)
            chunks = None if spec.overlap == "off" else spec.overlap
            if spec.ndim == 1:
                from repro.core.fft.distributed import plan_distributed
                self.dist = plan_distributed(
                    spec.n, num_devices, natural_order=spec.natural_order,
                    chunks=chunks)
                # the local factorization covers the longest per-device
                # pass — global n can exceed MAX_LEAF**2, each pass can't
                local_n = max(self.dist.n1, self.dist.n2)
            else:
                from repro.core.fft.distributed import (pencil_grid,
                                                        pencil_r2c_half,
                                                        plan_pencil)
                axis_sizes = tuple(mesh.shape[a] for a in spec.axes)
                grid = pencil_grid(spec.shape, num_devices, axis_sizes)
                eff_shape = spec.shape
                if spec.kind == "r2c":
                    half = pencil_r2c_half(spec.shape, grid, spec.impl)
                    if half is not None:
                        self._fast_r2c_pencil = True
                        eff_shape = half
                self.dist = plan_pencil(eff_shape, num_devices, grid=grid,
                                        chunks=chunks)
                local_n = max(eff_shape)
        elif spec.ndim == 1:
            local_n = spec.n // 2 if self._fast_r2c else spec.n
        else:
            # contiguous axis dominates; halved by the r2c packing
            last = spec.shape[-1] // 2 if self._fast_r2c else spec.shape[-1]
            local_n = max(last, *spec.shape[:-1])
        #: level-0/1 factorization of the longest per-device axis pass
        self.leaf = kplan.make_plan(max(local_n, 1))
        self._traces = {"forward": 0, "inverse": 0}
        self._fwd = None  # (inner, jitted), built lazily
        self._fwd_donated = None  # donate-argnums variant (execute_async)
        self._fwd_shardings = None  # (in, out) captured for donated builds
        self._inv = None
        object.__setattr__(self, "_frozen", True)

    def __setattr__(self, name, value):
        if getattr(self, "_frozen", False) and not name.startswith("_"):
            raise AttributeError(
                f"ExecutablePlan is frozen; cannot set {name!r}")
        object.__setattr__(self, name, value)

    def __repr__(self):
        s = self.spec
        return (f"ExecutablePlan(kind={s.kind!r}, shape={s.shape}, "
                f"batch_shape={s.batch_shape}, placement={s.placement!r}, "
                f"layout={s.layout!r}, impl={s.impl!r}, "
                f"levels={self.leaf.levels}, "
                f"fused_untangle={self.fused_untangle})")

    # ------------------------------------------------------------------
    # resolved-strategy views

    @property
    def kind(self) -> str:
        return self.spec.kind

    @property
    def n(self) -> int:
        """Total transform points (the length, for 1-D specs)."""
        return self.spec.n

    @property
    def shape(self) -> tuple:
        return self.spec.shape

    @property
    def ndim(self) -> int:
        return self.spec.ndim

    @property
    def batch_shape(self) -> tuple:
        return self.spec.batch_shape

    @property
    def placement(self) -> str:
        return self.spec.placement

    @property
    def levels(self) -> int:
        return self.leaf.levels

    @property
    def fused_untangle(self) -> bool:
        """True when the r2c untangle epilogue fuses into one leaf kernel.

        False in the known n > 2*MAX_LEAF regime where the half-length
        transform is level-1 and the untangle runs as a host epilogue
        (byte-neutral there, still flop-halved — DESIGN.md §4), for all
        c2c plans, and for N-D plans (the N-D untangle is deferred past
        the leading-axis passes and runs vectorized on the host).
        """
        return (self._fast_r2c and self.spec.ndim == 1
                and self.leaf.levels == 1)

    # ------------------------------------------------------------------
    # analytic cost model (roofline numerators; DESIGN.md §3-4, §9)

    @property
    def flops_per_row(self) -> float:
        """Algorithmic complex-FLOPs per batch row (5 n log2 n convention).

        N-D is a sum over axis passes; the r2c fast path halves the
        working width after the contiguous-axis pass and adds the O(N/2)
        untangle (~10 real ops per bin).
        """
        s = self.spec
        n = s.n
        if n <= 1:
            return 0.0
        if not (self._fast_r2c or self._fast_r2c_pencil):
            return 5.0 * n * math.log2(n)
        m = s.shape[-1] // 2
        if s.ndim == 1:
            return 5.0 * m * math.log2(m) + 10.0 * m if m > 1 else 10.0 * m
        half_n = n // 2
        f = 10.0 * half_n  # untangle
        if m > 1:
            f += (half_n // m) * 5.0 * m * math.log2(m)
        for ax_len in s.shape[:-1]:
            f += (half_n // ax_len) * 5.0 * ax_len * math.log2(ax_len)
        return f

    @property
    def flops(self) -> float:
        return self.spec.rows * self.flops_per_row

    @property
    def gemm_macs_per_row(self) -> float:
        """Real MACs the matmul formulation issues per batch row."""
        s = self.spec
        if s.ndim > 1:
            # per-axis passes; identical for local / segmented / pencil
            # placements (the pencil runs exactly the local GEMMs)
            fast = self._fast_r2c or self._fast_r2c_pencil
            width = s.n // 2 if fast else s.n
            last = s.shape[-1] // 2 if fast else s.shape[-1]
            macs = ((width // max(last, 1))
                    * kplan.make_plan(max(last, 1)).gemm_macs)
            for ax_len in s.shape[:-1]:
                macs += (width // ax_len) * kplan.make_plan(ax_len).gemm_macs
            return macs
        if s.placement == "distributed":
            d = self.dist
            # pass 1: n2 length-n1 transforms; pass 2: n1 length-n2
            return (d.n2 * kplan.make_plan(d.n1).gemm_macs
                    + d.n1 * kplan.make_plan(d.n2).gemm_macs)
        return self.leaf.gemm_macs

    @property
    def gemm_macs(self) -> float:
        return self.spec.rows * self.gemm_macs_per_row

    @property
    def hbm_bytes_per_row(self) -> int:
        """Planar-f32 payload HBM bytes per batch row (table traffic excl.)."""
        s = self.spec
        if s.placement == "distributed":
            plane = _F32 * s.n
            per_pass = 2 * 2 * plane
            if s.ndim > 1:
                # pencil: ndim local passes + each of the ndim-1 exchange
                # legs' buffers landing in HBM (one round-trip per leg)
                legs = s.ndim - 1
                m1 = s.shape[-1] // 2 + 1
                if self._fast_r2c_pencil:
                    # every pass and leg moves the packed HALF volume; the
                    # global untangle re-reads the half planes and writes
                    # the m+1-bin one-sided spectrum (DESIGN.md §14)
                    half_pass = per_pass // 2
                    return ((s.ndim + legs) * half_pass
                            + 2 * _F32 * (s.n // 2)
                            + 2 * _F32 * (s.n // s.shape[-1]) * m1)
                bytes_ = s.ndim * per_pass + legs * per_pass
                if s.kind == "r2c":
                    # legacy c2c + one-sided slice fallback
                    bytes_ += 2 * _F32 * (s.n // s.shape[-1]) * m1
                return bytes_
            # 1-D: two local passes, each read 2 planes + write 2 planes,
            # plus the a2a buffers landing in HBM (one round-trip per a2a)
            # and, unfused, the elementwise twiddle's extra round-trip
            n_a2a = 3 if s.natural_order else 2
            bytes_ = 2 * per_pass + n_a2a * per_pass
            if not s.fuse_twiddle:
                bytes_ += per_pass
            return bytes_
        if s.ndim > 1:
            if s.kind == "r2c" and self._fast_r2c:
                return kplan.rfftn_hbm_bytes(s.shape)
            if s.kind == "r2c":
                m1 = s.shape[-1] // 2 + 1
                return (kplan.fftn_hbm_bytes(s.shape, s.layout)
                        + 2 * _F32 * (s.n // s.shape[-1]) * m1)
            return kplan.fftn_hbm_bytes(s.shape, s.layout)
        if s.kind == "r2c" and self._fast_r2c:
            return kplan.rfft_hbm_bytes(s.n)
        if s.kind == "r2c":
            # legacy full transform + sliced one-sided write
            return (kplan.fft_hbm_bytes(s.n, s.layout)
                    + 2 * _F32 * (s.n // 2 + 1))
        return kplan.fft_hbm_bytes(s.n, s.layout)

    @property
    def hbm_bytes(self) -> int:
        return self.spec.rows * self.hbm_bytes_per_row

    @property
    def collective_bytes(self) -> int:
        """Total planar payload crossing ICI (distributed placement only).

        Mirrors `DistPlan.collective_bytes_per_device`, which now folds the
        exchange count — transposed-out plans (natural_order=False) skip
        exchange #3 and report one leg fewer.
        """
        if self.dist is None:
            return 0
        return self.dist.d * self.dist.collective_bytes_per_device

    @property
    def exposed_collective_bytes(self) -> int:
        """Collective bytes the overlap pipeline cannot hide (fill/drain
        slab per exchange — `DistPlan.exposed_collective_bytes_per_device`).
        Equal to `collective_bytes` for overlap="off" plans."""
        if self.dist is None:
            return 0
        return self.dist.d * self.dist.exposed_collective_bytes_per_device

    @property
    def hidden_collective_bytes(self) -> int:
        """Collective bytes the chunked ppermute pipeline overlaps with
        local MXU compute (the predicted overlap win's numerator)."""
        return self.collective_bytes - self.exposed_collective_bytes

    @property
    def per_leg_collective_bytes(self) -> tuple:
        """Total payload crossing ICI per exchange leg, in leg order
        (pencil: axis nd-2 first; 1-D: the three four-step exchanges).
        Sums to `collective_bytes`; () for non-distributed plans. The
        tuner ranks candidates against this per-leg accounting."""
        if self.dist is None:
            return ()
        return tuple(self.dist.d * b
                     for b in self.dist.per_leg_bytes_per_device)

    @property
    def per_leg_exposed_collective_bytes(self) -> tuple:
        """Per-leg structurally exposed (fill/drain) payload; sums to
        `exposed_collective_bytes` up to integer division."""
        if self.dist is None:
            return ()
        return tuple(self.dist.d * b
                     for b in self.dist.per_leg_exposed_bytes_per_device)

    @property
    def verify_flops(self) -> float:
        """Flops the spec's ABFT mode adds (O(rows*n) invariant checks;
        zero for verify="off"). Kept separate from `flops` — that is the
        transform's algorithmic count, which verification never changes."""
        from repro.core.resilience.verify import verify_flops
        s = self.spec
        return float(verify_flops(s.verify, s.n, max(s.rows, 1)))

    @property
    def verify_hbm_bytes(self) -> int:
        """Extra host/HBM traffic of the spec's ABFT mode (re-reads of the
        input/output planes for the energy and checksum reductions)."""
        from repro.core.resilience.verify import verify_hbm_bytes
        s = self.spec
        return verify_hbm_bytes(s.verify, s.n, max(s.rows, 1))

    @property
    def verify_overhead(self) -> float:
        """Analytic verification overhead: verify_flops / flops (0.0 when
        either side is zero) — the cost-model number the bench_verify gate
        reports alongside the measured wall-clock ratio."""
        f = self.flops
        return self.verify_flops / f if f else 0.0

    # ------------------------------------------------------------------
    # executables

    @property
    def trace_counts(self) -> dict:
        return dict(self._traces)

    @property
    def trace_count(self) -> int:
        return sum(self._traces.values())

    @property
    def executable(self):
        """The id-stable jit'd forward callable (compiled once per shape)."""
        return self._forward()[1]

    def _forward(self):
        if self._fwd is None:
            with self._build_lock:
                if self._fwd is None:
                    self._fwd = self._build_forward()
        return self._fwd

    def _build_forward(self):
        s = self.spec
        in_shardings = out_shardings = None
        if s.placement == "local":
            if s.kind == "c2c" and s.ndim == 1:
                def inner(xr, xi):
                    return executors.fft(
                        xr, xi, impl=s.impl, interpret=s.interpret,
                        batch_tile=s.batch_tile, layout=s.layout)
            elif s.kind == "c2c":
                def inner(xr, xi):
                    return executors.fftn(
                        xr, xi, s.shape, impl=s.impl, interpret=s.interpret,
                        batch_tile=s.batch_tile, layout=s.layout)
            elif s.ndim == 1:
                def inner(x):
                    return executors.rfft(
                        x, impl=s.impl, interpret=s.interpret,
                        batch_tile=s.batch_tile, layout=s.layout)
            else:
                def inner(x):
                    return executors.rfftn(
                        x, s.shape, impl=s.impl, interpret=s.interpret,
                        batch_tile=s.batch_tile, layout=s.layout)
        elif s.placement == "segmented":
            from repro.core.fft import segmented
            inner, in_shardings, out_shardings = segmented.build_segmented(
                self.mesh, s.axes, kind=s.kind, shape=s.shape, impl=s.impl,
                interpret=s.interpret, layout=s.layout)
        elif s.ndim == 1:
            from repro.core.fft import distributed
            inner = distributed.build_distributed(
                s.n, self.mesh, s.axes, impl=s.impl,
                natural_order=s.natural_order, fuse_twiddle=s.fuse_twiddle,
                interpret=s.interpret, layout=s.layout,
                overlap=None if s.overlap == "off" else s.overlap)
        else:
            from repro.core.fft import distributed
            build_kw = dict(
                impl=s.impl, interpret=s.interpret, layout=s.layout,
                batch_tile=s.batch_tile,
                overlap=None if s.overlap == "off" else s.overlap)
            if s.kind == "c2c":
                inner = distributed.build_pencil(s.shape, self.mesh,
                                                 s.axes, **build_kw)
            elif self._fast_r2c_pencil:
                half_pencil = distributed.build_pencil_r2c(
                    s.shape, self.mesh, s.axes, **build_kw)
                vr, vi = (jnp.asarray(a)
                          for a in kplan.rfft_twiddle(s.shape[-1]))
                nd = s.ndim

                def inner(x):
                    # flop-halved r2c pencil: the packed half-width volume
                    # runs the contiguous pass + every exchange leg, and
                    # the ONE N-D untangle runs on the GLOBAL half
                    # spectrum outside the shard_map — exactly where the
                    # local rfftn applies it, so this is bitwise vs the
                    # local oracle (DESIGN.md §14)
                    zr, zi = half_pencil(x)
                    return executors._untangle_nd(zr, zi, vr, vi, nd)
            else:
                pencil = distributed.build_pencil(s.shape, self.mesh,
                                                  s.axes, **build_kw)
                m1 = s.shape[-1] // 2 + 1

                def inner(x):
                    # fallback r2c pencil (grid cannot split the half
                    # width, or non-GEMM impl): ride the c2c engine and
                    # slice the one-sided spectrum (global slice, outside
                    # the shard_map — same exchange-leg count, not
                    # flop-halved)
                    yr, yi = pencil(x, jnp.zeros_like(x))
                    return yr[..., :m1], yi[..., :m1]

        def counted(*args):
            # python side effect: runs once per trace OF THIS PLAN'S JIT,
            # so this counts retraces — the "zero retrace" observable. The
            # tracer path below inlines `inner` instead, so outer-jit
            # traces by callers never pollute the count.
            self._traces["forward"] += 1
            return inner(*args)

        self._fwd_shardings = (in_shardings, out_shardings)
        if in_shardings is not None:
            jitted = jax.jit(counted, in_shardings=in_shardings,
                             out_shardings=out_shardings)
        else:
            jitted = jax.jit(counted)
        return inner, jitted

    def _forward_donated(self):
        """The forward jit with every operand buffer donated.

        A distinct executable from `_forward()` (donation is a compile-time
        property), so its first call costs one extra trace of this plan;
        after that, repeat calls are zero-retrace like the plain path. On
        backends without donation support (CPU) XLA ignores the donation
        and the call stays correct.
        """
        if self._fwd_donated is None:
            with self._build_lock:
                if self._fwd_donated is None:
                    inner = self._forward()[0]
                    nargs = 1 if self.spec.kind == "r2c" else 2

                    def counted(*args):
                        self._traces["forward"] += 1
                        return inner(*args)

                    in_sh, out_sh = self._fwd_shardings
                    donate = tuple(range(nargs))
                    if in_sh is not None:
                        self._fwd_donated = jax.jit(
                            counted, in_shardings=in_sh, out_shardings=out_sh,
                            donate_argnums=donate)
                    else:
                        self._fwd_donated = jax.jit(counted,
                                                    donate_argnums=donate)
        return self._fwd_donated

    def _inverse(self):
        if self._inv is None:
            with self._build_lock:
                if self._inv is None:
                    self._inv = self._build_inverse()
        return self._inv

    def _build_inverse(self):
        s = self.spec
        fwd_inner = self._forward()[0]
        if s.kind == "c2c":
            if (s.placement == "distributed" and s.ndim == 1
                    and not s.natural_order):
                raise NotImplementedError(
                    "execute_inverse needs natural_order=True: the "
                    "transposed-out forward returns o1-major block order, "
                    "so the conjugation identity would invert a permuted "
                    "spectrum. Plan the inverse leg with "
                    "natural_order=True (TRANSPOSED_OUT consumers apply "
                    "their pointwise op, then run a separate inverse plan)")
            n = s.n  # total points: the N-D conjugation identity's scale

            def inner(yr, yi):
                # conjugation identity; the forward must return natural
                # order for this to be the true inverse (checked above —
                # the 2-D pencil is always natural-order, just re-sharded)
                ar, ai = fwd_inner(yr, -yi)
                return ar / n, -ai / n
        else:
            if s.placement != "local":
                raise NotImplementedError(
                    f"execute_inverse for r2c plans is local-only, "
                    f"got placement={s.placement!r}")
            if s.ndim == 1:
                def inner(yr, yi):
                    return executors.irfft(
                        yr, yi, impl=s.impl, interpret=s.interpret,
                        batch_tile=s.batch_tile, layout=s.layout)
            else:
                def inner(yr, yi):
                    return executors.irfftn(
                        yr, yi, s.shape, impl=s.impl, interpret=s.interpret,
                        batch_tile=s.batch_tile, layout=s.layout)

        def counted(yr, yi):
            self._traces["inverse"] += 1
            return inner(yr, yi)

        return inner, jax.jit(counted)

    # ------------------------------------------------------------------

    def _check_shape(self, got, expected, what):
        if tuple(got) != expected:
            raise ValueError(
                f"{what}: plan was built for shape {expected} "
                f"(batch_shape={self.spec.batch_shape}, "
                f"shape={self.spec.shape}), got {tuple(got)}")

    def execute(self, xr, xi):
        """Forward c2c transform of planar (*batch_shape, *shape) float32
        arrays."""
        if self.spec.kind != "c2c":
            raise ValueError(
                "execute() is for kind='c2c' plans; use execute_real(x) "
                "on this r2c plan")
        shape = self.spec.operand_shape
        self._check_shape(xr.shape, shape, "execute")
        self._check_shape(xi.shape, shape, "execute")
        raw, jitted = self._forward()
        if _is_tracer(xr, xi):
            return raw(xr, xi)
        return jitted(xr, xi)

    def execute_real(self, x):
        """Forward r2c transform: real (*batch_shape, *shape) -> planar
        one-sided (*batch_shape, *shape[:-1], shape[-1]//2 + 1) spectrum."""
        if self.spec.kind != "r2c":
            raise ValueError(
                "execute_real() is for kind='r2c' plans; use "
                "execute(xr, xi) on this c2c plan")
        self._check_shape(x.shape, self.spec.operand_shape, "execute_real")
        raw, jitted = self._forward()
        if _is_tracer(x):
            return raw(x)
        return jitted(x)

    def execute_async(self, *operands, donate: bool = False):
        """Launch the forward transform WITHOUT synchronizing.

        Returns unrealized device arrays immediately (JAX async dispatch);
        the caller decides where the sync point is — e.g. the stream
        executor's in-flight window boundary (`core/pipeline/stream.py`)
        realizes results in its writeback stage while later batches are
        already dispatched. `execute`/`execute_real` have the same launch
        semantics but are documented as the simple path; this entry exists
        so pipelined callers state their intent and get `donate`.

        Operands: `(xr, xi)` for c2c plans, `(x,)` for r2c.
        donate=True compiles a variant that donates the operand buffers to
        XLA, letting outputs alias the staging buffers' device memory (the
        operands must not be reused after the call). Ignored (correctly,
        with no aliasing) on backends without donation support.
        """
        nargs = 1 if self.spec.kind == "r2c" else 2
        if len(operands) != nargs:
            raise ValueError(
                f"execute_async on a {self.spec.kind!r} plan takes "
                f"{nargs} operand(s), got {len(operands)}")
        shape = self.spec.operand_shape
        for op in operands:
            self._check_shape(op.shape, shape, "execute_async")
        if _is_tracer(*operands):
            return self._forward()[0](*operands)
        if donate:
            # backends without donation support ignore the hint (correct,
            # no aliasing); any "donated buffers were not usable" warning
            # is deduped per call site by the default warnings filter
            return self._forward_donated()(*operands)
        return self._forward()[1](*operands)

    def execute_inverse(self, yr, yi):
        """Inverse transform.

        c2c: planar spectrum -> planar signal (both (*batch_shape, *shape)).
        r2c: one-sided (*batch_shape, *shape[:-1], shape[-1]//2 + 1)
        spectrum -> real (*batch_shape, *shape) signal.
        """
        s = self.spec
        if s.kind == "c2c":
            shape = s.operand_shape
        else:
            shape = (*s.batch_shape, *s.shape[:-1], s.shape[-1] // 2 + 1)
        self._check_shape(yr.shape, shape, "execute_inverse")
        self._check_shape(yi.shape, shape, "execute_inverse")
        raw, jitted = self._inverse()
        if _is_tracer(yr, yi):
            return raw(yr, yi)
        return jitted(yr, yi)


# ---------------------------------------------------------------------------
# the facade


def plan(kind: str = "c2c", *, n: int | None = None, shape=None,
         batch_shape=(), mesh=None,
         placement: str = "auto", layout: str = "zero_copy",
         impl: str = "matfft", precision: str = "f32",
         interpret: bool | None = None, batch_tile: int | None = None,
         axes=None, natural_order: bool = True,
         fuse_twiddle: bool = False, overlap="auto",
         r2c_axis: int = -1, fallback: str = "error",
         verify: str = "off", tune: bool = False, wisdom_path=None,
         tune_config=None,
         store=None, work_dir=None, budget_bytes: int | None = None,
         job_config=None):
    """Resolve a transform spec and return the cached `ExecutablePlan`.

    Args:
      kind: "c2c" (planar complex) or "r2c" (real input, one-sided output).
      n: 1-D transform length — sugar for ``shape=(n,)``; pass exactly one
        of ``n``/``shape`` (power-of-two axes; real length for r2c).
      shape: N-D transform shape over the TRAILING operand axes, e.g.
        ``shape=(n0, n1)`` for a 2-D image FFT. The contiguous (last) axis
        runs the level-0/1 four-step (up to MAX_LEAF**2); earlier axes run
        as single column-kernel passes (up to MAX_LEAF each). Scalar-n and
        the equivalent 1-tuple resolve to the SAME cache key.
      batch_shape: leading batch dims of the operands; () for a single
        signal/image (required for placement="distributed").
      mesh: jax Mesh for segmented/distributed placements.
      placement: "auto" (heuristic over shape/batch/mesh), "local",
        "segmented" (map-only batch sharding, zero collectives), or
        "distributed" (1-D: cross-device four-step, 3 exchanges; 2-D:
        pencil decomposition, ONE exchange — DESIGN.md §9).
      layout: "zero_copy" (default) or "copy" (measured legacy baseline;
        for N-D the naive transpose-per-axis path bench_fft2.py gates on).
      impl: leaf kernel ("matfft" MXU GEMM, "stockham" VPU, "ref" jnp).
      precision: "f32" (reserved for future variants).
      interpret: Pallas interpret-mode override; None = auto off-TPU.
      batch_tile: kernel batch/column tile override.
      axes: mesh axes to use; None = every axis of the mesh.
      natural_order / fuse_twiddle: 1-D distributed-placement options
        (DESIGN.md §2; ignored elsewhere — the pencil is always natural).
      overlap: distributed-placement exchange engine (DESIGN.md §8):
        "off" = monolithic all_to_alls; an int = that many ppermute
        pipeline slabs per exchange, hidden behind the local FFTs (must
        divide the per-device slab widths — validated at plan time);
        "auto" picks a chunk count or "off" from the size and ring.
        Resolved before the cache key, so overlap="auto" and the
        equivalent explicit value share one plan.
      r2c_axis: which transform axis carries the real-to-complex halving;
        only the contiguous axis (-1) is supported — anything else is a
        plan-time ValueError (the packed-real reshape is only free there).
      fallback: "error" (default) raises when the requested strategy can't
        be built; "degrade" re-plans instead of raising when the mesh has
        lost devices (core/resilience/meshstate.py) or the mesh-bound
        strategy is unsatisfiable — first on the largest healthy pow2
        sub-mesh, then mesh-free/local. Every downgrade drops the stale
        mesh's cached plans (`invalidate_mesh`) and records a
        "plan_downgrade" resilience event (DESIGN.md §10).
      verify: ABFT mode for consumers that run the plan's invariant
        checks (DESIGN.md §13): "off" (default), "parseval" (per-member
        energy invariant), or "abft" (linearity checksum row per batch).
        Resolved pre-cache-key, so verified and unverified plans are
        distinct cache entries; `verify_flops`/`verify_hbm_bytes`/
        `verify_overhead` report the mode's analytic cost.
      tune: measure instead of model (DESIGN.md §14): the autotuner in
        `repro.fft.tuner` times the real candidate space — overlap chunk
        count + exchange engine, layout, batch tile (and OOC panel
        heights) — on small representative shards, applies the winner's
        knobs, and persists the decision as wisdom keyed on resolved
        spec + mesh fingerprint + backend. A wisdom hit is a pure lookup:
        zero measurement, zero retrace (counted by cache_info()'s
        `wisdom_hits`). The tuned knobs resolve BEFORE the cache key, so
        tuned and hand-specified-equivalent plans share one cache entry.
      wisdom_path: wisdom file override (default
        ~/.cache/repro_fft/wisdom.json); tune=True only.
      tune_config: `tuner.TuneConfig` override (seed, repeats, injectable
        timer/measurer, model constants); tune=True only.

    Same resolved spec (and mesh) -> the SAME plan object, with its jit'd
    executables and twiddle tables already built.
    """
    if fallback not in ("error", "degrade"):
        raise ValueError(
            f"fallback must be 'error' or 'degrade', got {fallback!r}")

    if placement == "out_of_core":
        # the operand lives in a BlockStore and the plan carries live
        # store/manifest state, so it is built here directly (never
        # process-cached) — the per-pass FFTs it launches are the cached
        # ExecutablePlans, which is where the reuse actually matters
        if kind != "c2c":
            raise ValueError(
                "placement='out_of_core' streams the four-step c2c "
                "decomposition; run real captures as packed c2c")
        if shape is not None:
            shape_t = (shape,) if isinstance(shape, int) else tuple(shape)
            if n is not None or len(shape_t) != 1:
                raise ValueError(
                    f"placement='out_of_core' transforms ONE 1-D signal; "
                    f"pass n= (or a 1-tuple shape), got shape={shape}")
            n = int(shape_t[0])
        if n is None:
            raise ValueError("placement='out_of_core' requires n=")
        if batch_shape not in ((), None):
            raise ValueError(
                f"placement='out_of_core' takes no batch_shape, got "
                f"{batch_shape}; the panel batching is internal")
        if mesh is not None:
            raise ValueError(
                "placement='out_of_core' streams through storage on one "
                "host; it takes no mesh=")
        if impl not in spec_mod.IMPLS:
            raise ValueError(
                f"unknown fft impl {impl!r}; expected one of "
                f"{spec_mod.IMPLS}")
        if store is None or work_dir is None or budget_bytes is None:
            raise ValueError(
                "placement='out_of_core' requires store= (the BlockStore "
                "holding the operand), work_dir= (tiles/manifests/output), "
                "and budget_bytes= (the host working-set cap)")
        from repro.core.fft.outofcore import plan_out_of_core
        panel_scale = 1
        if tune:
            from repro.fft import tuner
            panel_scale, rep = tuner.tune_out_of_core(
                int(n), int(budget_bytes), impl=impl,
                block_bytes=getattr(store, "block_bytes", None),
                wisdom_path=wisdom_path, config=tune_config)
            if rep.wisdom_hit:
                with _CACHE_LOCK:
                    _CACHE_INFO["wisdom_hits"] += 1
        return plan_out_of_core(int(n), store, work_dir, int(budget_bytes),
                                impl=impl, config=job_config, verify=verify,
                                panel_scale=panel_scale)
    if store is not None or work_dir is not None or budget_bytes is not None:
        raise ValueError(
            "store=/work_dir=/budget_bytes= apply only to "
            "placement='out_of_core'")

    # resolve interpret-mode auto-detection BEFORE the spec is built, so
    # interpret=None and the equivalent explicit bool key the same plan
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def _degrade(reason: str):
        """Graceful-degradation chain: shrunk healthy mesh, then local.

        Returns the downgraded plan, or None when every candidate fails
        (the caller re-raises its own error). The stale mesh's cached
        plans are dropped first — they capture collectives over devices
        that no longer answer, so a later cache hit on the old key would
        resurrect a hung strategy after the mesh heals its entry.
        """
        from repro.core.resilience import meshstate
        from repro.core.resilience.events import record_event
        dropped = invalidate_mesh(mesh)
        sub = meshstate.shrunk_mesh(mesh)
        candidates = []
        if sub is not None:
            candidates.append((sub, placement))
            if placement not in ("auto", "local"):
                candidates.append((sub, "auto"))
        candidates.append((None, "local"))
        for sub_mesh, sub_placement in candidates:
            try:
                p = plan(kind=kind, n=n, shape=shape,
                         batch_shape=batch_shape, mesh=sub_mesh,
                         placement=sub_placement, layout=layout, impl=impl,
                         precision=precision, interpret=interpret,
                         batch_tile=batch_tile, axes=None,
                         natural_order=natural_order,
                         fuse_twiddle=fuse_twiddle, overlap=overlap,
                         r2c_axis=r2c_axis, fallback="error",
                         verify=verify)
            except (ValueError, NotImplementedError):
                continue
            record_event(
                "plan_downgrade", reason=reason,
                requested_placement=placement,
                resolved_placement=p.placement,
                from_devices=int(mesh.devices.size),
                to_devices=(int(sub_mesh.devices.size)
                            if sub_mesh is not None else 0),
                epoch=meshstate.epoch(), plans_invalidated=dropped)
            return p
        return None

    if fallback == "degrade" and mesh is not None:
        from repro.core.resilience import meshstate
        if not meshstate.mesh_healthy(mesh):
            p = _degrade("mesh_degraded")
            if p is not None:
                return p
            raise RuntimeError(
                f"fallback='degrade': no viable plan for a mesh with "
                f"{len(meshstate.healthy_devices(mesh))}/"
                f"{mesh.devices.size} healthy devices")

    num_devices = None
    if mesh is not None:
        if axes is None:
            axes = tuple(mesh.shape.keys())
        else:
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            raise ValueError(
                f"none of the requested axes exist in mesh axes "
                f"{tuple(mesh.shape.keys())}")
        num_devices = math.prod(mesh.shape[a] for a in axes)
    elif axes is not None:
        raise ValueError("axes= requires mesh=")
    axis_sizes = (tuple(mesh.shape[a] for a in axes)
                  if mesh is not None else None)

    if tune:
        # measure-then-plan: the tuner picks layout/batch_tile/overlap and
        # the winning knobs resolve into the spec BEFORE the cache key —
        # a later plan() with the same knobs spelled out is the same plan.
        # A wisdom hit performs zero measurements and zero retraces.
        from repro.fft import tuner
        knobs, report = tuner.tune(
            kind=kind, n=n, shape=shape, batch_shape=batch_shape,
            mesh=mesh, axes=axes, num_devices=num_devices,
            axis_sizes=axis_sizes, placement=placement, layout=layout,
            impl=impl, precision=precision, interpret=interpret,
            batch_tile=batch_tile, natural_order=natural_order,
            fuse_twiddle=fuse_twiddle, overlap=overlap, r2c_axis=r2c_axis,
            verify=verify, wisdom_path=wisdom_path, config=tune_config)
        layout = knobs.get("layout", layout)
        batch_tile = knobs.get("batch_tile", batch_tile)
        overlap = knobs.get("overlap", overlap)
        if report.wisdom_hit:
            with _CACHE_LOCK:
                _CACHE_INFO["wisdom_hits"] += 1

    try:
        resolved = spec_mod.resolve(
            kind=kind, n=n, shape=shape, batch_shape=batch_shape,
            placement=placement, layout=layout, impl=impl,
            precision=precision, interpret=interpret, batch_tile=batch_tile,
            num_devices=num_devices, axes=axes, natural_order=natural_order,
            fuse_twiddle=fuse_twiddle, overlap=overlap, r2c_axis=r2c_axis,
            verify=verify, axis_sizes=axis_sizes)
    except ValueError:
        # mesh-bound strategy unsatisfiable (e.g. too few devices for the
        # split): degrade walks the same chain instead of raising. A
        # mesh-free failure is a genuine spec error — nothing to degrade
        # to — so it always propagates.
        if fallback == "degrade" and mesh is not None:
            p = _degrade("resolve_failed")
            if p is not None:
                return p
        raise

    # local plans don't touch the mesh -> key them mesh-free so the same
    # spec planned with and without a mesh unifies
    mesh_for_key = None if resolved.placement == "local" else mesh
    key = (resolved, mesh_for_key)
    with _CACHE_LOCK:
        cached = _PLAN_CACHE.get(key)
        if cached is not None:
            _CACHE_INFO["hits"] += 1
            return cached
        _CACHE_INFO["misses"] += 1
        p = ExecutablePlan(resolved, mesh_for_key)
        _PLAN_CACHE[key] = p
        return p


# ---------------------------------------------------------------------------
# 2-D convenience wrappers (numpy.fft.fft2/rfft2 conventions): plan over the
# trailing two axes, execute through the cached plan


def _check_2d(a, what: str) -> None:
    # numpy.fft.fft2/rfft2 raise for <2-D input; silently planning a 1-D
    # transform here would hand back a wrong-dimensionality spectrum
    if a.ndim < 2:
        raise ValueError(
            f"{what} transforms the trailing TWO axes; got a "
            f"{a.ndim}-D operand of shape {tuple(a.shape)} — use the 1-D "
            f"plan (n=...) for single-axis transforms")


def fft2(xr, xi, **kw):
    """Forward 2-D FFT over the trailing two axes of planar float32 arrays.

    ``kw`` passes through to `plan` (mesh=, placement=, overlap=, ...);
    repeat calls with the same shapes hit the plan cache.
    """
    _check_2d(xr, "fft2")
    p = plan(kind="c2c", shape=tuple(xr.shape[-2:]),
             batch_shape=tuple(xr.shape[:-2]), **kw)
    return p.execute(xr, xi)


def ifft2(yr, yi, **kw):
    """Inverse 2-D FFT over the trailing two axes (planar)."""
    _check_2d(yr, "ifft2")
    p = plan(kind="c2c", shape=tuple(yr.shape[-2:]),
             batch_shape=tuple(yr.shape[:-2]), **kw)
    return p.execute_inverse(yr, yi)


def rfft2(x, **kw):
    """Real-input 2-D FFT: (*batch, n0, n1) real -> planar one-sided
    (*batch, n0, n1//2 + 1) spectrum (numpy.fft.rfft2 convention)."""
    _check_2d(x, "rfft2")
    p = plan(kind="r2c", shape=tuple(x.shape[-2:]),
             batch_shape=tuple(x.shape[:-2]), **kw)
    return p.execute_real(x)


def irfft2(yr, yi, shape=None, **kw):
    """Inverse of rfft2: one-sided spectrum -> real (*batch, n0, n1).

    ``shape`` is the real-image shape (n0, n1); default reconstructs the
    even length 2*(yr.shape[-1] - 1) like numpy.fft.irfft2.
    """
    _check_2d(yr, "irfft2")
    if shape is None:
        shape = (yr.shape[-2], 2 * (yr.shape[-1] - 1))
    p = plan(kind="r2c", shape=tuple(shape),
             batch_shape=tuple(yr.shape[:-2]), **kw)
    return p.execute_inverse(yr, yi)


def cache_info() -> dict:
    """Process-level plan-cache stats:
    {entries, hits, misses, invalidations, wisdom_hits, size}.

    ``entries`` is the live plan count (``size`` kept as its legacy
    alias); ``invalidations`` counts plans dropped by `invalidate_mesh` /
    `clear_plan_cache` over the process lifetime. ``wisdom_hits`` counts
    tune=True plans whose knobs came from the wisdom file with zero
    measurement — distinct from ``hits``: a wisdom hit that still builds
    a new ExecutablePlan is a plan-cache MISS (it re-traces), and only
    lookups returning an existing plan object count as hits. Workloads
    that churn the cache across phases (the out-of-core job's two pass
    lengths, the degrade path's mesh drops) report this dict —
    launch/fft_job.py carries it in every run report.
    """
    with _CACHE_LOCK:
        return {**_CACHE_INFO, "entries": len(_PLAN_CACHE),
                "size": len(_PLAN_CACHE)}


def invalidate_mesh(mesh) -> int:
    """Drop every cached plan keyed on ``mesh``; returns how many.

    Called by the degrade path when the mesh loses devices: the cached
    plans' collectives span the dead devices, so serving them from the
    cache would hand back a strategy that can never complete. Local plans
    (keyed mesh-free) are untouched.
    """
    if mesh is None:
        return 0
    with _CACHE_LOCK:
        stale = [k for k in _PLAN_CACHE
                 if k[1] is not None and k[1] == mesh]
        for k in stale:
            del _PLAN_CACHE[k]
        _CACHE_INFO["invalidations"] += len(stale)
    return len(stale)


def clear_plan_cache() -> None:
    """Drop every cached plan (tests/benchmarks; compiled fns are freed)
    and reset the cache counters."""
    with _CACHE_LOCK:
        _PLAN_CACHE.clear()
        _CACHE_INFO["hits"] = 0
        _CACHE_INFO["misses"] = 0
        _CACHE_INFO["invalidations"] = 0
        _CACHE_INFO["wisdom_hits"] = 0
