"""Spec resolution for the plan-and-execute facade (`repro.fft.plan`).

The pipeline is: user kwargs -> `resolve()` -> a frozen, hashable
`FftSpec`. Resolution does ALL the up-front validation the paper's
`cufftPlanMany` analogue needs — kind/layout/impl membership, power-of-two
lengths, the placement heuristic, and the distributed `D | n1` constraint —
so strategy errors surface as one clear `ValueError` at plan time instead
of a deep shard_map/pallas failure at execute time.

Placement resolution (`placement="auto"`):

  no mesh                      -> "local"   (error if n > MAX_LEAF**2)
  mesh + 1-D batch of >1 rows  -> "segmented"   (the paper's map-only regime)
  mesh + single signal, D > 1,
      n >= D^2                 -> "distributed" (cross-device four-step)
  mesh + anything that still
      fits one device          -> "local"
  otherwise                    -> ValueError

The spec is the plan-cache key (together with the mesh), so every field is
normalized here: fields that don't apply to the resolved placement are
forced to their defaults, and mesh axes are filtered to the axes the mesh
actually has.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.kernels.fft import plan as kplan

KINDS = ("c2c", "r2c")
PLACEMENTS = ("auto", "local", "segmented", "distributed")
LAYOUTS = ("zero_copy", "copy")
IMPLS = ("matfft", "stockham", "ref")
PRECISIONS = ("f32",)  # reserved: bf16/f64 variants are future work

# largest single-device transform: two nested four-step levels of MAX_LEAF
MAX_LOCAL_N = kplan.MAX_LEAF ** 2


@dataclass(frozen=True)
class FftSpec:
    """Fully-resolved transform spec; hashable plan-cache key (sans mesh)."""

    kind: str                     # "c2c" | "r2c"
    n: int                        # transform length (real length for r2c)
    batch_shape: tuple            # leading batch dims; () for distributed
    placement: str                # resolved: "local"|"segmented"|"distributed"
    layout: str                   # "zero_copy" | "copy"
    impl: str                     # "matfft" | "stockham" | "ref"
    precision: str                # "f32"
    interpret: bool | None        # planner resolves None -> bool pre-cache
    batch_tile: int | None        # kernel batch/col tile override
    axes: tuple | None            # mesh axes (segmented batch / distributed)
    natural_order: bool           # distributed only: all_to_all #3 or not
    fuse_twiddle: bool            # distributed only: twiddle in leaf epilogue
    overlap: object = "off"       # distributed only: "off" | int chunks
    #                               ("auto" is resolved here, pre-cache-key)

    @property
    def rows(self) -> int:
        return math.prod(self.batch_shape)


def resolve_placement(n: int, rows: int, batch_ndim: int,
                      num_devices: int | None) -> str:
    """The `placement="auto"` heuristic (pure; unit-tested directly).

    Args:
      n: transform length.
      rows: total batch rows (prod of batch_shape).
      batch_ndim: len(batch_shape).
      num_devices: mesh size over the candidate axes, or None if no mesh.
    """
    if num_devices is None:
        if n > MAX_LOCAL_N:
            raise ValueError(
                f"n={n} exceeds the single-device maximum MAX_LEAF**2="
                f"{MAX_LOCAL_N}; pass mesh= so the planner can pick "
                f"placement='distributed'")
        return "local"
    if (rows > 1 and batch_ndim == 1 and n <= MAX_LOCAL_N
            and rows % num_devices == 0):
        # an indivisible batch cannot shard evenly; falls through to local
        return "segmented"
    if (rows == 1 and batch_ndim == 0 and num_devices > 1
            and n >= num_devices ** 2):
        return "distributed"
    if n <= MAX_LOCAL_N:
        return "local"
    raise ValueError(
        f"cannot auto-place n={n}: larger than the single-device maximum "
        f"({MAX_LOCAL_N}) but not distributable — the cross-device "
        f"four-step needs a scalar batch_shape and n >= D^2="
        f"{num_devices ** 2} (D={num_devices} devices)")


def _validate_distributed(n: int, num_devices: int, axes) -> None:
    """The transpose-based distributed FFT constraint, surfaced early.

    The four-step split n = n1 * n2 must satisfy D | n1 and D | n2 so each
    all_to_all exchanges equal shards — i.e. n >= D^2 for pow2 D.
    """
    p = kplan.log2i(n)
    if not kplan.is_pow2(num_devices):
        raise ValueError(
            f"distributed placement needs a power-of-two device count "
            f"along {axes}, got D={num_devices}")
    pd = kplan.log2i(num_devices)
    if p < 2 * pd:
        raise ValueError(
            f"distributed four-step requires D | n1 and D | n2 for the "
            f"split n = n1*n2, i.e. n >= D^2: got n=2^{p}, D=2^{pd} over "
            f"axes {axes}; use placement='segmented' for batches of "
            f"block-sized transforms")


def resolve(kind: str, n: int, batch_shape, placement: str, layout: str,
            impl: str, precision: str, interpret: bool | None,
            batch_tile: int | None, num_devices: int | None, axes,
            natural_order: bool, fuse_twiddle: bool,
            overlap="auto") -> FftSpec:
    """Validate + normalize everything into a frozen FftSpec."""
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    if placement not in PLACEMENTS:
        raise ValueError(
            f"unknown placement {placement!r}; expected one of {PLACEMENTS}")
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; expected one of {LAYOUTS}")
    if impl not in IMPLS:
        raise ValueError(f"unknown fft impl {impl!r}; expected one of {IMPLS}")
    if precision not in PRECISIONS:
        raise ValueError(
            f"unsupported precision {precision!r}; supported: {PRECISIONS}")
    n = int(n)
    kplan.log2i(n)  # raises for non-pow2 / non-positive
    if kind == "r2c" and n < 2:
        raise ValueError(f"r2c needs n >= 2, got n={n}")
    batch_shape = tuple(int(d) for d in batch_shape)
    if any(d < 1 for d in batch_shape):
        raise ValueError(f"batch_shape dims must be >= 1, got {batch_shape}")
    if batch_tile is not None and batch_tile < 1:
        raise ValueError(f"batch_tile must be >= 1, got {batch_tile}")

    rows = math.prod(batch_shape)
    if placement == "auto":
        placement = resolve_placement(n, rows, len(batch_shape), num_devices)

    if placement == "local":
        if n > MAX_LOCAL_N:
            raise ValueError(
                f"placement='local' caps n at MAX_LEAF**2={MAX_LOCAL_N}, "
                f"got n={n}; use placement='distributed' with a mesh")
        axes = None
    elif placement == "segmented":
        if num_devices is None:
            raise ValueError("placement='segmented' requires mesh=")
        if len(batch_shape) != 1:
            raise ValueError(
                f"placement='segmented' shards a 1-D batch of segments; "
                f"reshape to (batch, n), got batch_shape={batch_shape}")
        if n > MAX_LOCAL_N:
            raise ValueError(
                f"segmented segments run device-locally, so n caps at "
                f"MAX_LEAF**2={MAX_LOCAL_N}, got n={n}")
        if rows % num_devices:
            raise ValueError(
                f"segmented batch of {rows} rows does not shard evenly "
                f"over {num_devices} devices (axes {axes}); pad the batch "
                f"or use placement='local'")
    else:  # distributed
        if num_devices is None:
            raise ValueError("placement='distributed' requires mesh=")
        if kind != "c2c":
            raise ValueError(
                "kind='r2c' is not supported for placement='distributed'; "
                "run a c2c transform of the packed signal or use "
                "placement='segmented' for batches of real segments")
        if batch_shape != ():
            raise ValueError(
                f"placement='distributed' transforms ONE global signal of "
                f"shape (n,); got batch_shape={batch_shape} — use "
                f"placement='segmented' for batches")
        _validate_distributed(n, num_devices, axes)

    if placement == "distributed":
        # resolve "auto" and validate explicit chunk counts NOW, so an
        # indivisible chunks value is a plan-time ValueError and the
        # resolved spec (the cache key) never carries "auto". Lazy import:
        # the strategy module imports executors, not this spec module.
        from repro.core.fft.distributed import resolve_overlap
        chunks = resolve_overlap(n, num_devices, overlap)
        overlap = "off" if chunks is None else int(chunks)
    else:
        overlap = "off"

    spec = FftSpec(kind=kind, n=n, batch_shape=batch_shape,
                   placement=placement, layout=layout, impl=impl,
                   precision=precision, interpret=interpret,
                   batch_tile=batch_tile,
                   axes=tuple(axes) if axes is not None else None,
                   natural_order=bool(natural_order),
                   fuse_twiddle=bool(fuse_twiddle),
                   overlap=overlap)
    # normalize placement-irrelevant knobs so equivalent specs cache-hit
    if placement != "distributed":
        spec = replace(spec, natural_order=True, fuse_twiddle=False)
    return spec
