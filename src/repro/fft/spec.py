"""Spec resolution for the plan-and-execute facade (`repro.fft.plan`).

The pipeline is: user kwargs -> `resolve()` -> a frozen, hashable
`FftSpec`. Resolution does ALL the up-front validation the paper's
`cufftPlanMany` analogue needs — kind/layout/impl membership, power-of-two
axis lengths, the placement heuristic, and the distributed divisibility
constraints — so strategy errors surface as one clear `ValueError` at plan
time instead of a deep shard_map/pallas failure at execute time.

Transforms are N-D: `shape` is the tuple of transform-axis lengths over
the TRAILING axes of the operand (scalar ``n`` is kept as 1-D sugar and
normalizes to ``shape=(n,)`` — same cache key). The contiguous (last) axis
can run the level-0/1 four-step up to MAX_LEAF**2; every earlier axis runs
as ONE column-strided kernel pass, so it caps at MAX_LEAF. r2c rides the
packed-real fast path on the contiguous axis only (`r2c_axis` must
normalize to -1).

Placement resolution (`placement="auto"`):

  no mesh                      -> "local"   (error if the shape can't fit)
  mesh + 1-D batch of >1 rows  -> "segmented"   (the paper's map-only regime)
  mesh + single 1-D signal, D > 1,
      n >= D^2                 -> "distributed" (cross-device four-step)
  mesh + single 2-D image, D > 1,
      D | n0 and D | n1        -> "distributed" (pencil decomposition:
                                  shard rows, ONE transpose exchange)
  mesh + anything that still
      fits one device          -> "local"
  otherwise                    -> ValueError

(3-D pencil volumes are explicit-only — `placement="distributed"` with a
mesh whose axes form the device grid; the auto heuristic cannot see mesh
axis structure, so 3-D shapes that fit one device auto-place "local".)

The spec is the plan-cache key (together with the mesh), so every field is
normalized here: fields that don't apply to the resolved placement are
forced to their defaults, and mesh axes are filtered to the axes the mesh
actually has.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.kernels.fft import plan as kplan

KINDS = ("c2c", "r2c")
PLACEMENTS = ("auto", "local", "segmented", "distributed", "out_of_core")
LAYOUTS = ("zero_copy", "copy")
IMPLS = ("matfft", "stockham", "ref")
PRECISIONS = ("f32",)  # reserved: bf16/f64 variants are future work

# largest single-device transform: two nested four-step levels of MAX_LEAF
MAX_LOCAL_N = kplan.MAX_LEAF ** 2


@dataclass(frozen=True)
class FftSpec:
    """Fully-resolved transform spec; hashable plan-cache key (sans mesh)."""

    kind: str                     # "c2c" | "r2c"
    shape: tuple                  # transform-axis lengths (trailing axes;
    #                               real length on the last axis for r2c)
    batch_shape: tuple            # leading batch dims; () for distributed
    placement: str                # resolved: "local"|"segmented"|"distributed"
    layout: str                   # "zero_copy" | "copy"
    impl: str                     # "matfft" | "stockham" | "ref"
    precision: str                # "f32"
    interpret: bool | None        # planner resolves None -> bool pre-cache
    batch_tile: int | None        # kernel batch/col tile override
    axes: tuple | None            # mesh axes (segmented batch / distributed)
    natural_order: bool           # 1-D distributed only: all_to_all #3 or not
    fuse_twiddle: bool            # 1-D distributed only: twiddle in leaf
    overlap: object = "off"       # distributed only: "off" | int chunks
    #                               ("auto" is resolved here, pre-cache-key)
    verify: str = "off"           # ABFT mode: "off"|"parseval"|"abft"
    #                               (pre-cache-key: verified and unverified
    #                               plans are distinct cache entries)

    @property
    def rows(self) -> int:
        return math.prod(self.batch_shape)

    @property
    def ndim(self) -> int:
        """Number of transform axes."""
        return len(self.shape)

    @property
    def n(self) -> int:
        """Total transform points (== the length for 1-D specs)."""
        return math.prod(self.shape)

    @property
    def operand_shape(self) -> tuple:
        return (*self.batch_shape, *self.shape)


def _fits_local(shape: tuple) -> bool:
    """Can one device run this shape? The contiguous axis gets the nested
    four-step (MAX_LEAF**2); each earlier axis is a single column-kernel
    pass (MAX_LEAF)."""
    return (shape[-1] <= MAX_LOCAL_N
            and all(d <= kplan.MAX_LEAF for d in shape[:-1]))


def resolve_placement(shape, rows: int, batch_ndim: int,
                      num_devices: int | None) -> str:
    """The `placement="auto"` heuristic (pure; unit-tested directly).

    Args:
      shape: transform shape tuple (an int is 1-D sugar).
      rows: total batch rows (prod of batch_shape).
      batch_ndim: len(batch_shape).
      num_devices: mesh size over the candidate axes, or None if no mesh.
    """
    shape = (int(shape),) if isinstance(shape, int) else tuple(shape)
    fits = _fits_local(shape)
    if num_devices is None:
        if not fits:
            raise ValueError(
                f"shape={shape} exceeds the single-device maximum "
                f"(contiguous axis <= MAX_LEAF**2={MAX_LOCAL_N}, earlier "
                f"axes <= MAX_LEAF={kplan.MAX_LEAF}); pass mesh= so the "
                f"planner can pick placement='distributed'")
        return "local"
    if (rows > 1 and batch_ndim == 1 and fits
            and rows % num_devices == 0):
        # an indivisible batch cannot shard evenly; falls through to local
        return "segmented"
    if rows == 1 and batch_ndim == 0 and num_devices > 1:
        if len(shape) == 1 and shape[0] >= num_devices ** 2:
            return "distributed"
        if (len(shape) == 2 and kplan.is_pow2(num_devices)
                and all(d % num_devices == 0 for d in shape)):
            return "distributed"  # pencil: shard rows, one exchange
    if fits:
        return "local"
    raise ValueError(
        f"cannot auto-place shape={shape}: larger than the single-device "
        f"maximum but not distributable — the cross-device engines need a "
        f"scalar batch_shape and either a 1-D signal with n >= D^2="
        f"{num_devices ** 2} or a 2-D image with both axes divisible by "
        f"D={num_devices}")


def _validate_distributed(n: int, num_devices: int, axes) -> None:
    """The transpose-based 1-D distributed FFT constraint, surfaced early.

    The four-step split n = n1 * n2 must satisfy D | n1 and D | n2 so each
    all_to_all exchanges equal shards — i.e. n >= D^2 for pow2 D.
    """
    p = kplan.log2i(n)
    if not kplan.is_pow2(num_devices):
        raise ValueError(
            f"distributed placement needs a power-of-two device count "
            f"along {axes}, got D={num_devices}")
    pd = kplan.log2i(num_devices)
    if p < 2 * pd:
        raise ValueError(
            f"distributed four-step requires D | n1 and D | n2 for the "
            f"split n = n1*n2, i.e. n >= D^2: got n=2^{p}, D=2^{pd} over "
            f"axes {axes}; use placement='segmented' for batches of "
            f"block-sized transforms")


def _validate_pencil(shape: tuple, num_devices: int, axes,
                     grid=None) -> None:
    """The N-D pencil decomposition constraints, surfaced early.

    Each exchange leg k shards axis k on input and splits axis k+1 — so
    grid[k] must divide both (for the flattened 2-D grid, both axes must
    be divisible by D). Every non-contiguous axis runs as one
    column-kernel pass, so it caps at MAX_LEAF; the contiguous axis runs
    the local level-0/1 path (MAX_LEAF**2).
    """
    if not kplan.is_pow2(num_devices):
        raise ValueError(
            f"distributed placement needs a power-of-two device count "
            f"along {axes}, got D={num_devices}")
    if grid is None:
        grid = (num_devices,) * (len(shape) - 1)
    for ax_i, d in enumerate(shape):
        # the grid factors touching axis i: leg i-1 splits it, leg i
        # shards it — both must divide (2-D: the one flattened factor D)
        for g in {grid[k] for k in (ax_i - 1, ax_i) if 0 <= k < len(grid)}:
            if not kplan.is_pow2(g):
                raise ValueError(
                    f"pencil device-grid factors must be powers of two, "
                    f"got grid={grid} (axes {axes})")
            if d % g:
                raise ValueError(
                    f"distributed pencil shapes need every sharded axis "
                    f"divisible by D: axis {ax_i} of shape {shape} is {d}, "
                    f"not divisible by D={g} (grid={grid}, axes {axes})")
    for ax_i, d in enumerate(shape[:-1]):
        if d > kplan.MAX_LEAF:
            raise ValueError(
                f"pencil axis {ax_i} runs as one column-kernel pass per "
                f"device, so it caps at MAX_LEAF={kplan.MAX_LEAF}; got "
                f"{d}")
    if shape[-1] > MAX_LOCAL_N:
        raise ValueError(
            f"pencil axis {len(shape) - 1} runs the local level-0/1 path, "
            f"so it caps at MAX_LEAF**2={MAX_LOCAL_N}; got {shape[-1]}")


def _normalize_shape(n, shape) -> tuple:
    if (n is None) == (shape is None):
        raise ValueError(
            "pass exactly one of n= (1-D sugar) or shape= (N-D tuple)")
    if shape is None:
        shape = (int(n),)
    elif isinstance(shape, int):
        shape = (int(shape),)
    else:
        shape = tuple(int(d) for d in shape)
    if not shape or len(shape) > 3:
        raise ValueError(
            f"shape must have 1-3 transform axes, got {shape}")
    for ax_i, d in enumerate(shape):
        if not kplan.is_pow2(d):
            raise ValueError(
                f"every transform axis must be a power of two; axis "
                f"{ax_i} of shape {shape} is {d}")
    if len(shape) > 1 and min(shape) < 2:
        raise ValueError(
            f"N-D transform axes must be >= 2, got shape {shape}")
    return shape


def resolve(kind: str, n=None, batch_shape=(), placement: str = "auto",
            layout: str = "zero_copy", impl: str = "matfft",
            precision: str = "f32", interpret: bool | None = None,
            batch_tile: int | None = None, num_devices: int | None = None,
            axes=None, natural_order: bool = True,
            fuse_twiddle: bool = False, overlap="auto", shape=None,
            r2c_axis: int = -1, verify: str = "off",
            axis_sizes=None) -> FftSpec:
    """Validate + normalize everything into a frozen FftSpec.

    ``axis_sizes`` is the per-mesh-axis device count in ``axes`` order
    (the planner supplies it from the mesh); 3-D pencil volumes need it
    to form the device grid — 1-D/2-D placements ignore it.
    """
    from repro.core.resilience.verify import VERIFY_MODES
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    if verify not in VERIFY_MODES:
        raise ValueError(
            f"unknown verify mode {verify!r}; expected one of {VERIFY_MODES}")
    if placement not in PLACEMENTS:
        raise ValueError(
            f"unknown placement {placement!r}; expected one of {PLACEMENTS}")
    if layout not in LAYOUTS:
        raise ValueError(f"unknown layout {layout!r}; expected one of {LAYOUTS}")
    if impl not in IMPLS:
        raise ValueError(f"unknown fft impl {impl!r}; expected one of {IMPLS}")
    if precision not in PRECISIONS:
        raise ValueError(
            f"unsupported precision {precision!r}; supported: {PRECISIONS}")
    if placement == "out_of_core":
        # out-of-core plans bind to live store/directory state, so they
        # are built (and NOT process-cached) by `repro.fft.plan` itself —
        # there is no frozen mesh spec to resolve here
        raise ValueError(
            "placement='out_of_core' is constructed by repro.fft.plan("
            "store=..., work_dir=..., budget_bytes=...) and has no "
            "resolvable FftSpec (the plan is bound to a BlockStore, "
            "not a mesh)")
    shape = _normalize_shape(n, shape)
    ndim = len(shape)
    if kind == "r2c":
        if shape[-1] < 2:
            raise ValueError(f"r2c needs n >= 2, got n={shape[-1]}")
        ax = r2c_axis if r2c_axis >= 0 else ndim + r2c_axis
        if ax != ndim - 1:
            raise ValueError(
                f"r2c_axis={r2c_axis} is not the contiguous axis: the "
                f"packed-real fast path reads n reals as n/2 complex via a "
                f"free reshape, which only the LAST transform axis "
                f"(r2c_axis=-1) supports; transpose the operand or use "
                f"kind='c2c'")
    batch_shape = tuple(int(d) for d in batch_shape)
    if any(d < 1 for d in batch_shape):
        raise ValueError(f"batch_shape dims must be >= 1, got {batch_shape}")
    if batch_tile is not None and batch_tile < 1:
        raise ValueError(f"batch_tile must be >= 1, got {batch_tile}")

    rows = math.prod(batch_shape)
    if placement == "auto":
        placement = resolve_placement(shape, rows, len(batch_shape),
                                      num_devices)

    if placement == "local":
        if not _fits_local(shape):
            raise ValueError(
                f"placement='local' caps the contiguous axis at "
                f"MAX_LEAF**2={MAX_LOCAL_N} and earlier axes at "
                f"MAX_LEAF={kplan.MAX_LEAF}, got shape={shape}; use "
                f"placement='distributed' with a mesh")
        axes = None
    elif placement == "segmented":
        if num_devices is None:
            raise ValueError("placement='segmented' requires mesh=")
        if len(batch_shape) != 1:
            raise ValueError(
                f"placement='segmented' shards a 1-D batch of segments; "
                f"reshape to (batch, *shape), got batch_shape={batch_shape}")
        if not _fits_local(shape):
            raise ValueError(
                f"segmented segments run device-locally, so the contiguous "
                f"axis caps at MAX_LEAF**2={MAX_LOCAL_N} and earlier axes "
                f"at MAX_LEAF={kplan.MAX_LEAF}, got shape={shape}")
        if rows % num_devices:
            raise ValueError(
                f"segmented batch of {rows} rows does not shard evenly "
                f"over {num_devices} devices (axes {axes}); pad the batch "
                f"or use placement='local'")
    else:  # distributed
        if num_devices is None:
            raise ValueError("placement='distributed' requires mesh=")
        if batch_shape != ():
            raise ValueError(
                f"placement='distributed' transforms ONE global signal of "
                f"shape {shape}; got batch_shape={batch_shape} — use "
                f"placement='segmented' for batches")
        if ndim == 1:
            if kind != "c2c":
                raise ValueError(
                    "kind='r2c' is not supported for 1-D "
                    "placement='distributed'; run a c2c transform of the "
                    "packed signal or use placement='segmented' for "
                    "batches of real segments")
            _validate_distributed(shape[0], num_devices, axes)
        else:
            # N-D pencil (2-D: one flattened exchange ring; 3-D: one mesh
            # axis per sharded leading axis — pencil_grid validates that
            # the mesh structure matches). Lazy import: the strategy
            # module imports executors, not this spec module.
            from repro.core.fft.distributed import pencil_grid
            grid = pencil_grid(shape, num_devices, axis_sizes)
            _validate_pencil(shape, num_devices, axes, grid)

    if placement == "distributed":
        # resolve "auto" and validate explicit chunk counts NOW, so an
        # indivisible chunks value is a plan-time ValueError and the
        # resolved spec (the cache key) never carries "auto". Lazy import:
        # the strategy module imports executors, not this spec module.
        if ndim == 1:
            from repro.core.fft.distributed import resolve_overlap
            chunks = resolve_overlap(shape[0], num_devices, overlap)
        else:
            from repro.core.fft.distributed import (pencil_r2c_half,
                                                    resolve_overlap_pencil)
            # the flop-halved r2c pencil runs its exchanges on the HALF
            # width (DESIGN.md §14), so chunk validity resolves against
            # the half shape; the legacy c2c+slice fallback (half=None)
            # keeps the full shape
            eff_shape = shape
            if kind == "r2c":
                half = pencil_r2c_half(shape, grid, impl)
                if half is not None:
                    eff_shape = half
            chunks = resolve_overlap_pencil(eff_shape, num_devices,
                                            overlap, grid=grid)
        overlap = "off" if chunks is None else int(chunks)
    else:
        overlap = "off"

    spec = FftSpec(kind=kind, shape=shape, batch_shape=batch_shape,
                   placement=placement, layout=layout, impl=impl,
                   precision=precision, interpret=interpret,
                   batch_tile=batch_tile,
                   axes=tuple(axes) if axes is not None else None,
                   natural_order=bool(natural_order),
                   fuse_twiddle=bool(fuse_twiddle),
                   overlap=overlap, verify=verify)
    # normalize placement-irrelevant knobs so equivalent specs cache-hit
    # (the pencil engine has no outer twiddle and is always natural-order)
    if placement != "distributed" or len(shape) > 1:
        spec = replace(spec, natural_order=True, fuse_twiddle=False)
    return spec
