"""Execution bodies behind `repro.fft` plans: the level-0/1 transform code.

This module is the *mechanism* layer of the plan-and-execute facade: plain
functions over planar float32 arrays that drive the Pallas kernels
(`kernels/fft/matfft.py`, `kernels/fft/stockham.py`). It holds what used to
be the bodies of `kernels.fft.ops` before the facade existed; `ops.*` is
now a set of deprecated shims over `repro.fft.plan`.

Hierarchy (mirrors the paper's block decomposition, DESIGN.md §2):

  level 0  (VMEM/MXU)   matfft kernel, n <= plan.MAX_LEAF
  level 1  (HBM, here)  host four-step n = n1*n2, leaf = level 0, with the
                        outer twiddle FUSED into the first leaf's epilogue
  level 2  (ICI)        cross-device four-step — core/fft/distributed.py,
                        which calls back into these executors for local work

The ``layout`` option selects how level-1 pass boundaries move data
(DESIGN.md §3):

  "zero_copy" (default)  column-strided Pallas kernels read/write the
                         natural buffers directly; no transposed tensor is
                         ever materialized in HBM
  "copy"                 the legacy reshape+swapaxes path, kept as the
                         measured baseline (benchmarks/bench_fft.py) and
                         as the fallback for non-matfft leaf impls

``interpret=None`` auto-selects interpret mode off-TPU so the same code
runs on this CPU container and on real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fft import plan as fft_plan
from repro.kernels.fft import ref as fft_ref
from repro.kernels.fft.matfft import (four_step_zero_copy, matfft,
                                      matfft_cols, rfft_leaf,
                                      untangle_half_spectrum)
from repro.kernels.fft.stockham import stockham_fft

Planar = tuple[jnp.ndarray, jnp.ndarray]


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _leaf(xr, xi, impl: str, interpret: bool, epilogue=None, batch_tile=None):
    if impl == "matfft":
        return matfft(xr, xi, epilogue=epilogue, batch_tile=batch_tile,
                      interpret=interpret)
    if impl == "stockham":
        if epilogue is not None:
            yr, yi = stockham_fft(xr, xi, batch_tile=batch_tile,
                                  interpret=interpret)
            er, ei = epilogue
            period = er.shape[0]
            rows = yr.shape[0]
            er = jnp.tile(er, (rows // period, 1))
            ei = jnp.tile(ei, (rows // period, 1))
            return yr * er - yi * ei, yr * ei + yi * er
        return stockham_fft(xr, xi, batch_tile=batch_tile, interpret=interpret)
    if impl == "ref":
        yr, yi = fft_ref.fft_ref(xr, xi)
        if epilogue is not None:
            er, ei = epilogue
            period = er.shape[0]
            er = jnp.tile(er, (yr.shape[0] // period, 1))
            ei = jnp.tile(ei, (yr.shape[0] // period, 1))
            return yr * er - yi * ei, yr * ei + yi * er
        return yr, yi
    raise ValueError(f"unknown fft impl {impl!r}")


def fft(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
        interpret: bool | None = None, batch_tile: int | None = None,
        global_twiddle=None, layout: str = "zero_copy") -> Planar:
    """Batched forward FFT along the last axis of planar float32 arrays.

    Any leading batch shape; last-axis length must be a power of two up to
    MAX_LEAF**2 (single device). Larger transforms go through
    core/fft/distributed.py.
    """
    if layout not in ("zero_copy", "copy"):
        raise ValueError(f"unknown layout {layout!r}")
    interpret = _auto_interpret(interpret)
    batch_shape, n = xr.shape[:-1], xr.shape[-1]
    if n == 1:
        return xr, xi
    fft_plan.log2i(n)
    rows = 1
    for d in batch_shape:
        rows *= d
    xr2 = xr.reshape(rows, n)
    xi2 = xi.reshape(rows, n)

    p = fft_plan.make_plan(n)
    if p.levels == 1:
        if global_twiddle is not None and impl == "matfft":
            # fused distributed twiddle (core/fft/distributed.py): computed
            # on the fly in the kernel epilogue, no HBM table
            yr, yi = matfft(xr2, xi2, global_twiddle=global_twiddle,
                            batch_tile=batch_tile,
                            interpret=_auto_interpret(interpret))
        else:
            yr, yi = _leaf(xr2, xi2, impl, interpret, batch_tile=batch_tile)
    else:
        if global_twiddle is not None:
            raise ValueError("global_twiddle requires a single-level plan")
        yr, yi = _four_step(xr2, xi2, p.n1, p.n2, impl, interpret, batch_tile,
                            layout)
    return yr.reshape(*batch_shape, n), yi.reshape(*batch_shape, n)


def _four_step(xr, xi, n1: int, n2: int, impl: str, interpret: bool,
               batch_tile: int | None, layout: str = "zero_copy") -> Planar:
    """Host-level four-step: two batched leaf passes.

    layout="zero_copy" (matfft only): both passes are column-strided Pallas
    kernels over free reshapes of the same buffers — no transposed tensor
    is ever materialized (matfft.four_step_zero_copy).

    layout="copy": the legacy path — three reshape+swapaxes transposes
    around two row-major leaf passes, each a full HBM round-trip. Pass 1
    still fuses the outer twiddle W_N^{o1*i2} into the leaf epilogue: the
    epilogue operand is just the (n2, n1) table indexed periodically — no
    O(batch*n) twiddle tensor is ever materialized.
    """
    rows, n = xr.shape
    assert n == n1 * n2

    if layout == "zero_copy" and impl == "matfft":
        return four_step_zero_copy(xr, xi, n1, n2, col_tile=batch_tile,
                                   interpret=interpret)

    # T[o1, i2] -> transpose to (i2, o1): row (b, i2) of pass-1 output gets
    # multiplied by T^T[i2, :]. Periodic with period n2 in the row index.
    tr, ti = fft_plan.twiddle_table(n1, n2, n)
    epi = (jnp.asarray(tr.T.copy()), jnp.asarray(ti.T.copy()))

    def to_cols(a):  # (rows, n1*n2) -> (rows*n2, n1)
        return a.reshape(rows, n1, n2).swapaxes(1, 2).reshape(rows * n2, n1)

    ar, ai = _leaf(to_cols(xr), to_cols(xi), impl, interpret,
                   epilogue=epi, batch_tile=batch_tile)

    def to_rows(a):  # (rows*n2, n1) -> (rows*n1, n2)
        return a.reshape(rows, n2, n1).swapaxes(1, 2).reshape(rows * n1, n2)

    cr, ci = _leaf(to_rows(ar), to_rows(ai), impl, interpret,
                   batch_tile=batch_tile)

    def out_order(a):  # rows (b, o1), cols o2 -> flat o = o2*n1 + o1
        return a.reshape(rows, n1, n2).swapaxes(1, 2).reshape(rows, n)

    return out_order(cr), out_order(ci)


def fft_cols(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
             interpret: bool | None = None, col_tile: int | None = None,
             global_twiddle=None, layout: str = "zero_copy",
             out_major: str = "row", col_offset: int = 0,
             ncols: int | None = None) -> Planar:
    """FFT each COLUMN of planar (L, C) arrays.

    Returns (C', L) row-major for ``out_major="row"`` or (L, C')
    column-major for ``out_major="col"`` (C' = ncols when a slab is
    selected). Semantically ``fft(xr.T, xi.T)`` (transposed again for
    "col"), but with layout="zero_copy" the column-strided Pallas kernel
    reads the operand in place and writes the requested layout directly —
    the materialized `.T` copies at distributed-FFT pass boundaries fold
    into the kernel (DESIGN.md §3).

    ``col_offset``/``ncols`` restrict the call to the column slab
    ``[col_offset, col_offset + ncols)``: on the zero-copy path the
    BlockSpec index map fetches the slab from the full operand in place
    (no retile); the fallback slices (it already materializes a copy).
    """
    interpret_b = _auto_interpret(interpret)
    L, C = xr.shape
    nc = C - col_offset if ncols is None else ncols
    if (layout == "zero_copy" and impl == "matfft" and L > 1
            and fft_plan.is_pow2(C) and fft_plan.is_pow2(nc)
            and fft_plan.make_plan(L).levels == 1):
        yr, yi = matfft_cols(xr.reshape(1, L, C), xi.reshape(1, L, C),
                             out_major=out_major,
                             global_twiddle=global_twiddle,
                             col_tile=col_tile, col_offset=col_offset,
                             ncols=nc, interpret=interpret_b)
        if out_major == "col":
            return yr.reshape(L, nc), yi.reshape(L, nc)
        return yr, yi
    # fallback materializes the transpose; the columns become batch rows,
    # so the caller's tile request carries over as batch_tile
    if col_offset or nc != C:
        xr = xr[:, col_offset:col_offset + nc]
        xi = xi[:, col_offset:col_offset + nc]
    yr, yi = fft(xr.T, xi.T, impl=impl, interpret=interpret,
                 batch_tile=col_tile, global_twiddle=global_twiddle,
                 layout=layout)
    if out_major == "col":
        return yr.T, yi.T
    return yr, yi


def ifft(xr: jnp.ndarray, xi: jnp.ndarray, **kw) -> Planar:
    """Inverse FFT via the conjugation identity: ifft(x) = conj(fft(conj(x)))/n."""
    n = xr.shape[-1]
    yr, yi = fft(xr, -xi, **kw)
    return yr / n, -yi / n


def rfft(x: jnp.ndarray, *, impl: str = "matfft",
         interpret: bool | None = None, batch_tile: int | None = None,
         layout: str = "zero_copy") -> Planar:
    """Real-input FFT; returns planar one-sided spectrum (n//2 + 1 bins).

    Fast path (impl="matfft", n >= 4): n real samples are packed as n/2
    complex points by a FREE reshape, one half-length transform runs on the
    MXU, and the conjugate-symmetric spectrum is untangled in the kernel
    epilogue (leaf sizes) or a vectorized jnp epilogue (level-1 sizes) —
    ~half the flops and HBM bytes of fft()+slice (DESIGN.md §4).
    """
    n = x.shape[-1]
    x = x.astype(jnp.float32)
    if n < 4 or impl != "matfft":
        # legacy path: full complex transform, slice the half spectrum
        yr, yi = fft(x, jnp.zeros_like(x), impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
        return yr[..., : n // 2 + 1], yi[..., : n // 2 + 1]
    fft_plan.log2i(n)
    m = n // 2
    batch_shape = x.shape[:-1]
    rows = 1
    for d in batch_shape:
        rows *= d
    x2 = x.reshape(rows, n)
    if fft_plan.make_plan(m).levels == 1:
        yr, yi = rfft_leaf(x2, batch_tile=batch_tile,
                           interpret=_auto_interpret(interpret))
    else:
        # level-1: the untangle can't live inside one leaf tile (bin o
        # pairs with m - o, a different o1-block), so pack + untangle run
        # as host epilogues around the half-length zero-copy transform
        z = x2.reshape(rows, m, 2)
        zr, zi = fft(z[..., 0], z[..., 1], impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
        vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n))
        yr, yi = untangle_half_spectrum(zr, zi, vr, vi)
    return yr.reshape(*batch_shape, m + 1), yi.reshape(*batch_shape, m + 1)


def irfft(yr: jnp.ndarray, yi: jnp.ndarray, *, impl: str = "matfft",
          interpret: bool | None = None, batch_tile: int | None = None,
          layout: str = "zero_copy") -> jnp.ndarray:
    """Inverse of rfft: one-sided (..., n//2 + 1) spectrum -> real (..., n).

    Runs the packing trick in reverse: re-entangle the even/odd sub-spectra
    into a half-length spectrum, one half-length inverse transform, then
    interleave — the same ~2x saving as the forward fast path.
    """
    m = yr.shape[-1] - 1
    n = 2 * m
    if m < 2 or impl != "matfft":
        # legacy path: mirror to the full spectrum, full inverse transform
        fr = jnp.concatenate([yr, yr[..., -2:0:-1]], axis=-1)
        fi = jnp.concatenate([yi, -yi[..., -2:0:-1]], axis=-1)
        zr, _ = ifft(fr, fi, impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
        return zr
    # E[k] = (X[k] + conj(X[m-k]))/2 ; O[k] = conj(v[k])*(X[k] - conj(X[m-k]))/2
    xr_, xi_ = yr[..., :m], yi[..., :m]
    pr, pi = yr[..., :0:-1], -yi[..., :0:-1]  # conj(X[m-k]), k = 0..m-1
    er, ei = 0.5 * (xr_ + pr), 0.5 * (xi_ + pi)
    dr, di = 0.5 * (xr_ - pr), 0.5 * (xi_ - pi)
    vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n))
    our = vr * dr + vi * di  # conj(v) * D
    oui = vr * di - vi * dr
    # Z = E + i*O, z = IDFT_m(Z), x[2k] = Re z[k], x[2k+1] = Im z[k]
    zr, zi = ifft(er - oui, ei + our, impl=impl, interpret=interpret,
                  batch_tile=batch_tile, layout=layout)
    return jnp.stack([zr, zi], axis=-1).reshape(*zr.shape[:-1], n)
