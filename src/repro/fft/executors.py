"""Execution bodies behind `repro.fft` plans: the level-0/1 transform code.

This module is the *mechanism* layer of the plan-and-execute facade: plain
functions over planar float32 arrays that drive the Pallas kernels
(`kernels/fft/matfft.py`, `kernels/fft/stockham.py`). It holds what used to
be the bodies of `kernels.fft.ops` before the facade existed; `ops.*` is
now a set of deprecated shims over `repro.fft.plan`.

Hierarchy (mirrors the paper's block decomposition, DESIGN.md §2):

  level 0  (VMEM/MXU)   matfft kernel, n <= plan.MAX_LEAF
  level 1  (HBM, here)  host four-step n = n1*n2, leaf = level 0, with the
                        outer twiddle FUSED into the first leaf's epilogue
  level 2  (ICI)        cross-device four-step — core/fft/distributed.py,
                        which calls back into these executors for local work

The ``layout`` option selects how level-1 pass boundaries move data
(DESIGN.md §3):

  "zero_copy" (default)  column-strided Pallas kernels read/write the
                         natural buffers directly; no transposed tensor is
                         ever materialized in HBM
  "copy"                 the legacy reshape+swapaxes path, kept as the
                         measured baseline (benchmarks/bench_fft.py) and
                         as the fallback for non-matfft leaf impls

``interpret=None`` auto-selects interpret mode off-TPU so the same code
runs on this CPU container and on real hardware.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.fft import plan as fft_plan
from repro.kernels.fft import ref as fft_ref
from repro.kernels.fft.matfft import (matfft, matfft_cols, rfft_leaf,
                                      rfft_pack_leaf,
                                      untangle_half_spectrum)
from repro.kernels.fft.stockham import stockham_fft

Planar = tuple[jnp.ndarray, jnp.ndarray]


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _leaf(xr, xi, impl: str, interpret: bool, epilogue=None, batch_tile=None):
    if impl == "matfft":
        return matfft(xr, xi, epilogue=epilogue, batch_tile=batch_tile,
                      interpret=interpret)
    if impl == "stockham":
        if epilogue is not None:
            yr, yi = stockham_fft(xr, xi, batch_tile=batch_tile,
                                  interpret=interpret)
            er, ei = epilogue
            period = er.shape[0]
            rows = yr.shape[0]
            er = jnp.tile(er, (rows // period, 1))
            ei = jnp.tile(ei, (rows // period, 1))
            return yr * er - yi * ei, yr * ei + yi * er
        return stockham_fft(xr, xi, batch_tile=batch_tile, interpret=interpret)
    if impl == "ref":
        yr, yi = fft_ref.fft_ref(xr, xi)
        if epilogue is not None:
            er, ei = epilogue
            period = er.shape[0]
            er = jnp.tile(er, (yr.shape[0] // period, 1))
            ei = jnp.tile(ei, (yr.shape[0] // period, 1))
            return yr * er - yi * ei, yr * ei + yi * er
        return yr, yi
    raise ValueError(f"unknown fft impl {impl!r}")


# ---------------------------------------------------------------------------
# the shared axis-pass primitive: every multi-axis transform in the repo —
# the level-1 four-step, true N-D fftn/rfftn, and the distributed pass
# boundaries (via fft_cols) — is a chain of these


def axis_pass(xr: jnp.ndarray, xi: jnp.ndarray, view, *,
              out_major: str = "row",
              epilogue: tuple | None = None, global_twiddle=None,
              impl: str = "matfft", interpret: bool | None = None,
              col_tile: int | None = None, col_offset: int = 0,
              ncols: int | None = None, layout: str = "zero_copy") -> Planar:
    """FFT along the MIDDLE axis of a planar ``view = (B, L, C)`` reshape.

    The single shared primitive behind every multi-pass transform: "FFT one
    axis of a 2-D view, with optional twiddle, with row/col-major store".
    ``out_major="row"`` returns (B*nc, L) with row index b*nc + c;
    ``out_major="col"`` returns (B, L, nc) — the result written back in
    column order, i.e. the transformed axis stays where it was, which is
    what keeps a chain of passes transpose-free in HBM.

    ``epilogue`` is a planar (C, L) table multiplied into output row
    (b, c) (the four-step's outer twiddle); ``global_twiddle`` is the
    distributed on-the-fly variant. ``col_offset``/``ncols`` select an
    aligned column slab fetched in place from the full operand (the
    overlapped exchange engines' slab reads).

    layout="zero_copy" + impl="matfft" runs the column-strided Pallas
    kernel (`matfft_cols`); anything else falls back to a materialized
    transpose around the row-major leaf (the measured "copy" baseline).
    """
    if epilogue is not None and global_twiddle is not None:
        # matfft_cols asserts this deep in the kernel; the transpose
        # fallback used to silently drop the twiddle — fail loudly so the
        # two layouts can never diverge on a combined call
        raise ValueError(
            "axis_pass: epilogue and global_twiddle are mutually exclusive")
    B, L, C = view
    xr3 = xr.reshape(B, L, C)
    xi3 = xi.reshape(B, L, C)
    nc = C - col_offset if ncols is None else ncols
    if (layout == "zero_copy" and impl == "matfft" and L > 1
            and fft_plan.is_pow2(C) and fft_plan.is_pow2(nc)
            and fft_plan.make_plan(L).levels == 1):
        return matfft_cols(xr3, xi3, out_major=out_major, epilogue=epilogue,
                           global_twiddle=global_twiddle, col_tile=col_tile,
                           col_offset=col_offset, ncols=nc,
                           interpret=_auto_interpret(interpret))
    # fallback: materialize the transpose; columns become batch rows
    if col_offset or nc != C:
        xr3 = xr3[:, :, col_offset:col_offset + nc]
        xi3 = xi3[:, :, col_offset:col_offset + nc]
    xrt = xr3.swapaxes(1, 2).reshape(B * nc, L)
    xit = xi3.swapaxes(1, 2).reshape(B * nc, L)
    if epilogue is not None:
        er, ei = epilogue
        er = jnp.tile(er[col_offset:col_offset + nc], (B, 1))
        ei = jnp.tile(ei[col_offset:col_offset + nc], (B, 1))
        yr, yi = fft(xrt, xit, impl=impl, interpret=interpret,
                     batch_tile=col_tile, layout=layout)
        yr, yi = yr * er - yi * ei, yr * ei + yi * er
    else:
        yr, yi = fft(xrt, xit, impl=impl, interpret=interpret,
                     batch_tile=col_tile, global_twiddle=global_twiddle,
                     layout=layout)
    if out_major == "col":
        return (yr.reshape(B, nc, L).swapaxes(1, 2),
                yi.reshape(B, nc, L).swapaxes(1, 2))
    return yr, yi


def four_step_zero_copy(xr: jnp.ndarray, xi: jnp.ndarray, n1: int, n2: int,
                        *, impl: str = "matfft",
                        col_tile: int | None = None,
                        interpret: bool | None = None) -> Planar:
    """Level-1 four-step re-expressed as two shared axis passes.

    Pass 1 transforms the n1-axis of the (rows, n1, n2) view with the outer
    twiddle W_N^{o1*i2} fused into the store (row-major out); pass 2
    transforms the n2-axis of the resulting (rows, n2, n1) view with a
    column-major store — which IS the o2-major final order. No transposed
    tensor is ever materialized in HBM (DESIGN.md §3): 4 traversals total
    vs the legacy 10 (plan.fft_hbm_bytes).
    """
    rows, n = xr.shape
    assert n == n1 * n2
    # T[o1, i2] -> (i2, o1): pass-1 output row (b, i2) is multiplied by
    # T^T[i2, :] — period n2 == the pass-1 column count, no O(batch*n)
    # twiddle tensor.
    tr, ti = fft_plan.twiddle_table(n1, n2, n)
    epi = (jnp.asarray(tr.T.copy()), jnp.asarray(ti.T.copy()))

    ar, ai = axis_pass(xr, xi, (rows, n1, n2), out_major="row", epilogue=epi,
                       impl=impl, col_tile=col_tile,
                       interpret=interpret)  # (rows*n2, n1), row (b, i2)
    cr, ci = axis_pass(ar, ai, (rows, n2, n1), out_major="col", impl=impl,
                       col_tile=col_tile,
                       interpret=interpret)  # (rows, n2, n1) = [b, o2, o1]
    return cr.reshape(rows, n), ci.reshape(rows, n)


def fft(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
        interpret: bool | None = None, batch_tile: int | None = None,
        global_twiddle=None, layout: str = "zero_copy") -> Planar:
    """Batched forward FFT along the last axis of planar float32 arrays.

    Any leading batch shape; last-axis length must be a power of two up to
    MAX_LEAF**2 (single device). Larger transforms go through
    core/fft/distributed.py.
    """
    if layout not in ("zero_copy", "copy"):
        raise ValueError(f"unknown layout {layout!r}")
    interpret = _auto_interpret(interpret)
    batch_shape, n = xr.shape[:-1], xr.shape[-1]
    if n == 1:
        return xr, xi
    fft_plan.log2i(n)
    rows = 1
    for d in batch_shape:
        rows *= d
    xr2 = xr.reshape(rows, n)
    xi2 = xi.reshape(rows, n)

    p = fft_plan.make_plan(n)
    if p.levels == 1:
        if global_twiddle is not None and impl == "matfft":
            # fused distributed twiddle (core/fft/distributed.py): computed
            # on the fly in the kernel epilogue, no HBM table
            yr, yi = matfft(xr2, xi2, global_twiddle=global_twiddle,
                            batch_tile=batch_tile,
                            interpret=_auto_interpret(interpret))
        else:
            yr, yi = _leaf(xr2, xi2, impl, interpret, batch_tile=batch_tile)
    else:
        if global_twiddle is not None:
            raise ValueError("global_twiddle requires a single-level plan")
        yr, yi = _four_step(xr2, xi2, p.n1, p.n2, impl, interpret, batch_tile,
                            layout)
    return yr.reshape(*batch_shape, n), yi.reshape(*batch_shape, n)


def _four_step(xr, xi, n1: int, n2: int, impl: str, interpret: bool,
               batch_tile: int | None, layout: str = "zero_copy") -> Planar:
    """Host-level four-step: two batched leaf passes.

    layout="zero_copy" (matfft only): both passes are column-strided Pallas
    kernels over free reshapes of the same buffers — no transposed tensor
    is ever materialized (four_step_zero_copy, on the shared axis_pass).

    layout="copy": the legacy path — three reshape+swapaxes transposes
    around two row-major leaf passes, each a full HBM round-trip. Pass 1
    still fuses the outer twiddle W_N^{o1*i2} into the leaf epilogue: the
    epilogue operand is just the (n2, n1) table indexed periodically — no
    O(batch*n) twiddle tensor is ever materialized.
    """
    rows, n = xr.shape
    assert n == n1 * n2

    if layout == "zero_copy" and impl == "matfft":
        return four_step_zero_copy(xr, xi, n1, n2, impl=impl,
                                   col_tile=batch_tile, interpret=interpret)

    # T[o1, i2] -> transpose to (i2, o1): row (b, i2) of pass-1 output gets
    # multiplied by T^T[i2, :]. Periodic with period n2 in the row index.
    tr, ti = fft_plan.twiddle_table(n1, n2, n)
    epi = (jnp.asarray(tr.T.copy()), jnp.asarray(ti.T.copy()))

    def to_cols(a):  # (rows, n1*n2) -> (rows*n2, n1)
        return a.reshape(rows, n1, n2).swapaxes(1, 2).reshape(rows * n2, n1)

    ar, ai = _leaf(to_cols(xr), to_cols(xi), impl, interpret,
                   epilogue=epi, batch_tile=batch_tile)

    def to_rows(a):  # (rows*n2, n1) -> (rows*n1, n2)
        return a.reshape(rows, n2, n1).swapaxes(1, 2).reshape(rows * n1, n2)

    cr, ci = _leaf(to_rows(ar), to_rows(ai), impl, interpret,
                   batch_tile=batch_tile)

    def out_order(a):  # rows (b, o1), cols o2 -> flat o = o2*n1 + o1
        return a.reshape(rows, n1, n2).swapaxes(1, 2).reshape(rows, n)

    return out_order(cr), out_order(ci)


def fft_cols(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
             interpret: bool | None = None, col_tile: int | None = None,
             global_twiddle=None, layout: str = "zero_copy",
             out_major: str = "row", col_offset: int = 0,
             ncols: int | None = None) -> Planar:
    """FFT each COLUMN of planar (L, C) arrays.

    Returns (C', L) row-major for ``out_major="row"`` or (L, C')
    column-major for ``out_major="col"`` (C' = ncols when a slab is
    selected). Semantically ``fft(xr.T, xi.T)`` (transposed again for
    "col"), but with layout="zero_copy" the column-strided Pallas kernel
    reads the operand in place and writes the requested layout directly —
    the materialized `.T` copies at distributed-FFT pass boundaries fold
    into the kernel (DESIGN.md §3).

    ``col_offset``/``ncols`` restrict the call to the column slab
    ``[col_offset, col_offset + ncols)``: on the zero-copy path the
    BlockSpec index map fetches the slab from the full operand in place
    (no retile); the fallback slices (it already materializes a copy).

    Thin wrapper over the shared `axis_pass` builder with a B=1 view.
    """
    L, C = xr.shape
    nc = C - col_offset if ncols is None else ncols
    yr, yi = axis_pass(xr, xi, (1, L, C), out_major=out_major,
                       global_twiddle=global_twiddle, impl=impl,
                       interpret=interpret, col_tile=col_tile,
                       col_offset=col_offset, ncols=nc, layout=layout)
    if out_major == "col":
        return yr.reshape(L, nc), yi.reshape(L, nc)
    return yr, yi


def ifft(xr: jnp.ndarray, xi: jnp.ndarray, **kw) -> Planar:
    """Inverse FFT via the conjugation identity: ifft(x) = conj(fft(conj(x)))/n."""
    n = xr.shape[-1]
    yr, yi = fft(xr, -xi, **kw)
    return yr / n, -yi / n


def rfft(x: jnp.ndarray, *, impl: str = "matfft",
         interpret: bool | None = None, batch_tile: int | None = None,
         layout: str = "zero_copy") -> Planar:
    """Real-input FFT; returns planar one-sided spectrum (n//2 + 1 bins).

    Fast path (impl="matfft", n >= 4): n real samples are packed as n/2
    complex points by a FREE reshape, one half-length transform runs on the
    MXU, and the conjugate-symmetric spectrum is untangled in the kernel
    epilogue (leaf sizes) or a vectorized jnp epilogue (level-1 sizes) —
    ~half the flops and HBM bytes of fft()+slice (DESIGN.md §4).
    """
    n = x.shape[-1]
    x = x.astype(jnp.float32)
    if n < 4 or impl != "matfft":
        # legacy path: full complex transform, slice the half spectrum
        yr, yi = fft(x, jnp.zeros_like(x), impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
        return yr[..., : n // 2 + 1], yi[..., : n // 2 + 1]
    fft_plan.log2i(n)
    m = n // 2
    batch_shape = x.shape[:-1]
    rows = 1
    for d in batch_shape:
        rows *= d
    x2 = x.reshape(rows, n)
    if fft_plan.make_plan(m).levels == 1:
        yr, yi = rfft_leaf(x2, batch_tile=batch_tile,
                           interpret=_auto_interpret(interpret))
    else:
        # level-1: the untangle can't live inside one leaf tile (bin o
        # pairs with m - o, a different o1-block), so pack + untangle run
        # as host epilogues around the half-length zero-copy transform
        z = x2.reshape(rows, m, 2)
        zr, zi = fft(z[..., 0], z[..., 1], impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
        vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n))
        yr, yi = untangle_half_spectrum(zr, zi, vr, vi)
    return yr.reshape(*batch_shape, m + 1), yi.reshape(*batch_shape, m + 1)


def irfft(yr: jnp.ndarray, yi: jnp.ndarray, *, impl: str = "matfft",
          interpret: bool | None = None, batch_tile: int | None = None,
          layout: str = "zero_copy") -> jnp.ndarray:
    """Inverse of rfft: one-sided (..., n//2 + 1) spectrum -> real (..., n).

    Runs the packing trick in reverse: re-entangle the even/odd sub-spectra
    into a half-length spectrum, one half-length inverse transform, then
    interleave — the same ~2x saving as the forward fast path.
    """
    m = yr.shape[-1] - 1
    n = 2 * m
    if m < 2 or impl != "matfft":
        # legacy path: mirror to the full spectrum, full inverse transform
        fr = jnp.concatenate([yr, yr[..., -2:0:-1]], axis=-1)
        fi = jnp.concatenate([yi, -yi[..., -2:0:-1]], axis=-1)
        zr, _ = ifft(fr, fi, impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
        return zr
    # E[k] = (X[k] + conj(X[m-k]))/2 ; O[k] = conj(v[k])*(X[k] - conj(X[m-k]))/2
    xr_, xi_ = yr[..., :m], yi[..., :m]
    pr, pi = yr[..., :0:-1], -yi[..., :0:-1]  # conj(X[m-k]), k = 0..m-1
    er, ei = 0.5 * (xr_ + pr), 0.5 * (xi_ + pi)
    dr, di = 0.5 * (xr_ - pr), 0.5 * (xi_ - pi)
    vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n))
    our = vr * dr + vi * di  # conj(v) * D
    oui = vr * di - vi * dr
    # Z = E + i*O, z = IDFT_m(Z), x[2k] = Re z[k], x[2k+1] = Im z[k]
    zr, zi = ifft(er - oui, ei + our, impl=impl, interpret=interpret,
                  batch_tile=batch_tile, layout=layout)
    return jnp.stack([zr, zi], axis=-1).reshape(*zr.shape[:-1], n)


# ---------------------------------------------------------------------------
# true N-D transforms: axis passes, no outer twiddle (the DFT is separable)


def rfft_pack_pass(x2: jnp.ndarray, n_last: int, *, impl: str = "matfft",
                   interpret: bool | None = None,
                   batch_tile: int | None = None,
                   layout: str = "zero_copy") -> Planar:
    """Contiguous-axis pass of the rfftn fast path: (rows, n_last) real
    rows -> (rows, n_last//2) RAW packed half spectrum (no untangle).

    Shared by the local `rfftn` and the distributed r2c pencil
    (`core.fft.distributed.build_pencil_r2c`) so both issue literally the
    same kernels — the bitwise gate between them depends on it.
    """
    m = n_last // 2
    if fft_plan.make_plan(m).levels == 1:
        return rfft_pack_leaf(x2, batch_tile=batch_tile,
                              interpret=_auto_interpret(interpret))
    # n_last > 2*MAX_LEAF: the half transform is level-1; pack on the
    # host (one extra round trip, counted by plan.rfftn_hbm_bytes)
    z = x2.reshape(x2.shape[0], m, 2)
    return fft(z[..., 0], z[..., 1], impl=impl, interpret=interpret,
               batch_tile=batch_tile, layout=layout)


def _flip_leading(pr, pi, ndim: int, nd: int):
    """Index-negate (k -> (-k) mod n) every transformed axis but the last."""
    for ax in range(ndim - nd, ndim - 1):
        pr = jnp.roll(jnp.flip(pr, axis=ax), 1, axis=ax)
        pi = jnp.roll(jnp.flip(pi, axis=ax), 1, axis=ax)
    return pr, pi


def _untangle_nd(zr, zi, vr, vi, nd: int) -> Planar:
    """N-D untangle of the packed half spectrum AFTER the leading axes'
    DFTs have run on it.

    Same E/O algebra as `untangle_half_spectrum`, but conjugation is
    antilinear — it anticommutes with the leading-axis DFTs — so the
    Hermitian partner of bin (k0, .., k) sits at ((-k0) % n0, ..,
    (m-k) % m): flipped along EVERY transformed axis, not just the last.
    The Nyquist column m is no longer real for nd > 1 (only the full N-D
    Hermitian symmetry survives, not per-column realness).
    """
    pr, pi = _flip_leading(zr, zi, zr.ndim, nd)
    pr = jnp.roll(pr[..., ::-1], 1, axis=-1)
    pi = jnp.roll(pi[..., ::-1], 1, axis=-1)
    er, ei = 0.5 * (zr + pr), 0.5 * (zi - pi)
    our, oui = 0.5 * (zi + pi), 0.5 * (pr - zr)
    xr = er + vr * our - vi * oui
    xi = ei + vr * oui + vi * our
    nyq_r = er[..., :1] - our[..., :1]
    nyq_i = ei[..., :1] - oui[..., :1]
    return (jnp.concatenate([xr, nyq_r], axis=-1),
            jnp.concatenate([xi, nyq_i], axis=-1))


def fftn(xr: jnp.ndarray, xi: jnp.ndarray, shape, *, impl: str = "matfft",
         interpret: bool | None = None, batch_tile: int | None = None,
         layout: str = "zero_copy") -> Planar:
    """N-D forward FFT over the trailing ``len(shape)`` axes.

    The contiguous (last) axis runs the batched 1-D path (level 0/1, incl.
    the zero-copy four-step for long rows); every earlier axis is one
    shared `axis_pass` with a column-major store, so the data never leaves
    its natural layout — the whole chain is transpose-free in HBM
    (layout="zero_copy"). layout="copy" materializes a swapaxes round-trip
    per non-contiguous axis: the naive baseline benchmarks/bench_fft2.py
    gates against.
    """
    shape = tuple(int(d) for d in shape)
    nd = len(shape)
    if tuple(xr.shape[-nd:]) != shape:
        raise ValueError(
            f"operand trailing dims {tuple(xr.shape[-nd:])} do not match "
            f"transform shape {shape}")
    if nd == 1:
        return fft(xr, xi, impl=impl, interpret=interpret,
                   batch_tile=batch_tile, layout=layout)
    batch = xr.shape[:-nd]
    rows = math.prod(batch)
    yr, yi = fft(xr, xi, impl=impl, interpret=interpret,
                 batch_tile=batch_tile, layout=layout)
    for k in range(nd - 2, -1, -1):
        L = shape[k]
        inner = math.prod(shape[k + 1:])
        b = rows * math.prod(shape[:k])
        yr, yi = axis_pass(yr, yi, (b, L, inner), out_major="col",
                           impl=impl, interpret=interpret,
                           col_tile=batch_tile, layout=layout)
    return yr.reshape(*batch, *shape), yi.reshape(*batch, *shape)


def ifftn(xr: jnp.ndarray, xi: jnp.ndarray, shape, **kw) -> Planar:
    """Inverse N-D FFT via the global conjugation identity (/prod(shape))."""
    n_total = math.prod(int(d) for d in shape)
    yr, yi = fftn(xr, -xi, shape, **kw)
    return yr / n_total, -yi / n_total


def rfftn(x: jnp.ndarray, shape, *, impl: str = "matfft",
          interpret: bool | None = None, batch_tile: int | None = None,
          layout: str = "zero_copy") -> Planar:
    """N-D real-input FFT; one-sided over the contiguous axis.

    Returns planar ``(*batch, *shape[:-1], shape[-1]//2 + 1)`` — the
    numpy.fft.rfftn/rfft2 convention (r2c on the last axis).

    Fast path (impl="matfft", shape[-1] >= 4): the contiguous axis packs
    n reals as n/2 complex and transforms at half length WITHOUT the
    untangle (`rfft_pack_leaf` reads the real rows in the kernel — no
    even/odd planes in HBM); the remaining axes transform the half-width
    spectrum (the conjugate-symmetry untangle is a linear map on the last
    axis, so it commutes with the other axes' DFTs); ONE vectorized
    untangle epilogue widens m -> m+1 bins at the end. Every pass stays on
    pow2 widths — fully zero-copy.
    """
    shape = tuple(int(d) for d in shape)
    nd = len(shape)
    x = x.astype(jnp.float32)
    if nd == 1:
        return rfft(x, impl=impl, interpret=interpret,
                    batch_tile=batch_tile, layout=layout)
    n_last = shape[-1]
    if n_last < 4 or impl != "matfft":
        # legacy path: full complex N-D transform, slice the half spectrum
        yr, yi = fftn(x, jnp.zeros_like(x), shape, impl=impl,
                      interpret=interpret, batch_tile=batch_tile,
                      layout=layout)
        return yr[..., : n_last // 2 + 1], yi[..., : n_last // 2 + 1]
    fft_plan.log2i(n_last)
    m = n_last // 2
    batch = x.shape[:-nd]
    rows = math.prod(batch)
    half = (*shape[:-1], m)

    # pass over the contiguous axis: packed half-length transform, raw
    # (un-untangled) half spectrum out
    x2 = x.reshape(rows * math.prod(shape[:-1]), n_last)
    zr, zi = rfft_pack_pass(x2, n_last, impl=impl, interpret=interpret,
                            batch_tile=batch_tile, layout=layout)
    zr = zr.reshape(*batch, *half)
    zi = zi.reshape(*batch, *half)

    # remaining axes on the half-width spectrum (all pow2)
    for k in range(nd - 2, -1, -1):
        L = shape[k]
        inner = math.prod(half[k + 1:])
        b = rows * math.prod(shape[:k])
        zr, zi = axis_pass(zr, zi, (b, L, inner), out_major="col",
                           impl=impl, interpret=interpret,
                           col_tile=batch_tile, layout=layout)
        zr = zr.reshape(*batch, *half)
        zi = zi.reshape(*batch, *half)

    # one vectorized N-D untangle: m -> m + 1 bins
    vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n_last))
    return _untangle_nd(zr, zi, vr, vi, nd)


def irfftn(yr: jnp.ndarray, yi: jnp.ndarray, shape, *, impl: str = "matfft",
           interpret: bool | None = None, batch_tile: int | None = None,
           layout: str = "zero_copy") -> jnp.ndarray:
    """Inverse of rfftn: one-sided spectrum -> real ``(*batch, *shape)``.

    Runs the forward factorization in reverse: re-entangle the one-sided
    bins into the half-length spectrum (pow2 width again), inverse
    transform the leading axes, then the half-length inverse + interleave
    on the contiguous axis — the same ~2x saving as the forward fast path.
    """
    shape = tuple(int(d) for d in shape)
    nd = len(shape)
    if nd == 1:
        return irfft(yr, yi, impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
    n_last = shape[-1]
    m = n_last // 2
    if m < 2 or impl != "matfft":
        # legacy: inverse the leading axes as c2c via materialized
        # swapaxes, then the 1-D irfft on the contiguous axis
        for k in range(nd - 1):
            ax = k - nd  # negative axis index of shape[k] in the operand
            ar = jnp.swapaxes(yr, ax, -1)
            ai = jnp.swapaxes(yi, ax, -1)
            ar, ai = ifft(ar, ai, impl=impl, interpret=interpret,
                          batch_tile=batch_tile, layout=layout)
            yr = jnp.swapaxes(ar, ax, -1)
            yi = jnp.swapaxes(ai, ax, -1)
        return irfft(yr, yi, impl=impl, interpret=interpret,
                     batch_tile=batch_tile, layout=layout)
    batch = yr.shape[:-nd]
    rows = math.prod(batch)
    half = (*shape[:-1], m)

    # U^-1: re-entangle one-sided bins -> half-length spectrum. Same
    # algebra as irfft, but the Hermitian partner is flipped along every
    # transformed axis (see _untangle_nd): conj(X[(-k0) % n0, .., m-k]).
    xr_, xi_ = yr[..., :m], yi[..., :m]
    pr, pi = yr[..., :0:-1], -yi[..., :0:-1]  # conj partner, last axis
    pr, pi = _flip_leading(pr, pi, pr.ndim, nd)
    er, ei = 0.5 * (xr_ + pr), 0.5 * (xi_ + pi)
    dr, di = 0.5 * (xr_ - pr), 0.5 * (xi_ - pi)
    vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n_last))
    our = vr * dr + vi * di  # conj(v) * D
    oui = vr * di - vi * dr
    zr, zi = er - oui, ei + our

    # leading-axis inverses on the pow2 half width (conjugation identity)
    for k in range(nd - 2, -1, -1):
        L = shape[k]
        inner = math.prod(half[k + 1:])
        b = rows * math.prod(shape[:k])
        ar, ai = axis_pass(zr, -zi, (b, L, inner), out_major="col",
                           impl=impl, interpret=interpret,
                           col_tile=batch_tile, layout=layout)
        zr = ar.reshape(*batch, *half) / L
        zi = -ai.reshape(*batch, *half) / L

    # contiguous axis: half-length inverse + interleave
    wr, wi = ifft(zr, zi, impl=impl, interpret=interpret,
                  batch_tile=batch_tile, layout=layout)
    return jnp.stack([wr, wi], axis=-1).reshape(*wr.shape[:-1], n_last)
