"""`repro.fft` — the plan-and-execute FFT facade.

One entry point for every transform in the reproduction, mirroring the
paper's plan-then-execute discipline (`cufftPlanMany` built once per block
size, reused across every map task):

    import repro.fft

    p = repro.fft.plan(kind="r2c", n=4096, batch_shape=(1024,))
    yr, yi = p.execute_real(x)        # compiled once, cached process-wide
    p.hbm_bytes, p.gemm_macs, p.flops # analytic roofline cost model
    p.fused_untangle                  # resolved strategy, inspectable

Placements scale the same call from one core to the full mesh:
"local" (level-0/1 kernels), "segmented" (the paper's map-only regime,
zero collectives), "distributed" (cross-device four-step over all_to_all);
"auto" picks from n, batch_shape, and mesh size.

The deprecated per-call entry points (`repro.kernels.fft.ops.fft` etc.)
are thin shims over this facade. Smoke-check with
``python -m repro.fft.selftest``.
"""

from repro.fft.planner import (ExecutablePlan, cache_info, clear_plan_cache,
                               plan)
from repro.fft.spec import MAX_LOCAL_N, FftSpec, resolve_placement

__all__ = [
    "ExecutablePlan",
    "FftSpec",
    "MAX_LOCAL_N",
    "cache_info",
    "clear_plan_cache",
    "plan",
    "resolve_placement",
]
