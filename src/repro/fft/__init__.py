"""`repro.fft` — the plan-and-execute FFT facade.

One entry point for every transform in the reproduction, mirroring the
paper's plan-then-execute discipline (`cufftPlanMany` built once per block
size, reused across every map task):

    import repro.fft

    p = repro.fft.plan(kind="r2c", n=4096, batch_shape=(1024,))
    yr, yi = p.execute_real(x)        # compiled once, cached process-wide
    p.hbm_bytes, p.gemm_macs, p.flops # analytic roofline cost model
    p.fused_untangle                  # resolved strategy, inspectable

Transforms are N-D: ``plan(kind="c2c", shape=(n0, n1))`` plans a true 2-D
image FFT over the trailing axes (scalar ``n`` stays as 1-D sugar), built
from the same shared axis-pass engine as the 1-D four-step — transpose-
free in HBM. `fft2`/`ifft2`/`rfft2`/`irfft2` are the numpy-convention
wrappers.

Placements scale the same call from one core to the full mesh:
"local" (level-0/1 kernels), "segmented" (the paper's map-only regime,
zero collectives), "distributed" (1-D cross-device four-step over three
exchanges; N-D pencil decomposition over ``ndim-1`` re-pencil exchange
legs — a 3-D volume on a 2-axis mesh runs two, with per-leg
collective-byte accounting; r2c pencils stream the PACKED half-width
volume, halving flops and exchange bytes); "auto" picks from shape,
batch_shape, and mesh size. "out_of_core" streams a single huge 1-D c2c
whose operand lives in a `BlockStore` through the two-pass four-step
under a host memory budget (``plan(..., store=, work_dir=,
budget_bytes=)`` -> `core.fft.outofcore.OutOfCorePlan`).

``plan(..., tune=True)`` turns on the measuring autotuner (DESIGN.md
§14): plan time sweeps the real candidate space — exchange engine,
layout, batch tile, out-of-core panel height — on small representative
shards, picks the winner by measurement, and persists it as wisdom
(``wisdom_path=``, default ``~/.cache/repro_fft/wisdom.json``). A wisdom
hit is a pure lookup: zero measurements, zero retraces, counted in
``cache_info()["wisdom_hits"]``.

The deprecated per-call entry points (`repro.kernels.fft.ops.fft` etc.)
are thin shims over this facade. Smoke-check with
``python -m repro.fft.selftest``.
"""

from repro.core.fft.outofcore import (OocPlan, OutOfCorePlan,
                                      factor_out_of_core)
from repro.fft.planner import (ExecutablePlan, cache_info, clear_plan_cache,
                               fft2, ifft2, invalidate_mesh, irfft2, plan,
                               rfft2)
from repro.fft.spec import MAX_LOCAL_N, FftSpec, resolve_placement
from repro.fft.tuner import (TuneConfig, TuneReport, WisdomStore,
                             tune_stats, reset_tune_stats)

__all__ = [
    "ExecutablePlan",
    "FftSpec",
    "MAX_LOCAL_N",
    "OocPlan",
    "OutOfCorePlan",
    "cache_info",
    "clear_plan_cache",
    "factor_out_of_core",
    "fft2",
    "ifft2",
    "invalidate_mesh",
    "irfft2",
    "plan",
    "resolve_placement",
    "reset_tune_stats",
    "rfft2",
    "TuneConfig",
    "TuneReport",
    "tune_stats",
    "WisdomStore",
]
