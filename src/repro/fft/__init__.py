"""`repro.fft` — the plan-and-execute FFT facade.

One entry point for every transform in the reproduction, mirroring the
paper's plan-then-execute discipline (`cufftPlanMany` built once per block
size, reused across every map task):

    import repro.fft

    p = repro.fft.plan(kind="r2c", n=4096, batch_shape=(1024,))
    yr, yi = p.execute_real(x)        # compiled once, cached process-wide
    p.hbm_bytes, p.gemm_macs, p.flops # analytic roofline cost model
    p.fused_untangle                  # resolved strategy, inspectable

Transforms are N-D: ``plan(kind="c2c", shape=(n0, n1))`` plans a true 2-D
image FFT over the trailing axes (scalar ``n`` stays as 1-D sugar), built
from the same shared axis-pass engine as the 1-D four-step — transpose-
free in HBM. `fft2`/`ifft2`/`rfft2`/`irfft2` are the numpy-convention
wrappers.

Placements scale the same call from one core to the full mesh:
"local" (level-0/1 kernels), "segmented" (the paper's map-only regime,
zero collectives), "distributed" (1-D cross-device four-step over three
exchanges; 2-D pencil decomposition over ONE exchange); "auto" picks from
shape, batch_shape, and mesh size. "out_of_core" streams a single huge
1-D c2c whose operand lives in a `BlockStore` through the two-pass
four-step under a host memory budget (``plan(..., store=, work_dir=,
budget_bytes=)`` -> `core.fft.outofcore.OutOfCorePlan`).

The deprecated per-call entry points (`repro.kernels.fft.ops.fft` etc.)
are thin shims over this facade. Smoke-check with
``python -m repro.fft.selftest``.
"""

from repro.core.fft.outofcore import (OocPlan, OutOfCorePlan,
                                      factor_out_of_core)
from repro.fft.planner import (ExecutablePlan, cache_info, clear_plan_cache,
                               fft2, ifft2, invalidate_mesh, irfft2, plan,
                               rfft2)
from repro.fft.spec import MAX_LOCAL_N, FftSpec, resolve_placement

__all__ = [
    "ExecutablePlan",
    "FftSpec",
    "MAX_LOCAL_N",
    "OocPlan",
    "OutOfCorePlan",
    "cache_info",
    "clear_plan_cache",
    "factor_out_of_core",
    "fft2",
    "ifft2",
    "invalidate_mesh",
    "irfft2",
    "plan",
    "resolve_placement",
    "rfft2",
]
