"""Measuring autotuner + persistent wisdom for `repro.fft.plan(tune=True)`.

The planner's analytic cost model ranks strategies by roofline numerators;
FFTW's wisdom mechanism is the canonical proof that MEASURED plan
selection beats modeled selection (and arXiv:1409.5757 reports the same
for blocking/tiling choices on large 1-D transforms). This module closes
that gap (DESIGN.md §14):

  * `tune(...)` enumerates the real candidate space for a spec — overlap
    chunk count (which selects the exchange engine: "off" = monolithic
    all_to_all, an int = the chunked ppermute ring), layout (zero_copy vs
    copy), batch tile — builds each candidate at a SMALL representative
    shard shape, times it (min-of-repeats wall clock over the plan's own
    executable), and returns the winner's knobs.
  * The decision persists as wisdom: a JSON file keyed on the resolved
    base spec + mesh fingerprint + backend. A wisdom hit is a pure
    plan-cache-style lookup — ZERO measurements, ZERO retraces — so
    fleets and repeat processes skip re-tuning entirely.
  * Every measurement is compared against the analytic model
    (`modeled_wall`); when measured and modeled argmins disagree, the
    report flags it and a `tune_disagreement` resilience event records
    the case — the running score of where the model is wrong.
  * `tune_out_of_core(...)` tunes the OOC panel-height knob
    (`panel_scale`) on the deterministic disk model.

Measurement determinism is injectable for tests and benches: a
`TuneConfig` carries the rng seed, repeat count, a `timer` (monotonic
clock) and a `measurer` override ("analytic" ranks candidates purely on
the cost model; a callable gets `(plan, config)` and returns seconds).
Candidates that fail to build or execute are discarded (logged), and a
corrupt/truncated wisdom file degrades to measuring with a logged
`wisdom_corrupt` event — tuning never turns a plannable spec into an
error.
"""

from __future__ import annotations

import json
import math
import os
import threading
import time
from dataclasses import dataclass, field, replace
from pathlib import Path

import jax
import numpy as np

from repro.core.resilience.events import record_event
from repro.fft import spec as spec_mod

WISDOM_VERSION = 1
DEFAULT_WISDOM_PATH = "~/.cache/repro_fft/wisdom.json"

# deterministic CPU-ish model constants (the analytic ranking's rates;
# absolute values cancel in argmin comparisons, ratios matter)
PEAK_FLOPS = 5e10     # effective FLOP/s for the leaf GEMMs
HBM_BPS = 2e10        # memory bandwidth
ICI_BPS = 5e9         # per-device interconnect bandwidth
DISK_BPS = 250e6      # ThrottledStore's modeled spindle (testing.DISK_MB_S)
JOB_OVERHEAD_S = 5e-3 # per-streamed-job dispatch/manifest overhead (OOC)
COPY_PENALTY = 0.5    # layout="copy" adds this fraction of hbm time
                      # (the materialized transpose round-trips)


@dataclass
class TuneConfig:
    """Knobs of the measurement protocol itself (all injectable)."""

    seed: int = 0                 # operand rng seed (determinism)
    repeats: int = 3              # min-of-N wall-clock measurements
    timer: object = None          # monotonic clock; None = perf_counter
    measurer: object = None       # None = real wall clock;
    #                               "analytic" = rank on modeled_wall;
    #                               callable(plan, cfg) -> seconds
    peak_flops: float = PEAK_FLOPS
    hbm_bps: float = HBM_BPS
    ici_bps: float = ICI_BPS
    disk_bps: float = DISK_BPS
    job_overhead_s: float = JOB_OVERHEAD_S


@dataclass
class TuneReport:
    """What one tune() call did (wisdom hit or full measurement sweep)."""

    key: str                      # the wisdom key consulted
    wisdom_hit: bool              # True -> zero measurements performed
    winner: dict                  # the chosen knobs
    candidates: list = field(default_factory=list)  # per-candidate rows
    measurements: int = 0         # candidate timings performed (0 on hit)
    disagreement: bool = False    # measured argmin != modeled argmin
    degraded: bool = False        # tuning failed; analytic defaults kept
    meas_shape: tuple | None = None   # representative shard measured
    meas_batch: tuple | None = None


_STATS_LOCK = threading.Lock()
_STATS = {"tuned": 0, "wisdom_hits": 0, "measurements": 0,
          "disagreements": 0, "degraded": 0}


def tune_stats() -> dict:
    """Process-level tuner counters (reported by launch/fft_job.py)."""
    with _STATS_LOCK:
        return dict(_STATS)


def reset_tune_stats() -> None:
    with _STATS_LOCK:
        for k in _STATS:
            _STATS[k] = 0


def _bump(key: str, by: int = 1) -> None:
    with _STATS_LOCK:
        _STATS[key] += by


# ---------------------------------------------------------------------------
# wisdom persistence


class WisdomStore:
    """One wisdom file: tolerant load, atomic writes, process-cached.

    A corrupt or truncated file NEVER raises — it logs a `wisdom_corrupt`
    event and degrades to an empty store (the caller re-measures and the
    next record overwrites the bad file). Writes go through a temp file +
    os.replace so a crash mid-write can't truncate existing wisdom.
    """

    _REGISTRY: dict = {}
    _REGISTRY_LOCK = threading.Lock()

    def __init__(self, path):
        self.path = Path(path).expanduser()
        self._lock = threading.Lock()
        self._entries: dict = {}
        self._load()

    @classmethod
    def get(cls, path=None) -> "WisdomStore":
        p = str(Path(path or DEFAULT_WISDOM_PATH).expanduser())
        with cls._REGISTRY_LOCK:
            store = cls._REGISTRY.get(p)
            if store is None:
                store = cls._REGISTRY[p] = cls(p)
            return store

    def _load(self) -> None:
        try:
            raw = self.path.read_text()
        except FileNotFoundError:
            return
        except OSError as e:
            record_event("wisdom_corrupt", path=str(self.path),
                         error=repr(e))
            return
        try:
            doc = json.loads(raw)
            if not isinstance(doc, dict):
                raise ValueError("wisdom document is not an object")
            if doc.get("version") != WISDOM_VERSION:
                raise ValueError(
                    f"wisdom version {doc.get('version')!r} != "
                    f"{WISDOM_VERSION}")
            entries = doc.get("entries")
            if not isinstance(entries, dict):
                raise ValueError("wisdom entries missing or not an object")
            self._entries = entries
        except (ValueError, KeyError, TypeError) as e:
            record_event("wisdom_corrupt", path=str(self.path),
                         error=repr(e))
            self._entries = {}

    def lookup(self, key: str):
        with self._lock:
            entry = self._entries.get(key)
            return dict(entry) if isinstance(entry, dict) else None

    def record(self, key: str, entry: dict) -> None:
        with self._lock:
            self._entries[key] = entry
            try:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                tmp = self.path.with_name(self.path.name + ".tmp")
                tmp.write_text(json.dumps(
                    {"version": WISDOM_VERSION, "entries": self._entries},
                    indent=1, sort_keys=True))
                os.replace(tmp, self.path)
            except OSError as e:
                # wisdom is an accelerator, not a correctness surface:
                # an unwritable cache dir degrades to per-process tuning
                record_event("wisdom_write_failed", path=str(self.path),
                             error=repr(e))

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


def mesh_fingerprint(mesh) -> str:
    """Stable identity of the hardware a tuning decision was measured on.

    Device count + per-axis name:size structure + platform/device kind:
    stale wisdom from a DIFFERENT mesh shape or backend keys differently
    and is simply never consulted (the mismatch test relies on this).
    """
    if mesh is None:
        return "mesh=none"
    axes = ",".join(f"{k}={v}" for k, v in mesh.shape.items())
    devs = list(mesh.devices.flat)
    plats = sorted({getattr(d, "platform", "?") for d in devs})
    kinds = sorted({getattr(d, "device_kind", "?") for d in devs})
    return (f"devices={mesh.devices.size};axes={axes};"
            f"platform={'+'.join(plats)};kind={'+'.join(kinds)}")


def wisdom_key(base_spec, mesh) -> str:
    """version | backend | mesh fingerprint | tunable-neutral spec.

    The tunable knobs (layout, batch_tile, overlap) are normalized OUT of
    the key — they are the wisdom's VALUE, not its identity."""
    neutral = replace(base_spec, layout="zero_copy", batch_tile=None,
                      overlap="off")
    return (f"v{WISDOM_VERSION}|backend={jax.default_backend()}|"
            f"{mesh_fingerprint(mesh)}|{neutral!r}")


# ---------------------------------------------------------------------------
# the analytic side of the comparison


def modeled_wall(plan, cfg: TuneConfig) -> float:
    """The analytic model's wall estimate for one execute of ``plan``:
    roofline numerators over the config's rates, plus the exposed (non-
    overlappable) collective bytes and the copy-layout transpose
    penalty. Used to rank the same candidates the measurements rank —
    disagreement between the two argmins is the tuner's headline
    diagnostic."""
    hbm_t = plan.hbm_bytes / cfg.hbm_bps
    wall = (plan.flops / cfg.peak_flops + hbm_t
            + plan.exposed_collective_bytes / cfg.ici_bps)
    if plan.spec.layout == "copy":
        wall += COPY_PENALTY * hbm_t
    return wall


def modeled_ooc_wall(factors, cfg: TuneConfig) -> float:
    """Deterministic disk-model wall for an OOC factorization: streamed
    IO at the spindle rate + per-job overhead + the transform flops."""
    jobs = factors.pass1_jobs + factors.pass2_jobs
    flops = 5.0 * factors.n * math.log2(max(factors.n, 2))
    return (factors.io_bytes / cfg.disk_bps
            + jobs * cfg.job_overhead_s
            + flops / cfg.peak_flops)


# ---------------------------------------------------------------------------
# candidate space + representative measurement shapes


def _pow2_min(a: int, b: int) -> int:
    return min(int(a), int(b))


def _shrink(base, num_devices, grid):
    """Representative measurement (shape, batch_shape) for a base spec:
    small enough to time in milliseconds, same validity class (placement,
    divisibility, pow2-ness) as the full spec."""
    if base.placement == "distributed":
        if base.ndim == 1:
            d = num_devices
            n_meas = _pow2_min(base.shape[0], max(d * d, 1 << 12))
            return (n_meas,), ()
        gmax = max(grid)
        dims = tuple(_pow2_min(dim, max(64, 2 * gmax))
                     for dim in base.shape)
        return dims, ()
    dims = tuple(_pow2_min(dim, 1024 if i == base.ndim - 1 else 64)
                 for i, dim in enumerate(base.shape))
    rows = base.rows
    if base.placement == "segmented":
        b = _pow2_min(rows, 2 * (num_devices or 1))
    else:
        b = min(rows, 16)
    batch = (b,) if base.batch_shape else ()
    return dims, batch


def _spec_ok(kwargs) -> bool:
    try:
        spec_mod.resolve(**kwargs)
        return True
    except (ValueError, NotImplementedError):
        return False


def _candidates(base, num_devices, grid, meas_shape, meas_batch):
    """Deterministically-ordered knob combinations. The base spec's own
    (already-resolved) knobs are candidate 0, so the measured winner can
    never rank behind the analytic default under the same measurer."""
    layouts = (["zero_copy", "copy"] if base.impl == "matfft"
               else ["zero_copy"])
    overlaps: list = ["off"]
    tiles: list = [None]
    if base.placement == "distributed":
        overlaps += [2, 4, 8]
        if base.ndim > 1:
            # local contiguous-rows count at the MEASUREMENT shape; both
            # pow2, meas <= full, so these divide the full shard too
            rows_local = math.prod(m // g
                                   for m, g in zip(meas_shape, grid))
            tiles += [t for t in (rows_local, rows_local // 2) if t >= 1]
    else:
        rows = math.prod(meas_batch) if meas_batch else 1
        if rows > 1:
            tiles += [min(rows, 8)]
    combos = [{"overlap": base.overlap, "layout": base.layout,
               "batch_tile": base.batch_tile}]
    for ov in overlaps:
        for ly in layouts:
            for bt in dict.fromkeys(tiles):
                combos.append({"overlap": ov, "layout": ly,
                               "batch_tile": bt})
    seen, out = set(), []
    for c in combos:
        k = (c["overlap"], c["layout"], c["batch_tile"])
        if k not in seen:
            seen.add(k)
            out.append(c)
    return out


def _measure_exec(plan, cfg: TuneConfig) -> float:
    """Default measurer: seeded operands, warm once (compile), then
    min-of-repeats wall clock around a fully-realized execute."""
    timer = cfg.timer or time.perf_counter
    rng = np.random.default_rng(cfg.seed)
    shape = plan.spec.operand_shape

    def _mk():
        return jax.numpy.asarray(
            rng.standard_normal(shape).astype(np.float32))

    if plan.kind == "r2c":
        ops = (_mk(),)
        run = lambda: plan.execute_real(*ops)  # noqa: E731
    else:
        ops = (_mk(), _mk())
        run = lambda: plan.execute(*ops)  # noqa: E731
    out = run()
    jax.block_until_ready(out)  # warm: compile + first dispatch
    best = math.inf
    for _ in range(max(cfg.repeats, 1)):
        t0 = timer()
        jax.block_until_ready(run())
        best = min(best, timer() - t0)
    return best


def _measure(plan, cfg: TuneConfig) -> float:
    if cfg.measurer == "analytic":
        return modeled_wall(plan, cfg)
    if callable(cfg.measurer):
        return float(cfg.measurer(plan, cfg))
    return _measure_exec(plan, cfg)


# ---------------------------------------------------------------------------
# the entry points


def tune(*, kind, n=None, shape=None, batch_shape=(), mesh=None, axes=None,
         num_devices=None, axis_sizes=None, placement="auto",
         layout="zero_copy", impl="matfft", precision="f32",
         interpret=None, batch_tile=None, natural_order=True,
         fuse_twiddle=False, overlap="auto", r2c_axis=-1, verify="off",
         wisdom_path=None, config: TuneConfig | None = None):
    """Pick (layout, batch_tile, overlap) for a spec by measurement.

    Returns ``(knobs, TuneReport)``. On a wisdom hit the knobs come
    straight from disk (zero measurements). On a miss every valid
    candidate is built at the representative shard shape, measured, and
    the winner is persisted. Degrades to ``({}, report)`` — analytic
    defaults — when the base spec cannot resolve or no candidate
    measures; the caller's own plan() then surfaces the real error.
    """
    cfg = config or TuneConfig()
    base_kwargs = dict(kind=kind, n=n, shape=shape, batch_shape=batch_shape,
                       placement=placement, layout=layout, impl=impl,
                       precision=precision, interpret=interpret,
                       batch_tile=batch_tile, num_devices=num_devices,
                       axes=axes, natural_order=natural_order,
                       fuse_twiddle=fuse_twiddle, overlap=overlap,
                       r2c_axis=r2c_axis, verify=verify,
                       axis_sizes=axis_sizes)
    _bump("tuned")
    try:
        base = spec_mod.resolve(**base_kwargs)
    except (ValueError, NotImplementedError) as e:
        _bump("degraded")
        record_event("tune_degraded", reason="resolve_failed",
                     error=repr(e))
        return {}, TuneReport(key="", wisdom_hit=False, winner={},
                              degraded=True)
    key = wisdom_key(base, mesh)
    store = WisdomStore.get(wisdom_path)

    entry = store.lookup(key)
    if entry is not None:
        knobs = dict(entry.get("knobs") or {})
        # sanity: stale-but-key-colliding knobs must still resolve; if
        # not, fall through to a fresh measurement sweep
        if _spec_ok({**base_kwargs, **knobs}):
            _bump("wisdom_hits")
            return knobs, TuneReport(
                key=key, wisdom_hit=True, winner=knobs,
                candidates=entry.get("candidates", []),
                measurements=0,
                disagreement=bool(entry.get("disagreement", False)))
        record_event("wisdom_stale", key=key, knobs=knobs)

    # ---- measurement sweep -------------------------------------------
    grid = None
    if base.placement == "distributed" and base.ndim > 1:
        from repro.core.fft.distributed import pencil_grid
        grid = pencil_grid(base.shape, num_devices, axis_sizes)
    meas_shape, meas_batch = _shrink(base, num_devices, grid)
    meas_kwargs = {**base_kwargs, "n": None, "shape": meas_shape,
                   "batch_shape": meas_batch,
                   "placement": base.placement}

    from repro.fft import planner
    results = []
    for knobs in _candidates(base, num_devices, grid, meas_shape,
                             meas_batch):
        full_kw = {**base_kwargs, **knobs}
        meas_kw = {**meas_kwargs, **knobs}
        if not (_spec_ok(full_kw) and _spec_ok(meas_kw)):
            continue
        try:
            p = planner.plan(
                kind=kind, shape=meas_shape, batch_shape=meas_batch,
                mesh=mesh, placement=base.placement,
                layout=knobs["layout"], impl=impl, precision=precision,
                interpret=interpret, batch_tile=knobs["batch_tile"],
                axes=axes, natural_order=natural_order,
                fuse_twiddle=fuse_twiddle, overlap=knobs["overlap"],
                r2c_axis=r2c_axis, verify=verify)
            measured = float(_measure(p, cfg))
            modeled = float(modeled_wall(p, cfg))
        except Exception as e:  # noqa: BLE001 — a candidate, not the plan
            record_event("tune_candidate_failed", key=key, knobs=knobs,
                         error=repr(e))
            continue
        _bump("measurements")
        results.append({"knobs": knobs, "measured_s": measured,
                        "modeled_s": modeled})

    if not results:
        _bump("degraded")
        record_event("tune_degraded", reason="no_candidate_measured",
                     key=key)
        return {}, TuneReport(key=key, wisdom_hit=False, winner={},
                              degraded=True, meas_shape=meas_shape,
                              meas_batch=meas_batch)

    meas_i = min(range(len(results)),
                 key=lambda i: (results[i]["measured_s"], i))
    model_i = min(range(len(results)),
                  key=lambda i: (results[i]["modeled_s"], i))
    disagreement = (results[meas_i]["knobs"] != results[model_i]["knobs"])
    if disagreement:
        _bump("disagreements")
        record_event(
            "tune_disagreement", key=key,
            measured_winner=results[meas_i]["knobs"],
            modeled_winner=results[model_i]["knobs"],
            measured_s=results[meas_i]["measured_s"],
            modeled_s=results[model_i]["modeled_s"])
    winner = dict(results[meas_i]["knobs"])
    store.record(key, {"knobs": winner,
                       "measured_s": results[meas_i]["measured_s"],
                       "modeled_s": results[meas_i]["modeled_s"],
                       "candidates": results,
                       "disagreement": disagreement,
                       "meas_shape": list(meas_shape),
                       "meas_batch": list(meas_batch)})
    return winner, TuneReport(
        key=key, wisdom_hit=False, winner=winner, candidates=results,
        measurements=len(results), disagreement=disagreement,
        meas_shape=meas_shape, meas_batch=meas_batch)


OOC_PANEL_SCALES = (1, 2, 4)


def tune_out_of_core(n: int, budget_bytes: int, *, impl: str = "ref",
                     block_bytes: int | None = None, wisdom_path=None,
                     config: TuneConfig | None = None):
    """Tune the OOC panel-height knob: try each valid ``panel_scale``
    on the deterministic disk model (or an injected measurer taking the
    OocPlan factorization) and persist the winner as wisdom.

    Returns ``(panel_scale, TuneReport)``; degrades to ``(1, report)``.
    """
    from repro.core.fft.outofcore import factor_out_of_core
    cfg = config or TuneConfig()
    key = (f"v{WISDOM_VERSION}|ooc|backend={jax.default_backend()}|"
           f"n={int(n)}|budget={int(budget_bytes)}|impl={impl}|"
           f"block={block_bytes}")
    store = WisdomStore.get(wisdom_path)
    _bump("tuned")
    entry = store.lookup(key)
    if entry is not None:
        knobs = dict(entry.get("knobs") or {})
        scale = int(knobs.get("panel_scale", 1))
        _bump("wisdom_hits")
        return scale, TuneReport(
            key=key, wisdom_hit=True, winner=knobs,
            candidates=entry.get("candidates", []), measurements=0,
            disagreement=bool(entry.get("disagreement", False)))

    results = []
    for scale in OOC_PANEL_SCALES:
        try:
            factors = factor_out_of_core(n, budget_bytes,
                                         block_bytes=block_bytes,
                                         panel_scale=scale)
        except ValueError:
            continue
        if callable(cfg.measurer):
            measured = float(cfg.measurer(factors, cfg))
        else:
            measured = modeled_ooc_wall(factors, cfg)
        modeled = modeled_ooc_wall(factors, cfg)
        _bump("measurements")
        results.append({"knobs": {"panel_scale": scale},
                        "measured_s": measured, "modeled_s": modeled})
    if not results:
        _bump("degraded")
        record_event("tune_degraded", reason="no_ooc_candidate", key=key)
        return 1, TuneReport(key=key, wisdom_hit=False, winner={},
                             degraded=True)
    meas_i = min(range(len(results)),
                 key=lambda i: (results[i]["measured_s"], i))
    model_i = min(range(len(results)),
                  key=lambda i: (results[i]["modeled_s"], i))
    disagreement = meas_i != model_i
    if disagreement:
        _bump("disagreements")
        record_event("tune_disagreement", key=key,
                     measured_winner=results[meas_i]["knobs"],
                     modeled_winner=results[model_i]["knobs"])
    winner = dict(results[meas_i]["knobs"])
    store.record(key, {"knobs": winner,
                       "measured_s": results[meas_i]["measured_s"],
                       "modeled_s": results[meas_i]["modeled_s"],
                       "candidates": results,
                       "disagreement": disagreement})
    return int(winner["panel_scale"]), TuneReport(
        key=key, wisdom_hit=False, winner=winner, candidates=results,
        measurements=len(results), disagreement=disagreement)
