"""Attention: GQA with chunked (flash-style) softmax, SWA, qk-norm, caches.

Memory/FLOP design (matters for §Roofline):
  * Scores are never materialized at (S, S): the query axis is split into
    static chunks (Python-unrolled), and each q-chunk scans its *statically
    bounded* kv range — causal chunks only see kv <= chunk end, SWA chunks
    only see the trailing window. So causal masking waste is limited to one
    boundary block per row instead of the 2x of a naive full-rectangle scan,
    and peak memory is O(q_chunk * kv_chunk) per head group.
  * GQA uses a grouped einsum (B,S,KV,G,hd) so KV heads are never repeated
    in memory.
  * Decode supports full caches and ring-buffer SWA caches (the latter make
    long_500k cells O(window) memory for SWA archs — DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import rms_norm, rope
from repro.models.scanning import maybe_scan
from repro.sharding.rules import ParamSpec

NEG = -1e30


# ---------------------------------------------------------------------------
# parameter specs


def attn_specs(cfg, stacked: tuple[int, ...] = (), cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pre = tuple("layers" for _ in stacked)
    out = {
        "wq": ParamSpec(stacked + (d, h, hd), pre + ("d_model", "heads", "head_dim")),
        "wk": ParamSpec(stacked + (d, kv, hd), pre + ("d_model", "kv_heads", "head_dim")),
        "wv": ParamSpec(stacked + (d, kv, hd), pre + ("d_model", "kv_heads", "head_dim")),
        "wo": ParamSpec(stacked + (h, hd, d), pre + ("heads", "head_dim", "d_model")),
    }
    if cfg.qkv_bias and not cross:
        out["bq"] = ParamSpec(stacked + (h, hd), pre + ("heads", "head_dim"), init="zeros")
        out["bk"] = ParamSpec(stacked + (kv, hd), pre + ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = ParamSpec(stacked + (kv, hd), pre + ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm and not cross:
        out["q_norm"] = ParamSpec(stacked + (hd,), pre + ("head_dim",), init="ones")
        out["k_norm"] = ParamSpec(stacked + (hd,), pre + ("head_dim",), init="ones")
    return out


# ---------------------------------------------------------------------------
# projections


def _qkv(cfg, p, x, pos_offset, theta):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope'd + normed."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if theta is not None:
        s = x.shape[1]
        positions = pos_offset + jnp.arange(s)
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked softmax attention core


def _chunk_body(q, k, v, q_pos, k_pos, scale, window, causal):
    """One (q_chunk x kv_chunk) tile of online softmax. Returns (s_max, p, pv).

    q: (B, qc, KV, G, hd); k, v: (B, kc, KV, hd).
    """
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k).astype(jnp.float32) * scale
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    mask = mask[None, None, None]  # (1,1,1,qc,kc)
    s = jnp.where(mask, s, NEG)
    return s, mask


def chunked_attention(q, k, v, *, causal=True, window=None, pos_offset=0,
                      q_chunk=2048, kv_chunk=1024, scale=None):
    """Flash-style attention. q (B,Sq,H,hd); k,v (B,Skv,KV,hd) -> (B,Sq,H,hd).

    ``pos_offset``: global position of q[0] minus position of k[0]
    (0 for self-attention over the same spans).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, sq, kvh, g, hd)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    out_blocks = []
    for q0 in range(0, sq, q_chunk):
        qc = min(q_chunk, sq - q0)
        q_blk = qg[:, q0:q0 + qc]
        q_pos = pos_offset + q0 + jnp.arange(qc)

        # Static kv bounds for this q chunk (the FLOP-honesty trick).
        hi = min(skv, _ceil_to(pos_offset + q0 + qc, kv_chunk)) if causal else skv
        lo = 0
        if window is not None:
            lo = max(0, _floor_to(pos_offset + q0 - window + 1, kv_chunk))
        k_rng = k[:, lo:hi]
        v_rng = v[:, lo:hi]
        n_blk = -(-(hi - lo) // kv_chunk)
        pad = n_blk * kv_chunk - (hi - lo)
        if pad:
            k_rng = jnp.pad(k_rng, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_rng = jnp.pad(v_rng, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_st = k_rng.reshape(b, n_blk, kv_chunk, kvh, hd).swapaxes(0, 1)
        v_st = v_rng.reshape(b, n_blk, kv_chunk, kvh, hd).swapaxes(0, 1)

        def step(carry, blk_in, q_blk=q_blk, q_pos=q_pos, lo=lo, hi=hi):
            m, l, acc = carry
            k_blk, v_blk, idx = blk_in
            k_pos = lo + idx * kv_chunk + jnp.arange(kv_chunk)
            s, mask = _chunk_body(q_blk, k_blk, v_blk, q_pos, k_pos, scale,
                                  window, causal)
            # also mask kv padding beyond hi
            s = jnp.where((k_pos < hi)[None, None, None, None, :], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(s <= NEG / 2, 0.0, p)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qc), NEG, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)
        # checkpoint: the backward pass recomputes the (qc, kc) score tile
        # per block instead of saving it (flash-attention memory behavior);
        # without this, scan residuals hold n_blk score tiles per layer.
        (m, l, acc), _ = maybe_scan(
            jax.checkpoint(step), (m0, l0, a0),
            (k_st, v_st, jnp.arange(n_blk)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        # (B,KV,G,qc,hd) -> (B,qc,KV,G,hd) -> (B,qc,H,hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, hd)
        out_blocks.append(out.astype(q.dtype))
    return jnp.concatenate(out_blocks, axis=1) if len(out_blocks) > 1 else out_blocks[0]


def _ceil_to(x, m):
    return -(-x // m) * m


def _floor_to(x, m):
    return (x // m) * m


# ---------------------------------------------------------------------------
# block-level entry points


def self_attention(cfg, p, x, *, window=None, theta=None, pos_offset=0,
                   causal=True, return_kv=False):
    """Training / prefill self-attention over x (B,S,d)."""
    theta = cfg.rope_theta if theta is None else theta
    q, k, v = _qkv(cfg, p, x, pos_offset, theta)
    out = chunked_attention(
        q, k, v, causal=causal, window=window, pos_offset=0,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


def cross_attention(cfg, p, x, enc_k, enc_v):
    """Decoder cross-attention (whisper): no rope, no causal mask."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    out = chunked_attention(
        q, enc_k, enc_v, causal=False, window=None,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encode_kv(cfg, p, enc_out):
    """Precompute cross-attention K/V from encoder output (cached once)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


# ---------------------------------------------------------------------------
# decode (one token) with full or ring cache


def decode_self_attention(cfg, p, x, cache_k, cache_v, pos, *,
                          window=None, theta=None):
    """x (B,1,d), cache (B,S_cache,KV,hd), pos: scalar int32 position.

    Returns (y, new_cache_k, new_cache_v). When ``window`` is set and the
    cache length equals the window, the cache is a ring buffer.
    """
    theta = cfg.rope_theta if theta is None else theta
    b, s_cache, kvh, hd = cache_k.shape
    h = cfg.num_heads
    g = h // kvh
    ring = window is not None and s_cache == window

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k_t = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v_t = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias and "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k_t = k_t + p["bk"].astype(x.dtype)
        v_t = v_t + p["bv"].astype(x.dtype)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k_t = rms_norm(k_t, p["k_norm"], cfg.norm_eps)
    if theta is not None:
        posv = jnp.full((1,), pos)
        q = rope(q, posv, theta)
        k_t = rope(k_t, posv, theta)

    slot = (pos % window) if ring else pos
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_t.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_t.astype(cache_v.dtype),
                                           (0, slot, 0, 0))

    idx = jnp.arange(s_cache)
    if ring:
        age = (pos - idx) % window
        valid = age <= jnp.minimum(pos, window - 1)
    else:
        valid = idx <= pos
        if window is not None:
            valid &= pos - idx < window

    qg = q.reshape(b, 1, kvh, g, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, cache_k.astype(q.dtype))
    s = s.astype(jnp.float32) * (cfg.head_dim ** -0.5)
    s = jnp.where(valid[None, None, None, None, :], s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", w.astype(q.dtype),
                     cache_v.astype(q.dtype))
    out = out.reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def decode_cross_attention(cfg, p, x, enc_k, enc_v):
    """One-token cross-attention against a fixed encoder cache."""
    b, tc, kvh, hd = enc_k.shape
    h, g = cfg.num_heads, cfg.num_heads // enc_k.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    qg = q.reshape(b, 1, kvh, g, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, enc_k.astype(q.dtype))
    s = s.astype(jnp.float32) * (cfg.head_dim ** -0.5)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", w.astype(q.dtype),
                     enc_v.astype(q.dtype)).reshape(b, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
