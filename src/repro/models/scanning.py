"""Scan/unroll switch for cost-accurate dry-run lowering.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count (verified in tests/test_roofline.py::test_cost_analysis_scan_gap), so
flops/bytes/collective counts lowered through lax.scan would be understated
by the trip count. The dry-run therefore lowers each cell twice:

  * scanned   (UNROLL=False, production form) -> memory_analysis + the
    compile-succeeds proof; buffer assignment handles loops correctly;
  * unrolled  (UNROLL=True) at reduced depths -> cost_analysis +
    collective bytes, extrapolated per-period (launch/dryrun.py).

Model code routes every scan through maybe_scan() so one flag flips the
whole stack.

Two unroll scopes exist because the two cost metrics need different forms:
  * mode "all":    every scan unrolled. flops + collective bytes are EXACT
    (slices cost no flops; collectives aren't fused). `bytes accessed` is
    an UPPER bound: fusions subsume slices of full tensors, so each inner
    iteration can get charged the whole sliced operand.
  * mode "layers": only the layer/period scans unrolled; inner scans
    (attention kv tiles, GLA chunks, loss chunks) stay rolled and are
    counted once -> `bytes accessed` is a LOWER bound on memory traffic.
The roofline reports memory as [lb, ub] (benchmarks/roofline.py).
"""

from __future__ import annotations

import jax

_MODE = "none"  # none | layers | all


def set_unroll(mode) -> None:
    """set_unroll(True/False) (back-compat) or 'none'|'layers'|'all'."""
    global _MODE
    if mode is True:
        mode = "all"
    elif mode is False:
        mode = "none"
    assert mode in ("none", "layers", "all"), mode
    _MODE = mode


def unrolling() -> str:
    return _MODE


def maybe_scan(f, init, xs, length=None, kind="inner"):
    unroll = (_MODE == "all") or (_MODE == "layers" and kind == "layers")
    return jax.lax.scan(f, init, xs, length=length, unroll=unroll or 1)
