"""Mixture-of-Experts: top-k routing with capacity-bounded GShard dispatch.

Two implementations, selected by ``cfg.moe_impl``:

  * ``tp``  (default, robust lowering): experts replicated across the data
    axis, each expert's d_ff sharded over ``model`` — communication is the
    same all-reduce pattern as a dense TP MLP, and dispatch never crosses
    devices (token groups align with the batch sharding).
  * ``ep``  (expert-parallel): experts sharded over ``model`` with a
    shard_map all_to_all dispatch/return. Implemented as the §Perf
    hillclimb alternative for collective-bound MoE cells — see
    EXPERIMENTS.md; same math, different layout.

FLOP honesty: the dispatch einsums are O(tokens * E*C * d) on top of the
O(tokens * k * 3*d_ff*d) expert GEMMs, with E*C = capacity_factor * k *
group tokens — a few percent overhead that shows up (correctly) in the
MODEL_FLOPS / HLO_FLOPs ratio rather than being hidden.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activate
from repro.sharding.rules import ParamSpec, constrain


def moe_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pre = tuple("layers" for _ in stacked)
    out = {
        "router": ParamSpec(stacked + (d, e), pre + ("d_model", "experts")),
        "wi": ParamSpec(stacked + (e, d, ff), pre + ("experts", "d_model", "d_ff")),
        "wg": ParamSpec(stacked + (e, d, ff), pre + ("experts", "d_model", "d_ff")),
        "wo": ParamSpec(stacked + (e, ff, d), pre + ("experts", "d_ff", "d_model")),
    }
    if cfg.shared_expert:
        out["shared_wi"] = ParamSpec(stacked + (d, ff), pre + ("d_model", "d_ff"))
        out["shared_wg"] = ParamSpec(stacked + (d, ff), pre + ("d_model", "d_ff"))
        out["shared_wo"] = ParamSpec(stacked + (ff, d), pre + ("d_ff", "d_model"))
    return out


def _route(cfg, p, x_flat):
    """x (N, d) -> (weights (N, k), idx (N, k)) with renormalized softmax."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx


def _dispatch_tensors(cfg, weights, idx, n_tokens):
    """GShard capacity dispatch for one group. Returns (dispatch, combine).

    dispatch: (N, E, C) one-hot-ish bf16; combine = dispatch * gate weight.
    Tokens over an expert's capacity are dropped (standard GShard; the
    capacity_factor knob trades drop rate vs dispatch memory).
    """
    e = cfg.num_experts
    k = cfg.num_experts_per_tok
    cap = int(cfg.capacity_factor * k * n_tokens / e)
    cap = max(cap, 1)

    counts = jnp.zeros((e,), jnp.int32)
    dispatch = jnp.zeros((n_tokens, e, cap), jnp.bfloat16)
    combine = jnp.zeros((n_tokens, e, cap), jnp.float32)
    for j in range(k):  # k <= 2 for all assigned archs
        mask_j = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)      # (N, E)
        pos_j = jnp.cumsum(mask_j, axis=0) - 1 + counts[None, :]    # (N, E)
        counts = counts + mask_j.sum(axis=0)
        keep = (pos_j < cap) & (mask_j > 0)                         # (N, E)
        oh = jax.nn.one_hot(jnp.clip(pos_j, 0, cap - 1), cap,
                            dtype=jnp.bfloat16)                     # (N, E, C)
        oh = oh * keep[..., None].astype(jnp.bfloat16)
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * weights[:, j, None, None]
    return dispatch, combine


def _expert_ffn(cfg, p, xe):
    """xe (E, C, d) -> (E, C, d) through per-expert gated MLPs.

    With ``moe_force_weight_gather`` the bf16 weights are explicitly
    constrained to drop their FSDP (d_model over 'data') sharding before
    the einsum: one ~per-layer weight all-gather replaces the partitioner's
    default plan of partial-summing (E, C, d_ff)-sized activations over
    'data' — the dominant collective in the mixtral train baseline
    (EXPERIMENTS.md §Perf).
    """
    dt = xe.dtype

    def wcast(w, axes_sharded, axes_full):
        w = w.astype(dt)
        if cfg.moe_force_weight_gather:
            # pin the bf16 cast BEFORE the gather (halves gather bytes),
            # then gather the bf16 copy over 'data'
            w = constrain(w, axes_sharded)
            w = constrain(w, axes_full)
        return w

    wi = wcast(p["wi"], ("experts", "d_model", "d_ff"), ("experts", None, "d_ff"))
    wg = wcast(p["wg"], ("experts", "d_model", "d_ff"), ("experts", None, "d_ff"))
    wo = wcast(p["wo"], ("experts", "d_ff", "d_model"), ("experts", "d_ff", None))
    g = activate(cfg.act, jnp.einsum("ecd,edf->ecf", xe, wg))
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    return jnp.einsum("ecf,efd->ecd", g * h, wo)


def moe_tp(cfg, p, x):
    """Tensor-parallel MoE over x (B, S, d)."""
    b, s, d = x.shape
    gs = min(cfg.moe_group_size, s)
    n_groups = (b * s) // gs
    x_flat = x.reshape(n_groups, gs, d)

    def per_group(xg):
        w, idx = _route(cfg, p, xg)
        dispatch, combine = _dispatch_tensors(cfg, w, idx, gs)
        xe = jnp.einsum("nec,nd->ecd", dispatch, xg.astype(jnp.bfloat16))
        ye = _expert_ffn(cfg, p, xe.astype(x.dtype))
        return jnp.einsum("nec,ecd->nd", combine.astype(x.dtype), ye)

    y = jax.vmap(per_group)(x_flat).reshape(b, s, d)
    if cfg.shared_expert:
        dt = x.dtype
        g = activate(cfg.act, jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(dt)))
        h = jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(dt))
        y = y + jnp.einsum("bsf,fd->bsd", g * h, p["shared_wo"].astype(dt))
    return y


def moe_ep(cfg, p, x, *, axis_name="model"):
    """Expert-parallel MoE: experts sharded over ``axis_name``; tokens are
    exchanged with a single all_to_all pair instead of activating every
    expert's weights through FSDP all-gathers.

    Must be called inside shard_map with experts sharded on ``axis_name``
    (p["wi"] local shape (E/D, d, ff)) and tokens sharded on batch axes.
    """
    b, s, d = x.shape
    dcount = jax.lax.axis_size(axis_name)
    e_local = p["wi"].shape[0]
    e = e_local * dcount
    n = b * s
    x_flat = x.reshape(n, d)

    w, idx = _route_global(cfg, p, x_flat, axis_name)
    cap = max(int(cfg.capacity_factor * cfg.num_experts_per_tok * n / e), 1)
    dispatch, combine = _dispatch_tensors_sized(cfg, w, idx, n, e, cap)

    # Local buffers per expert (experts in global expert-major order), then
    # one a2a pair: tokens travel to their expert's owner and back.
    xe = jnp.einsum("nec,nd->ecd", dispatch, x_flat.astype(jnp.bfloat16))
    xe = xe.reshape(dcount, e_local, cap, d)
    # tiled=False swaps dim 0 with the mesh axis: afterwards dim 0 indexes
    # the SOURCE device, and this device holds only its own e_local experts.
    xe = jax.lax.all_to_all(xe, axis_name, split_axis=0, concat_axis=0)
    xe = xe.transpose(1, 0, 2, 3).reshape(e_local, dcount * cap, d)
    ye = _expert_ffn(cfg, p, xe.astype(x.dtype))
    ye = ye.reshape(e_local, dcount, cap, d).transpose(1, 0, 2, 3)
    ye = jax.lax.all_to_all(ye.astype(jnp.bfloat16), axis_name,
                            split_axis=0, concat_axis=0)
    ye = ye.reshape(e, cap, d)
    y = jnp.einsum("nec,ecd->nd", combine.astype(x.dtype), ye.astype(x.dtype))
    y = y.reshape(b, s, d)
    if cfg.shared_expert:
        dt = x.dtype
        g = activate(cfg.act, jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(dt)))
        h = jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(dt))
        y = y + jnp.einsum("bsf,fd->bsd", g * h, p["shared_wo"].astype(dt))
    return y


def _route_global(cfg, p, x_flat, axis_name):
    """Routing against the full router table (router is replicated)."""
    return _route(cfg, p, x_flat)


def _dispatch_tensors_sized(cfg, weights, idx, n_tokens, e, cap):
    counts = jnp.zeros((e,), jnp.int32)
    dispatch = jnp.zeros((n_tokens, e, cap), jnp.bfloat16)
    combine = jnp.zeros((n_tokens, e, cap), jnp.float32)
    for j in range(cfg.num_experts_per_tok):
        mask_j = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)
        pos_j = jnp.cumsum(mask_j, axis=0) - 1 + counts[None, :]
        counts = counts + mask_j.sum(axis=0)
        keep = (pos_j < cap) & (mask_j > 0)
        oh = jax.nn.one_hot(jnp.clip(pos_j, 0, cap - 1), cap, dtype=jnp.bfloat16)
        oh = oh * keep[..., None].astype(jnp.bfloat16)
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * weights[:, j, None, None]
    return dispatch, combine
