"""Model configuration schema shared by all 10 assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None       # None -> d_model // num_heads

    # --- attention features ---
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int | None = None   # SWA width (None = full attention)
    layer_pattern: str = "G"            # repeating unit: G=global, L=local(SWA),
                                        # M=mamba2, R=rwkv6, S=shared-attn(zamba)
    rope_theta: float = 10_000.0
    rope_theta_local: float | None = None   # gemma3: local layers use 10k
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    post_norms: bool = False            # gemma3 sandwich norms
    embed_scale: bool = False           # gemma: h *= sqrt(d_model)
    tie_embeddings: bool = False
    act: str = "silu"                   # silu | gelu
    norm_eps: float = 1e-6

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    shared_expert: bool = False         # llama4: always-on shared expert
    capacity_factor: float = 1.25
    moe_impl: str = "tp"                # tp | ep  (ep = expert-parallel a2a)
    moe_force_weight_gather: bool = False  # kill d-contraction partial ARs
                                        # by gathering expert weights instead

    # --- SSM / linear attention ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    cross_len: int = 1500               # whisper: 30s of frames

    # --- frontends (stubs per spec) ---
    frontend: str | None = None         # audio_frames | vision_patches
    num_prefix_embeds: int = 0          # vlm: vision patches

    # --- numerics / training ---
    dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"
    remat: str = "full"                 # none | full
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 512
    loss_chunk: int = 512        # seq-chunked cross-entropy head
    moe_group_size: int = 2048          # tokens per MoE dispatch group

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return all(c in "MR" for c in self.layer_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if long_500k is runnable: SSM/hybrid state, SWA ring caches,
        or shared-attn hybrid (zamba2 — spec: run for SSM/hybrid)."""
        return all(c in "MRS" or (c == "L" and self.sliding_window)
                   for c in self.layer_pattern)

    def layer_kinds(self) -> list[str]:
        """Expanded per-layer kind string of length num_layers."""
        pat = self.layer_pattern
        return [pat[i % len(pat)] for i in range(self.num_layers)]

    def pattern_groups(self) -> tuple[int, int]:
        """(full_periods, tail_layers) when scanning by pattern period."""
        period = len(self.layer_pattern)
        return self.num_layers // period, self.num_layers % period

    def n_params(self) -> float:
        """Approximate parameter count (embedding + blocks), for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, kv = self.head_dim, self.num_heads, self.num_kv_heads
        per_attn = d * hd * (h + 2 * kv) + h * hd * d
        if self.num_experts:
            per_mlp = 3 * d * ff * self.num_experts + d * self.num_experts
            if self.shared_expert:
                per_mlp += 3 * d * ff
        else:
            per_mlp = 3 * d * ff
        d_in = self.ssm_expand * d
        per_ssm = d * (2 * d_in + 2 * self.ssm_state
                       + d_in // self.ssm_head_dim) + d_in * d
        per_rwkv = 4 * d * d + d * d + 2 * d * ff  # time-mix + channel-mix
        total = v * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind in "GLS":
                total += per_attn + per_mlp
            elif kind == "M":
                total += per_ssm
            elif kind == "R":
                total += per_rwkv
        total += self.encoder_layers * (per_attn + per_mlp)
        if self.encoder_layers:  # decoder cross-attention
            total += self.num_layers * per_attn
        return float(total)

    def n_active_params(self) -> float:
        """Active params per token (MoE: routed top-k + shared)."""
        if not self.num_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_moe = 3 * d * ff * self.num_experts
        active = 3 * d * ff * (self.num_experts_per_tok
                               + (1 if self.shared_expert else 0))
        n_moe_layers = sum(1 for k in self.layer_kinds() if k in "GLS")
        return self.n_params() - n_moe_layers * (dense_moe - active)

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = len(self.layer_pattern)
        small = dict(
            num_layers=max(2, min(2 * period, 6)),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            encoder_layers=2 if self.encoder_layers else 0,
            cross_len=16 if self.encoder_layers else 1500,
            num_prefix_embeds=8 if self.num_prefix_embeds else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else None,
            attn_q_chunk=64,
            attn_kv_chunk=32,
            loss_chunk=32,
            cache_dtype="float32",
            moe_group_size=64,
            dtype="float32",
            remat="none",
        )
        small.update(overrides)
        return replace(self, **small)
