"""Chunked linear attention with data-dependent decay (RWKV6 / Mamba2 core).

Recurrence (per head; S is a (dk, dv) state, decay w_t in (0,1)^dk):

    bonus (RWKV6) form:   o_t = q_t S_{t-1} + (q_t . (u * k_t)) v_t
                          S_t = Diag(w_t) S_{t-1} + k_t (x) v_t
    inclusive (Mamba2/SSD) form (u=None):
                          S_t = Diag(w_t) S_{t-1} + k_t (x) v_t
                          o_t = q_t S_t

Why FFT does NOT apply here (DESIGN.md §5): with data-dependent w_t the
map x -> o is not a convolution, so the paper's FFT technique cannot
accelerate it; the chunked scan below is the TPU-efficient form instead.

Numerical design: the naive factorization P[t,s] = (q_t e^{L_t})(k_s e^{-L_s})
overflows once cumulative decay |L| > ~88 in f32. Instead both sides are
referenced to the chunk END: P = (q e^{L_q - L_last}) @ (k e^{L_last - L})^T.
The k-side factors are <= 1; the q-side factors are bounded by the total
in-chunk decay, so per-step log-decay is clamped to >= MIN_LOG_DECAY
(applied identically in the naive reference — a decay of e^-5 per step
zeroes the state within two steps anyway, so the clamp is semantically
free) keeping every factor < e^80 with chunk=16. Every pairwise PRODUCT has
exponent L_q(t) - L(s) <= 0, so accumulation is exact-safe, and the intra-
chunk matrix is a plain MXU matmul — no (c, c, dk) pairwise tensor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.scanning import maybe_scan

# Per-step log-decay floor (see module header). exp(-5) ~ 0.0067/step.
MIN_LOG_DECAY = -5.0


def naive_gla(q, k, v, log_decay, u=None, initial_state=None):
    """Reference O(T) scan. q,k,log_decay: (B,T,H,dk); v: (B,T,H,dv)."""
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    log_decay = jnp.maximum(log_decay, MIN_LOG_DECAY)
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h, dk, dv), jnp.float32))

    def step(s, xs):
        qt, kt, vt, lw = xs  # (B,H,dk) x3, v (B,H,dv)
        w = jnp.exp(lw)
        if u is None:
            s = s * w[..., None] + kt[..., None] * vt[..., None, :]
            o = jnp.einsum("bhk,bhkv->bhv", qt, s)
        else:
            o = jnp.einsum("bhk,bhkv->bhv", qt, s)
            o = o + jnp.einsum("bhk,bhk->bh", qt * u, kt)[..., None] * vt
            s = s * w[..., None] + kt[..., None] * vt[..., None, :]
        return s, o

    xs = tuple(jnp.moveaxis(a, 1, 0).astype(jnp.float32)
               for a in (q, k, v, log_decay))
    s_fin, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1).astype(q.dtype), s_fin


def chunked_gla(q, k, v, log_decay, u=None, initial_state=None, chunk=16):
    """Chunk-parallel equivalent of naive_gla (exact; see module header).

    Shapes: q,k,log_decay (B,T,H,dk); v (B,T,H,dv); u (H,dk) or None.
    T must be a multiple of ``chunk`` (callers pad). Compute is f32.
    Returns (out (B,T,H,dv), final_state (B,H,dk,dv)).
    """
    b, t, h, dk = q.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    c = chunk
    f32 = jnp.float32

    qc = q.reshape(b, n, c, h, dk).astype(f32)
    kc = k.reshape(b, n, c, h, dk).astype(f32)
    vc = v.reshape(b, n, c, h, dv).astype(f32)
    lw = jnp.maximum(log_decay.reshape(b, n, c, h, dk).astype(f32),
                     MIN_LOG_DECAY)

    lcum = jnp.cumsum(lw, axis=2)                      # inclusive L_t
    lq = lcum if u is None else lcum - lw              # exclusive for bonus form
    l_last = lcum[:, :, -1:]                           # (B,N,1,H,dk)

    k_state = kc * jnp.exp(l_last - lcum)              # <= 1 factors
    q_inter = qc * jnp.exp(lq)                         # <= 1 factors
    chunk_kv = jnp.einsum("bnchk,bnchv->bnhkv", k_state, vc)
    chunk_decay = jnp.exp(l_last[:, :, 0])             # (B,N,H,dk)

    # intra-chunk matrix as one MXU matmul, both sides referenced to the
    # chunk end so every pairwise product has exponent <= 0 (module header):
    # P[t,s] = sum_d q[t,d] e^{Lq_t - L_last} * k[s,d] e^{L_last - L_s}
    q_shift = qc * jnp.exp(lq - l_last)                # <= e^{c*|MIN|} bounded
    pmat = jnp.einsum("bnthd,bnshd->bnhts", q_shift, k_state)
    if u is None:
        tri = jnp.tril(jnp.ones((c, c), bool))         # s <= t
    else:
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)   # s < t
    pmat = jnp.where(tri[None, None, None], pmat, 0.0)
    o_intra = jnp.einsum("bnhts,bnshv->bnthv", pmat, vc)

    if u is not None:
        bonus = jnp.einsum("bnthk,hk,bnthk->bnth", qc, u.astype(f32), kc)
        o_intra = o_intra + bonus[..., None] * vc

    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, h, dk, dv), f32))

    def scan_chunk(s, xs):
        q_i, kv_i, dec_i = xs  # (B,c,H,dk), (B,H,dk,dv), (B,H,dk)
        o_inter = jnp.einsum("bchk,bhkv->bchv", q_i, s)
        s_new = s * dec_i[..., None] + kv_i
        return s_new, o_inter

    xs = (jnp.moveaxis(q_inter, 1, 0), jnp.moveaxis(chunk_kv, 1, 0),
          jnp.moveaxis(chunk_decay, 1, 0))
    s_fin, o_inter = maybe_scan(scan_chunk, s0, xs)
    o_inter = jnp.moveaxis(o_inter, 0, 1)              # (B,N,c,H,dv)

    out = (o_intra + o_inter).reshape(b, t, h, dv)
    return out.astype(q.dtype), s_fin


def step_gla(q, k, v, log_decay, u, state):
    """Single decode step. q,k,log_decay (B,1,H,dk); v (B,1,H,dv).

    Returns (out (B,1,H,dv), new_state).
    """
    f32 = jnp.float32
    qt = q[:, 0].astype(f32)
    kt = k[:, 0].astype(f32)
    vt = v[:, 0].astype(f32)
    w = jnp.exp(jnp.maximum(log_decay[:, 0].astype(f32), MIN_LOG_DECAY))
    if u is None:
        state = state * w[..., None] + kt[..., None] * vt[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", qt, state)
    else:
        o = jnp.einsum("bhk,bhkv->bhv", qt, state)
        o = o + jnp.einsum("bhk,bhk->bh", qt * u.astype(f32), kt)[..., None] * vt
        state = state * w[..., None] + kt[..., None] * vt[..., None, :]
    return o[:, None].astype(q.dtype), state
