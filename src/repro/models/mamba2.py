"""Mamba2 (SSD) block, used standalone and inside the Zamba2 hybrid.

State-space duality form: per head, scalar decay a_t = exp(-softplus(dt_t +
dt_bias) * exp(A_log)), shared (ngroups=1) B_t/C_t of size ssm_state, value
path v_t = dt_t * x_t — i.e. linear attention with q=C, k=B and a scalar
per-head data-dependent decay, which reuses chunked_gla directly (decay
vector broadcast over ssm_state).

Like RWKV6 the decay is data-dependent, so the FFT-convolution route is
inapplicable (DESIGN.md §5); the chunked scan is the efficient TPU form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm
from repro.models.linear_attn import chunked_gla, step_gla
from repro.sharding.rules import ParamSpec


def mamba2_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    cw = cfg.ssm_conv
    pre = tuple("layers" for _ in stacked)

    def mat(shape, axes, **kw):
        return ParamSpec(stacked + shape, pre + axes, **kw)

    return {
        "wz": mat((d, di), ("d_model", "d_ff")),
        "wx": mat((d, di), ("d_model", "d_ff")),
        "wB": mat((d, ds), ("d_model", "ssm_state")),
        "wC": mat((d, ds), ("d_model", "ssm_state")),
        "wdt": mat((d, nh), ("d_model", "ssm_heads")),
        "dt_bias": mat((nh,), ("ssm_heads",), init="zeros"),
        "A_log": mat((nh,), ("ssm_heads",), init="zeros"),
        "D": mat((nh,), ("ssm_heads",), init="ones"),
        "conv_w": mat((cw, di), ("conv_width", "d_ff")),
        "conv_b": mat((di,), ("d_ff",), init="zeros"),
        "norm_scale": mat((di,), ("d_ff",), init="ones"),
        "wo": mat((di, d), ("d_ff", "d_model")),
    }


def _causal_conv(x, w, b, carry=None):
    """Depthwise causal conv over seq. x (B,S,di); w (cw,di).

    carry: (B, cw-1, di) previous inputs for decode; returns (y, new_carry).
    """
    cw = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw))
    return y + b.astype(x.dtype), xp[:, -(cw - 1):]


def _proj(cfg, p, x):
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt_))
    xs = jnp.einsum("bsd,de->bse", x, p["wx"].astype(dt_))
    bmat = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(dt_))
    cmat = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_))
    return z, xs, bmat, cmat, dt_raw


def _ssm_inputs(cfg, p, xs_conv, bmat, cmat, dt_raw):
    """Assemble (q, k, v, log_decay) for chunked_gla."""
    b, s, di = xs_conv.shape
    nh = di // cfg.ssm_head_dim
    ds = cfg.ssm_state
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    log_decay = -dt * a                                        # (B,S,H)
    log_decay = jnp.broadcast_to(log_decay[..., None], (b, s, nh, ds))
    k = jax.nn.silu(bmat)[:, :, None, :] * jnp.ones((1, 1, nh, 1), bmat.dtype)
    q = jax.nn.silu(cmat)[:, :, None, :] * jnp.ones((1, 1, nh, 1), cmat.dtype)
    v = xs_conv.reshape(b, s, nh, cfg.ssm_head_dim) * dt[..., None].astype(xs_conv.dtype)
    return q, k, v, log_decay, dt


def mamba2_block(cfg, p, x, carry=None):
    """x (B,S,d) -> (y, new_carry). carry = (conv (B,cw-1,di), state)."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    conv_carry, state = carry if carry is not None else (None, None)

    z, xs, bmat, cmat, dt_raw = _proj(cfg, p, x)
    xs, conv_carry = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_carry)
    xs = jax.nn.silu(xs)
    q, k, v, log_decay, _ = _ssm_inputs(cfg, p, xs, bmat, cmat, dt_raw)

    pad = (-s) % 16
    if pad:
        q, k, v, log_decay = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                              for a in (q, k, v, log_decay))
    o, state = chunked_gla(q, k, v, log_decay, u=None, initial_state=state)
    o = o[:, :s]

    o = o + p["D"].astype(o.dtype)[None, None, :, None] \
        * xs.reshape(b, s, nh, cfg.ssm_head_dim)
    o = o.reshape(b, s, di)
    o = rms_norm(o * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return y, (conv_carry, state)


def mamba2_step(cfg, p, x, carry):
    """Single-token decode. x (B,1,d)."""
    b, _, d = x.shape
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_head_dim
    conv_carry, state = carry
    z, xs, bmat, cmat, dt_raw = _proj(cfg, p, x)
    xs, conv_carry = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_carry)
    xs = jax.nn.silu(xs)
    q, k, v, log_decay, _ = _ssm_inputs(cfg, p, xs, bmat, cmat, dt_raw)
    o, state = step_gla(q, k, v, log_decay, None, state)
    o = o + p["D"].astype(o.dtype)[None, None, :, None] \
        * xs.reshape(b, 1, nh, cfg.ssm_head_dim)
    o = o.reshape(b, 1, di)
    o = rms_norm(o * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return y, (conv_carry, state)


def mamba2_state_init(cfg, batch: int, dtype=jnp.float32):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_head_dim
    return (jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
            jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32))
