"""Model assembly for all 10 assigned architectures.

Layers are stacked *by pattern period* and scanned: a config's
``layer_pattern`` (e.g. gemma3 "LLLLLG", zamba2 "MMMMMS") becomes one
lax.scan over ``num_layers // len(pattern)`` periods whose body applies one
block per pattern position — so the HLO stays O(pattern length) regardless
of depth (compile-time critical on the 512-device dry-run), heterogeneous
stacks need no lax.cond (static FLOPs stay honest), and Zamba2's *shared*
attention block falls out naturally: its params are closed over by the scan
body (applied every period) while its KV caches are per-period scan xs/ys.

Block kinds: G global attention, L local (SWA) attention, M mamba2,
R rwkv6, S shared attention (zamba2). Leftover ``num_layers % period``
layers run unscanned as the tail.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import cross_entropy, norm_apply, norm_specs, sinusoidal_embed
from repro.models.config import ModelConfig
from repro.models.mamba2 import mamba2_block, mamba2_specs, mamba2_state_init, mamba2_step
from repro.models.mlp import mlp, mlp_specs, rwkv_cmix, rwkv_cmix_specs
from repro.models.moe import moe_specs, moe_tp
from repro.models.rwkv6 import (rwkv_state_init, rwkv_tmix, rwkv_tmix_specs,
                                rwkv_tmix_step)
from repro.models.scanning import maybe_scan
from repro.sharding.rules import ParamSpec, constrain


# ---------------------------------------------------------------------------
# per-kind specs


def _attn_block_specs(cfg, stacked, *, cross=False, shared=False):
    st = () if shared else stacked
    out = {
        "attn": attn.attn_specs(cfg, st),
        "ln1": norm_specs(cfg, st),
        "ln2": norm_specs(cfg, st),
    }
    if cfg.post_norms:
        out["post_ln1"] = norm_specs(cfg, st)
        out["post_ln2"] = norm_specs(cfg, st)
    if cfg.num_experts and not shared and not cross:
        out["moe"] = moe_specs(cfg, st)
    else:
        out["mlp"] = mlp_specs(cfg, st)
    if cross:
        out["cross"] = attn.attn_specs(cfg, st, cross=True)
        out["ln_cross"] = norm_specs(cfg, st)
    return out


def _block_specs(cfg, kind, stacked, *, cross=False):
    if kind in "GL":
        return _attn_block_specs(cfg, stacked, cross=cross)
    if kind == "M":
        return {"mamba": mamba2_specs(cfg, stacked), "ln": norm_specs(cfg, stacked)}
    if kind == "R":
        return {"tmix": rwkv_tmix_specs(cfg, stacked),
                "cmix": rwkv_cmix_specs(cfg, stacked),
                "ln1": norm_specs(cfg, stacked), "ln2": norm_specs(cfg, stacked)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# per-kind application (mode: train | prefill | decode)


def _kind_window_theta(cfg, kind):
    if kind == "L":
        theta = cfg.rope_theta_local or cfg.rope_theta
        return cfg.sliding_window, theta
    return None, cfg.rope_theta


def _apply_attn_block(cfg, p, h, kind, mode, cache, pos, enc_out=None,
                      cache_len=None):
    window, theta = _kind_window_theta(cfg, kind)
    if cfg.frontend == "audio_frames":
        theta = None  # whisper: absolute sinusoidal positions, no rope
    x = norm_apply(cfg, h, p["ln1"])
    new_cache = {}
    if mode == "encode":
        y = attn.self_attention(cfg, p["attn"], x, window=None, theta=theta,
                                causal=False)
    elif mode == "decode":
        y, ck, cv = attn.decode_self_attention(
            cfg, p["attn"], x, cache["k"], cache["v"], pos,
            window=window, theta=theta)
        new_cache = {"k": ck, "v": cv}
    elif mode == "prefill":
        y, (k, v) = attn.self_attention(cfg, p["attn"], x, window=window,
                                        theta=theta, return_kv=True)
        s = k.shape[1]
        target = max(cache_len or s, s)
        if window is not None and target > window:
            if s > window:
                # ring-buffer cache: keep the trailing window, rotated so
                # that slot (pos % window) matches decode's indexing
                keep = jnp.arange(window) + (s - window)
                slot = keep % window
                k = jnp.zeros_like(k[:, :window]).at[:, slot].set(k[:, keep])
                v = jnp.zeros_like(v[:, :window]).at[:, slot].set(v[:, keep])
            else:  # slots [0, s) already match pos % window for pos < window
                pad = ((0, 0), (0, window - s), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        elif target > s:  # full cache with decode headroom
            pad = ((0, 0), (0, target - s), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cdt = cfg.cache_dtype
        new_cache = {"k": k.astype(cdt), "v": v.astype(cdt)}
    else:
        y = attn.self_attention(cfg, p["attn"], x, window=window, theta=theta)
    if cfg.post_norms:
        y = norm_apply(cfg, y, p["post_ln1"])
    h = h + y

    if "cross" in p and enc_out is not None:
        x = norm_apply(cfg, h, p["ln_cross"])
        if mode == "decode":
            y = attn.decode_cross_attention(cfg, p["cross"], x,
                                            cache["cross_k"], cache["cross_v"])
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            ek, ev = attn.encode_kv(cfg, p["cross"], enc_out)
            y = attn.cross_attention(cfg, p["cross"], x, ek, ev)
            if mode == "prefill":
                new_cache["cross_k"] = ek.astype(cfg.cache_dtype)
                new_cache["cross_v"] = ev.astype(cfg.cache_dtype)
        h = h + y

    x = norm_apply(cfg, h, p["ln2"])
    if "moe" in p:
        y = moe_tp(cfg, p["moe"], x)
    else:
        y = mlp(cfg, p["mlp"], x)
    if cfg.post_norms:
        y = norm_apply(cfg, y, p["post_ln2"])
    return h + y, (new_cache or None)


def _apply_block(cfg, kind, p, h, mode, cache, pos, enc_out=None,
                 cache_len=None):
    if kind in "GLS":
        k = "G" if kind == "S" else kind
        return _apply_attn_block(cfg, p, h, k, mode, cache, pos, enc_out,
                                 cache_len)
    if kind == "M":
        x = norm_apply(cfg, h, p["ln"])
        if mode == "decode":
            y, carry = mamba2_step(cfg, p["mamba"], x, cache)
        else:
            y, carry = mamba2_block(cfg, p["mamba"], x, None if mode == "train"
                                    else cache)
        return h + y, (carry if mode != "train" else None)
    if kind == "R":
        x = norm_apply(cfg, h, p["ln1"])
        tmix_carry = cache[0] if cache is not None else None
        if mode == "decode":
            y, tcarry = rwkv_tmix_step(cfg, p["tmix"], x, tmix_carry)
        else:
            y, tcarry = rwkv_tmix(cfg, p["tmix"], x, tmix_carry)
        h = h + y
        x = norm_apply(cfg, h, p["ln2"])
        if mode == "decode":
            prev = cache[1][:, None].astype(x.dtype)
            dt = x.dtype
            mu_k = p["cmix"]["mu_k"].astype(dt)
            mu_r = p["cmix"]["mu_r"].astype(dt)
            xk = x * mu_k + prev * (1 - mu_k)
            xr = x * mu_r + prev * (1 - mu_r)
            kk = jnp.square(jax.nn.relu(
                jnp.einsum("bsd,df->bsf", xk, p["cmix"]["wk"].astype(dt))))
            kv = jnp.einsum("bsf,fd->bsd", kk, p["cmix"]["wv"].astype(dt))
            r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                          p["cmix"]["wr"].astype(dt)))
            y, ccarry = r * kv, x[:, 0]
        else:
            y, ccarry = rwkv_cmix(cfg, p["cmix"], x)
        h = h + y
        return h, ((tcarry, ccarry) if mode != "train" else None)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# cache initialization


def _block_cache_init(cfg, kind, batch, cache_len, *, cross=False):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in "GLS":
        window, _ = _kind_window_theta(cfg, "L" if kind == "L" else "G")
        s = min(cache_len, window) if (kind == "L" and window) else cache_len
        cdt = cfg.cache_dtype
        c = {"k": jnp.zeros((batch, s, kv, hd), cdt),
             "v": jnp.zeros((batch, s, kv, hd), cdt)}
        if cross:
            c["cross_k"] = jnp.zeros((batch, cfg.cross_len, kv, hd), cdt)
            c["cross_v"] = jnp.zeros((batch, cfg.cross_len, kv, hd), cdt)
        return c
    if kind == "M":
        return mamba2_state_init(cfg, batch, jnp.bfloat16)
    if kind == "R":
        return (rwkv_state_init(cfg, batch, jnp.bfloat16),
                jnp.zeros((batch, cfg.d_model), jnp.bfloat16))
    raise ValueError(kind)


def _block_cache_axes(cfg, kind, *, cross=False, stacked=False):
    """Logical sharding axes mirroring _block_cache_init's structure."""
    pre = ("layers",) if stacked else ()
    kv_axes = pre + ("cache_batch", "cache_seq", "cache_heads",
                     "cache_head_dim")
    if kind in "GLS":
        c = {"k": kv_axes, "v": kv_axes}
        if cross:
            c["cross_k"] = kv_axes
            c["cross_v"] = kv_axes
        return c
    if kind == "M":
        return (pre + ("cache_batch", None, "d_ff"),
                pre + ("cache_batch", "ssm_heads", "ssm_state", None))
    if kind == "R":
        return ((pre + ("cache_batch", "d_model"),
                 pre + ("cache_batch", "cache_heads", None, None)),
                pre + ("cache_batch", "d_model"))
    raise ValueError(kind)


# ---------------------------------------------------------------------------


class TransformerLM:
    """Decoder-only (optionally enc-dec / prefix-LM) language model."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -------------------------- specs --------------------------------
    def param_specs(self):
        cfg = self.cfg
        full, tail = cfg.pattern_groups()
        pat = cfg.layer_pattern
        cross = cfg.encoder_layers > 0
        specs = {
            "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                               ("vocab", "d_model")),
            "final_norm": norm_specs(cfg),
            "blocks": {str(j): _block_specs(cfg, k, (full,), cross=cross)
                       for j, k in enumerate(pat) if k != "S" and full > 0},
            "tail": {str(i): _block_specs(cfg, pat[i], (), cross=cross)
                     for i in range(tail)},
        }
        if "S" in pat:
            specs["shared"] = _attn_block_specs(cfg, (), shared=True)
        if not cfg.tie_embeddings:
            specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                         ("d_model", "vocab"))
        if cfg.encoder_layers:
            specs["encoder"] = {
                "blocks": _attn_block_specs(cfg, (cfg.encoder_layers,)),
                "final_norm": norm_specs(cfg),
            }
        return specs

    # -------------------------- stacks -------------------------------
    def _run_stack(self, params, h, mode, caches, pos, enc_out=None,
                   cache_len=None):
        cfg = self.cfg
        full, tail = cfg.pattern_groups()
        pat = cfg.layer_pattern
        shared = params.get("shared")
        new_caches = {"blocks": None, "tail": {}}

        if full > 0:
            def period(h, xs):
                blk_params, blk_caches = xs
                outs = []
                for j, kind in enumerate(pat):
                    p_j = shared if kind == "S" else blk_params[str(j)]
                    c_j = None if blk_caches is None else blk_caches[str(j)]
                    h, nc = _apply_block(cfg, kind, p_j, h, mode, c_j, pos,
                                         enc_out, cache_len)
                    outs.append(nc)
                ys = ({str(j): outs[j] for j in range(len(pat))}
                      if mode != "train" else None)
                return h, ys

            if cfg.remat == "full":
                period = jax.checkpoint(period)
            blk_caches = caches["blocks"] if caches else None
            xs = (params["blocks"], blk_caches)
            h, ys = maybe_scan(period, h, xs, kind="layers")
            new_caches["blocks"] = ys

        for i in range(tail):
            kind = pat[i]
            p_i = shared if kind == "S" else params["tail"][str(i)]
            c_i = None if caches is None else caches["tail"][str(i)]
            h, nc = _apply_block(cfg, kind, p_i, h, mode, c_i, pos, enc_out,
                                 cache_len)
            new_caches["tail"][str(i)] = nc
        return h, (new_caches if mode != "train" else None)

    def _encode(self, params, frames):
        """Whisper encoder over stub frame embeddings (B, Se, d)."""
        cfg = self.cfg
        h = frames + jnp.asarray(sinusoidal_embed(frames.shape[1], cfg.d_model),
                                 frames.dtype)

        def layer(h, p):
            h, _ = _apply_attn_block(cfg, p, h, "G", "encode", None, 0)
            return h, None

        if cfg.remat == "full":
            layer = jax.checkpoint(layer)
        h, _ = maybe_scan(layer, h, params["encoder"]["blocks"],
                          kind="layers")
        return norm_apply(cfg, h, params["encoder"]["final_norm"])

    # -------------------------- embedding / head ---------------------
    def _embed(self, params, tokens, offset=0):
        cfg = self.cfg
        h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        if cfg.embed_scale:
            h = h * math.sqrt(cfg.d_model)
        if cfg.frontend == "audio_frames":  # decoder absolute positions
            table = sinusoidal_embed(offset + tokens.shape[1], cfg.d_model)
            h = h + jnp.asarray(table[offset:], h.dtype)
        return constrain(h, ("batch", "seq", None))

    def _logits(self, params, h):
        cfg = self.cfg
        h = norm_apply(cfg, h, params["final_norm"])
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))
        # keep vocab sharded over 'model': without this, propagation
        # replicates (B,S,V) logits -> ~16x activation blowup (DESIGN §Perf)
        return constrain(logits.astype(jnp.float32),
                         ("batch", None, "act_vocab"))

    # -------------------------- public API ---------------------------
    def forward(self, params, batch):
        """Training forward -> logits. batch: tokens (B,S) [+frames/patches]."""
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch["frames"].astype(cfg.dtype))
        h = self._embed(params, tokens)
        if cfg.num_prefix_embeds:
            h = jnp.concatenate(
                [batch["patches"].astype(h.dtype), h], axis=1)
        h, _ = self._run_stack(params, h, "train", None, 0, enc_out)
        return self._logits(params, h)

    def loss(self, params, batch):
        """Mean next-token NLL with a SEQ-CHUNKED head: the (B, S, V) logits
        tensor is never materialized — each chunk's logits are (re)computed
        inside a checkpointed scan body, flash-style. For the 151k-262k
        vocab configs this removes the single largest training activation
        (e.g. gemma3 train_4k: 2 x 4.3 GiB/device of fp32 logits+grad).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch["frames"].astype(cfg.dtype))
        h = self._embed(params, tokens)
        if cfg.num_prefix_embeds:
            h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
        h, _ = self._run_stack(params, h, "train", None, 0, enc_out)
        if cfg.num_prefix_embeds:
            h = h[:, cfg.num_prefix_embeds:]

        h = norm_apply(cfg, h, params["final_norm"])[:, :-1]
        labels = tokens[:, 1:]
        mask = batch.get("loss_mask")
        mask = (jnp.ones(labels.shape, jnp.float32) if mask is None
                else mask[:, 1:].astype(jnp.float32))
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

        b, s1, d = h.shape
        chunk = min(cfg.loss_chunk, s1)
        pad = (-s1) % chunk
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)))
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n = h.shape[1] // chunk
        hs = h.reshape(b, n, chunk, d).swapaxes(0, 1)
        ls = labels.reshape(b, n, chunk).swapaxes(0, 1)
        ms = mask.reshape(b, n, chunk).swapaxes(0, 1)

        def body(acc, xs):
            hc, lc, mc = xs
            logits = jnp.einsum("bsd,dv->bsv", hc, w.astype(hc.dtype))
            logits = constrain(logits.astype(jnp.float32),
                               ("batch", None, "act_vocab"))
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            nll = (lse - gold) * mc
            return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

        (total, count), _ = maybe_scan(jax.checkpoint(body), (0.0, 0.0),
                                       (hs, ls, ms))
        return total / jnp.maximum(count, 1.0)

    def init_cache(self, batch, cache_len):
        cfg = self.cfg
        full, tail = cfg.pattern_groups()
        pat = cfg.layer_pattern
        cross = cfg.encoder_layers > 0

        def stack(tree):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (full,) + x.shape), tree)

        caches = {"blocks": None, "tail": {}}
        if full > 0:
            caches["blocks"] = {
                str(j): stack(_block_cache_init(cfg, k, batch, cache_len,
                                                cross=cross))
                for j, k in enumerate(pat)}
        for i in range(tail):
            caches["tail"][str(i)] = _block_cache_init(cfg, pat[i], batch,
                                                       cache_len, cross=cross)
        return caches

    def cache_axes(self):
        """Logical sharding axes tree parallel to init_cache()'s structure."""
        cfg = self.cfg
        full, tail = cfg.pattern_groups()
        pat = cfg.layer_pattern
        cross = cfg.encoder_layers > 0
        axes = {"blocks": None, "tail": {}}
        if full > 0:
            axes["blocks"] = {
                str(j): _block_cache_axes(cfg, k, cross=cross, stacked=True)
                for j, k in enumerate(pat)}
        for i in range(tail):
            axes["tail"][str(i)] = _block_cache_axes(cfg, pat[i], cross=cross)
        return axes

    def prefill(self, params, batch, cache_len=None):
        """Full-context forward building decode caches.

        ``cache_len``: total cache size including decode headroom (defaults
        to the prompt length). Returns (last-position logits, caches).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = None
        if cfg.encoder_layers:
            enc_out = self._encode(params, batch["frames"].astype(cfg.dtype))
        h = self._embed(params, tokens)
        if cfg.num_prefix_embeds:
            h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
        h, caches = self._run_stack(params, h, "prefill", None, 0, enc_out,
                                    cache_len=cache_len)
        return self._logits(params, h[:, -1:]), caches

    def decode_step(self, params, caches, token, pos):
        """One token. token (B,1) int32; pos scalar int32 (same across batch).

        Returns (logits (B,1,V), new caches).
        """
        cfg = self.cfg
        h = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)
        if cfg.embed_scale:
            h = h * math.sqrt(cfg.d_model)
        if cfg.frontend == "audio_frames":
            # absolute sinusoidal row at `pos` (table sized by cache length)
            s_max = _cache_len_of(caches)
            table = jnp.asarray(sinusoidal_embed(s_max, cfg.d_model), h.dtype)
            h = h + jax.lax.dynamic_slice_in_dim(table, pos, 1, axis=0)[None]
        h, caches = self._run_stack(params, h, "decode", caches, pos, 1)
        return self._logits(params, h), caches


def _cache_len_of(caches):
    """Static self-attention cache length from any attention cache leaf."""
    for grp in (caches.get("blocks") or {}), caches.get("tail", {}):
        for c in grp.values():
            if isinstance(c, dict) and "k" in c:
                k = c["k"]
                return k.shape[-3] if k.ndim == 4 else k.shape[-3]
    raise ValueError("no attention cache found")
