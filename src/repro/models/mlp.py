"""Dense MLP blocks: gated (SwiGLU/GeGLU) and RWKV channel-mix."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activate
from repro.sharding.rules import ParamSpec


def mlp_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    pre = tuple("layers" for _ in stacked)
    return {
        "wi": ParamSpec(stacked + (d, ff), pre + ("d_model", "d_ff")),
        "wg": ParamSpec(stacked + (d, ff), pre + ("d_model", "d_ff")),
        "wo": ParamSpec(stacked + (ff, d), pre + ("d_ff", "d_model")),
    }


def mlp(cfg, p, x):
    """Gated MLP: act(x @ wg) * (x @ wi) @ wo."""
    dt = x.dtype
    g = activate(cfg.act, jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt)))
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", g * h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# RWKV channel mix (Finch): token-shift lerp + squared-relu FFN


def rwkv_cmix_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    pre = tuple("layers" for _ in stacked)
    return {
        "mu_k": ParamSpec(stacked + (d,), pre + ("d_model",), init="ones", scale=0.5),
        "mu_r": ParamSpec(stacked + (d,), pre + ("d_model",), init="ones", scale=0.5),
        "wk": ParamSpec(stacked + (d, ff), pre + ("d_model", "d_ff")),
        "wv": ParamSpec(stacked + (ff, d), pre + ("d_ff", "d_model")),
        "wr": ParamSpec(stacked + (d, d), pre + ("d_model", "d_model")),
    }


def _token_shift(x, x_last=None):
    """x_{t-1} along seq; first position sees x_last (decode carry) or 0."""
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_last is not None:
        prev = prev.at[:, 0].set(x_last)
    return prev


def rwkv_cmix(cfg, p, x, x_last=None):
    """Returns (y, new_x_last) — new_x_last is the carry for decode."""
    dt = x.dtype
    prev = _token_shift(x, x_last)
    mu_k = p["mu_k"].astype(dt)
    mu_r = p["mu_r"].astype(dt)
    xk = x * mu_k + prev * (1 - mu_k)
    xr = x * mu_r + prev * (1 - mu_r)
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(dt)))
    return r * kv, x[:, -1]
