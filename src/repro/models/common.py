"""Shared model primitives: norms, RoPE, activations, losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import ParamSpec, constrain


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps=1e-6, plus_one=False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (y * s).astype(dt)


def layer_norm(x, scale, bias, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_apply(cfg, x, p):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    plus_one = cfg.post_norms  # gemma-style (1+w) scaling
    return rms_norm(x, p["scale"], cfg.norm_eps, plus_one=plus_one)


def norm_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    axes = tuple("layers" for _ in stacked)
    out = {"scale": ParamSpec(stacked + (d,), axes + ("d_model",),
                              init="zeros" if cfg.post_norms else "ones")}
    if cfg.norm == "layernorm":
        out["bias"] = ParamSpec(stacked + (d,), axes + ("d_model",), init="zeros")
    return out


# ---------------------------------------------------------------------------
# rotary embeddings


def rope(x, positions, theta: float):
    """Apply rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-np.arange(0, half, dtype=np.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_embed(length: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal position table (length, d)."""
    half = d // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / (half - 1))
    ang = np.arange(length)[:, None] * freq[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# activations


def activate(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# losses


def cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """Mean token NLL with optional validity mask; fp32 throughout."""
    logits = constrain(logits.astype(jnp.float32),
                       ("batch", None, "act_vocab"))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
