"""RWKV6 "Finch" time-mix block (arXiv:2404.05892).

Implements the signature Finch feature exactly — *data-dependent decay*
w_t = exp(-exp(base + LoRA(x_shift))) feeding the chunked linear-attention
core — plus token-shift lerps, per-head bonus u, grouped output norm and
output gating. Simplification vs the released model: the r/k/v/g token-shift
mixes are static learned lerps (Finch additionally LoRA-modulates them);
the decay path, which defines the architecture family, is full fidelity.

Because the decay is data-dependent, the recurrence is NOT a convolution
and the paper's FFT technique cannot apply (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.linear_attn import chunked_gla, step_gla
from repro.models.mlp import _token_shift
from repro.sharding.rules import ParamSpec

DECAY_LORA = 64


def rwkv_tmix_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dk = d // h
    pre = tuple("layers" for _ in stacked)

    def mat(shape, axes, **kw):
        return ParamSpec(stacked + shape, pre + axes, **kw)

    return {
        "mu_r": mat((d,), ("d_model",), init="ones", scale=0.5),
        "mu_k": mat((d,), ("d_model",), init="ones", scale=0.5),
        "mu_v": mat((d,), ("d_model",), init="ones", scale=0.5),
        "mu_g": mat((d,), ("d_model",), init="ones", scale=0.5),
        "mu_w": mat((d,), ("d_model",), init="ones", scale=0.5),
        "wr": mat((d, h, dk), ("d_model", "heads", "head_dim")),
        "wk": mat((d, h, dk), ("d_model", "heads", "head_dim")),
        "wv": mat((d, h, dk), ("d_model", "heads", "head_dim")),
        "wg": mat((d, d), ("d_model", "d_model")),
        "wo": mat((h, dk, d), ("heads", "head_dim", "d_model")),
        "w_base": mat((h, dk), ("heads", "head_dim"), init="zeros"),
        "w_lora_a": mat((d, DECAY_LORA), ("d_model", None)),
        "w_lora_b": mat((DECAY_LORA, h, dk), (None, "heads", "head_dim"),
                        init="zeros"),
        "u": mat((h, dk), ("heads", "head_dim"), init="zeros"),
        "ln_scale": mat((h, dk), ("heads", "head_dim"), init="ones"),
        "ln_bias": mat((h, dk), ("heads", "head_dim"), init="zeros"),
    }


def _head_groupnorm(o, scale, bias, eps=64e-5):
    """RWKV GroupNorm(H): normalize each head's dk channels."""
    f = o.astype(jnp.float32)
    mu = f.mean(-1, keepdims=True)
    var = ((f - mu) ** 2).mean(-1, keepdims=True)
    y = (f - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(o.dtype)


def _mix_proj(cfg, p, x, prev):
    dt = x.dtype

    def lerp(mu):
        m = p[mu].astype(dt)
        return x * m + prev * (1 - m)

    r = jnp.einsum("bsd,dhk->bshk", lerp("mu_r"), p["wr"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", lerp("mu_k"), p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", lerp("mu_v"), p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", lerp("mu_g"), p["wg"].astype(dt)))
    # data-dependent decay: logw = -exp(base + lora(x_w)), always < 0
    lora = jnp.einsum("bsd,dr->bsr", lerp("mu_w"), p["w_lora_a"].astype(dt))
    lora = jnp.einsum("bsr,rhk->bshk", jnp.tanh(lora), p["w_lora_b"].astype(dt))
    logw = -jnp.exp(p["w_base"].astype(jnp.float32) + lora.astype(jnp.float32))
    return r, k, v, g, logw


def rwkv_tmix(cfg, p, x, carry=None):
    """x (B,S,d) -> (y, new_carry). carry = (x_last (B,d), state (B,H,dk,dk))."""
    b, s, d = x.shape
    h = cfg.num_heads
    dk = d // h
    x_last, state = carry if carry is not None else (None, None)
    prev = _token_shift(x, x_last)
    r, k, v, g, logw = _mix_proj(cfg, p, x, prev)

    pad = (-s) % 16
    if pad:  # chunk alignment
        r, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    o, state = chunked_gla(r, k, v, logw, u=p["u"], initial_state=state)
    o = o[:, :s]

    o = _head_groupnorm(o, p["ln_scale"], p["ln_bias"])
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    y = y * g.astype(y.dtype)
    return y, (x[:, -1], state)


def rwkv_tmix_step(cfg, p, x, carry):
    """Single-token decode. x (B,1,d); carry as in rwkv_tmix."""
    x_last, state = carry
    prev = x_last[:, None] if x_last is not None else jnp.zeros_like(x)
    r, k, v, g, logw = _mix_proj(cfg, p, x, prev)
    o, state = step_gla(r, k, v, logw, p["u"], state)
    o = _head_groupnorm(o, p["ln_scale"], p["ln_bias"])
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return y * g.astype(y.dtype), (x[:, 0], state)


def rwkv_state_init(cfg, batch: int, dtype=jnp.float32):
    h = cfg.num_heads
    dk = cfg.d_model // h
    return (jnp.zeros((batch, cfg.d_model), dtype),
            jnp.zeros((batch, h, dk, dk), jnp.float32))
