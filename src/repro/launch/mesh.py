"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state — the dry-run must set XLA_FLAGS before any
device query, and tests/benches must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single pod; (2,16,16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (tests / examples): 1-D data mesh
    or a small (data, model) mesh when enough local devices exist."""
    n = len(jax.devices())
    if model_axis > 1 and n % model_axis == 0:
        return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
    return jax.make_mesh((n,), ("data",))
