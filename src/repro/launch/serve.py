"""Serving launcher: batched prefill + greedy decode on local devices.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models.transformer import TransformerLM
from repro.serve import ServeEngine
from repro.sharding.rules import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)))}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, 64, cfg.d_model)), jnp.float32)
    if cfg.num_prefix_embeds:
        batch["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_prefix_embeds, cfg.d_model)), jnp.float32)

    engine = ServeEngine(model)
    total = args.batch * args.new_tokens
    # first call pays prefill+decode compilation; time it separately so
    # the steady-state number reflects actual serving throughput
    t0 = time.monotonic()
    out = jax.block_until_ready(
        engine.generate(params, batch, args.new_tokens))
    first = time.monotonic() - t0
    t0 = time.monotonic()
    out = jax.block_until_ready(
        engine.generate(params, batch, args.new_tokens))
    steady = time.monotonic() - t0
    print(f"generated {out.shape}")
    print(f"first call (incl. compile): {first:.2f}s "
          f"({total / first:.1f} tok/s)")
    print(f"steady state:               {steady:.2f}s "
          f"({total / steady:.1f} tok/s)")
    print(np.asarray(out)[:2])


if __name__ == "__main__":
    main()
