import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(*abstract_inputs).compile()`` must succeed
on the 16x16 single-pod mesh AND the (2,16,16) multi-pod mesh for every
assigned cell, and the compiled artifact yields the roofline inputs:
``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()`` (FLOPs /
bytes), and the optimized HLO (collective bytes).

NOTE the first two lines: XLA locks the device count at first backend init,
so the 512-device override must precede every other import. Tests and
benches never import this module (they see 1 device).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --mesh single_pod [--out out.json] [--rules k=v ...]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.hlo_analysis import collective_stats, cost_analysis_dict
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, cell_runnable, input_specs
from repro.models.scanning import set_unroll
from repro.models.transformer import TransformerLM
from repro.sharding.rules import (ShardingRules, abstract_params,
                                  param_shardings, resolve_pspec,
                                  tree_shardings, use_rules)
from repro.train.trainer import TrainerConfig, make_train_step, state_shardings

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", None, None),
    "patches": ("batch", None, None),
}


def _batch_shardings(batch_abs, rules, mesh):
    return {
        k: NamedSharding(mesh, resolve_pspec(tuple(v.shape), BATCH_AXES[k],
                                             rules, mesh))
        for k, v in batch_abs.items()
    }


def pick_optimizer(cfg) -> str:
    """Adafactor for >20B-param configs (halves optimizer HBM), else adamw."""
    return "adafactor" if cfg.n_params() > 20e9 else "adamw"


CFG_OVERRIDES: dict = {}
GRAD_ACCUM = [1]


def _apply_cfg_overrides(cfg):
    if CFG_OVERRIDES:
        cfg = dataclasses.replace(cfg, **CFG_OVERRIDES)
    return cfg


def build_lowered(arch: str, shape: str, mesh, rules: ShardingRules,
                  optimizer: str | None = None, cfg=None):
    cfg = _apply_cfg_overrides(cfg or get_config(arch))
    case = SHAPES[shape]
    if case.mode == "prefill":
        # prefill has no backward: larger tiles bound the Python-unrolled
        # q-chunk count at 32k (HLO size) without a remat-memory cost
        cfg = dataclasses.replace(cfg, attn_q_chunk=4096, attn_kv_chunk=2048)
    model = TransformerLM(cfg)
    batch_abs = input_specs(cfg, shape)

    if case.mode == "train":
        specs = model.param_specs()
        params_abs = abstract_params(specs)
        accum = GRAD_ACCUM[0]
        tc = TrainerConfig(optimizer=optimizer or pick_optimizer(cfg),
                           grad_accum=accum)
        if accum > 1:  # microbatched inputs: (accum, B/accum, ...)
            batch_abs = {k: jax.ShapeDtypeStruct(
                (accum, v.shape[0] // accum) + v.shape[1:], v.dtype)
                for k, v in batch_abs.items()}
        opt, step_fn = make_train_step(model, tc)
        state_abs = {
            "params": params_abs,
            "opt_state": jax.eval_shape(opt.init, params_abs),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_sh = state_shardings(model, state_abs, rules, mesh)
        if GRAD_ACCUM[0] > 1:
            batch_sh = {k: NamedSharding(mesh, resolve_pspec(
                tuple(v.shape), (None,) + BATCH_AXES[k], rules, mesh))
                for k, v in batch_abs.items()}
        else:
            batch_sh = _batch_shardings(batch_abs, rules, mesh)
        rep = NamedSharding(mesh, P())
        metrics_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
        fn = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh), donate_argnums=0)
        return fn.lower(state_abs, batch_abs)

    params_abs = abstract_params(model.param_specs(), dtype="bfloat16")
    params_sh = param_shardings(model.param_specs(), rules, mesh)

    if case.mode == "prefill":
        batch_sh = _batch_shardings(batch_abs, rules, mesh)
        fn = jax.jit(lambda p, b: model.prefill(p, b),
                     in_shardings=(params_sh, batch_sh))
        return fn.lower(params_abs, batch_abs)

    # decode
    caches_abs, token_abs, pos_abs = batch_abs
    cache_sh = tree_shardings(caches_abs, model.cache_axes(), rules, mesh)
    tok_sh = NamedSharding(mesh, resolve_pspec(
        tuple(token_abs.shape), ("cache_batch", None), rules, mesh))
    pos_sh = NamedSharding(mesh, P())
    fn = jax.jit(model.decode_step,
                 in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
                 donate_argnums=1)
    return fn.lower(params_abs, caches_abs, token_abs, pos_abs)


def _cost_vector(compiled) -> dict:
    cost = cost_analysis_dict(compiled.cost_analysis())
    colls = collective_stats(compiled.as_text())
    vec = {
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "transcendentals": cost.get("transcendentals", 0.0),
        "collective_bytes": colls["total_bytes"],
    }
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        vec[f"cb_{k}"] = colls[k]["bytes"]
        vec[f"cn_{k}"] = colls[k]["count"]
    return vec


def _extrapolated_cost(arch, shape, mesh, rules, optimizer) -> dict:
    """Cost pass: XLA's cost analysis counts while-loop bodies once (see
    models/scanning.py), so costs are measured on FULLY UNROLLED reduced-
    depth variants and extrapolated linearly in the period count:

        total = C(1p) + (periods-1) * (C(2p) - C(1p)) + [C(1p+tail) - C(1p)]

    which is exact for layer-uniform cost (the stack is periodic by
    construction). Validated against a full unroll in tests/test_roofline.py.
    """
    cfg = get_config(arch)
    period = len(cfg.layer_pattern)
    full_p, tail = cfg.pattern_groups()
    # SSM/hybrid patterns at long seq: a full unroll of the GLA chunk scans
    # (256-2048 iterations x depth) blows up compile time; fall back to
    # layers-only unroll there (flops are then a LOWER bound for the
    # inter-chunk scan portion — recorded as cost.mode).
    heavy_inner = (any(k in cfg.layer_pattern for k in "MR")
                   and SHAPES[shape].mode in ("train", "prefill"))
    mode = "layers" if heavy_inner else "all"
    set_unroll(mode)
    try:
        def measure(n_layers):
            cfg_v = dataclasses.replace(_apply_cfg_overrides(cfg),
                                        num_layers=n_layers)
            lowered = build_lowered(arch, shape, mesh, rules, optimizer,
                                    cfg=cfg_v)
            return _cost_vector(lowered.compile())

        c1 = measure(period)
        c2 = measure(2 * period)
        ct = measure(period + tail) if tail else None
    finally:
        set_unroll(False)

    out = {}
    for k in c1:
        per = c2[k] - c1[k]
        total = c1[k] + (full_p - 1) * per
        if ct is not None:
            total += ct[k] - c1[k]
        out[k] = total
    out["_per_period"] = {k: c2[k] - c1[k] for k in c1}
    out["_fixed"] = {k: 2 * c1[k] - c2[k] for k in c1}
    out["mode"] = mode
    return out


def run_cell(arch: str, shape: str, mesh_name: str, rules_overrides=None,
             optimizer: str | None = None, keep_hlo: bool = False,
             skip_cost: bool = False) -> dict:
    multi_pod = mesh_name == "multi_pod"
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "mode": SHAPES[shape].mode, "ok": False}
    runnable, reason = cell_runnable(cfg, shape)
    if not runnable:
        rec.update(skipped=True, reason=reason, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules.default(multi_pod=multi_pod)
    if rules_overrides:
        rules = rules.with_overrides(**rules_overrides)

    try:
        t0 = time.monotonic()
        with mesh, use_rules(rules):
            # ---- pass 1: production (scanned) form — compile proof + memory
            lowered = build_lowered(arch, shape, mesh, rules, optimizer)
            t1 = time.monotonic()
            compiled = lowered.compile()
            t2 = time.monotonic()
            mem = compiled.memory_analysis()
            cost_scanned = _cost_vector(compiled)
            hlo = compiled.as_text()
            print(mem)
            print({k: cost_scanned[k] for k in ("flops", "bytes_accessed")})
            rec.update(
                ok=True,
                lower_s=round(t1 - t0, 2),
                compile_s=round(t2 - t1, 2),
                memory={
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                },
                cost_scanned=cost_scanned,
            )
            # ---- pass 2: unrolled depth variants -> true per-device cost
            if not skip_cost:
                t3 = time.monotonic()
                rec["cost"] = _extrapolated_cost(arch, shape, mesh, rules,
                                                 optimizer)
                rec["cost_s"] = round(time.monotonic() - t3, 2)
        if keep_hlo:
            rec["hlo"] = hlo
    except Exception as e:  # a failure here is a bug in the system
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--rules", nargs="*", default=[],
                    help="logical=mesh overrides, e.g. cache_seq=model "
                         "or d_ff=data,model ('' = replicate)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-cost", action="store_true",
                    help="memory/compile pass only (skip unrolled cost pass)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--cfg", nargs="*", default=[],
                    help="ModelConfig overrides, e.g. moe_force_weight_gather=true")
    args = ap.parse_args(argv)

    GRAD_ACCUM[0] = args.grad_accum
    for kv in args.cfg:
        k, _, v = kv.partition("=")
        if v.lower() in ("true", "false"):
            val = v.lower() == "true"
        else:
            try:
                val = int(v)
            except ValueError:
                val = v
        CFG_OVERRIDES[k] = val

    overrides = {}
    for kv in args.rules:
        k, _, v = kv.partition("=")
        axes = tuple(x for x in v.split(",") if x)
        overrides[k] = axes if len(axes) > 1 else (axes[0] if axes else None)

    rec = run_cell(args.arch, args.shape, args.mesh, overrides,
                   args.optimizer, skip_cost=args.skip_cost)
    print(json.dumps({k: v for k, v in rec.items() if k != "hlo"}, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
