"""Collective-traffic accounting from compiled (post-SPMD) HLO text.

cost_analysis() has no collective term, so we parse the optimized module:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction's shapes are summed into per-device byte
counts, with the standard ring-algorithm multipliers:

    all-gather          (N-1)/N * result_bytes received per device
    reduce-scatter      (N-1)/N * operand_bytes
    all-reduce          2*(N-1)/N * operand_bytes   (RS + AG phases)
    all-to-all          (N-1)/N * operand_bytes
    collective-permute  operand_bytes

N (the group size) is parsed from replica_groups when present; the
conservative N->inf multiplier 1 (or 2) is used otherwise. This module
imports no jax — safe to use from benchmarks without touching device state.
"""

from __future__ import annotations

import re
from collections import defaultdict


def cost_analysis_dict(raw) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Depending on the release it returns a dict, a one-element list of dicts
    (one per executable program), or None. Callers always want a flat
    ``{"flops": ..., "bytes accessed": ...}`` mapping.
    """
    if raw is None:
        return {}
    if isinstance(raw, dict):
        return raw
    if isinstance(raw, (list, tuple)):
        out: dict = {}
        for entry in raw:
            for k, v in dict(entry).items():
                out[k] = out.get(k, 0.0) + v if isinstance(v, (int, float)) \
                    else v
        return out
    return dict(raw)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.:  %ag = bf16[64,1024]{1,0} all-gather(%x), ... replica_groups=...
# Result may be a long tuple with /*index=N*/ comments (the tuple form of
# all-to-all), hence the permissive lazy capture up to the op name.
_INSTR_RE = re.compile(
    r"=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int | None:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    return None


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective byte totals from optimized HLO text."""
    out = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # count start/done pairs once (at -start)
        result_shape, kind = m.group(1), m.group(2)
        n = _group_size(line)
        frac = (n - 1) / n if n else 1.0
        rb = _shape_bytes(result_shape)
        if kind == "all-gather":
            b = frac * rb                      # result is the gathered shape
        elif kind == "all-reduce":
            b = 2.0 * frac * rb                # ring RS + AG phases
        elif kind == "reduce-scatter":
            b = (n - 1) * rb if n else rb      # result is input/N
        elif kind == "all-to-all":
            b = frac * rb                      # result size == operand size
        else:  # collective-permute
            b = rb
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out
