import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Dry-run + roofline for the paper's own workload on the production mesh.

Variants measured (each lower+compile on the 256-chip and 512-chip meshes,
costs are exact — no scans in this path):

  segmented      the paper's map-only regime: batch of independent FFTs,
                 zero collectives (the baseline reproduction)
  dist_base      distributed four-step, natural output order, elementwise
                 jnp twiddle (paper-faithful cluster FFT: their §VI plan)
  dist_fused     + twiddle fused into the Pallas leaf kernel epilogue
                 (computed on the fly from iota: no HBM table, no extra
                 output round-trip)
  dist_transposed + natural_order=False (skip all_to_all #3, FFTW
                 TRANSPOSED_OUT) for convolution-style consumers
  pencil2d       2-D pencil decomposition of an equal-point image
                 (default 16384 x 16384 = 2^28 points): rows sharded,
                 local axis passes, ONE transpose exchange — a third of
                 dist_base's collective bytes for the same point count

An `ooc_2^K_analytic` record carries the out-of-core factorization and IO
cost model at the terabyte-class point (default 2^34 points = 128 GiB
under a 1 GiB budget): io_bytes/shuffle_bytes/working_set plus the
seconds predicted by the shared ThrottledStore disk model.

Each distributed record also carries the plan's exposed-vs-total
collective split, and a `dist_overlap*_analytic` record reports the
PREDICTED win of the chunked ppermute pipeline (DESIGN.md §8) from the
analytic cost model alone — the overlapped executable is never compiled
here: its ring unrolls D-1 collective-permutes per slab, which at 512
devices is exactly the regime `overlap="auto"` declines (the same reason
this dryrun would take hours to lower it). benchmarks/bench_distributed.py
compiles + executes the pipeline on the 8-device mesh.

  PYTHONPATH=src python -m repro.launch.fft_dryrun --n 268435456
"""

import argparse
import json
import math

import jax
import jax.numpy as jnp

import repro.fft as fft_api
from repro.launch.hlo_analysis import collective_stats, cost_analysis_dict
from repro.launch.mesh import make_production_mesh

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def measure(plan, args_abs, name):
    """Lower+compile one ExecutablePlan's jit'd callable; exact XLA costs."""
    lowered = plan.executable.lower(*args_abs)
    compiled = lowered.compile()
    cost = cost_analysis_dict(compiled.cost_analysis())
    mem = compiled.memory_analysis()
    colls = collective_stats(compiled.as_text())
    flops = cost.get("flops", 0.0)
    byts = cost.get("bytes accessed", 0.0)
    rec = {
        "name": name,
        "flops": flops,
        "bytes": byts,
        "collective_bytes": colls["total_bytes"],
        "a2a_bytes": colls["all-to-all"]["bytes"],
        "temp_bytes": mem.temp_size_in_bytes,
        "compute_s": flops / PEAK,
        "memory_s": byts / HBM,
        "collective_s": colls["total_bytes"] / ICI,
        # the plan's analytic model next to XLA's measured costs, so the
        # two stay honest against each other in the trajectory
        "plan_flops": plan.flops,
        "plan_hbm_bytes": plan.hbm_bytes,
        "plan_collective_bytes": plan.collective_bytes,
        "plan_exposed_collective_bytes": plan.exposed_collective_bytes,
    }
    rec["bound"] = max(("compute_s", "memory_s", "collective_s"),
                       key=lambda k: rec[k])
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 28,
                    help="global FFT length (distributed variants)")
    ap.add_argument("--n2d", type=int, nargs=2, default=[1 << 14, 1 << 14],
                    help="global image shape (pencil2d variant)")
    ap.add_argument("--n3d", type=int, nargs=3,
                    default=[1 << 10, 1 << 10, 1 << 8],
                    help="global volume shape (pencil3d variant; axes 0 "
                         "and 1 shard over the (data, model) mesh axes)")
    ap.add_argument("--tune", action="store_true",
                    help="run the measuring autotuner (analytic measurer "
                         "— nothing executes here) on the pencil2d spec "
                         "and report the winner + wisdom stats")
    ap.add_argument("--wisdom-path", default=None,
                    help="wisdom file for --tune (default "
                         "~/.cache/repro_fft/wisdom.json)")
    ap.add_argument("--seg-batch", type=int, default=1 << 15)
    ap.add_argument("--seg-len", type=int, default=4096)
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    ap.add_argument("--ooc-log2-n", type=int, default=34,
                    help="out-of-core analytic record: log2 points")
    ap.add_argument("--ooc-budget-mb", type=int, default=1024,
                    help="out-of-core analytic record: budget in MiB")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.mesh == "multi_pod")
    axes = tuple(mesh.shape.keys())
    sds = jax.ShapeDtypeStruct
    recs = []

    # paper regime: segmented map-only
    seg = sds((args.seg_batch, args.seg_len), jnp.float32)
    p_seg = fft_api.plan(kind="c2c", n=args.seg_len,
                         batch_shape=(args.seg_batch,), mesh=mesh,
                         placement="segmented", axes=axes)
    recs.append(measure(p_seg, (seg, seg), "segmented"))

    # distributed four-step variants
    sig = sds((args.n,), jnp.float32)
    for name, kw in (
        ("dist_base", dict(natural_order=True, fuse_twiddle=False)),
        ("dist_fused", dict(natural_order=True, fuse_twiddle=True)),
        ("dist_transposed", dict(natural_order=False, fuse_twiddle=True)),
    ):
        p = fft_api.plan(kind="c2c", n=args.n, mesh=mesh,
                         placement="distributed", axes=axes, overlap="off",
                         **kw)
        recs.append(measure(p, (sig, sig), name))

    # 2-D pencil: same machinery, one exchange leg instead of three —
    # the plan's collective counter is the headline (a third of
    # dist_base's bytes at the same point count, DESIGN.md §9)
    shape2d = tuple(args.n2d)
    img = sds(shape2d, jnp.float32)
    p_pencil = fft_api.plan(kind="c2c", shape=shape2d, mesh=mesh,
                            placement="distributed", axes=axes,
                            overlap="off")
    recs.append(measure(p_pencil, (img, img), "pencil2d"))

    if args.tune:
        # measured plan selection for the pencil spec — the analytic
        # measurer ranks candidates on the cost model without executing
        # anything (this is a dryrun); winners persist as wisdom so a
        # real launch with --tune re-plans with zero measurements
        from repro.fft import tuner
        cfg = tuner.TuneConfig(measurer="analytic")
        knobs, trep = tuner.tune(
            kind="c2c", shape=shape2d, mesh=mesh, axes=axes,
            num_devices=math.prod(mesh.shape[a] for a in axes),
            axis_sizes=tuple(mesh.shape[a] for a in axes),
            placement="distributed", wisdom_path=args.wisdom_path,
            config=cfg)
        recs.append({
            "name": "pencil2d_tuned", "analytic_only": True,
            "winner": knobs, "wisdom_hit": trep.wisdom_hit,
            "candidates": len(trep.candidates),
            "disagreement": trep.disagreement,
            "tune_stats": tuner.tune_stats(),
        })

    # 3-D pencil: one mesh axis per sharded volume axis, ndim-1 == 2
    # re-pencil exchange legs (arXiv:2202.12756) — the per-leg
    # collective split is the record's headline
    shape3d = tuple(args.n3d)
    axes3 = axes[-2:]
    vol = sds(shape3d, jnp.float32)
    p_pencil3 = fft_api.plan(kind="c2c", shape=shape3d, mesh=mesh,
                             placement="distributed", axes=axes3,
                             overlap="off")
    rec3 = measure(p_pencil3, (vol, vol), "pencil3d")
    rec3["n_exchanges"] = p_pencil3.dist.n_exchanges
    rec3["plan_per_leg_collective_bytes"] = list(
        p_pencil3.per_leg_collective_bytes)
    recs.append(rec3)

    # predicted overlap win, analytic only (module docstring): plan the
    # chunked pipeline — never lower it — and report what its cost model
    # says the monolithic path leaves exposed on the ICI critical path
    from repro.core.fft.distributed import plan_distributed
    dp = plan_distributed(args.n, math.prod(mesh.shape[a] for a in axes))
    chunks = min(4, dp.n1 // dp.d, dp.n2 // dp.d)  # valid for any --n
    p_ov = fft_api.plan(kind="c2c", n=args.n, mesh=mesh,
                        placement="distributed", axes=axes,
                        natural_order=True, fuse_twiddle=True,
                        overlap=chunks)
    recs.append({
        "name": f"dist_overlap{chunks}_analytic",
        "analytic_only": True,
        "plan_collective_bytes": p_ov.collective_bytes,
        "plan_exposed_collective_bytes": p_ov.exposed_collective_bytes,
        "plan_hidden_collective_bytes": p_ov.hidden_collective_bytes,
        "collective_s": p_ov.collective_bytes / ICI,
        "exposed_collective_s": p_ov.exposed_collective_bytes / ICI,
        "predicted_overlap_win_s": p_ov.hidden_collective_bytes / ICI,
    })

    # out-of-core terabyte point: factorization + IO cost model only (the
    # operand would be 8*2^ooc-log2-n bytes of disk; the streamed run lives
    # in benchmarks/bench_outofcore.py at verifiable sizes). Disk-model
    # seconds use the shared ThrottledStore rate so the record is
    # comparable with bench_pipeline's throughput numbers.
    from repro.core.pipeline.testing import DISK_MB_S
    f_ooc = fft_api.factor_out_of_core(1 << args.ooc_log2_n,
                                       args.ooc_budget_mb << 20)
    disk_bytes_s = DISK_MB_S * (1 << 20)
    recs.append({
        "name": f"ooc_2^{args.ooc_log2_n}_analytic",
        "analytic_only": True,
        **f_ooc.as_dict(),
        "budget_bytes": args.ooc_budget_mb << 20,
        "disk_model_mb_s": DISK_MB_S,
        "disk_model_s": f_ooc.io_bytes / disk_bytes_s,
    })

    for r in recs:
        print(json.dumps(r))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"n": args.n, "mesh": args.mesh, "variants": recs}, f,
                      indent=1)


if __name__ == "__main__":
    main()
