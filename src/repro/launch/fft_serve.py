"""FFT-as-a-service launcher: open-loop load against `FftService`.

  PYTHONPATH=src python -m repro.launch.fft_serve --qps 500 --clients 4 \
      --duration 5 --deadline-ms 50 --faults 'seed=7,rate=0.25,sites=serve.admit+serve.batch+serve.execute'

Drives the dynamic-batching front-end (repro/serve/fft_service.py) with
the shared synthetic workload generator (repro/serve/loadgen.py) and
emits one JSON report: admitted/rejected/shed/failed counts, latency
percentiles, coalescing, plan-cache `cache_info()`, and fault/retry
stats. ``--faults`` takes the same `FaultPlan.parse` spec grammar as
fft_job (kv string, inline JSON, or @file.json) restricted here to the
serve.* sites by default — replaying a service fault storm is one flag.
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core.resilience import (FaultInjector, FaultPlan, RetryPolicy,
                                   event_stats, events)
import repro.fft as fft_api
from repro.serve import FftService
from repro.serve import loadgen
from repro.serve.fft_service import SHED_POLICIES


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=None,
                    help="aggregate offered request rate (default: flood — "
                         "clients submit flat-out, open loop)")
    ap.add_argument("--clients", type=int, default=3,
                    help="concurrent open-loop client threads")
    ap.add_argument("--duration", type=float, default=None,
                    help="wall-clock cap in seconds; with --qps it also "
                         "sizes the request count")
    ap.add_argument("--requests", type=int, default=200,
                    help="request count when --qps/--duration don't size it")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (enforced end-to-end on the "
                         "retry-policy clock; late work is shed pre-launch)")
    ap.add_argument("--faults", default=None,
                    help="deterministic fault schedule to replay "
                         "(FaultPlan.parse spec: 'seed=N,rate=R,"
                         "sites=serve.admit+serve.batch+serve.execute', "
                         "inline JSON, or @file.json)")
    ap.add_argument("--impl", default="ref",
                    choices=["matfft", "stockham", "ref"])
    ap.add_argument("--coalesce", type=int, default=4,
                    help="requests per full dynamic batch")
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="admission bound (outstanding requests)")
    ap.add_argument("--max-inflight", type=int, default=4,
                    help="launched-but-unrealized batch window")
    ap.add_argument("--max-attempts", type=int, default=3,
                    help="per-request retry budget")
    ap.add_argument("--per-spec-qps", type=float, default=None,
                    help="token-bucket admission rate per spec key")
    ap.add_argument("--per-spec-inflight", type=int, default=None,
                    help="admitted-incomplete cap per spec key")
    ap.add_argument("--shed-policy", default="oldest_deadline",
                    choices=list(SHED_POLICIES))
    ap.add_argument("--verify", default="off",
                    choices=["off", "parseval", "abft"],
                    help="ABFT silent-corruption defense (DESIGN.md §13): "
                         "parseval checks each result's energy, abft adds "
                         "a checksum row per launch; detections quarantine "
                         "and recompute through the retry path "
                         "(corruption_detected / corruption_recomputed in "
                         "the service stats)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (request mix + operand content)")
    args = ap.parse_args(argv)

    num_requests = args.requests
    if args.qps and args.duration:
        num_requests = max(1, int(args.qps * args.duration))

    injector = None
    if args.faults:
        injector = FaultInjector(
            FaultPlan.parse(args.faults, num_blocks=num_requests))

    service = FftService(
        impl=args.impl, coalesce=args.coalesce,
        queue_depth=args.queue_depth, max_inflight=args.max_inflight,
        per_spec_qps=args.per_spec_qps,
        per_spec_inflight=args.per_spec_inflight,
        shed_policy=args.shed_policy,
        default_deadline_s=(args.deadline_ms / 1e3
                            if args.deadline_ms else None),
        retry=RetryPolicy(max_attempts=args.max_attempts),
        injector=injector, verify=args.verify)

    t0 = time.monotonic()
    records = loadgen.drive(service, num_requests=num_requests,
                            clients=args.clients, seed=args.seed,
                            qps=args.qps, duration_s=args.duration)
    outcomes = [loadgen.classify(rec) for rec in records]
    service.close(drain=True)
    wall = time.monotonic() - t0

    buckets: dict = {}
    for o in outcomes:
        buckets[o] = buckets.get(o, 0) + 1
    stats = service.stats.snapshot()
    print(json.dumps({
        "requests": len(records),
        "wall_s": round(wall, 3),
        "qps_completed": round(buckets.get("ok", 0) / wall, 1) if wall
        else None,
        "outcomes": dict(sorted(buckets.items())),
        "drained_idle": service.idle(),
        "verify": args.verify,
        "verify_failed_events": len(events("verify_failed")),
        "service": stats,
        "degrade_events": events("service_degrade"),
        "event_log": event_stats(),
        "faults": injector.summary() if injector is not None else None,
        "plan_cache": fft_api.cache_info(),
    }, indent=1))


if __name__ == "__main__":
    main()
