"""Training launcher (host-scale; the production mesh path is dryrun.py).

Trains any assigned arch at a reduced or custom size on local devices:

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Auto-resumes from the newest committed checkpoint in --ckpt-dir (kill it
mid-run and relaunch to see the fault-tolerance path).
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCHS, get_config
from repro.data import TokenPipeline, synthetic_corpus
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import TransformerLM
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--data-dir", default="/tmp/repro_corpus")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = TransformerLM(cfg)

    store = synthetic_corpus(args.data_dir, vocab_size=cfg.vocab_size,
                             n_tokens=max(4_000_000,
                                          args.batch * (args.seq + 1) * 50),
                             seed=args.seed)
    pipe = TokenPipeline(store, batch=args.batch, seq=args.seq)

    tc = TrainerConfig(optimizer=args.optimizer, base_lr=args.lr,
                       warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps,
                       grad_compression=args.grad_compression,
                       ckpt_dir=args.ckpt_dir)
    trainer = Trainer(model, tc, mesh=None)
    state = trainer.restore_or_init(jax.random.PRNGKey(args.seed))
    start = int(state["step"])
    if start:
        print(f"resumed from step {start}")
    state, history = trainer.run(state, iter(pipe), steps=args.steps - start)
    for m in history:
        print(json.dumps(m))


if __name__ == "__main__":
    main()
