"""Dry-run sweep driver: every (arch x shape x mesh) cell as a subprocess.

Each cell runs in its own process (XLA device-count isolation + crash
containment — one OOM'ing compile can't kill the sweep). Single-pod cells
get the unrolled cost pass (the roofline table is single-pod per spec);
multi-pod cells are the sharding-coherence compile proof only.

  PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

from repro.configs import ARCHS
from repro.launch.specs import SHAPES


def run_one(arch, shape, mesh, out_dir: Path, timeout_s: int,
            skip_cost: bool) -> dict:
    out = out_dir / f"{arch}__{shape}__{mesh}.json"
    if out.exists():
        try:
            return json.loads(out.read_text())
        except json.JSONDecodeError:
            out.unlink()
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(out)]
    if skip_cost:
        cmd.append("--skip-cost")
    t0 = time.monotonic()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout_s)
        if out.exists():
            return json.loads(out.read_text())
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": False,
               "error": f"exit={proc.returncode}",
               "stderr": proc.stderr[-2000:]}
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": False,
               "error": f"timeout after {timeout_s}s"}
    rec["wall_s"] = round(time.monotonic() - t0, 1)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--meshes", nargs="*", default=["single_pod", "multi_pod"])
    ap.add_argument("--archs", nargs="*", default=ARCHS)
    ap.add_argument("--shapes", nargs="*", default=list(SHAPES))
    args = ap.parse_args(argv)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    for mesh in args.meshes:
        for arch in args.archs:
            for shape in args.shapes:
                t0 = time.monotonic()
                rec = run_one(arch, shape, mesh, out_dir, args.timeout,
                              skip_cost=(mesh == "multi_pod"))
                status = ("SKIP" if rec.get("skipped")
                          else "ok" if rec.get("ok") else "FAIL")
                print(f"[{status:4s}] {mesh:10s} {arch:24s} {shape:12s} "
                      f"({time.monotonic() - t0:6.1f}s)", flush=True)
                results.append(rec)
    n_fail = sum(1 for r in results if not r.get("ok"))
    print(f"\n{len(results)} cells, {n_fail} failures")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
