"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation ever happens here — everything is abstract, exactly
like shannon/kernels' dry-run pattern. Frontend stubs per the assignment:
whisper gets precomputed frame embeddings, internvl2 gets precomputed patch
embeddings.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import TransformerLM


@dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}


def cell_runnable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason). long_500k only for sub-quadratic archs (spec)."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch; long_500k needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _bf16(shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def input_specs(cfg: ModelConfig, shape: str):
    """Abstract model inputs for one cell.

    train:   {"tokens","labels"[,"frames","patches"]}
    prefill: {"tokens"[,"frames","patches"]}
    decode:  (caches, token, pos)  — caches built via jax.eval_shape
    """
    case = SHAPES[shape]
    b, s = case.global_batch, case.seq_len

    if case.mode in ("train", "prefill"):
        batch = {}
        if cfg.encoder_layers:  # whisper: seq splits 1:1 enc frames : dec toks
            batch["tokens"] = _i32((b, s // 2))
            batch["frames"] = _bf16((b, s // 2, cfg.d_model))
        elif cfg.num_prefix_embeds:  # vlm: patch prefix + text
            batch["tokens"] = _i32((b, s - cfg.num_prefix_embeds))
            batch["patches"] = _bf16((b, cfg.num_prefix_embeds, cfg.d_model))
        else:
            batch["tokens"] = _i32((b, s))
        if case.mode == "train":
            batch["labels"] = _i32(batch["tokens"].shape)
        return batch

    # decode: one new token against a cache of length s
    model = TransformerLM(cfg)
    caches = jax.eval_shape(lambda: model.init_cache(b, s))
    token = _i32((b, 1))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, token, pos
