import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Memory-lower-bound pass: re-measure cost with ONLY layer scans unrolled.

Inner scans (attention kv tiles, GLA chunks, loss chunks) stay rolled, so
`bytes accessed` counts their bodies once -> a LOWER bound on per-device
HBM traffic that avoids the fusion-subsumed-slice inflation of the full
unroll (see models/scanning.py). Results are merged into the existing
results/dryrun/*.json as the "cost_lb" field.

  PYTHONPATH=src python -m repro.launch.bytes_pass [--out results/dryrun]
"""

import argparse
import dataclasses
import json
from pathlib import Path

from repro.configs import ARCHS, get_config
from repro.launch.dryrun import _cost_vector, build_lowered
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES
from repro.models.scanning import set_unroll
from repro.sharding.rules import ShardingRules, use_rules


def cell_lb(arch, shape, mesh, rules):
    cfg = get_config(arch)
    period = len(cfg.layer_pattern)
    full_p, tail = cfg.pattern_groups()
    set_unroll("layers")
    try:
        def measure(n_layers):
            cfg_v = dataclasses.replace(cfg, num_layers=n_layers)
            return _cost_vector(
                build_lowered(arch, shape, mesh, rules, cfg=cfg_v).compile())

        c1 = measure(period)
        c2 = measure(2 * period)
        ct = measure(period + tail) if tail else None
    finally:
        set_unroll("none")
    out = {}
    for k in c1:
        total = c1[k] + (full_p - 1) * (c2[k] - c1[k])
        if ct is not None:
            total += ct[k] - c1[k]
        out[k] = total
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)
    out_dir = Path(args.out)
    mesh = make_production_mesh()
    rules = ShardingRules.default()
    for arch in ARCHS:
        for shape in SHAPES:
            p = out_dir / f"{arch}__{shape}__single_pod.json"
            if not p.exists():
                continue
            rec = json.loads(p.read_text())
            if rec.get("skipped") or not rec.get("ok") or "cost_lb" in rec:
                continue
            try:
                with mesh, use_rules(rules):
                    rec["cost_lb"] = cell_lb(arch, shape, mesh, rules)
                print(f"[ok] {arch} {shape}", flush=True)
            except Exception as e:
                rec["cost_lb_error"] = f"{type(e).__name__}: {e}"
                print(f"[fail] {arch} {shape}: {e}", flush=True)
            p.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
