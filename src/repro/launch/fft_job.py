"""The paper's workload as a launcher: block-distributed FFT over a file.

  PYTHONPATH=src python -m repro.launch.fft_job --size-mb 64 --fft-len 1024 \
      --workers 4 --work-dir /tmp/fft_job

Mirrors the paper's Figure 1 flow: copy-in (split into blocks) -> map-only
batched FFT per block -> direct output writes -> getmerge. Reports the
paper's metrics: total time, I/O vs FFT fraction, and the Amdahl/runtime-
model prediction for larger clusters.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core.amdahl import ClusterModel, calibrate_unit_time, fit_parallel_fraction
from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 block_of_segments, segments_of_block)
from repro.core.pipeline.records import segment_block_bytes
import repro.fft as fft_api


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=64)
    ap.add_argument("--fft-len", type=int, default=1024)
    ap.add_argument("--segments-per-block", type=int, default=2048)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--impl", default="matfft",
                    choices=["matfft", "stockham", "ref"])
    ap.add_argument("--work-dir", default="/tmp/repro_fft_job")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    work = Path(args.work_dir)
    n_seg = args.size_mb * (1 << 20) // (8 * args.fft_len)
    rng = np.random.default_rng(args.seed)

    # --- copy-in (HDFS put) ---
    t0 = time.monotonic()
    sig = rng.standard_normal((n_seg, args.fft_len, 2)).astype(np.float32)
    store = BlockStore(work / "in", block_bytes=segment_block_bytes(
        args.fft_len, args.segments_per_block))
    store.put_bytes(sig.tobytes())
    t_put = time.monotonic() - t0

    # --- map-only FFT job ---
    io_s = [0.0]
    fft_s = [0.0]

    def map_fn(data: bytes, idx: int) -> bytes:
        t = time.monotonic()
        re, im = segments_of_block(data, args.fft_len)
        re, im = jnp.asarray(re), jnp.asarray(im)
        io_s[0] += time.monotonic() - t
        t = time.monotonic()
        # every same-shaped block hits the process-level plan cache: the
        # jit'd callable is built once, the cufftPlanMany amortization
        p = fft_api.plan(kind="c2c", n=args.fft_len,
                         batch_shape=re.shape[:-1], impl=args.impl)
        yr, yi = p.execute(re, im)
        yr.block_until_ready()
        fft_s[0] += time.monotonic() - t
        t = time.monotonic()
        out = block_of_segments(np.asarray(yr), np.asarray(yi))
        io_s[0] += time.monotonic() - t
        return out

    job = MapOnlyJob(store, work / "out", map_fn,
                     JobConfig(workers=args.workers))
    t0 = time.monotonic()
    stats = job.run()
    t_job = time.monotonic() - t0
    t0 = time.monotonic()
    nbytes = job.merge(work / "merged.bin")
    t_merge = time.monotonic() - t0

    # --- paper metrics ---
    p_frac = fit_parallel_fraction(io_s[0], fft_s[0])
    n = n_seg * args.fft_len
    unit = calibrate_unit_time(n, t_job, servers=1, cores=args.workers,
                               efficiency=1.0)
    model = ClusterModel(unit_time_s=unit)
    print(json.dumps({
        "size_mb": args.size_mb,
        "blocks": len(store.blocks),
        "copy_in_s": round(t_put, 3),
        "job_s": round(t_job, 3),
        "merge_s": round(t_merge, 3),
        "merged_bytes": nbytes,
        "fft_fraction": round(p_frac, 3),
        "io_fraction": round(1 - p_frac, 3),
        "attempts": stats.attempts,
        "speculative": stats.speculative_launches,
        "predicted_s_8_workers": round(model.predict(n, 1, 8), 3),
        "predicted_s_64_workers": round(model.predict(n, 8, 8), 3),
        "plan_cache": fft_api.cache_info(),
    }, indent=1))


if __name__ == "__main__":
    main()
