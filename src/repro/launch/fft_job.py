"""The paper's workload as a launcher: block-distributed FFT over a file.

  PYTHONPATH=src python -m repro.launch.fft_job --size-mb 64 --fft-len 1024 \
      --workers 4 --work-dir /tmp/fft_job --pipelined --coalesce 4

Mirrors the paper's Figure 1 flow: copy-in (split into blocks) -> map-only
batched FFT per block -> direct output writes -> getmerge. Two execution
modes over the same store:

  * serial (default): the classic one-thread-per-block map task, each
    attempt doing read -> decode -> H2D -> execute -> sync -> D2H ->
    encode -> write in sequence;
  * --pipelined: the overlapped stream executor (core/pipeline/stream.py)
    with ``--coalesce`` same-shaped blocks per device batch and an
    ``--inflight`` launch window, so device compute hides behind block I/O.

Both report per-stage clocks (read/h2d/compute/d2h/write) instead of the
old lumped io/fft split, plus the paper's Amdahl/runtime-model prediction.
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core.amdahl import ClusterModel, calibrate_unit_time, fit_parallel_fraction
from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 SegmentFFTTransform, block_of_segments,
                                 segments_of_block)
from repro.core.pipeline.records import segment_block_bytes
import repro.fft as fft_api


class _TimedStore:
    """Serial-mode shim: clocks block file I/O into the shared stage dict
    so the serial path's "read"/"write" totals cover the same work as the
    stream executor's (file I/O happens inside MapOnlyJob._attempt, out of
    map_fn's reach)."""

    def __init__(self, store: BlockStore, add):
        self._store = store
        self._add = add

    def __getattr__(self, name):
        return getattr(self._store, name)

    def read_block(self, index: int, verify: bool = True) -> bytes:
        t0 = time.monotonic()
        data = self._store.read_block(index, verify)
        self._add("read", t0)
        return data

    def write_output_block(self, out_dir, index: int, data) -> None:
        t0 = time.monotonic()
        self._store.write_output_block(out_dir, index, data)
        self._add("write", t0)


def serial_map_fn(fft_len: int, impl: str, add, verify: str = "off",
                  tune: bool = False, wisdom_path=None):
    """The synchronous per-block map task, with per-stage clocks.

    Stage names match the stream executor's so the two paths are
    comparable ("read"/"write" also accumulate the block file I/O, via
    `_TimedStore`).
    """

    def map_fn(data: bytes, idx: int) -> bytes:
        t = time.monotonic()
        re, im = segments_of_block(data, fft_len)
        t = add("read", t)
        re, im = jnp.asarray(re), jnp.asarray(im)
        t = add("h2d", t)
        # every same-shaped block hits the process-level plan cache: the
        # jit'd callable is built once, the cufftPlanMany amortization
        p = fft_api.plan(kind="c2c", n=fft_len,
                         batch_shape=re.shape[:-1], impl=impl,
                         verify=verify, tune=tune,
                         wisdom_path=wisdom_path)
        yr, yi = p.execute(re, im)
        yr.block_until_ready()  # the serial path's per-block sync
        t = add("compute", t)
        yr, yi = np.asarray(yr), np.asarray(yi)
        t = add("d2h", t)
        out = block_of_segments(yr, yi)
        add("write", t)
        return out

    return map_fn


def parseval_verify_fn(fft_len: int):
    """Serial-mode ABFT hook (`JobConfig.verify_fn`): block-aggregate
    Parseval over the map output — every segment is length fft_len, so
    the whole block must carry fft_len x its input energy."""
    from repro.core.resilience import verify as abft

    def verify_fn(data: bytes, out: bytes, index: int) -> None:
        re, im = segments_of_block(data, fft_len)
        yr, yi = segments_of_block(out, fft_len)
        abft.check_parseval(abft.energy(re, im), abft.energy(yr, yi),
                            fft_len, "f32", site="maponly.attempt",
                            index=index)

    return verify_fn


def run_job(store: BlockStore, out_dir, *, fft_len: int, impl: str,
            cfg: JobConfig, pipelined: bool, verify: str = "off",
            tune: bool = False, wisdom_path=None):
    """Run the FFT job serial or pipelined; returns (job, stats, stage_s)."""
    if pipelined:
        job = MapOnlyJob(store, out_dir, config=cfg, pipelined=True,
                         transform=SegmentFFTTransform(fft_len, impl=impl,
                                                       verify=verify))
        stats = job.run()
        return job, stats, dict(stats.stage_s)
    stage_s = {k: 0.0 for k in ("read", "h2d", "compute", "d2h", "write")}
    lock = threading.Lock()  # map tasks run on the job's worker pool

    def add(stage: str, t0: float) -> float:
        now = time.monotonic()
        with lock:
            stage_s[stage] += now - t0
        return now

    if verify != "off":
        from dataclasses import replace as _replace
        cfg = _replace(cfg, verify_fn=parseval_verify_fn(fft_len))
    job = MapOnlyJob(_TimedStore(store, add), out_dir,
                     serial_map_fn(fft_len, impl, add, verify,
                                   tune=tune, wisdom_path=wisdom_path),
                     config=cfg)
    stats = job.run()
    return job, stats, stage_s


def run_out_of_core(args) -> dict:
    """The >RAM workload: one giant 1-D c2c streamed through the store.

    Ingests 2^log2_n random complex64 samples as a `BlockStore`, builds
    the ``placement="out_of_core"`` plan under ``--budget-mb``, executes
    both streamed passes (crash-resume: re-running the same --work-dir
    picks up from the phase manifests), and getmerges the spectrum.
    """
    work = Path(args.work_dir)
    n = 1 << args.log2_n
    budget = args.budget_mb << 20
    factors = fft_api.factor_out_of_core(n, budget)
    # one job's panel per block, capped at 4 MB: both are powers of two,
    # so the block always tiles the panel
    block_bytes = min(factors.pass1_panel_bytes, 1 << 22)

    t0 = time.monotonic()
    rng = np.random.default_rng(args.seed)
    store = BlockStore(work / "in", block_bytes=block_bytes,
                       replication=args.replication)
    sig = rng.standard_normal((n, 2)).astype(np.float32)
    store.put_bytes(sig.tobytes())
    del sig
    t_put = time.monotonic() - t0

    injector = None
    if args.faults:
        from repro.core.resilience import FaultInjector, FaultPlan
        injector = FaultInjector(
            FaultPlan.parse(args.faults, num_blocks=len(store.blocks)))
        store.injector = injector
    cfg = JobConfig(readers=args.readers, writers=args.writers,
                    inflight=args.inflight, speculation=False,
                    max_retries=args.max_retries, injector=injector)

    plan = fft_api.plan(kind="c2c", n=n, placement="out_of_core",
                        store=store, work_dir=work / "ooc", impl=args.impl,
                        budget_bytes=budget, job_config=cfg,
                        verify=args.verify, tune=args.tune,
                        wisdom_path=args.wisdom_path)
    t0 = time.monotonic()
    stats = plan.execute()
    t_job = time.monotonic() - t0
    t0 = time.monotonic()
    nbytes = plan.merge(work / "merged.bin")
    t_merge = time.monotonic() - t0
    from repro.core.resilience import events
    return {
        "mode": "out_of_core",
        "verify": args.verify,
        "corruption_detected": len(events("verify_failed")),
        "corruption_recomputed": (stats.pass1.retries + stats.pass2.retries
                                  if stats.pass1 and stats.pass2 else 0),
        "factors": factors.as_dict(),
        "block_bytes": block_bytes,
        "budget_bytes": budget,
        "operand_over_budget_x": round(factors.operand_bytes / budget, 2),
        "copy_in_s": round(t_put, 3),
        "job_s": round(t_job, 3),
        "merge_s": round(t_merge, 3),
        "merged_bytes": nbytes,
        "stats": stats.as_dict(),
        "store": store.stats.as_dict(),
        "faults": injector.summary() if injector is not None else None,
        "plan_cache": fft_api.cache_info(),
        "tuner": _tuner_stats(args.tune),
    }


def _tuner_stats(tune: bool):
    """Wisdom/measurement counters for the report; None when --tune off
    (the tuner module is never imported on the default path)."""
    if not tune:
        return None
    from repro.fft import tuner
    return tuner.tune_stats()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--size-mb", type=int, default=64)
    ap.add_argument("--fft-len", type=int, default=1024)
    ap.add_argument("--segments-per-block", type=int, default=2048)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--impl", default="matfft",
                    choices=["matfft", "stockham", "ref"])
    ap.add_argument("--work-dir", default="/tmp/repro_fft_job")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipelined", action="store_true",
                    help="overlapped stream executor instead of the "
                         "serial per-block map loop")
    ap.add_argument("--coalesce", type=int, default=4,
                    help="same-shaped blocks per device batch (pipelined)")
    ap.add_argument("--inflight", type=int, default=2,
                    help="launched-but-unrealized batch window (pipelined)")
    ap.add_argument("--readers", type=int, default=2,
                    help="prefetch/decode threads (pipelined)")
    ap.add_argument("--writers", type=int, default=2,
                    help="writeback threads (pipelined)")
    ap.add_argument("--replication", type=int, default=1,
                    help="block replicas kept in the store")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="per-block attempt budget")
    ap.add_argument("--faults", default=None,
                    help="deterministic fault schedule to replay "
                         "(core/resilience/faults.py FaultPlan.parse spec: "
                         "'seed=N,rate=R,sites=a+b', inline JSON, or "
                         "@file.json; add kind=corrupt for silent "
                         "bit-rot) — the report then carries retry, "
                         "repair, and injector stats")
    ap.add_argument("--verify", default="off",
                    choices=["off", "parseval", "abft"],
                    help="ABFT invariant verification (DESIGN.md §13): "
                         "parseval checks output energy per unit, abft "
                         "adds a linearity checksum row per batch; "
                         "detections quarantine-and-recompute through "
                         "the retry path and are counted in the report")
    ap.add_argument("--out-of-core", action="store_true",
                    help="run one 2^log2-n-point c2c whose operand lives "
                         "in the BlockStore, streamed under --budget-mb "
                         "(ignores the segment-batch options above)")
    ap.add_argument("--log2-n", type=int, default=20,
                    help="out-of-core transform size, log2 of points")
    ap.add_argument("--budget-mb", type=int, default=16,
                    help="out-of-core working-set budget in MiB")
    ap.add_argument("--tune", action="store_true",
                    help="measuring autotuner (DESIGN.md §14): plan-time "
                         "candidate sweeps pick layout/batch-tile/"
                         "exchange-engine (and the out-of-core panel "
                         "height) by measurement; winners persist as "
                         "wisdom so later runs re-plan with zero "
                         "measurements — the report carries the "
                         "tuned/wisdom-hit/measurement counters")
    ap.add_argument("--wisdom-path", default=None,
                    help="wisdom file for --tune (default "
                         "~/.cache/repro_fft/wisdom.json)")
    args = ap.parse_args(argv)

    if args.out_of_core:
        print(json.dumps(run_out_of_core(args), indent=1))
        return

    work = Path(args.work_dir)
    n_seg = args.size_mb * (1 << 20) // (8 * args.fft_len)
    rng = np.random.default_rng(args.seed)

    # --- copy-in (HDFS put) ---
    t0 = time.monotonic()
    sig = rng.standard_normal((n_seg, args.fft_len, 2)).astype(np.float32)
    store = BlockStore(work / "in", block_bytes=segment_block_bytes(
        args.fft_len, args.segments_per_block),
        replication=args.replication)
    store.put_bytes(sig.tobytes())
    t_put = time.monotonic() - t0

    # --- optional deterministic chaos replay ---
    injector = None
    if args.faults:
        from repro.core.resilience import FaultInjector, FaultPlan
        injector = FaultInjector(
            FaultPlan.parse(args.faults, num_blocks=len(store.blocks)))
        store.injector = injector

    # --- map-only FFT job ---
    cfg = JobConfig(workers=args.workers, readers=args.readers,
                    writers=args.writers, coalesce=args.coalesce,
                    inflight=args.inflight, max_retries=args.max_retries,
                    injector=injector)
    t0 = time.monotonic()
    job, stats, stage_s = run_job(store, work / "out", fft_len=args.fft_len,
                                  impl=args.impl, cfg=cfg,
                                  pipelined=args.pipelined,
                                  verify=args.verify, tune=args.tune,
                                  wisdom_path=args.wisdom_path)
    t_job = time.monotonic() - t0
    t0 = time.monotonic()
    nbytes = job.merge(work / "merged.bin")
    t_merge = time.monotonic() - t0

    # --- paper metrics ---
    # NOTE: stage clocks are per-thread sums; in pipelined mode they run
    # concurrently, so these fractions are shares of total STAGE TIME
    # (thread-seconds of work), not a wall-clock split. The device side is
    # compute + d2h: with async dispatch the launch call returns in
    # microseconds and the real device wait surfaces at realization (the
    # d2h clock), so counting "compute" alone would report ~0 fft work on
    # accelerators. The Amdahl model below calibrates on wall time (t_job)
    # and is unaffected.
    fft_s = stage_s.get("compute", 0.0) + stage_s.get("d2h", 0.0)
    io_s = sum(v for k, v in stage_s.items()
               if k not in ("compute", "d2h"))
    p_frac = fit_parallel_fraction(io_s, fft_s)
    n = n_seg * args.fft_len
    unit = calibrate_unit_time(n, t_job, servers=1, cores=args.workers,
                               efficiency=1.0)
    model = ClusterModel(unit_time_s=unit)
    stage_total = sum(stage_s.values())
    from repro.core.resilience import events
    print(json.dumps({
        "mode": "pipelined" if args.pipelined else "serial",
        "verify": args.verify,
        "corruption_detected": len(events("verify_failed")),
        "corruption_recomputed": stats.retries,
        "size_mb": args.size_mb,
        "blocks": len(store.blocks),
        "copy_in_s": round(t_put, 3),
        "job_s": round(t_job, 3),
        "merge_s": round(t_merge, 3),
        "merged_bytes": nbytes,
        "stage_s": {k: round(v, 3) for k, v in stage_s.items()},
        "stage_total_s": round(stage_total, 3),
        # >1 means stages genuinely overlapped (wall < sum of stage time)
        "overlap_x": round(stage_total / t_job, 3) if t_job else None,
        "batches": stats.batches,
        "coalesced_blocks": stats.coalesced_blocks,
        "fft_fraction": round(p_frac, 3),
        "io_fraction": round(1 - p_frac, 3),
        "attempts": stats.attempts,
        "speculative": stats.speculative_launches,
        "retries": stats.retries,
        "failed_blocks": stats.failed_blocks,
        "store": store.stats.as_dict(),
        "faults": injector.summary() if injector is not None else None,
        "predicted_s_8_workers": round(model.predict(n, 1, 8), 3),
        "predicted_s_64_workers": round(model.predict(n, 8, 8), 3),
        "plan_cache": fft_api.cache_info(),
        "tuner": _tuner_stats(args.tune),
    }, indent=1))


if __name__ == "__main__":
    main()
