"""Checkpointing: sharded, atomic, async, keep-N, mesh-shape-agnostic.

Layout:  <dir>/step_<n>/leaf_<i>.npy + manifest.json + COMMIT marker.

Fault-tolerance properties (tested in tests/test_checkpoint.py):
  * atomic: leaves land in ``.tmp_step_<n>``; the directory is renamed and
    a COMMIT marker written only after every leaf fsync'd — a crash mid-save
    never yields a checkpoint that ``latest_step`` would pick up;
  * auto-resume: ``latest_step`` returns the newest COMMITted step and
    ignores torn ones;
  * elastic: leaves are saved as *global* (unsharded) arrays; ``restore``
    re-device_puts onto whatever shardings the *current* mesh asks for, so
    a job can come back on a different data-parallel width (DESIGN.md §7);
  * async: ``CheckpointManager.save_async`` snapshots to host (blocking on
    device->host copy only) and writes in a background thread; keep_n GC.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax
import numpy as np

COMMIT = "COMMIT"


def _tree_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(step: int, tree, ckpt_dir: os.PathLike) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _tree_paths(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)  # gathers sharded arrays to host
        path = tmp / f"leaf_{i:05d}.npy"
        with open(path, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append(
            {"i": i, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    (final / COMMIT).touch()
    return final


def latest_step(ckpt_dir: os.PathLike) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.glob("step_*"):
        if (p / COMMIT).exists() and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: os.PathLike, step: int, like, shardings=None):
    """Load step ``step`` shaped like ``like`` (a pytree of arrays or
    ShapeDtypeStructs); if ``shardings`` given, device_put each leaf onto it
    (this is where elastic resharding happens)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    leaves, treedef = _tree_paths(like)
    out = []
    for i, leaf in enumerate(leaves):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != model {leaf.shape}")
        out.append(arr)
    tree = treedef.unflatten(out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree


class CheckpointManager:
    def __init__(self, ckpt_dir: os.PathLike, keep_n: int = 3):
        self.dir = Path(ckpt_dir)
        self.keep_n = keep_n
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        self._lock = threading.Lock()

    def save_async(self, step: int, tree):
        """Snapshot to host now, write in the background."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        with self._lock:
            if self._pending is not None:
                self._pending.result()  # backpressure: one in flight
            self._pending = self._pool.submit(self._write, step, host_tree)

    def _write(self, step, host_tree):
        save(step, host_tree, self.dir)
        self._gc()

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if (p / COMMIT).exists())
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.dir)

    def restore_latest(self, like, shardings=None):
        s = self.latest()
        if s is None:
            return None, None
        return s, restore(self.dir, s, like, shardings)
