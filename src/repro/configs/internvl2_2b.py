"""InternVL2-2B [arXiv:2404.16821; hf-verified].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 — InternLM2-1.8B
language backbone; InternViT vision tower STUBBED per the assignment:
input_specs() provides 256 precomputed patch embeddings prepended to the
token sequence (prefix-LM layout, loss masked over the prefix).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    num_prefix_embeds=256,
    layer_pattern="G",
)
