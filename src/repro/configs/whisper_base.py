"""Whisper-base [arXiv:2212.04356; unverified tier].

6L d_model=512 8H d_ff=2048 vocab=51865 — encoder-decoder backbone
(6 encoder + 6 decoder layers), LayerNorm + GELU, absolute sinusoidal
positions (no rope), conv audio frontend STUBBED per the assignment:
input_specs() provides precomputed frame embeddings. The real frontend
math (log-mel STFT) is the paper's own workload and lives in
core/spectral.py (see examples/spectral_analysis.py).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    frontend="audio_frames",
    cross_len=1500,
    tie_embeddings=True,
    layer_pattern="G",
)
