"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 routed + always-on shared expert (llama4's MoE design). Text
backbone only per the assignment ("early fusion" multimodality not in
scope of the assigned shape set).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_experts_per_tok=1,
    shared_expert=True,
    rope_theta=500_000.0,
    layer_pattern="G",
)
