"""Assigned-architecture registry: ``get_config(name)`` / ``--arch <id>``."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "qwen3-0.6b",
    "h2o-danube-1.8b",
    "qwen2-0.5b",
    "gemma3-1b",
    "rwkv6-3b",
    "llama4-scout-17b-a16e",
    "mixtral-8x22b",
    "whisper-base",
    "zamba2-7b",
    "internvl2-2b",
]

_MODULES = {name: "repro.configs." + name.replace("-", "_").replace(".", "_")
            for name in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(_MODULES[name]).CONFIG
