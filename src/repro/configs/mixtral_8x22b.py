"""Mixtral-8x22B [arXiv:2401.04088; hf-verified].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts
top-2, sliding-window attention (per the assigned config) window 4096.
Pure-SWA decode => long_500k runs with an O(window) ring cache.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    sliding_window=4096,
    layer_pattern="L",
    rope_theta=1_000_000.0,
)
