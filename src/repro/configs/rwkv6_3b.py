"""RWKV6 "Finch" 3B [arXiv:2404.05892; hf-verified].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 — data-dependent
decay WKV with 40 heads of 64 (head_dim = 64 convention). The paper's FFT
technique is inapplicable to the data-dependent-decay mixer (DESIGN.md §5);
long_500k runs with O(1) recurrent state.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    layer_pattern="R",
)
