"""Gemma3-1B [hf:google/gemma-3-1b-pt; unverified tier].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 — 5:1 local:global
attention pattern (window 512 local layers, full-attention every 6th),
qk-norm, sandwich norms, GeGLU, head_dim=256, dual rope thetas
(10k local / 1M global), tied embeddings, sqrt(d) embedding scale.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    qk_norm=True,
    sliding_window=512,
    layer_pattern="LLLLLG",
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu",
)
