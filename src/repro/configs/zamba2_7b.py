"""Zamba2-7B [arXiv:2411.15242; unverified tier].

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64 —
Mamba2 backbone with a SHARED full-attention transformer block applied
every 6th layer (13 applications, one set of weights): pattern "MMMMMS"
with 81 = 13*6 + 3 (tail = 3 mamba layers). The shared block's params are
scan-closure constants; its 13 KV caches are per-period scan xs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    layer_pattern="MMMMMS",
)
