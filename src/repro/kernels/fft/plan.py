"""FFT planning helpers: factorizations and twiddle tables.

The paper's CUFFT "batched plan" becomes, on TPU, a static factorization of
the transform length into MXU-friendly GEMM factors plus precomputed twiddle
tables. Everything here is host-side numpy (float64 internally, cast on
export) and cached — the analogue of ``cufftPlanMany`` construction.

Naming follows the classic four-step (Bailey) decomposition of a length-N
DFT with N = n1 * n2, input index i = i1*n2 + i2, output index o = o2*n1 + o1:

    A[o1, i2] = sum_i1 x[i1, i2] * W_{n1}^{i1*o1}        (column DFTs)
    B[o1, i2] = A[o1, i2] * W_N^{o1*i2}                  (twiddle)
    C[o1, o2] = sum_i2 B[o1, i2] * W_{n2}^{i2*o2}        (row DFTs)
    X[o2*n1 + o1] = C[o1, o2]                            (transpose)
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import numpy as np

# Maximum transform length handled directly by one kernel invocation
# (a (batch_tile x N) tile plus two DFT matrices must fit in ~16MB VMEM).
MAX_LEAF = 16384


def is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def log2i(n: int) -> int:
    if not is_pow2(n):
        raise ValueError(f"length must be a power of two, got {n}")
    return n.bit_length() - 1


def split_pow2(n: int, max_leaf: int = MAX_LEAF) -> tuple[int, int]:
    """Split n = n1 * n2 (both pow2, both <= max_leaf), near-square.

    Near-square factors minimize total GEMM MACs: cost ~ N*(n1 + n2).
    """
    p = log2i(n)
    n1 = 1 << (p // 2)
    n2 = 1 << (p - p // 2)  # n2 >= n1
    if n2 > max_leaf:
        raise ValueError(f"cannot split {n} into factors <= {max_leaf}")
    return n1, n2


@functools.lru_cache(maxsize=None)
def dft_matrix(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Planar (re, im) forward DFT matrix W[i, o] = exp(-2j*pi*i*o/n), f32."""
    idx = np.arange(n, dtype=np.float64)
    ang = -2.0 * math.pi * np.outer(idx, idx) / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


@functools.lru_cache(maxsize=None)
def twiddle_table(n1: int, n2: int, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Planar inner twiddle T[o1, i2] = exp(-2j*pi*o1*i2/n), shape (n1, n2)."""
    o1 = np.arange(n1, dtype=np.float64)
    i2 = np.arange(n2, dtype=np.float64)
    ang = -2.0 * math.pi * np.outer(o1, i2) / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


@functools.lru_cache(maxsize=None)
def rfft_twiddle(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Planar packing twiddle v[k] = exp(-2j*pi*k/n), shape (1, n//2).

    Combines the even/odd sub-spectra of the half-length packed transform
    into the one-sided real-input spectrum (matfft._rfft_kernel).
    """
    k = np.arange(n // 2, dtype=np.float64)
    ang = -2.0 * math.pi * k / n
    return (np.cos(ang).astype(np.float32).reshape(1, -1),
            np.sin(ang).astype(np.float32).reshape(1, -1))


@functools.lru_cache(maxsize=None)
def stockham_twiddles(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Packed per-stage twiddles for the radix-2 Stockham kernel.

    Stage s (s = 0..log2(n)-1) uses l = n >> (s+1) twiddles
    w_j = exp(-2j*pi*j/(2l)), j in [0, l). They are packed contiguously:
    stage 0 at offset 0 (l = n/2), stage 1 at offset n/2 (l = n/4), ...
    Total packed length = n - 1; padded to n for a clean block shape.
    """
    re = np.zeros((n,), dtype=np.float32)
    im = np.zeros((n,), dtype=np.float32)
    off = 0
    l = n // 2
    while l >= 1:
        j = np.arange(l, dtype=np.float64)
        ang = -2.0 * math.pi * j / (2 * l)
        re[off:off + l] = np.cos(ang)
        im[off:off + l] = np.sin(ang)
        off += l
        l //= 2
    return re, im


def stockham_stage_offsets(n: int) -> list[tuple[int, int, int]]:
    """[(offset, l, m)] per stage for the packed twiddle layout above."""
    out = []
    off, l, m = 0, n // 2, 1
    while l >= 1:
        out.append((off, l, m))
        off += l
        l //= 2
        m *= 2
    return out


@dataclass(frozen=True)
class FftPlan:
    """Execution plan for a batched 1-D FFT of length ``n``.

    levels == 1: single kernel call (n <= max_leaf).
    levels == 2: host-level four-step with leaf kernel calls on both passes.
    (Distributed cross-device planning lives in core/fft/distributed.py and
    composes on top of this plan for the per-device local work.)
    """

    n: int
    levels: int
    n1: int  # levels==2: outer factor (column count);   levels==1: in-kernel n1
    n2: int  # levels==2: inner factor (row FFT length); levels==1: in-kernel n2

    @property
    def flops(self) -> float:
        """Algorithmic complex-FLOPs (5 n log2 n), the roofline numerator."""
        return 5.0 * self.n * log2i(self.n)

    @property
    def gemm_macs(self) -> float:
        """Actual real MACs issued by the matmul formulation (per batch row)."""
        if self.levels == 1:
            return 4.0 * self.n * (self.n1 + self.n2)
        f1, f2 = split_pow2(self.n1), split_pow2(self.n2)
        return 4.0 * self.n * (f1[0] + f1[1] + f2[0] + f2[1])


# ---------------------------------------------------------------------------
# analytic HBM traffic counters (the roofline byte numerators; see DESIGN.md
# §3-4 and benchmarks/bench_fft.py). All counts are planar-f32 payload bytes
# per batch row, ignoring the O(table) twiddle/DFT-matrix operands.

_F32 = 4  # bytes


def fft_hbm_bytes(n: int, layout: str = "zero_copy",
                  max_leaf: int = MAX_LEAF) -> int:
    """HBM bytes moved per batch row by the complex transform.

    levels == 1: one kernel pass — read 2 planes, write 2 planes.
    levels == 2, zero_copy: two passes, each read+write (4 traversals).
    levels == 2, copy (legacy): the three materialized transposes
    (to_cols / to_rows / out_order) each add a full read+write on top.
    """
    p = make_plan(n, max_leaf)
    plane = _F32 * n
    per_pass = 2 * 2 * plane  # 2 planes in + 2 planes out
    if p.levels == 1:
        return per_pass
    if layout == "zero_copy":
        return 2 * per_pass
    return 2 * per_pass + 3 * per_pass  # + transpose round-trips


def rfft_hbm_bytes(n: int, max_leaf: int = MAX_LEAF) -> int:
    """HBM bytes moved per batch row by the real-input fast path.

    Leaf regime (n//2 a leaf length): the fused kernel reads the real
    buffer once and writes the one-sided planar spectrum — nothing else
    touches HBM. Level-1 regime: host pack + half-length zero-copy
    transform + vectorized untangle.
    """
    m = n // 2
    plane_n = _F32 * n
    out_sided = 2 * _F32 * (m + 1)
    if make_plan(m, max_leaf).levels == 1:
        return plane_n + out_sided  # read real input, write spectrum
    pack = plane_n + 2 * _F32 * m          # read x, write (zr, zi)
    untangle = 2 * 2 * _F32 * m + out_sided  # read Y, write spectrum
    return pack + fft_hbm_bytes(m, "zero_copy", max_leaf) + untangle


def fftn_hbm_bytes(shape, layout: str = "zero_copy",
                   max_leaf: int = MAX_LEAF) -> int:
    """HBM bytes moved per batch row (one image/volume) by the N-D c2c
    transform over the trailing ``len(shape)`` axes.

    zero_copy: the contiguous (last) axis runs the 1-D row-major path
    (level-0/1, see fft_hbm_bytes); every earlier axis is ONE column-strided
    pass — read 2 planes + write 2 planes of the whole image, with the
    transpose absorbed into the kernel's BlockSpec. No transposed tensor
    ever lands in HBM between passes.

    copy (the naive baseline bench_fft2.py gates against): each
    non-contiguous axis is brought to the minor position by a materialized
    swapaxes, row-FFT'd, and swapped back — two extra full round-trips of
    the image per axis on top of the pass itself.
    """
    shape = tuple(int(d) for d in shape)
    n_last = shape[-1]
    total_n = math.prod(shape)
    total = (total_n // n_last) * fft_hbm_bytes(n_last, layout, max_leaf)
    per_pass = 2 * 2 * _F32 * total_n  # 2 planes in + 2 planes out
    for _ in shape[:-1]:
        total += per_pass
        if layout != "zero_copy":
            total += 2 * per_pass  # swapaxes there and back, materialized
    return total


def rfftn_hbm_bytes(shape, max_leaf: int = MAX_LEAF) -> int:
    """HBM bytes per batch row for the N-D real-input fast path.

    The packed-real trick rides the contiguous axis: n_last reals enter as
    n_last/2 complex via a free reshape, the remaining axes transform the
    half-width spectrum (conjugate untangle commutes with the other axes'
    DFTs — both are linear maps over different axes), and ONE vectorized
    untangle epilogue widens m -> m+1 bins at the end.
    """
    shape = tuple(int(d) for d in shape)
    if len(shape) == 1:
        return rfft_hbm_bytes(shape[0], max_leaf)
    n_last = shape[-1]
    m = n_last // 2
    rows_last = math.prod(shape[:-1])
    half_n = rows_last * m  # complex points after packing
    # pass over the contiguous axis: the fused kernel (rfft_pack_leaf)
    # reads the real rows and writes the packed half-spectrum planes; when
    # the half transform is level-1 the pack happens on the host (one
    # round trip) before the full half-length zero-copy transform
    pass_a = rows_last * (_F32 * n_last + 2 * _F32 * m)
    if make_plan(m, max_leaf).levels != 1:
        pass_a += rows_last * fft_hbm_bytes(m, "zero_copy", max_leaf)
    per_pass = 2 * 2 * _F32 * half_n
    passes_rest = (len(shape) - 1) * per_pass
    untangle = 2 * 2 * _F32 * half_n + 2 * _F32 * rows_last * (m + 1)
    return pass_a + passes_rest + untangle


def make_plan(n: int, max_leaf: int = MAX_LEAF) -> FftPlan:
    if n <= max_leaf:
        n1, n2 = (1, n) if n <= 2 else split_pow2(n, max_leaf)
        return FftPlan(n=n, levels=1, n1=n1, n2=n2)
    n1, n2 = split_pow2(n, max_leaf)
    return FftPlan(n=n, levels=2, n1=n1, n2=n2)
