"""Radix-2 Stockham autosort Pallas kernel (VPU comparison variant).

This is the literal port of what CUFFT-style libraries run on CUDA cores:
log2(n) butterfly stages, no bit-reversal (Stockham's ping-pong reindexing
keeps outputs in natural order). On TPU these butterflies execute on the
VPU at ~4 TFLOP/s — the matmul formulation in matfft.py beats it by moving
the work onto the MXU, and keeping both lets the benchmark harness measure
that adaptation decision instead of asserting it (see EXPERIMENTS.md §Perf).

Per-stage twiddles arrive packed in a single (n,) planar pair (see
plan.stockham_twiddles); stage s slices its l = n >> (s+1) factors at a
static offset, so the whole stage loop unrolls with static shapes.

NOTE on layout: the (bt, 2, l, m) reshapes with small m are lane-hostile on
real Mosaic lowering; this kernel exists as the measured baseline, not the
production path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft import plan as fft_plan


def _stockham_kernel(xr_ref, xi_ref, twr_ref, twi_ref, outr_ref, outi_ref,
                     *, n: int):
    bt = xr_ref.shape[0]
    xr = xr_ref[...]
    xi = xi_ref[...]
    twr = twr_ref[...].reshape(-1)
    twi = twi_ref[...].reshape(-1)

    for off, l, m in fft_plan.stockham_stage_offsets(n):
        # x viewed as [b, h, j, k] with flat index h*l*m + j*m + k, h in {0,1}
        xr4 = xr.reshape(bt, 2, l, m)
        xi4 = xi.reshape(bt, 2, l, m)
        ar, ai = xr4[:, 0], xi4[:, 0]
        br, bi = xr4[:, 1], xi4[:, 1]
        wr = twr[off:off + l].reshape(1, l, 1)
        wi = twi[off:off + l].reshape(1, l, 1)
        # DIF butterfly: y0 = a + b ; y1 = (a - b) * w
        dr, di = ar - br, ai - bi
        tr = wr * dr - wi * di
        ti = wr * di + wi * dr
        # y[b, j, t, k] at flat index j*2m + t*m + k
        xr = jnp.stack([ar + br, tr], axis=2).reshape(bt, n)
        xi = jnp.stack([ai + bi, ti], axis=2).reshape(bt, n)

    outr_ref[...] = xr
    outi_ref[...] = xi


def stockham_fft(xr: jnp.ndarray, xi: jnp.ndarray, *,
                 batch_tile: int | None = None,
                 interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched forward DFT along the last axis via radix-2 Stockham stages."""
    if xr.ndim != 2:
        raise ValueError(f"stockham_fft expects 2-D (rows, n), got {xr.shape}")
    rows, n = xr.shape
    fft_plan.log2i(n)  # validates pow2
    if n > fft_plan.MAX_LEAF:
        raise ValueError(f"n={n} exceeds single-kernel capacity; use ops.fft")
    if n == 1:
        return xr, xi

    bt = batch_tile or max(8, min(256, (1 << 17) // n))
    pad = (-rows) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // bt,)

    twr, twi = (jnp.asarray(a) for a in fft_plan.stockham_twiddles(n))
    row_spec = pl.BlockSpec((bt, n), lambda i: (i, 0))
    tw_spec = pl.BlockSpec((n,), lambda i: (0,))

    yr, yi = pl.pallas_call(
        lambda *refs: _stockham_kernel(*refs, n=n),
        grid=grid,
        in_specs=[row_spec, row_spec, tw_spec, tw_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct(xr.shape, jnp.float32)] * 2,
        interpret=interpret,
        name=f"stockham_{n}",
    )(xr, xi, twr, twi)

    if pad:
        yr, yi = yr[:rows], yi[:rows]
    return yr, yi
