"""MXU matmul-DFT Pallas kernels (the primary FFT kernels).

Hardware adaptation (see DESIGN.md §2): CUFFT runs Cooley-Tukey butterflies
on scalar CUDA cores; a TPU's throughput lives in the MXU systolic array,
which only speaks GEMM. So the per-tile DFT is expressed as the Bailey
four-step *inside VMEM*:

    (bt, n) tile --reshape--> (bt, n1, n2)
      GEMM with W_{n1}  ->  inner twiddle  ->  GEMM with W_{n2}  ->  reorder

i.e. 8 real (planar complex) 2-D GEMMs per tile, all operands resident in
VMEM. For n <= DIRECT_N the full (n, n) DFT matrix is used instead (one
complex GEMM, perfectly MXU-aligned at n = 128/256).

Three kernel entry points share that tile math (DESIGN.md §3):

  * ``matfft``       row-major batch: (rows, n) in, (rows, n) out.
  * ``matfft_cols``  column-strided batch: transforms the MIDDLE axis of a
    (B, L, C) view. The BlockSpec index map fetches (1, L, ct) tiles, the
    transpose happens in VMEM, and the output is written either row-major
    or back in column order. Chaining two of these is the ZERO-COPY host
    four-step: no transposed tensor is ever materialized in HBM — the TPU
    analogue of the paper's "one allocate+memcpy pair per block" rule.
  * ``rfft_leaf``    real-input fast path: n real samples enter as the
    free (rows, n/2, 2) reshape (even samples = re, odd = im), one
    half-length DFT runs on the MXU, and the kernel epilogue untangles the
    conjugate-symmetric half spectrum — half the flops AND half the HBM
    bytes of the complex transform it replaces.

The optional *epilogue* input fuses the four-step's outer twiddle multiply
into the kernel's final store, which removes one full HBM round-trip when
a kernel is used as the leaf of a host-level (or distributed-level)
four-step. The epilogue operand is a (rows_period, n) table indexed
*periodically* by the grid, so it costs O(table) HBM traffic, not
O(batch * n).

Issued MAC count per batch row: 4*n*(n1+n2) real MACs vs the algorithmic
5*n*log2(n) flops — the GEMM formulation trades ~2-5x more MACs for MXU
residency (197 TFLOP/s vs ~4 TFLOP/s VPU on v5e), a >10x net win. This
trade is recorded in the roofline's MODEL_FLOPS/HLO_FLOPs ratio.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft import plan as fft_plan

# Transform lengths up to this use one full DFT-matrix GEMM.
DIRECT_N = 256
# Target elements per (bt, n) tile: keeps planar f32 in/out + intermediates
# + tables well under half of v5e's ~16MB/core VMEM (double buffering).
_TILE_ELEMS = 1 << 18


def default_batch_tile(n: int) -> int:
    return max(8, min(512, _TILE_ELEMS // max(n, 1)))


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _cgemm(ar, ai, br, bi):
    """Planar complex GEMM with f32 accumulation (4 real MXU GEMMs)."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


# ---------------------------------------------------------------------------
# shared in-VMEM tile DFT (used by every kernel entry point)


def _tile_dft_direct(xr, xi, wr, wi):
    """Direct DFT of a (bt, n) VMEM tile: one complex GEMM."""
    return _cgemm(xr, xi, wr, wi)


def _tile_dft_4step(xr, xi, w1r, w1i, tr, ti, w2r, w2i, *, n1: int, n2: int):
    """In-VMEM four-step DFT of a (bt, n1*n2) VMEM tile."""
    bt = xr.shape[0]
    n = n1 * n2

    # x[b, i1, i2] -> (bt*n2, n1) rows=(b,i2): contract i1 on the MXU.
    def col_major(x):
        return x.reshape(bt, n1, n2).swapaxes(1, 2).reshape(bt * n2, n1)

    ar, ai = _cgemm(col_major(xr), col_major(xi), w1r, w1i)  # cols = o1

    # Inner twiddle T^T[i2, o1], broadcast over b.
    ar = ar.reshape(bt, n2, n1)
    ai = ai.reshape(bt, n2, n1)
    br_, bi_ = _cmul(ar, ai, tr.reshape(1, n2, n1), ti.reshape(1, n2, n1))

    # (bt*n1, n2) rows=(b,o1): contract i2 on the MXU.
    br_ = br_.swapaxes(1, 2).reshape(bt * n1, n2)
    bi_ = bi_.swapaxes(1, 2).reshape(bt * n1, n2)
    cr, ci = _cgemm(br_, bi_, w2r, w2i)  # cols = o2

    # X[b, o2*n1 + o1] = C[b, o1, o2] -> swap to (b, o2, o1) and flatten.
    yr = cr.reshape(bt, n1, n2).swapaxes(1, 2).reshape(bt, n)
    yi = ci.reshape(bt, n1, n2).swapaxes(1, 2).reshape(bt, n)
    return yr, yi


def _global_twiddle(row_base, bt, n, n_global):
    """On-the-fly W_{n_global}^{(global_row) * col} for one (bt, n) tile,
    global_row = row_base + r.

    Exponent reduced exactly via uint32 wraparound (n_global is pow2, see
    core/fft/distributed.py) — zero HBM traffic: the table is never
    materialized; the VPU computes iota*iota, mask, cos/sin in registers.
    This is the distributed four-step's twiddle fused into the leaf kernel
    epilogue (the cross-device analogue of the level-1 table epilogue).
    """
    row = row_base.astype(jnp.uint32) + jax.lax.broadcasted_iota(
        jnp.uint32, (bt, n), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (bt, n), 1)
    m = (row * col) & jnp.uint32(n_global - 1)
    ang = (-2.0 * 3.14159265358979323846 / n_global) * m.astype(jnp.float32)
    return jnp.cos(ang), jnp.sin(ang)


# ---------------------------------------------------------------------------
# row-major batch kernel (level 0 leaf)


def _dft_kernel(xr_ref, xi_ref, wr_ref, wi_ref, er_ref, ei_ref,
                outr_ref, outi_ref, *, fuse_epilogue: bool,
                global_n: int = 0):
    """Direct DFT: one complex GEMM with the full (n, n) DFT matrix."""
    yr, yi = _tile_dft_direct(xr_ref[...], xi_ref[...], wr_ref[...],
                              wi_ref[...])
    if global_n:
        bt, n = yr.shape
        row_base = er_ref[0] + pl.program_id(0) * bt
        tr, ti = _global_twiddle(row_base, bt, n, global_n)
        yr, yi = _cmul(yr, yi, tr, ti)
    elif fuse_epilogue:
        yr, yi = _cmul(yr, yi, er_ref[...], ei_ref[...])
    outr_ref[...] = yr
    outi_ref[...] = yi


def _matfft_kernel(xr_ref, xi_ref, w1r_ref, w1i_ref, tr_ref, ti_ref,
                   w2r_ref, w2i_ref, er_ref, ei_ref, outr_ref, outi_ref,
                   *, n1: int, n2: int, fuse_epilogue: bool,
                   global_n: int = 0):
    """In-VMEM four-step DFT of the (bt, n1*n2) tile."""
    yr, yi = _tile_dft_4step(xr_ref[...], xi_ref[...],
                             w1r_ref[...], w1i_ref[...],
                             tr_ref[...], ti_ref[...],
                             w2r_ref[...], w2i_ref[...], n1=n1, n2=n2)
    if global_n:
        bt, n = yr.shape
        row_base = er_ref[0] + pl.program_id(0) * bt
        tr_, ti_ = _global_twiddle(row_base, bt, n, global_n)
        yr, yi = _cmul(yr, yi, tr_, ti_)
    elif fuse_epilogue:
        yr, yi = _cmul(yr, yi, er_ref[...], ei_ref[...])
    outr_ref[...] = yr
    outi_ref[...] = yi


def matfft(xr: jnp.ndarray, xi: jnp.ndarray, *,
           epilogue: tuple[jnp.ndarray, jnp.ndarray] | None = None,
           global_twiddle: tuple[int, jnp.ndarray] | None = None,
           batch_tile: int | None = None,
           interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched forward DFT along the last axis of planar (rows, n) arrays.

    Args:
      xr, xi: float32 (rows, n) planes; n a power of two <= plan.MAX_LEAF.
      epilogue: optional planar (period, n) twiddle table; row r of the
        output is multiplied by ``epilogue[r % period]``. ``period`` must be
        a multiple of the batch tile (both are powers of two — the tile is
        clamped to the period, so any pow2 period works).
      batch_tile: rows per kernel instance (defaults to a VMEM-sized tile).
      interpret: run in interpret mode (CPU container); False on real TPU.
    """
    if xr.ndim != 2:
        raise ValueError(f"matfft expects 2-D (rows, n), got {xr.shape}")
    rows, n = xr.shape
    p = fft_plan.make_plan(n)
    if p.levels != 1:
        raise ValueError(f"n={n} exceeds single-kernel capacity; use ops.fft")

    bt = batch_tile or default_batch_tile(n)
    g_n = 0
    if global_twiddle is not None:
        assert epilogue is None
        g_n, row_off = global_twiddle
    fuse = epilogue is not None
    if fuse:
        period = epilogue[0].shape[0]
        if period & (period - 1):
            raise ValueError("epilogue period must be a power of two")
        bt = min(bt, period)

    pad = (-rows) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // bt,)

    row_spec = pl.BlockSpec((bt, n), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct(xr.shape, jnp.float32)] * 2

    if fuse:
        er, ei = epilogue
        blocks_per_period = er.shape[0] // bt
        epi_spec = pl.BlockSpec((bt, n), lambda i: (i % blocks_per_period, 0))
    elif g_n:
        # the epilogue slot carries only the (1,) global row offset scalar
        er = row_off.reshape(1).astype(jnp.int32)
        ei = jnp.zeros((1,), jnp.int32)
        epi_spec = pl.BlockSpec((1,), lambda i: (0,))
    else:
        # Dummy 1-row operand; never read.
        er = ei = jnp.zeros((bt, n), jnp.float32)
        epi_spec = pl.BlockSpec((bt, n), lambda i: (0, 0))

    def table_spec(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    if n <= DIRECT_N:
        wr, wi = (jnp.asarray(a) for a in fft_plan.dft_matrix(n))
        kernel = functools.partial(_dft_kernel, fuse_epilogue=fuse,
                                   global_n=g_n)
        yr, yi = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[row_spec, row_spec,
                      table_spec((n, n)), table_spec((n, n)),
                      epi_spec, epi_spec],
            out_specs=[row_spec, row_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"dft_direct_{n}",
        )(xr, xi, wr, wi, er, ei)
    else:
        n1, n2 = p.n1, p.n2
        w1r, w1i = (jnp.asarray(a) for a in fft_plan.dft_matrix(n1))
        w2r, w2i = (jnp.asarray(a) for a in fft_plan.dft_matrix(n2))
        tr, ti = (jnp.asarray(a.T.copy()) for a in fft_plan.twiddle_table(n1, n2, n))
        kernel = functools.partial(_matfft_kernel, n1=n1, n2=n2,
                                   fuse_epilogue=fuse, global_n=g_n)
        yr, yi = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[row_spec, row_spec,
                      table_spec((n1, n1)), table_spec((n1, n1)),
                      table_spec((n2, n1)), table_spec((n2, n1)),
                      table_spec((n2, n2)), table_spec((n2, n2)),
                      epi_spec, epi_spec],
            out_specs=[row_spec, row_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"matfft_{n1}x{n2}",
        )(xr, xi, w1r, w1i, tr, ti, w2r, w2i, er, ei)

    if pad:
        yr, yi = yr[:rows], yi[:rows]
    return yr, yi


# ---------------------------------------------------------------------------
# column-strided batch kernel (zero-copy four-step passes)


def _col_kernel(*refs, direct: bool, n1: int, n2: int, cols: int,
                col_tile: int, out_major: str, fuse_epilogue: bool,
                global_n: int):
    """DFT of ct columns of one (L, C) matrix: load (1, L, ct), transpose in
    VMEM, transform, and store row-major or column-major."""
    if direct:
        (xr_ref, xi_ref, wr_ref, wi_ref,
         er_ref, ei_ref, outr_ref, outi_ref) = refs
    else:
        (xr_ref, xi_ref, w1r_ref, w1i_ref, tr_ref, ti_ref, w2r_ref, w2i_ref,
         er_ref, ei_ref, outr_ref, outi_ref) = refs

    xr = xr_ref[...][0].T  # (1, L, ct) -> (ct, L): VMEM transpose, not HBM
    xi = xi_ref[...][0].T
    # A 1-row tile would contract on XLA's M=1 GEMV path, whose accumulation
    # order differs from the GEMM path every wider tile takes. Pad to M=2 in
    # VMEM (per-row GEMM results are independent of other rows' values), so
    # single-column slab calls stay bitwise equal to the monolithic kernel —
    # the overlapped distributed pipeline's chunks=n2l edge relies on this.
    squeeze = xr.shape[0] == 1
    if squeeze:
        xr = jnp.concatenate([xr, jnp.zeros_like(xr)], axis=0)
        xi = jnp.concatenate([xi, jnp.zeros_like(xi)], axis=0)
    if direct:
        yr, yi = _tile_dft_direct(xr, xi, wr_ref[...], wi_ref[...])
    else:
        yr, yi = _tile_dft_4step(xr, xi, w1r_ref[...], w1i_ref[...],
                                 tr_ref[...], ti_ref[...],
                                 w2r_ref[...], w2i_ref[...], n1=n1, n2=n2)
    if squeeze:
        yr, yi = yr[:1], yi[:1]

    if global_n:
        # logical row of this tile's first output = b*C + j*ct
        row_base = (er_ref[0] + pl.program_id(0) * cols
                    + pl.program_id(1) * col_tile)
        tw_r, tw_i = _global_twiddle(row_base, yr.shape[0], yr.shape[1],
                                     global_n)
        yr, yi = _cmul(yr, yi, tw_r, tw_i)
    elif fuse_epilogue:
        yr, yi = _cmul(yr, yi, er_ref[...], ei_ref[...])

    if out_major == "row":
        outr_ref[...] = yr
        outi_ref[...] = yi
    else:
        outr_ref[...] = yr.T[None]
        outi_ref[...] = yi.T[None]


def matfft_cols(xr: jnp.ndarray, xi: jnp.ndarray, *, out_major: str = "row",
                epilogue: tuple[jnp.ndarray, jnp.ndarray] | None = None,
                global_twiddle: tuple[int, jnp.ndarray] | None = None,
                col_tile: int | None = None, col_offset: int = 0,
                ncols: int | None = None,
                interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched forward DFT along the MIDDLE axis of planar (B, L, C) arrays.

    Logical batch row r = b*C + c transforms the column x[b, :, c]. The
    column-strided fetch and the transpose both happen at the BlockSpec /
    VMEM level, so no transposed copy of the operand ever exists in HBM.

    Args:
      xr, xi: float32 (B, L, C) planes; L a pow2 <= plan.MAX_LEAF, C pow2.
      out_major: "row" returns (B*ncols, L) row-major (row index b*ncols + c);
        "col" returns (B, L, ncols) with out[b, o, c] — i.e. the result is
        written back in column order, which is exactly the o2-major store
        the four-step's final reorder needs.
      epilogue: optional planar (C, L) table; output row (b, c) is
        multiplied by ``epilogue[col_offset + c]`` (period == C).
      global_twiddle: (n_global, row_off) — on-the-fly distributed twiddle
        for logical row ``row_off + b*ncols + c`` (see _global_twiddle).
      col_tile: columns per kernel instance (defaults to a VMEM-sized tile).
      col_offset, ncols: transform only the column slab
        ``[col_offset, col_offset + ncols)``, fetched from the full operand
        by the BlockSpec index map — a per-slab call reads the big buffer
        in place instead of forcing XLA to materialize (retile) a slice.
        The overlapped distributed pipeline's pass-2 slabs use this. Both
        must be pow2-aligned (ncols pow2, col_offset a multiple of it).
    """
    if xr.ndim != 3:
        raise ValueError(f"matfft_cols expects 3-D (B, L, C), got {xr.shape}")
    B, L, C = xr.shape
    p = fft_plan.make_plan(L)
    if p.levels != 1:
        raise ValueError(f"L={L} exceeds single-kernel capacity")
    if not fft_plan.is_pow2(C):
        raise ValueError(f"column count must be a power of two, got {C}")
    if out_major not in ("row", "col"):
        raise ValueError(f"unknown out_major {out_major!r}")
    nc = C - col_offset if ncols is None else ncols
    if not fft_plan.is_pow2(nc):
        raise ValueError(f"ncols must be a power of two, got {nc}")
    if col_offset % nc or col_offset + nc > C:
        raise ValueError(
            f"column slab [{col_offset}, {col_offset + nc}) must be an "
            f"aligned pow2 slab of the {C} columns")

    ct = min(col_tile or default_batch_tile(L), nc)
    # round down to a power of two so ct always divides nc (validated pow2):
    # a ragged tile would leave trailing output blocks unwritten
    ct = 1 << (ct.bit_length() - 1)
    grid = (B, nc // ct)
    off_blocks = col_offset // ct  # exact: ct | nc | col_offset

    in_spec = pl.BlockSpec((1, L, ct), lambda b, j: (b, 0, j + off_blocks))

    g_n = 0
    if global_twiddle is not None:
        assert epilogue is None
        g_n, row_off = global_twiddle
    fuse = epilogue is not None
    if fuse:
        er, ei = epilogue
        if er.shape != (C, L):
            raise ValueError(f"epilogue must be (C, L)=({C}, {L}), "
                             f"got {er.shape}")
        epi_spec = pl.BlockSpec((ct, L), lambda b, j: (j + off_blocks, 0))
    elif g_n:
        er = row_off.reshape(1).astype(jnp.int32)
        ei = jnp.zeros((1,), jnp.int32)
        epi_spec = pl.BlockSpec((1,), lambda b, j: (0,))
    else:
        er = ei = jnp.zeros((ct, L), jnp.float32)
        epi_spec = pl.BlockSpec((ct, L), lambda b, j: (0, 0))

    if out_major == "row":
        out_shape = [jax.ShapeDtypeStruct((B * nc, L), jnp.float32)] * 2
        blocks_per_b = nc // ct
        out_spec = pl.BlockSpec((ct, L),
                                lambda b, j: (b * blocks_per_b + j, 0))
    else:
        out_shape = [jax.ShapeDtypeStruct((B, L, nc), jnp.float32)] * 2
        out_spec = pl.BlockSpec((1, L, ct), lambda b, j: (b, 0, j))

    def table_spec(shape):
        return pl.BlockSpec(shape, lambda b, j: tuple(0 for _ in shape))

    common = dict(cols=nc, col_tile=ct, out_major=out_major,
                  fuse_epilogue=fuse, global_n=g_n)
    if L <= DIRECT_N:
        wr, wi = (jnp.asarray(a) for a in fft_plan.dft_matrix(L))
        kernel = functools.partial(_col_kernel, direct=True, n1=0, n2=0,
                                   **common)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[in_spec, in_spec,
                      table_spec((L, L)), table_spec((L, L)),
                      epi_spec, epi_spec],
            out_specs=[out_spec, out_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"dft_cols_{L}",
        )(xr, xi, wr, wi, er, ei)

    l1, l2 = p.n1, p.n2
    w1r, w1i = (jnp.asarray(a) for a in fft_plan.dft_matrix(l1))
    w2r, w2i = (jnp.asarray(a) for a in fft_plan.dft_matrix(l2))
    tr, ti = (jnp.asarray(a.T.copy())
              for a in fft_plan.twiddle_table(l1, l2, L))
    kernel = functools.partial(_col_kernel, direct=False, n1=l1, n2=l2,
                               **common)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec, in_spec,
                  table_spec((l1, l1)), table_spec((l1, l1)),
                  table_spec((l2, l1)), table_spec((l2, l1)),
                  table_spec((l2, l2)), table_spec((l2, l2)),
                  epi_spec, epi_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
        name=f"matfft_cols_{l1}x{l2}",
    )(xr, xi, w1r, w1i, tr, ti, w2r, w2i, er, ei)


# NOTE: the level-1 four-step that chained two matfft_cols calls
# (`four_step_zero_copy`) moved to repro/fft/executors.py, re-expressed on
# the shared `axis_pass` builder — the same primitive that powers the true
# N-D fftn/rfftn passes and the distributed pass boundaries.


# ---------------------------------------------------------------------------
# real-input fast path (rfft leaf)


def untangle_half_spectrum(yr, yi, vr, vi):
    """One-sided real-input spectrum from the half-length packed transform.

    Given Y = DFT_m(x[..., 0::2] + 1j*x[..., 1::2]) along the last axis,
    the even/odd sub-spectra are recovered from the conjugate-symmetric
    partner Y[(m-k) % m] and combined with the packing twiddle
    v[k] = W_{2m}^k:

        E[k] = (Y[k] + conj(Y[m-k]))/2      O[k] = (Y[k] - conj(Y[m-k]))/2i
        X[k] = E[k] + v[k]*O[k]   k < m;    X[m] = E[0] - O[0]  (Nyquist)

    Pure jnp on (..., m) planes -> (..., m+1): runs fused inside
    _rfft_kernel's epilogue at leaf sizes and as the host epilogue of the
    level-1 rfft path (ops.rfft) — one implementation for both.
    """
    # conj partner p[k] = Y[(m-k) % m]: reverse then rotate right by one.
    pr = jnp.roll(yr[..., ::-1], 1, axis=-1)
    pi = jnp.roll(yi[..., ::-1], 1, axis=-1)
    er, ei = 0.5 * (yr + pr), 0.5 * (yi - pi)
    our, oui = 0.5 * (yi + pi), 0.5 * (pr - yr)
    xr = er + vr * our - vi * oui
    xi = ei + vr * oui + vi * our
    nyq = er[..., :1] - our[..., :1]
    return (jnp.concatenate([xr, nyq], axis=-1),
            jnp.concatenate([xi, jnp.zeros_like(nyq)], axis=-1))


def _rfft_kernel(*refs, direct: bool, n1: int, n2: int,
                 untangle: bool = True):
    """Half-length DFT of packed real input + conjugate-symmetry untangle.

    The input tile is the natural (bt, n) real block — lane-aligned in HBM;
    the even/odd split into z[b, k] = x[b, 2k] + i*x[b, 2k+1] happens on
    the tile in VMEM. With ``untangle=True`` the one-sided (bt, m+1)
    spectrum (untangle_half_spectrum fused in the epilogue) is the only
    thing that ever leaves VMEM; ``untangle=False`` stores the raw packed
    (bt, m) half spectrum instead — the N-D rfftn path defers the untangle
    until after the remaining axes' passes (it commutes with them) so every
    intermediate stays pow2-wide.
    """
    if direct:
        (x_ref, wr_ref, wi_ref, vr_ref, vi_ref, outr_ref, outi_ref) = refs
    else:
        (x_ref, w1r_ref, w1i_ref, tr_ref, ti_ref, w2r_ref, w2i_ref,
         vr_ref, vi_ref, outr_ref, outi_ref) = refs

    x = x_ref[...]  # (bt, n) natural layout: pack in VMEM, never in HBM
    z = x.reshape(x.shape[0], x.shape[1] // 2, 2)
    zr, zi = z[:, :, 0], z[:, :, 1]
    if direct:
        yr, yi = _tile_dft_direct(zr, zi, wr_ref[...], wi_ref[...])
    else:
        yr, yi = _tile_dft_4step(zr, zi, w1r_ref[...], w1i_ref[...],
                                 tr_ref[...], ti_ref[...],
                                 w2r_ref[...], w2i_ref[...], n1=n1, n2=n2)

    if untangle:
        yr, yi = untangle_half_spectrum(yr, yi, vr_ref[...], vi_ref[...])
    outr_ref[...] = yr
    outi_ref[...] = yi


def _rfft_pallas(x: jnp.ndarray, batch_tile: int | None, interpret: bool,
                 untangle: bool, what: str
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Shared plumbing behind rfft_leaf / rfft_pack_leaf (see those)."""
    if x.ndim != 2:
        raise ValueError(f"{what} expects 2-D (rows, n), got {x.shape}")
    rows, n = x.shape
    fft_plan.log2i(n)
    if n < 4:
        raise ValueError(f"{what} needs n >= 4, got {n}")
    m = n // 2
    p = fft_plan.make_plan(m)
    if p.levels != 1:
        raise ValueError(f"n={n} exceeds {what} capacity; use ops.rfft")

    bt = batch_tile or default_batch_tile(m)
    pad = (-rows) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // bt,)
    width = m + 1 if untangle else m

    in_spec = pl.BlockSpec((bt, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((bt, width), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((x.shape[0], width), jnp.float32)] * 2
    vr, vi = (jnp.asarray(a) for a in fft_plan.rfft_twiddle(n))

    def table_spec(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    if m <= DIRECT_N:
        wr, wi = (jnp.asarray(a) for a in fft_plan.dft_matrix(m))
        kernel = functools.partial(_rfft_kernel, direct=True, n1=0, n2=0,
                                   untangle=untangle)
        yr, yi = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[in_spec,
                      table_spec((m, m)), table_spec((m, m)),
                      table_spec((1, m)), table_spec((1, m))],
            out_specs=[out_spec, out_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"{what}_direct_{n}",
        )(x, wr, wi, vr, vi)
    else:
        m1, m2 = p.n1, p.n2
        w1r, w1i = (jnp.asarray(a) for a in fft_plan.dft_matrix(m1))
        w2r, w2i = (jnp.asarray(a) for a in fft_plan.dft_matrix(m2))
        tr, ti = (jnp.asarray(a.T.copy())
                  for a in fft_plan.twiddle_table(m1, m2, m))
        kernel = functools.partial(_rfft_kernel, direct=False, n1=m1, n2=m2,
                                   untangle=untangle)
        yr, yi = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[in_spec,
                      table_spec((m1, m1)), table_spec((m1, m1)),
                      table_spec((m2, m1)), table_spec((m2, m1)),
                      table_spec((m2, m2)), table_spec((m2, m2)),
                      table_spec((1, m)), table_spec((1, m))],
            out_specs=[out_spec, out_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"{what}_{m1}x{m2}",
        )(x, w1r, w1i, tr, ti, w2r, w2i, vr, vi)

    if pad:
        yr, yi = yr[:rows], yi[:rows]
    return yr, yi


def rfft_leaf(x: jnp.ndarray, *, batch_tile: int | None = None,
              interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One-sided spectrum of real (rows, n) input, n pow2 with n//2 a leaf
    length. Returns planar (rows, n//2 + 1) arrays.

    Costs one HALF-length DFT: the packing is a free reshape (the kernel
    reads the real buffer directly), and the untangle runs in the kernel
    epilogue — ~50% of the flops and HBM bytes of the complex path.
    """
    return _rfft_pallas(x, batch_tile, interpret, True, "rfft")


def rfft_pack_leaf(x: jnp.ndarray, *, batch_tile: int | None = None,
                   interpret: bool = True
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Raw packed half spectrum of real (rows, n) input: DFT_m of
    x[:, 0::2] + i*x[:, 1::2], (rows, n//2) planar, NO untangle.

    The N-D rfftn contiguous-axis pass: the kernel still reads the natural
    real rows (no even/odd planes in HBM) but keeps the half spectrum
    pow2-wide so the remaining axes' column passes stay zero-copy; the
    untangle runs once, vectorized, after them (executors.rfftn).
    """
    return _rfft_pallas(x, batch_tile, interpret, False, "rfft_pack")
