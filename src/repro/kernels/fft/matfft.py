"""MXU matmul-DFT Pallas kernel (the primary FFT kernel).

Hardware adaptation (see DESIGN.md §2): CUFFT runs Cooley-Tukey butterflies
on scalar CUDA cores; a TPU's throughput lives in the MXU systolic array,
which only speaks GEMM. So the per-tile DFT is expressed as the Bailey
four-step *inside VMEM*:

    (bt, n) tile --reshape--> (bt, n1, n2)
      GEMM with W_{n1}  ->  inner twiddle  ->  GEMM with W_{n2}  ->  reorder

i.e. 8 real (planar complex) 2-D GEMMs per tile, all operands resident in
VMEM. For n <= DIRECT_N the full (n, n) DFT matrix is used instead (one
complex GEMM, perfectly MXU-aligned at n = 128/256).

The optional *epilogue* input fuses the four-step's outer twiddle multiply
into the kernel's final store, which is what removes one full HBM round-trip
when this kernel is used as the leaf of a host-level (or distributed-level)
four-step — the TPU analogue of the paper's "one allocate+memcpy pair per
block" PCIe-minimization rule. The epilogue operand is a (rows_period, n)
table indexed *periodically* by the grid, so it costs O(table) HBM traffic,
not O(batch * n).

Issued MAC count per batch row: 4*n*(n1+n2) real MACs vs the algorithmic
5*n*log2(n) flops — the GEMM formulation trades ~2-5x more MACs for MXU
residency (197 TFLOP/s vs ~4 TFLOP/s VPU on v5e), a >10x net win. This
trade is recorded in the roofline's MODEL_FLOPS/HLO_FLOPs ratio.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fft import plan as fft_plan

# Transform lengths up to this use one full DFT-matrix GEMM.
DIRECT_N = 256
# Target elements per (bt, n) tile: keeps planar f32 in/out + intermediates
# + tables well under half of v5e's ~16MB/core VMEM (double buffering).
_TILE_ELEMS = 1 << 18


def default_batch_tile(n: int) -> int:
    return max(8, min(512, _TILE_ELEMS // max(n, 1)))


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _cgemm(ar, ai, br, bi):
    """Planar complex GEMM with f32 accumulation (4 real MXU GEMMs)."""
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def _global_twiddle(off_ref, bt, n, n_global):
    """On-the-fly W_{n_global}^{(global_row) * col} for one (bt, n) tile,
    global_row = off_ref[0] + program_id(0)*bt + r.

    Exponent reduced exactly via uint32 wraparound (n_global is pow2, see
    core/fft/distributed.py) — zero HBM traffic: the table is never
    materialized; the VPU computes iota*iota, mask, cos/sin in registers.
    This is the distributed four-step's twiddle fused into the leaf kernel
    epilogue (the cross-device analogue of the level-1 table epilogue).
    """
    base = off_ref[0].astype(jnp.uint32) + jnp.uint32(pl.program_id(0) * bt)
    row = base + jax.lax.broadcasted_iota(jnp.uint32, (bt, n), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (bt, n), 1)
    m = (row * col) & jnp.uint32(n_global - 1)
    ang = (-2.0 * 3.14159265358979323846 / n_global) * m.astype(jnp.float32)
    return jnp.cos(ang), jnp.sin(ang)


def _dft_kernel(xr_ref, xi_ref, wr_ref, wi_ref, er_ref, ei_ref,
                outr_ref, outi_ref, *, fuse_epilogue: bool,
                global_n: int = 0):
    """Direct DFT: one complex GEMM with the full (n, n) DFT matrix."""
    yr, yi = _cgemm(xr_ref[...], xi_ref[...], wr_ref[...], wi_ref[...])
    if global_n:
        bt, n = yr.shape
        tr, ti = _global_twiddle(er_ref, bt, n, global_n)
        yr, yi = _cmul(yr, yi, tr, ti)
    elif fuse_epilogue:
        yr, yi = _cmul(yr, yi, er_ref[...], ei_ref[...])
    outr_ref[...] = yr
    outi_ref[...] = yi


def _matfft_kernel(xr_ref, xi_ref, w1r_ref, w1i_ref, tr_ref, ti_ref,
                   w2r_ref, w2i_ref, er_ref, ei_ref, outr_ref, outi_ref,
                   *, n1: int, n2: int, fuse_epilogue: bool,
                   global_n: int = 0):
    """In-VMEM four-step DFT of the (bt, n1*n2) tile."""
    bt = xr_ref.shape[0]
    n = n1 * n2

    # x[b, i1, i2] -> (bt*n2, n1) rows=(b,i2): contract i1 on the MXU.
    def col_major(ref):
        return ref[...].reshape(bt, n1, n2).swapaxes(1, 2).reshape(bt * n2, n1)

    ar, ai = _cgemm(col_major(xr_ref), col_major(xi_ref),
                    w1r_ref[...], w1i_ref[...])  # (bt*n2, n1), cols = o1

    # Inner twiddle T^T[i2, o1], broadcast over b.
    tr = tr_ref[...].reshape(1, n2, n1)
    ti = ti_ref[...].reshape(1, n2, n1)
    ar = ar.reshape(bt, n2, n1)
    ai = ai.reshape(bt, n2, n1)
    br_, bi_ = _cmul(ar, ai, tr, ti)

    # (bt*n1, n2) rows=(b,o1): contract i2 on the MXU.
    br_ = br_.swapaxes(1, 2).reshape(bt * n1, n2)
    bi_ = bi_.swapaxes(1, 2).reshape(bt * n1, n2)
    cr, ci = _cgemm(br_, bi_, w2r_ref[...], w2i_ref[...])  # cols = o2

    # X[b, o2*n1 + o1] = C[b, o1, o2] -> swap to (b, o2, o1) and flatten.
    yr = cr.reshape(bt, n1, n2).swapaxes(1, 2).reshape(bt, n)
    yi = ci.reshape(bt, n1, n2).swapaxes(1, 2).reshape(bt, n)
    if global_n:
        tr_, ti_ = _global_twiddle(er_ref, bt, n, global_n)
        yr, yi = _cmul(yr, yi, tr_, ti_)
    elif fuse_epilogue:
        yr, yi = _cmul(yr, yi, er_ref[...], ei_ref[...])
    outr_ref[...] = yr
    outi_ref[...] = yi


def matfft(xr: jnp.ndarray, xi: jnp.ndarray, *,
           epilogue: tuple[jnp.ndarray, jnp.ndarray] | None = None,
           global_twiddle: tuple[int, jnp.ndarray] | None = None,
           batch_tile: int | None = None,
           interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched forward DFT along the last axis of planar (rows, n) arrays.

    Args:
      xr, xi: float32 (rows, n) planes; n a power of two <= plan.MAX_LEAF.
      epilogue: optional planar (period, n) twiddle table; row r of the
        output is multiplied by ``epilogue[r % period]``. ``period`` must be
        a multiple of the batch tile (both are powers of two — the tile is
        clamped to the period, so any pow2 period works).
      batch_tile: rows per kernel instance (defaults to a VMEM-sized tile).
      interpret: run in interpret mode (CPU container); False on real TPU.
    """
    if xr.ndim != 2:
        raise ValueError(f"matfft expects 2-D (rows, n), got {xr.shape}")
    rows, n = xr.shape
    p = fft_plan.make_plan(n)
    if p.levels != 1:
        raise ValueError(f"n={n} exceeds single-kernel capacity; use ops.fft")

    bt = batch_tile or default_batch_tile(n)
    g_n = 0
    if global_twiddle is not None:
        assert epilogue is None
        g_n, row_off = global_twiddle
    fuse = epilogue is not None
    if fuse:
        period = epilogue[0].shape[0]
        if period & (period - 1):
            raise ValueError("epilogue period must be a power of two")
        bt = min(bt, period)

    pad = (-rows) % bt
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // bt,)

    row_spec = pl.BlockSpec((bt, n), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct(xr.shape, jnp.float32)] * 2

    if fuse:
        er, ei = epilogue
        blocks_per_period = er.shape[0] // bt
        epi_spec = pl.BlockSpec((bt, n), lambda i: (i % blocks_per_period, 0))
    elif g_n:
        # the epilogue slot carries only the (1,) global row offset scalar
        er = row_off.reshape(1).astype(jnp.int32)
        ei = jnp.zeros((1,), jnp.int32)
        epi_spec = pl.BlockSpec((1,), lambda i: (0,))
    else:
        # Dummy 1-row operand; never read.
        er = ei = jnp.zeros((bt, n), jnp.float32)
        epi_spec = pl.BlockSpec((bt, n), lambda i: (0, 0))

    def table_spec(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    if n <= DIRECT_N:
        wr, wi = (jnp.asarray(a) for a in fft_plan.dft_matrix(n))
        kernel = functools.partial(_dft_kernel, fuse_epilogue=fuse,
                                   global_n=g_n)
        yr, yi = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[row_spec, row_spec,
                      table_spec((n, n)), table_spec((n, n)),
                      epi_spec, epi_spec],
            out_specs=[row_spec, row_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"dft_direct_{n}",
        )(xr, xi, wr, wi, er, ei)
    else:
        n1, n2 = p.n1, p.n2
        w1r, w1i = (jnp.asarray(a) for a in fft_plan.dft_matrix(n1))
        w2r, w2i = (jnp.asarray(a) for a in fft_plan.dft_matrix(n2))
        tr, ti = (jnp.asarray(a.T.copy()) for a in fft_plan.twiddle_table(n1, n2, n))
        kernel = functools.partial(_matfft_kernel, n1=n1, n2=n2,
                                   fuse_epilogue=fuse, global_n=g_n)
        yr, yi = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[row_spec, row_spec,
                      table_spec((n1, n1)), table_spec((n1, n1)),
                      table_spec((n2, n1)), table_spec((n2, n1)),
                      table_spec((n2, n2)), table_spec((n2, n2)),
                      epi_spec, epi_spec],
            out_specs=[row_spec, row_spec],
            out_shape=out_shape,
            interpret=interpret,
            name=f"matfft_{n1}x{n2}",
        )(xr, xi, w1r, w1i, tr, ti, w2r, w2i, er, ei)

    if pad:
        yr, yi = yr[:rows], yi[:rows]
    return yr, yi
