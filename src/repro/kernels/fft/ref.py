"""Pure-jnp oracles for the FFT kernels.

Everything here is the *reference* semantics the Pallas kernels must match:
``fft_ref`` delegates to jnp.fft (pocketfft on CPU, itself a trusted oracle),
and ``four_step_ref`` spells out the Bailey decomposition in plain jnp so the
kernel's internal algebra can be cross-checked stage by stage.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fft import plan as fft_plan


def fft_ref(xr: jnp.ndarray, xi: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Planar forward FFT along the last axis via jnp.fft."""
    x = jnp.asarray(xr, jnp.float32) + 1j * jnp.asarray(xi, jnp.float32)
    y = jnp.fft.fft(x, axis=-1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def ifft_ref(xr: jnp.ndarray, xi: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    x = jnp.asarray(xr, jnp.float32) + 1j * jnp.asarray(xi, jnp.float32)
    y = jnp.fft.ifft(x, axis=-1)
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _cmatmul(ar, ai, br, bi):
    """Complex matmul on planar operands: (ar+i*ai) @ (br+i*bi)."""
    rr = ar @ br - ai @ bi
    ri = ar @ bi + ai @ br
    return rr, ri


def four_step_ref(xr: jnp.ndarray, xi: jnp.ndarray, n1: int, n2: int):
    """Four-step DFT of length n = n1*n2 along the last axis, pure jnp.

    Mirrors plan.py's index convention exactly; used to validate both the
    in-kernel GEMM formulation and the distributed shard_map version.
    """
    n = n1 * n2
    assert xr.shape[-1] == n
    batch = xr.shape[:-1]
    w1r, w1i = (jnp.asarray(a) for a in fft_plan.dft_matrix(n1))
    w2r, w2i = (jnp.asarray(a) for a in fft_plan.dft_matrix(n2))
    tr, ti = (jnp.asarray(a) for a in fft_plan.twiddle_table(n1, n2, n))

    # x[i1, i2] with i = i1*n2 + i2
    xr2 = xr.reshape(*batch, n1, n2)
    xi2 = xi.reshape(*batch, n1, n2)

    # A[o1, i2] = sum_i1 x[i1, i2] W_{n1}[i1, o1]  -> contract over axis -2.
    ar = jnp.einsum("...ij,io->...oj", xr2, w1r) - jnp.einsum("...ij,io->...oj", xi2, w1i)
    ai = jnp.einsum("...ij,io->...oj", xr2, w1i) + jnp.einsum("...ij,io->...oj", xi2, w1r)

    # B = A * T (inner twiddle)
    br, bi = _cmul(ar, ai, tr, ti)

    # C[o1, o2] = sum_i2 B[o1, i2] W_{n2}[i2, o2]
    cr = br @ w2r - bi @ w2i
    ci = br @ w2i + bi @ w2r

    # X[o2*n1 + o1] = C[o1, o2] -> transpose then flatten.
    outr = jnp.swapaxes(cr, -1, -2).reshape(*batch, n)
    outi = jnp.swapaxes(ci, -1, -2).reshape(*batch, n)
    return outr, outi
