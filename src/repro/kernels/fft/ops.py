"""DEPRECATED per-call FFT entry points — thin shims over `repro.fft`.

These functions predate the plan-and-execute facade. Each call now
resolves a spec and fetches the cached `ExecutablePlan` from the
process-level plan cache (`repro.fft.plan`), then executes it — so repeat
calls with the same shape/options reuse the compiled callable instead of
re-threading impl/layout/interpret kwargs through the kernel stack.

New code should hold a plan directly:

    p = repro.fft.plan(kind="c2c", n=n, batch_shape=batch)
    yr, yi = p.execute(xr, xi)

Shim transparency: when called under an outer trace (e.g. inside a user's
`jax.jit`), the plan inlines its raw executor instead of nesting a jit, so
traced programs still read as reshapes + pallas_calls (asserted by
tests/test_zero_copy_rfft.py). The execution bodies themselves live in
`repro/fft/executors.py`; `fft_cols` and the ``global_twiddle`` path are
layout-level internals used by `core/fft/distributed.py` and delegate
straight to the executors.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

import repro.fft as fft_api
from repro.fft import executors as _ex

Planar = tuple[jnp.ndarray, jnp.ndarray]

# one DeprecationWarning per public entry point per process — repeated
# calls (the whole point of the old per-call API) stay quiet after the
# first. Internal `global_twiddle` calls never warn: that path is the
# distributed engine's layout-level plumbing, not a user migration target.
_WARNED: set = set()


def _warn_deprecated(name: str) -> None:
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"repro.kernels.fft.ops.{name} is deprecated; plan once with "
        f"repro.fft.plan(...) and reuse the returned ExecutablePlan "
        f"(execute/execute_real/execute_inverse)",
        DeprecationWarning, stacklevel=3)


def _reset_deprecation_warnings() -> None:
    """Test hook: make each entry point warn again."""
    _WARNED.clear()


def fft(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
        interpret: bool | None = None, batch_tile: int | None = None,
        global_twiddle=None, layout: str = "zero_copy") -> Planar:
    """Deprecated shim: batched forward FFT along the last axis.

    See `repro.fft.plan(kind="c2c", ...)`.
    """
    if global_twiddle is not None:
        # internal distributed path: the traced row offset cannot key a
        # process-level plan cache, so run the executor directly (no
        # deprecation warning — nothing for the caller to migrate)
        return _ex.fft(xr, xi, impl=impl, interpret=interpret,
                       batch_tile=batch_tile, global_twiddle=global_twiddle,
                       layout=layout)
    _warn_deprecated("fft")
    p = fft_api.plan(kind="c2c", n=xr.shape[-1], batch_shape=xr.shape[:-1],
                     layout=layout, impl=impl, interpret=interpret,
                     batch_tile=batch_tile)
    return p.execute(xr, xi)


def fft_cols(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
             interpret: bool | None = None, col_tile: int | None = None,
             global_twiddle=None, layout: str = "zero_copy") -> Planar:
    """Deprecated shim: FFT each COLUMN of planar (L, C) arrays.

    Layout-level internal (distributed pass boundaries); delegates to
    `repro.fft.executors.fft_cols`.
    """
    return _ex.fft_cols(xr, xi, impl=impl, interpret=interpret,
                        col_tile=col_tile, global_twiddle=global_twiddle,
                        layout=layout)


def ifft(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
         interpret: bool | None = None, batch_tile: int | None = None,
         layout: str = "zero_copy") -> Planar:
    """Deprecated shim: inverse FFT. See `ExecutablePlan.execute_inverse`."""
    _warn_deprecated("ifft")
    p = fft_api.plan(kind="c2c", n=xr.shape[-1], batch_shape=xr.shape[:-1],
                     layout=layout, impl=impl, interpret=interpret,
                     batch_tile=batch_tile)
    return p.execute_inverse(xr, xi)


def fft_c64(x: jnp.ndarray, **kw) -> jnp.ndarray:
    """complex64 convenience wrapper (deprecated shim)."""
    yr, yi = fft(jnp.real(x).astype(jnp.float32),
                 jnp.imag(x).astype(jnp.float32), **kw)
    return (yr + 1j * yi).astype(jnp.complex64)


def ifft_c64(x: jnp.ndarray, **kw) -> jnp.ndarray:
    yr, yi = ifft(jnp.real(x).astype(jnp.float32),
                  jnp.imag(x).astype(jnp.float32), **kw)
    return (yr + 1j * yi).astype(jnp.complex64)


def rfft(x: jnp.ndarray, *, impl: str = "matfft",
         interpret: bool | None = None, batch_tile: int | None = None,
         layout: str = "zero_copy") -> Planar:
    """Deprecated shim: real-input FFT, planar one-sided spectrum.

    See `repro.fft.plan(kind="r2c", ...)` / `ExecutablePlan.execute_real`.
    """
    _warn_deprecated("rfft")
    x = x.astype(jnp.float32)
    if x.shape[-1] < 2:
        # degenerate n=1 predates the facade's r2c domain (n >= 2)
        return _ex.rfft(x, impl=impl, interpret=interpret,
                        batch_tile=batch_tile, layout=layout)
    p = fft_api.plan(kind="r2c", n=x.shape[-1], batch_shape=x.shape[:-1],
                     layout=layout, impl=impl, interpret=interpret,
                     batch_tile=batch_tile)
    return p.execute_real(x)


def irfft(yr: jnp.ndarray, yi: jnp.ndarray, *, impl: str = "matfft",
          interpret: bool | None = None, batch_tile: int | None = None,
          layout: str = "zero_copy") -> jnp.ndarray:
    """Deprecated shim: inverse of rfft, one-sided spectrum -> real signal."""
    _warn_deprecated("irfft")
    n = 2 * (yr.shape[-1] - 1)
    if n < 2:
        # degenerate 1-bin spectrum predates the facade's r2c domain
        return _ex.irfft(yr, yi, impl=impl, interpret=interpret,
                         batch_tile=batch_tile, layout=layout)
    p = fft_api.plan(kind="r2c", n=n, batch_shape=yr.shape[:-1],
                     layout=layout, impl=impl, interpret=interpret,
                     batch_tile=batch_tile)
    return p.execute_inverse(yr, yi)


@functools.partial(jax.jit,
                   static_argnames=("impl", "interpret", "batch_tile",
                                    "layout"))
def fft_jit(xr, xi, *, impl="matfft", interpret=None, batch_tile=None,
            layout="zero_copy"):
    return fft(xr, xi, impl=impl, interpret=interpret, batch_tile=batch_tile,
               layout=layout)
