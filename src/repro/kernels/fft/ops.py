"""Public FFT ops: jit'd wrappers around the Pallas kernels.

Hierarchy (mirrors the paper's block decomposition, DESIGN.md §2):

  level 0  (VMEM/MXU)   matfft kernel, n <= plan.MAX_LEAF
  level 1  (HBM, here)  host four-step n = n1*n2, leaf = level 0, with the
                        outer twiddle FUSED into the first leaf's epilogue
  level 2  (ICI)        cross-device four-step — core/fft/distributed.py,
                        which calls back into these ops for local work

``interpret=None`` auto-selects interpret mode off-TPU so the same code
runs on this CPU container and on real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fft import plan as fft_plan
from repro.kernels.fft import ref as fft_ref
from repro.kernels.fft.matfft import matfft
from repro.kernels.fft.stockham import stockham_fft

Planar = tuple[jnp.ndarray, jnp.ndarray]


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _leaf(xr, xi, impl: str, interpret: bool, epilogue=None, batch_tile=None):
    if impl == "matfft":
        return matfft(xr, xi, epilogue=epilogue, batch_tile=batch_tile,
                      interpret=interpret)
    if impl == "stockham":
        if epilogue is not None:
            yr, yi = stockham_fft(xr, xi, batch_tile=batch_tile,
                                  interpret=interpret)
            er, ei = epilogue
            period = er.shape[0]
            rows = yr.shape[0]
            er = jnp.tile(er, (rows // period, 1))
            ei = jnp.tile(ei, (rows // period, 1))
            return yr * er - yi * ei, yr * ei + yi * er
        return stockham_fft(xr, xi, batch_tile=batch_tile, interpret=interpret)
    if impl == "ref":
        yr, yi = fft_ref.fft_ref(xr, xi)
        if epilogue is not None:
            er, ei = epilogue
            period = er.shape[0]
            er = jnp.tile(er, (yr.shape[0] // period, 1))
            ei = jnp.tile(ei, (yr.shape[0] // period, 1))
            return yr * er - yi * ei, yr * ei + yi * er
        return yr, yi
    raise ValueError(f"unknown fft impl {impl!r}")


def fft(xr: jnp.ndarray, xi: jnp.ndarray, *, impl: str = "matfft",
        interpret: bool | None = None, batch_tile: int | None = None,
        global_twiddle=None) -> Planar:
    """Batched forward FFT along the last axis of planar float32 arrays.

    Any leading batch shape; last-axis length must be a power of two up to
    MAX_LEAF**2 (single device). Larger transforms go through
    core/fft/distributed.py.
    """
    interpret = _auto_interpret(interpret)
    batch_shape, n = xr.shape[:-1], xr.shape[-1]
    if n == 1:
        return xr, xi
    fft_plan.log2i(n)
    rows = 1
    for d in batch_shape:
        rows *= d
    xr2 = xr.reshape(rows, n)
    xi2 = xi.reshape(rows, n)

    p = fft_plan.make_plan(n)
    if p.levels == 1:
        if global_twiddle is not None and impl == "matfft":
            # fused distributed twiddle (core/fft/distributed.py): computed
            # on the fly in the kernel epilogue, no HBM table
            yr, yi = matfft(xr2, xi2, global_twiddle=global_twiddle,
                            batch_tile=batch_tile,
                            interpret=_auto_interpret(interpret))
        else:
            yr, yi = _leaf(xr2, xi2, impl, interpret, batch_tile=batch_tile)
    else:
        if global_twiddle is not None:
            raise ValueError("global_twiddle requires a single-level plan")
        yr, yi = _four_step(xr2, xi2, p.n1, p.n2, impl, interpret, batch_tile)
    return yr.reshape(*batch_shape, n), yi.reshape(*batch_shape, n)


def _four_step(xr, xi, n1: int, n2: int, impl: str, interpret: bool,
               batch_tile: int | None) -> Planar:
    """Host-level four-step: two batched leaf passes + transposes.

    Pass 1 FFTs the n1-columns (rows keyed by (b, i2)) and fuses the outer
    twiddle W_N^{o1*i2} into the leaf epilogue: the epilogue operand is just
    the (n2, n1) table indexed periodically — no O(batch*n) twiddle tensor
    is ever materialized (the HBM-traffic analogue of the paper's
    one-memcpy-per-block rule).
    """
    rows, n = xr.shape
    assert n == n1 * n2

    # T[o1, i2] -> transpose to (i2, o1): row (b, i2) of pass-1 output gets
    # multiplied by T^T[i2, :]. Periodic with period n2 in the row index.
    tr, ti = fft_plan.twiddle_table(n1, n2, n)
    epi = (jnp.asarray(tr.T.copy()), jnp.asarray(ti.T.copy()))

    def to_cols(a):  # (rows, n1*n2) -> (rows*n2, n1)
        return a.reshape(rows, n1, n2).swapaxes(1, 2).reshape(rows * n2, n1)

    ar, ai = _leaf(to_cols(xr), to_cols(xi), impl, interpret,
                   epilogue=epi, batch_tile=batch_tile)

    def to_rows(a):  # (rows*n2, n1) -> (rows*n1, n2)
        return a.reshape(rows, n2, n1).swapaxes(1, 2).reshape(rows * n1, n2)

    cr, ci = _leaf(to_rows(ar), to_rows(ai), impl, interpret,
                   batch_tile=batch_tile)

    def out_order(a):  # rows (b, o1), cols o2 -> flat o = o2*n1 + o1
        return a.reshape(rows, n1, n2).swapaxes(1, 2).reshape(rows, n)

    return out_order(cr), out_order(ci)


def ifft(xr: jnp.ndarray, xi: jnp.ndarray, **kw) -> Planar:
    """Inverse FFT via the conjugation identity: ifft(x) = conj(fft(conj(x)))/n."""
    n = xr.shape[-1]
    yr, yi = fft(xr, -xi, **kw)
    return yr / n, -yi / n


def fft_c64(x: jnp.ndarray, **kw) -> jnp.ndarray:
    """complex64 convenience wrapper."""
    yr, yi = fft(jnp.real(x).astype(jnp.float32),
                 jnp.imag(x).astype(jnp.float32), **kw)
    return (yr + 1j * yi).astype(jnp.complex64)


def ifft_c64(x: jnp.ndarray, **kw) -> jnp.ndarray:
    yr, yi = ifft(jnp.real(x).astype(jnp.float32),
                  jnp.imag(x).astype(jnp.float32), **kw)
    return (yr + 1j * yi).astype(jnp.complex64)


def rfft(x: jnp.ndarray, **kw) -> Planar:
    """Real-input FFT; returns planar one-sided spectrum (n//2 + 1 bins)."""
    n = x.shape[-1]
    yr, yi = fft(x.astype(jnp.float32), jnp.zeros_like(x, jnp.float32), **kw)
    return yr[..., : n // 2 + 1], yi[..., : n // 2 + 1]


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "batch_tile"))
def fft_jit(xr, xi, *, impl="matfft", interpret=None, batch_tile=None):
    return fft(xr, xi, impl=impl, interpret=interpret, batch_tile=batch_tile)
