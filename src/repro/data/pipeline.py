"""Token data pipeline backed by the paper's BlockStore.

The LM trainer consumes data through the same block abstraction as the FFT
job: a corpus is a BlockStore of fixed-size token blocks (one block = one
read unit = one "split"), and the pipeline prefetches blocks on a background
thread so a slow block (the I/O straggler of the paper's Figures 4/5) never
stalls a training step — the Hadoop-overlap idea applied to training I/O.

``synthetic_corpus`` generates a deterministic Zipf-ish token stream so the
end-to-end examples run hermetically (no external data gate).
"""

from __future__ import annotations

import queue
import threading
from pathlib import Path

import numpy as np

from repro.core.pipeline.blockstore import BlockStore


def synthetic_corpus(root, *, vocab_size: int, n_tokens: int,
                     block_tokens: int = 65536, seed: int = 0) -> BlockStore:
    """Zipf-distributed int32 token stream split into BlockStore blocks."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    tokens = rng.choice(vocab_size, size=n_tokens, p=probs).astype(np.int32)
    store = BlockStore(Path(root), block_bytes=4 * block_tokens)
    store.put_bytes(tokens.tobytes())
    return store


class TokenPipeline:
    """Iterator of (batch, seq) token/label batches with block prefetch."""

    def __init__(self, store: BlockStore, *, batch: int, seq: int,
                 prefetch: int = 2, loop: bool = True):
        self.store = store
        self.batch = batch
        self.seq = seq
        self.loop = loop
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._started = False

    def _blocks(self):
        while True:
            for i in range(len(self.store.blocks)):
                yield np.frombuffer(self.store.read_block(i), np.int32)
            if not self.loop:
                return

    def _producer(self):
        need = self.batch * (self.seq + 1)
        buf = np.empty((0,), np.int32)
        for blk in self._blocks():
            buf = np.concatenate([buf, blk])
            while buf.size >= need:
                chunk, buf = buf[:need], buf[need:]
                chunk = chunk.reshape(self.batch, self.seq + 1)
                self._q.put({"tokens": chunk[:, :-1].copy(),
                             "labels": chunk[:, 1:].copy()})
        self._q.put(None)

    def __iter__(self):
        if not self._started:
            self._thread.start()
            self._started = True
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item
