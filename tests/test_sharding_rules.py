"""resolve_pspec: divisibility, no-reuse, fallback chains (1-device safe).

Mesh construction with >1 axis needs >1 device, so these tests build
abstract meshes via jax.sharding.Mesh over a numpy grid of the single CPU
device repeated — not executable, but resolve_pspec only reads .shape.
"""

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import ParamSpec, ShardingRules, resolve_pspec, spec_for


class FakeMesh:
    """Duck-typed mesh: resolve_pspec only touches .shape."""

    def __init__(self, **axes):
        self.shape = dict(axes)


RULES = ShardingRules.default()
MESH = FakeMesh(data=16, model=16)
MESH_MP = FakeMesh(pod=2, data=16, model=16)


def test_basic_tp_fsdp():
    ps = ParamSpec((1024, 4096), ("d_model", "d_ff"))
    assert spec_for(ps, RULES, MESH) == P("data", "model")


def test_divisibility_drops_axis():
    # 14 heads don't divide 16 -> heads replicated
    ps = ParamSpec((896, 14, 64), ("d_model", "heads", "head_dim"))
    assert spec_for(ps, RULES, MESH) == P("data", None, None)


def test_fallback_chain_cache_heads_then_head_dim():
    rules = RULES
    # kv=8 doesn't divide 16, head_dim=128 does -> fallback claims model
    spec = resolve_pspec((128, 32768, 8, 128),
                         ("cache_batch", "cache_seq", "cache_heads",
                          "cache_head_dim"), rules, MESH)
    assert spec == P("data", None, None, "model")


def test_no_axis_reuse():
    # kv=32 divides -> heads take model; head_dim must NOT reuse it
    spec = resolve_pspec((128, 4096, 32, 128),
                         ("cache_batch", "cache_seq", "cache_heads",
                          "cache_head_dim"), RULES, MESH)
    assert spec == P("data", None, "model", None)


def test_batch_of_one_replicates():
    spec = resolve_pspec((1, 1), ("cache_batch", None), RULES, MESH)
    assert spec == P(None, None)


def test_multi_pod_batch_tuple():
    rules = ShardingRules.default(multi_pod=True)
    spec = resolve_pspec((256, 4096), ("batch", "seq"), rules, MESH_MP)
    assert spec == P(("pod", "data"), None)


def test_multi_pod_partial_tuple():
    # batch=2 only fits the pod axis (2), not pod*data
    rules = ShardingRules.default(multi_pod=True)
    spec = resolve_pspec((2, 4096), ("batch", "seq"), rules, MESH_MP)
    assert spec == P("pod", None)


def test_overrides():
    rules = RULES.with_overrides(cache_seq="model")
    spec = resolve_pspec((128, 32768, 8, 128),
                         ("cache_batch", "cache_seq", "cache_heads",
                          "cache_head_dim"), rules, MESH)
    assert spec == P("data", "model", None, None)


def test_unknown_logical_axis_raises():
    import pytest
    with pytest.raises(KeyError):
        resolve_pspec((4,), ("nonsense",), RULES, MESH)
