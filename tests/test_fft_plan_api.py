"""Plan-and-execute facade invariants (`repro.fft`, DESIGN.md §6).

Covers the tentpole claims:
  * spec resolution validates the whole strategy up front: the auto
    placement heuristic, the distributed `D | n1` constraint as a clear
    plan-time ValueError, and kind/layout/impl/precision membership;
  * the process-level plan cache returns the SAME ExecutablePlan for the
    same resolved spec (different layout/impl miss), and repeat executes
    on identical specs trigger ZERO retraces of the compiled callable;
  * execute / execute_real / execute_inverse match the numpy oracles at
    every placement this host can run;
  * the analytic cost model folds the roofline byte counters.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.fft as fft_api
from repro import compat
from repro.fft.spec import MAX_LOCAL_N, resolve_placement
from repro.kernels.fft import plan as kplan


def _rel_err(got_r, got_i, want):
    got = np.asarray(got_r) + 1j * np.asarray(got_i)
    scale = np.abs(want).max() or 1.0
    return float(np.abs(got - want).max() / scale)


# ---------------------------------------------------------------------------
# placement="auto" heuristic (pure function, unit-tested directly)


def test_auto_local_without_mesh():
    assert resolve_placement(1024, 16, 1, None) == "local"
    assert resolve_placement(MAX_LOCAL_N, 1, 0, None) == "local"


def test_auto_too_large_without_mesh_raises():
    with pytest.raises(ValueError, match="pass mesh"):
        resolve_placement(2 * MAX_LOCAL_N, 1, 0, None)


def test_auto_segmented_for_batches_on_mesh():
    # a 1-D batch of block-sized segments is the paper's map-only regime
    assert resolve_placement(4096, 4096, 1, 8) == "segmented"
    assert resolve_placement(1024, 1024, 1, 512) == "segmented"
    # an indivisible batch cannot shard evenly -> stays local
    assert resolve_placement(1024, 2, 1, 512) == "local"
    assert resolve_placement(256, 3, 1, 8) == "local"


def test_auto_distributed_for_single_large_signal():
    assert resolve_placement(1 << 20, 1, 0, 8) == "distributed"
    assert resolve_placement(1 << 18, 1, 0, 512) == "distributed"


def test_auto_local_when_signal_too_small_to_distribute():
    # n < D^2: the four-step can't split evenly, keep it on one device
    assert resolve_placement(16, 1, 0, 8) == "local"


def test_auto_multidim_batch_stays_local():
    # segmented shards a 1-D (batch, n) layout; framed stft batches stay local
    assert resolve_placement(1024, 64, 2, 8) == "local"


def test_auto_unplaceable_raises():
    # a BATCH of transforms each longer than one device can hold: neither
    # segmented (per-segment cap) nor distributed (needs a scalar batch)
    with pytest.raises(ValueError, match="cannot auto-place"):
        resolve_placement(1 << 30, 4, 1, 8)


# ---------------------------------------------------------------------------
# plan-time validation (clear errors instead of deep shard_map failures)


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((jax.device_count(),), ("data",))


def test_distributed_constraint_valueerror():
    # n < D^2 must name the D | n1 constraint at plan time (spec-level pure
    # check so it runs regardless of this host's device count)
    from repro.fft import spec as spec_mod
    with pytest.raises(ValueError, match=r"D \| n1"):
        spec_mod.resolve(kind="c2c", n=32, batch_shape=(),
                         placement="distributed", layout="zero_copy",
                         impl="matfft", precision="f32", interpret=None,
                         batch_tile=None, num_devices=8, axes=("data",),
                         natural_order=True, fuse_twiddle=False)
    with pytest.raises(ValueError, match="power-of-two device count"):
        spec_mod.resolve(kind="c2c", n=1 << 20, batch_shape=(),
                         placement="distributed", layout="zero_copy",
                         impl="matfft", precision="f32", interpret=None,
                         batch_tile=None, num_devices=6, axes=("data",),
                         natural_order=True, fuse_twiddle=False)


def test_distributed_rejects_r2c(mesh):
    with pytest.raises(ValueError, match="r2c"):
        fft_api.plan(kind="r2c", n=1 << 20, mesh=mesh,
                     placement="distributed")


def test_distributed_rejects_batch(mesh):
    with pytest.raises(ValueError, match="batch"):
        fft_api.plan(kind="c2c", n=1 << 20, batch_shape=(4,), mesh=mesh,
                     placement="distributed")


def test_segmented_requires_mesh_and_1d_batch(mesh):
    with pytest.raises(ValueError, match="mesh"):
        fft_api.plan(kind="c2c", n=512, batch_shape=(8,),
                     placement="segmented")
    with pytest.raises(ValueError, match="1-D batch"):
        fft_api.plan(kind="c2c", n=512, batch_shape=(2, 4), mesh=mesh,
                     placement="segmented")


def test_segmented_indivisible_batch_plan_time_error():
    # explicit segmented with a batch that can't shard evenly must be a
    # plan-time ValueError, not a deep pjit sharding failure at execute
    from repro.fft import spec as spec_mod
    with pytest.raises(ValueError, match="shard evenly"):
        spec_mod.resolve(kind="c2c", n=512, batch_shape=(3,),
                         placement="segmented", layout="zero_copy",
                         impl="matfft", precision="f32", interpret=None,
                         batch_tile=None, num_devices=8, axes=("data",),
                         natural_order=True, fuse_twiddle=False)


def test_bad_enums_raise():
    for kw in (dict(kind="c2r"), dict(layout="strided"), dict(impl="cufft"),
               dict(precision="f64"), dict(placement="cluster")):
        with pytest.raises(ValueError, match="unknown|unsupported"):
            fft_api.plan(**{"kind": "c2c", "n": 256, **kw})


def test_non_pow2_raises():
    with pytest.raises(ValueError, match="power of two"):
        fft_api.plan(kind="c2c", n=768, batch_shape=(2,))


# ---------------------------------------------------------------------------
# plan cache: same spec -> same plan object + compiled fn; no retrace


def test_cache_identity_and_misses():
    fft_api.clear_plan_cache()
    p1 = fft_api.plan(kind="c2c", n=256, batch_shape=(3,))
    p2 = fft_api.plan(kind="c2c", n=256, batch_shape=(3,))
    assert p2 is p1
    assert fft_api.cache_info()["hits"] == 1
    # different layout / impl / kind / batch resolve to different plans
    assert fft_api.plan(kind="c2c", n=256, batch_shape=(3,),
                        layout="copy") is not p1
    assert fft_api.plan(kind="c2c", n=256, batch_shape=(3,),
                        impl="stockham") is not p1
    assert fft_api.plan(kind="r2c", n=256, batch_shape=(3,)) is not p1
    assert fft_api.plan(kind="c2c", n=256, batch_shape=(4,)) is not p1


def test_zero_retrace_on_repeat_execute(rng):
    """The cufftPlanMany property: repeat executes on an identical spec
    reuse the jit'd callable — the traced-fn counter stays at 1 and the
    executable is id-stable."""
    p = fft_api.plan(kind="c2c", n=512, batch_shape=(2,))
    assert p.executable is p.executable
    xr = jnp.asarray(rng.standard_normal((2, 512)).astype(np.float32))
    xi = jnp.asarray(rng.standard_normal((2, 512)).astype(np.float32))
    p.execute(xr, xi)
    assert p.trace_counts["forward"] == 1
    p.execute(xr, xi)
    p.execute(xr + 1.0, xi)  # new values, same shape: still no retrace
    assert p.trace_counts["forward"] == 1
    # the same spec fetched again is the same object -> same compiled fn
    p2 = fft_api.plan(kind="c2c", n=512, batch_shape=(2,))
    p2.execute(xr, xi)
    assert p2 is p and p.trace_counts["forward"] == 1


def test_plan_is_frozen():
    p = fft_api.plan(kind="c2c", n=64, batch_shape=(1,))
    with pytest.raises(AttributeError, match="frozen"):
        p.spec = None


# ---------------------------------------------------------------------------
# execution correctness per placement


def test_c2c_local_leaf_and_four_step(rng):
    for n, batch in ((1024, (3,)), (1 << 15, (2,))):
        xr = rng.standard_normal((*batch, n)).astype(np.float32)
        xi = rng.standard_normal((*batch, n)).astype(np.float32)
        p = fft_api.plan(kind="c2c", n=n, batch_shape=batch)
        yr, yi = p.execute(jnp.asarray(xr), jnp.asarray(xi))
        assert _rel_err(yr, yi, np.fft.fft(xr + 1j * xi)) < 5e-6
        br, bi = p.execute_inverse(yr, yi)
        assert float(jnp.abs(br - xr).max()) / np.abs(xr).max() < 1e-5


def test_r2c_execute_real_and_inverse(rng):
    x = rng.standard_normal((3, 2048)).astype(np.float32)
    p = fft_api.plan(kind="r2c", n=2048, batch_shape=(3,))
    sr, si = p.execute_real(jnp.asarray(x))
    assert sr.shape == (3, 1025)
    assert _rel_err(sr, si, np.fft.rfft(x)) < 5e-6
    back = p.execute_inverse(sr, si)
    assert float(jnp.abs(back - x).max()) / np.abs(x).max() < 1e-5


def test_segmented_placement_matches_numpy(mesh, rng):
    xs = rng.standard_normal((8, 512)).astype(np.float32)
    ys = rng.standard_normal((8, 512)).astype(np.float32)
    p = fft_api.plan(kind="c2c", n=512, batch_shape=(8,), mesh=mesh,
                     placement="segmented")
    zr, zi = p.execute(jnp.asarray(xs), jnp.asarray(ys))
    assert _rel_err(zr, zi, np.fft.fft(xs + 1j * ys, axis=-1)) < 5e-6
    p.execute(jnp.asarray(xs), jnp.asarray(ys))
    assert p.trace_counts["forward"] == 1


def test_segmented_r2c_matches_numpy(mesh, rng):
    xs = rng.standard_normal((8, 512)).astype(np.float32)
    p = fft_api.plan(kind="r2c", n=512, batch_shape=(8,), mesh=mesh,
                     placement="segmented")
    sr, si = p.execute_real(jnp.asarray(xs))
    assert _rel_err(sr, si, np.fft.rfft(xs)) < 5e-6


# ---------------------------------------------------------------------------
# guard rails


def test_wrong_method_and_shape_raise(rng):
    pc = fft_api.plan(kind="c2c", n=64, batch_shape=(2,))
    pr = fft_api.plan(kind="r2c", n=64, batch_shape=(2,))
    x = jnp.zeros((2, 64), jnp.float32)
    with pytest.raises(ValueError, match="execute_real"):
        pr.execute(x, x)
    with pytest.raises(ValueError, match="c2c"):
        pc.execute_real(x)
    with pytest.raises(ValueError, match="shape"):
        pc.execute(jnp.zeros((3, 64), jnp.float32),
                   jnp.zeros((3, 64), jnp.float32))
    with pytest.raises(ValueError, match="shape"):
        pr.execute_real(jnp.zeros((2, 128), jnp.float32))


def test_distributed_plan_beyond_single_device_capacity(mesh):
    # global n up to 2^32 is valid for distributed plans: the leaf
    # factorization must cover the per-device pass lengths, not global n
    p = fft_api.plan(kind="c2c", n=1 << 30, mesh=mesh,
                     placement="distributed")
    assert p.dist is not None
    assert max(p.dist.n1, p.dist.n2) == p.leaf.n
    assert p.gemm_macs > 0 and p.collective_bytes > 0


def test_trace_count_ignores_outer_jit_traces(rng):
    # callers jitting over execute (e.g. the deprecated shims inside a
    # user's jax.jit) inline the raw executor; only the plan's own jit
    # traces count toward the zero-retrace observable
    p = fft_api.plan(kind="c2c", n=64, batch_shape=(1,))
    x = jnp.asarray(rng.standard_normal((1, 64)).astype(np.float32))
    jax.jit(lambda a, b: p.execute(a, b))(x, x)
    assert p.trace_counts["forward"] == 0
    p.execute(x, x)
    p.execute(x, x)
    assert p.trace_counts["forward"] == 1


def test_shims_accept_degenerate_lengths(rng):
    # n=1 rfft and 1-bin irfft predate the facade's r2c domain and must
    # keep working through the deprecated shims
    from repro.kernels.fft import ops
    yr, yi = ops.rfft(jnp.ones((2, 1), jnp.float32))
    assert yr.shape == (2, 1)
    out = ops.irfft(jnp.ones((2, 1), jnp.float32),
                    jnp.zeros((2, 1), jnp.float32))
    assert out.shape[0] == 2


def test_overlap_resolution_and_rejection():
    # pure spec-level: runs regardless of this host's device count
    from repro.core.fft.distributed import (
        OVERLAP_AUTO_MIN_N, OVERLAP_RING_MAX_D, plan_distributed,
        resolve_overlap)
    # auto declines small n, huge rings, and 1-wide slabs
    assert resolve_overlap(4096, 8, "auto") is None
    assert resolve_overlap(OVERLAP_AUTO_MIN_N, 8, "auto") == 4
    assert resolve_overlap(OVERLAP_AUTO_MIN_N, 2 * OVERLAP_RING_MAX_D,
                           "auto") is None
    assert resolve_overlap(1 << 30, 8, "off") is None
    # explicit chunk counts are honoured where auto declines, but must
    # divide both per-device slab widths (n=4096, D=8 -> n1l = n2l = 8)
    assert resolve_overlap(4096, 8, 8) == 8
    for bad in (0, -1, 3, 16, "weird", 2.5, True):
        with pytest.raises(ValueError, match="overlap"):
            resolve_overlap(4096, 8, bad)
    # ... and surface through spec resolution as plan-time errors
    from repro.fft import spec as spec_mod
    with pytest.raises(ValueError, match="divide both"):
        spec_mod.resolve(kind="c2c", n=4096, batch_shape=(),
                         placement="distributed", layout="zero_copy",
                         impl="matfft", precision="f32", interpret=None,
                         batch_tile=None, num_devices=8, axes=("data",),
                         natural_order=True, fuse_twiddle=False, overlap=3)
    # "auto" resolves pre-cache-key: the resolved spec never carries it
    s = spec_mod.resolve(kind="c2c", n=4096, batch_shape=(),
                         placement="distributed", layout="zero_copy",
                         impl="matfft", precision="f32", interpret=False,
                         batch_tile=None, num_devices=8, axes=("data",),
                         natural_order=True, fuse_twiddle=False,
                         overlap="auto")
    assert s.overlap == "off"
    # non-distributed placements normalize overlap away entirely
    s2 = spec_mod.resolve(kind="c2c", n=256, batch_shape=(4,),
                          placement="local", layout="zero_copy",
                          impl="matfft", precision="f32", interpret=False,
                          batch_tile=None, num_devices=None, axes=None,
                          natural_order=True, fuse_twiddle=False, overlap=7)
    assert s2.overlap == "off"
    # DistPlan carries the chunk count
    assert plan_distributed(4096, 8, chunks=4).chunks == 4


def test_overlap_cache_key_and_cost_model(mesh):
    n = jax.device_count() ** 2 * 64
    p_off = fft_api.plan(kind="c2c", n=n, mesh=mesh,
                         placement="distributed", overlap="off")
    p_on = fft_api.plan(kind="c2c", n=n, mesh=mesh,
                        placement="distributed", overlap=2)
    assert p_on is not p_off
    assert p_on is fft_api.plan(kind="c2c", n=n, mesh=mesh,
                                placement="distributed", overlap=2)
    # exposed = total / chunks; "off" exposes everything
    assert p_off.exposed_collective_bytes == p_off.collective_bytes
    assert p_off.hidden_collective_bytes == 0
    assert p_on.exposed_collective_bytes * 2 == p_on.collective_bytes
    assert (p_on.hidden_collective_bytes
            == p_on.collective_bytes - p_on.exposed_collective_bytes)
    # overlap does not change the total payload
    assert p_on.collective_bytes == p_off.collective_bytes


def test_collective_bytes_account_for_transposed_out(mesh):
    """The DistPlan fix: natural_order=False skips exchange #3, so both
    the per-device and the plan-level counters report 2 legs, not 3."""
    from repro.core.fft.distributed import plan_distributed
    d_nat = plan_distributed(1 << 20, 8, natural_order=True)
    d_tr = plan_distributed(1 << 20, 8, natural_order=False)
    assert d_nat.n_exchanges == 3 and d_tr.n_exchanges == 2
    assert (d_nat.collective_bytes_per_device
            == 3 * d_nat.bytes_per_exchange_per_device)
    assert (d_tr.collective_bytes_per_device
            == 2 * d_tr.bytes_per_exchange_per_device)
    n = jax.device_count() ** 2 * 64
    p_nat = fft_api.plan(kind="c2c", n=n, mesh=mesh,
                         placement="distributed", natural_order=True,
                         overlap="off")
    p_tr = fft_api.plan(kind="c2c", n=n, mesh=mesh,
                        placement="distributed", natural_order=False,
                        overlap="off")
    assert p_tr.collective_bytes * 3 == p_nat.collective_bytes * 2


def test_distributed_transposed_out_inverse_raises(mesh):
    # the conjugation identity is only the true inverse when the forward
    # returned natural order; TRANSPOSED_OUT plans must fail fast
    p = fft_api.plan(kind="c2c", n=jax.device_count() ** 2 * 16, mesh=mesh,
                     placement="distributed", natural_order=False)
    y = jnp.zeros((p.n,), jnp.float32)
    with pytest.raises(NotImplementedError, match="natural_order"):
        p.execute_inverse(y, y)


def test_plan_cache_thread_safe():
    # map-only jobs plan() from ThreadPoolExecutor workers: concurrent
    # same-spec calls must all get the one cached plan
    from concurrent.futures import ThreadPoolExecutor
    fft_api.clear_plan_cache()
    with ThreadPoolExecutor(max_workers=8) as ex:
        plans = list(ex.map(
            lambda _: fft_api.plan(kind="c2c", n=128, batch_shape=(2,)),
            range(32)))
    assert all(p is plans[0] for p in plans)
    info = fft_api.cache_info()
    assert info["misses"] == 1 and info["hits"] == 31


def test_interpret_none_and_explicit_bool_share_a_plan():
    # interpret=None resolves to a concrete bool before the cache key, so
    # library callers (None) and tests (explicit) reuse one compiled plan
    auto = fft_api.plan(kind="c2c", n=128, batch_shape=(2,))
    explicit = fft_api.plan(kind="c2c", n=128, batch_shape=(2,),
                            interpret=jax.default_backend() != "tpu")
    assert explicit is auto


# ---------------------------------------------------------------------------
# analytic cost model folds the roofline byte counters


def test_cost_model_folds_byte_counters():
    for n in (4096, 32768):
        pc = fft_api.plan(kind="c2c", n=n, batch_shape=(4,))
        assert pc.hbm_bytes_per_row == kplan.fft_hbm_bytes(n, "zero_copy")
        assert pc.hbm_bytes == 4 * pc.hbm_bytes_per_row
        assert pc.gemm_macs_per_row == kplan.make_plan(n).gemm_macs
        pr = fft_api.plan(kind="r2c", n=n, batch_shape=(4,))
        assert pr.hbm_bytes_per_row == kplan.rfft_hbm_bytes(n)
        assert pr.flops_per_row < pc.flops_per_row
    pcopy = fft_api.plan(kind="c2c", n=32768, batch_shape=(4,),
                         layout="copy")
    assert pcopy.hbm_bytes_per_row == kplan.fft_hbm_bytes(32768, "copy")


# ---------------------------------------------------------------------------
# execute_async: the stream executor's launch entry (no sync, donate)


def test_execute_async_matches_execute(rng):
    p = fft_api.plan(kind="c2c", n=256, batch_shape=(4,))
    xr = rng.standard_normal((4, 256)).astype(np.float32)
    xi = rng.standard_normal((4, 256)).astype(np.float32)
    want_r, want_i = p.execute(jnp.asarray(xr), jnp.asarray(xi))
    got_r, got_i = p.execute_async(xr, xi)
    np.testing.assert_array_equal(np.asarray(want_r), np.asarray(got_r))
    np.testing.assert_array_equal(np.asarray(want_i), np.asarray(got_i))


def test_execute_async_donate_zero_retrace_on_repeat(rng):
    fft_api.clear_plan_cache()
    p = fft_api.plan(kind="c2c", n=256, batch_shape=(3,))
    want = np.asarray(p.execute_async(
        rng.standard_normal((3, 256)).astype(np.float32),
        rng.standard_normal((3, 256)).astype(np.float32), donate=True)[0])
    assert p.trace_counts["forward"] == 1
    for _ in range(3):  # repeats reuse the donated executable: no retrace
        xr = rng.standard_normal((3, 256)).astype(np.float32)
        xi = rng.standard_normal((3, 256)).astype(np.float32)
        ref_r, _ = np.fft.fft(xr + 1j * xi).real, None
        got = p.execute_async(xr, xi, donate=True)
        np.testing.assert_allclose(np.asarray(got[0]), ref_r,
                                   rtol=2e-4, atol=2e-3)
    assert p.trace_counts["forward"] == 1
    assert want is not None
    # the plain executable is a second (also cached-once) trace
    xr = rng.standard_normal((3, 256)).astype(np.float32)
    p.execute(jnp.asarray(xr), jnp.asarray(xr))
    assert p.trace_counts["forward"] == 2


def test_execute_async_r2c_and_arity_errors(rng):
    p = fft_api.plan(kind="r2c", n=256, batch_shape=(2,))
    x = rng.standard_normal((2, 256)).astype(np.float32)
    want = p.execute_real(jnp.asarray(x))
    got = p.execute_async(x)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    with pytest.raises(ValueError, match="1 operand"):
        p.execute_async(x, x)
    pc = fft_api.plan(kind="c2c", n=256, batch_shape=(2,))
    with pytest.raises(ValueError, match="2 operand"):
        pc.execute_async(x)
    with pytest.raises(ValueError, match="execute_async"):
        pc.execute_async(x[:, :128], x[:, :128])
