"""3-D pencil decomposition + true r2c pencil (DESIGN.md §14).

The N-D generalization's contract, tested on the 8-device CPU mesh:
  * a 3-D volume on a 2-axis mesh runs ``ndim-1 == 2`` re-pencil
    exchange legs, bitwise-equal to the LOCAL fftn plan (same kernel
    tiles) under both exchange engines (monolithic all_to_all and the
    chunked ppermute ring);
  * per-leg collective-byte accounting: ``per_leg_collective_bytes`` has
    one entry per leg and sums to ``collective_bytes`` (same for the
    exposed variants up to chunk integer division);
  * the r2c pencil streams the PACKED half-width volume through every
    leg — flops and exchange bytes halved vs the c2c pencil — and stays
    bitwise-equal to the local rfftn plan;
  * spec errors: a 3-D distributed volume without a mesh, with the
    wrong mesh-axis count, or with an axis the grid can't divide are
    plan-time ValueErrors, not shard_map crashes.
"""

import numpy as np
import jax
import pytest

import repro.fft as fft_api
from repro import compat
from repro.fft import spec as spec_mod

BT = 2  # matched kernel batch tile: pencil == local bitwise requires it


def _need(n):
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} devices, have {jax.device_count()}")


@pytest.fixture(scope="module")
def mesh2d():
    if jax.device_count() < 8:
        pytest.skip("needs >= 8 devices for the (4, 2) mesh")
    return compat.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(autouse=True)
def _clean():
    fft_api.clear_plan_cache()
    yield
    fft_api.clear_plan_cache()


def _operands(shape, seed=0, n=2):
    rng = np.random.default_rng(seed)
    return tuple(rng.standard_normal(shape).astype(np.float32)
                 for _ in range(n))


SHAPE3 = (16, 32, 64)


class TestPencil3D:
    @pytest.mark.parametrize("overlap", ["off", 2])
    def test_bitwise_vs_local_fftn(self, mesh2d, overlap):
        xr, xi = _operands(SHAPE3)
        local = fft_api.plan(kind="c2c", shape=SHAPE3, batch_tile=BT,
                             placement="local")
        want = local.execute(xr, xi)
        p = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh2d,
                         placement="distributed", batch_tile=BT,
                         overlap=overlap)
        got = p.execute(xr, xi)
        for g, w in zip(got, want):
            assert np.asarray(g).tobytes() == np.asarray(w).tobytes()

    def test_two_exchange_legs_and_per_leg_bytes(self, mesh2d):
        p = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh2d,
                         placement="distributed", overlap="off")
        assert p.dist.n_exchanges == len(SHAPE3) - 1 == 2
        legs = p.per_leg_collective_bytes
        assert len(legs) == 2
        assert sum(legs) == p.collective_bytes
        # chunked engine: exposed bytes shrink per leg
        pc = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh2d,
                          placement="distributed", overlap=2)
        exp = pc.per_leg_exposed_collective_bytes
        assert len(exp) == 2
        assert all(e <= b // 2 for e, b in zip(exp, legs))
        assert sum(exp) == pc.exposed_collective_bytes

    def test_grid_follows_mesh_axes(self, mesh2d):
        p = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh2d,
                         placement="distributed")
        assert p.dist.grid == (4, 2)
        assert p.dist.d == 8


class TestR2cPencil:
    @pytest.mark.parametrize("overlap", ["off", 2])
    def test_3d_bitwise_vs_local_rfftn(self, mesh2d, overlap):
        (x,) = _operands(SHAPE3, n=1)
        local = fft_api.plan(kind="r2c", shape=SHAPE3, batch_tile=BT,
                             placement="local")
        want = local.execute_real(x)
        p = fft_api.plan(kind="r2c", shape=SHAPE3, mesh=mesh2d,
                         placement="distributed", batch_tile=BT,
                         overlap=overlap)
        assert p._fast_r2c_pencil
        got = p.execute_real(x)
        for g, w in zip(got, want):
            assert np.asarray(g).tobytes() == np.asarray(w).tobytes()

    @pytest.mark.parametrize("overlap", ["off", 2])
    def test_2d_bitwise_vs_local_rfftn(self, overlap):
        _need(2)
        d = jax.device_count()
        mesh = compat.make_mesh((d,), ("data",))
        shape = (8 * d, 256)
        (x,) = _operands(shape, n=1)
        local = fft_api.plan(kind="r2c", shape=shape, batch_tile=BT,
                             placement="local")
        want = local.execute_real(x)
        p = fft_api.plan(kind="r2c", shape=shape, mesh=mesh,
                         placement="distributed", batch_tile=BT,
                         overlap=overlap)
        assert p._fast_r2c_pencil
        got = p.execute_real(x)
        for g, w in zip(got, want):
            assert np.asarray(g).tobytes() == np.asarray(w).tobytes()

    def test_flops_and_bytes_halved(self, mesh2d):
        c2c = fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh2d,
                           placement="distributed")
        r2c = fft_api.plan(kind="r2c", shape=SHAPE3, mesh=mesh2d,
                           placement="distributed")
        assert r2c._fast_r2c_pencil
        # the packed pencil moves HALF the exchange bytes of the c2c run
        assert r2c.collective_bytes == c2c.collective_bytes // 2
        assert r2c.flops < 0.75 * c2c.flops
        assert r2c.gemm_macs < 0.75 * c2c.gemm_macs


class TestSpecErrors:
    def test_3d_distributed_needs_mesh_axes(self):
        with pytest.raises(ValueError, match="mesh"):
            spec_mod.resolve(kind="c2c", shape=SHAPE3,
                             placement="distributed", num_devices=8)

    def test_3d_wrong_axis_count(self, mesh2d):
        # a 3-D volume on a 1-axis slice of the mesh: needs exactly 2
        with pytest.raises(ValueError, match="mesh axes"):
            fft_api.plan(kind="c2c", shape=SHAPE3, mesh=mesh2d,
                         axes=("data",), placement="distributed")

    def test_3d_indivisible_axis(self, mesh2d):
        # grid[0]=4 must divide BOTH axis 0 (8: ok) and axis 1 (2: not)
        with pytest.raises(ValueError, match="axis 1"):
            fft_api.plan(kind="c2c", shape=(8, 2, 64), mesh=mesh2d,
                         placement="distributed")
