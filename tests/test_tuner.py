"""Measuring autotuner + persistent wisdom (DESIGN.md §14).

Covers the tentpole claims that aren't the bench gate's job:
  * determinism — the same seed and the same injected timer pick the
    same winner, twice, from a cold store;
  * a wisdom hit is a pure lookup: zero measurements, stored knobs
    returned verbatim, and `fft.cache_info()["wisdom_hits"]` advances
    while a hit that still BUILDS a plan counts as a plan-cache miss;
  * corrupt/truncated wisdom degrades to measuring with a logged
    `wisdom_corrupt` event — never an exception;
  * wisdom is keyed on the mesh fingerprint: a different mesh shape
    re-measures instead of consulting stale knobs;
  * tuning a spec that cannot resolve degrades to analytic defaults so
    plan() surfaces the real error itself.
"""

import json

import numpy as np
import jax
import pytest

import repro.fft as fft_api
from repro import compat
import importlib

events = importlib.import_module("repro.core.resilience.events")
from repro.fft import tuner

pytestmark = pytest.mark.tune


@pytest.fixture(autouse=True)
def _clean():
    fft_api.clear_plan_cache()
    tuner.reset_tune_stats()
    events.clear_events()
    yield
    fft_api.clear_plan_cache()


def _wisdom(tmp_path, name="wisdom.json"):
    return str(tmp_path / name)


def _fake_measurer():
    """Deterministic stand-in for the wall clock: a pure function of the
    candidate's knobs, so two sweeps agree exactly."""
    def measure(plan, cfg):
        s = plan.spec
        base = 1e-3 + plan.hbm_bytes * 1e-12
        if s.layout == "copy":
            base *= 1.5
        if s.overlap != "off":
            base *= 0.9 / (1 + 0.01 * int(s.overlap))
        if s.batch_tile is not None:
            base *= 1.01
        return base
    return measure


KW = dict(kind="c2c", shape=(64, 256), batch_shape=(8,))


class TestDeterminism:
    def test_same_seed_same_timer_same_winner(self, tmp_path):
        cfg = tuner.TuneConfig(seed=7, measurer=_fake_measurer())
        k1, r1 = tuner.tune(**KW, wisdom_path=_wisdom(tmp_path, "a.json"),
                            config=cfg)
        k2, r2 = tuner.tune(**KW, wisdom_path=_wisdom(tmp_path, "b.json"),
                            config=cfg)
        assert not r1.wisdom_hit and not r2.wisdom_hit
        assert r1.measurements == r2.measurements > 0
        assert k1 == k2
        assert ([c["knobs"] for c in r1.candidates]
                == [c["knobs"] for c in r2.candidates])

    def test_analytic_measurer_is_deterministic(self, tmp_path):
        cfg = tuner.TuneConfig(measurer="analytic")
        k1, _ = tuner.tune(**KW, wisdom_path=_wisdom(tmp_path, "a.json"),
                           config=cfg)
        k2, _ = tuner.tune(**KW, wisdom_path=_wisdom(tmp_path, "b.json"),
                           config=cfg)
        assert k1 == k2

    def test_default_knobs_are_candidate_zero(self, tmp_path):
        cfg = tuner.TuneConfig(measurer="analytic")
        _, rep = tuner.tune(**KW, wisdom_path=_wisdom(tmp_path),
                            config=cfg)
        assert rep.candidates[0]["knobs"] == {
            "overlap": "off", "layout": "zero_copy", "batch_tile": None}


class TestWisdomRoundTrip:
    def test_hit_is_pure_lookup(self, tmp_path):
        wp = _wisdom(tmp_path)
        cfg = tuner.TuneConfig(measurer=_fake_measurer())
        k1, r1 = tuner.tune(**KW, wisdom_path=wp, config=cfg)
        assert r1.measurements > 0
        k2, r2 = tuner.tune(**KW, wisdom_path=wp, config=cfg)
        assert r2.wisdom_hit and r2.measurements == 0
        assert k2 == k1
        stats = tuner.tune_stats()
        assert stats["wisdom_hits"] == 1 and stats["tuned"] == 2

    def test_file_survives_reload(self, tmp_path):
        wp = _wisdom(tmp_path)
        cfg = tuner.TuneConfig(measurer="analytic")
        k1, r1 = tuner.tune(**KW, wisdom_path=wp, config=cfg)
        doc = json.loads((tmp_path / "wisdom.json").read_text())
        assert doc["version"] == tuner.WISDOM_VERSION
        assert r1.key in doc["entries"]
        assert doc["entries"][r1.key]["knobs"] == k1
        # a FRESH store object (new process analogue) hits
        store = tuner.WisdomStore(wp)
        assert store.lookup(r1.key)["knobs"] == k1

    def test_wisdom_hit_counts_cache_miss_not_hit(self, tmp_path):
        """The §14 bugfix: a wisdom hit that still builds a NEW
        ExecutablePlan is a plan-cache MISS plus a wisdom hit — only a
        plan reused from the cache is a cache hit."""
        wp = _wisdom(tmp_path)
        cfg = tuner.TuneConfig(measurer="analytic")
        fft_api.plan(**KW, tune=True, wisdom_path=wp, tune_config=cfg)
        base = fft_api.cache_info()
        assert base["wisdom_hits"] == 0  # first plan measured, no hit
        fft_api.clear_plan_cache()       # wisdom outlives the plan cache
        fft_api.plan(**KW, tune=True, wisdom_path=wp, tune_config=cfg)
        info = fft_api.cache_info()
        assert info["wisdom_hits"] == 1
        assert info["hits"] == 0         # new build: NOT a cache hit
        assert info["misses"] >= 1
        # same call again: plan cache hit AND wisdom hit
        fft_api.plan(**KW, tune=True, wisdom_path=wp, tune_config=cfg)
        info = fft_api.cache_info()
        assert info["wisdom_hits"] == 2 and info["hits"] == 1


class TestWisdomCorruption:
    @pytest.mark.parametrize("payload", [
        "{not json",                       # truncated/garbage
        '{"version": 99, "entries": {}}',  # wrong version
        '{"version": 1, "entries": 3}',    # wrong entries type
        '["list", "not", "object"]',       # wrong document type
    ])
    def test_corrupt_wisdom_degrades_with_event(self, tmp_path, payload):
        wp = tmp_path / "wisdom.json"
        wp.write_text(payload)
        store = tuner.WisdomStore(str(wp))  # must not raise
        assert len(store) == 0
        evs = events.events("wisdom_corrupt")
        assert evs and evs[-1]["path"] == str(wp)
        # tuning through the corrupt file measures and then REPAIRS it
        cfg = tuner.TuneConfig(measurer="analytic")
        _, rep = tuner.tune(**KW, wisdom_path=str(wp), config=cfg)
        assert not rep.wisdom_hit and rep.measurements > 0
        doc = json.loads(wp.read_text())
        assert doc["version"] == tuner.WISDOM_VERSION

    def test_stale_invalid_knobs_remeasure(self, tmp_path):
        wp = _wisdom(tmp_path)
        cfg = tuner.TuneConfig(measurer="analytic")
        _, rep = tuner.tune(**KW, wisdom_path=wp, config=cfg)
        # poison the stored knobs with an impossible overlap
        store = tuner.WisdomStore.get(wp)
        entry = store.lookup(rep.key)
        entry["knobs"] = {"overlap": 3, "layout": "nope",
                          "batch_tile": -1}
        store.record(rep.key, entry)
        _, rep2 = tuner.tune(**KW, wisdom_path=wp, config=cfg)
        assert not rep2.wisdom_hit and rep2.measurements > 0
        assert events.events("wisdom_stale")


class TestMeshFingerprint:
    def test_different_mesh_shape_remeasures(self, tmp_path):
        if jax.device_count() < 4:
            pytest.skip("needs >= 4 devices")
        wp = _wisdom(tmp_path)
        cfg = tuner.TuneConfig(measurer="analytic")
        mesh_a = compat.make_mesh((4,), ("data",))
        kw = dict(kind="c2c", shape=(64, 256), mesh=mesh_a,
                  axes=("data",), num_devices=4,
                  placement="distributed")
        _, r1 = tuner.tune(**kw, wisdom_path=wp, config=cfg)
        assert r1.measurements > 0
        # same spec, HALF the devices: fingerprint differs, no hit
        mesh_b = compat.make_mesh((2,), ("data",))
        kw_b = dict(kw, mesh=mesh_b, num_devices=2)
        _, r2 = tuner.tune(**kw_b, wisdom_path=wp, config=cfg)
        assert not r2.wisdom_hit and r2.measurements > 0
        assert r1.key != r2.key
        assert tuner.mesh_fingerprint(mesh_a) != \
            tuner.mesh_fingerprint(mesh_b)

    def test_fingerprint_stable_for_same_mesh(self):
        if jax.device_count() < 2:
            pytest.skip("needs >= 2 devices")
        m1 = compat.make_mesh((2,), ("data",))
        m2 = compat.make_mesh((2,), ("data",))
        assert tuner.mesh_fingerprint(m1) == tuner.mesh_fingerprint(m2)
        assert tuner.mesh_fingerprint(None) == "mesh=none"


class TestDegradation:
    def test_unresolvable_spec_degrades(self, tmp_path):
        cfg = tuner.TuneConfig(measurer="analytic")
        knobs, rep = tuner.tune(kind="c2c", shape=(96,),  # not pow2
                                wisdom_path=_wisdom(tmp_path), config=cfg)
        assert knobs == {} and rep.degraded
        assert events.events("tune_degraded")
        # and plan() itself still raises the REAL error
        with pytest.raises(ValueError, match="power of two"):
            fft_api.plan(kind="c2c", shape=(96,), tune=True,
                         wisdom_path=_wisdom(tmp_path), tune_config=cfg)


class TestOutOfCoreTuning:
    def test_round_trip_and_determinism(self, tmp_path):
        wp = _wisdom(tmp_path)
        s1, r1 = tuner.tune_out_of_core(1 << 24, 1 << 22, wisdom_path=wp)
        assert not r1.wisdom_hit and r1.measurements >= 1
        assert s1 in tuner.OOC_PANEL_SCALES
        s2, r2 = tuner.tune_out_of_core(1 << 24, 1 << 22, wisdom_path=wp)
        assert r2.wisdom_hit and r2.measurements == 0 and s2 == s1
        # fresh store, same model: same winner
        s3, _ = tuner.tune_out_of_core(
            1 << 24, 1 << 22, wisdom_path=_wisdom(tmp_path, "b.json"))
        assert s3 == s1

    def test_measurer_override_flips_winner(self, tmp_path):
        # a measurer that rewards SMALL panels (more jobs) inverts the
        # disk model's preference and must win + log the disagreement
        def like_small(factors, cfg):
            return 1.0 / (factors.pass1_jobs + factors.pass2_jobs)
        cfg = tuner.TuneConfig(measurer=like_small)
        s, rep = tuner.tune_out_of_core(
            1 << 24, 1 << 22, wisdom_path=_wisdom(tmp_path), config=cfg)
        assert s == max(c["knobs"]["panel_scale"] for c in rep.candidates)
        if len(rep.candidates) > 1:
            assert rep.disagreement
            assert events.events("tune_disagreement")


class TestServiceWarmup:
    def test_first_request_zero_plan_misses(self):
        from repro.serve.fft_service import FftService
        svc = FftService(coalesce=4)
        summary = svc.warmup([
            {"kind": "c2c", "shape": (64,), "rows": 2},
            ("r2c", (64,), 2),
        ])
        assert summary["specs"] == 2
        before = fft_api.cache_info()["misses"]
        with svc:
            t1 = svc.submit("c2c", np.ones((2, 64), np.float32),
                            np.zeros((2, 64), np.float32))
            t2 = svc.submit("r2c", np.ones((2, 64), np.float32))
            t1.result(timeout=60)
            t2.result(timeout=60)
        assert fft_api.cache_info()["misses"] == before
        want = np.fft.fft(np.ones((2, 64)))
        got_r, got_i = t1.result()
        np.testing.assert_allclose(np.asarray(got_r), want.real,
                                   atol=1e-3)

    def test_warmup_with_abft_covers_checksum_row(self):
        from repro.serve.fft_service import FftService
        svc = FftService(coalesce=2, verify="abft", impl="ref")
        svc.warmup([{"kind": "c2c", "shape": (64,), "rows": 2}])
        before = fft_api.cache_info()["misses"]
        with svc:
            t = svc.submit("c2c", np.ones((2, 64), np.float32),
                           np.zeros((2, 64), np.float32))
            t.result(timeout=60)
        assert fft_api.cache_info()["misses"] == before
