"""Spectral ops: fft_conv vs np.convolve, STFT, SpectralMixer."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import spectral


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([128, 500, 1024]), tk=st.sampled_from([3, 17, 64]),
       seed=st.integers(0, 50))
def test_fft_conv_matches_numpy(t, tk, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal(t).astype(np.float32)
    k = r.standard_normal(tk).astype(np.float32)
    got = np.asarray(spectral.fft_conv(jnp.asarray(x), jnp.asarray(k)))
    want = np.convolve(x, k)[:t]
    scale = np.abs(want).max() or 1.0
    assert np.abs(got - want).max() / scale < 1e-4


def test_fft_conv_batched(rng):
    x = rng.standard_normal((3, 256)).astype(np.float32)
    k = rng.standard_normal(16).astype(np.float32)
    got = np.asarray(spectral.fft_conv(jnp.asarray(x), jnp.asarray(k)))
    for i in range(3):
        want = np.convolve(x[i], k)[:256]
        assert np.abs(got[i] - want).max() / np.abs(want).max() < 1e-4


def test_stft_shapes_and_tone(rng):
    # a pure tone must concentrate energy in its bin
    n, frame, hop = 4096, 256, 128
    bin_idx = 32
    t = np.arange(n)
    x = np.cos(2 * np.pi * bin_idx * t / frame).astype(np.float32)
    ps = np.asarray(spectral.power_spectrogram(jnp.asarray(x), frame, hop))
    n_frames = 1 + (n - frame) // hop
    assert ps.shape == (n_frames, frame // 2 + 1)
    assert (ps.argmax(axis=-1) == bin_idx).mean() > 0.9


def test_spectral_mixer_matches_fnet_reference(rng):
    x = rng.standard_normal((2, 64, 32)).astype(np.float32)
    got = np.asarray(spectral.spectral_mixer(jnp.asarray(x)))
    want = np.fft.fft(np.fft.fft(x, axis=-1), axis=-2).real
    assert np.abs(got - want).max() / np.abs(want).max() < 1e-4


def test_frame_signal_strides(rng):
    x = rng.standard_normal(100).astype(np.float32)
    frames = np.asarray(spectral.frame_signal(jnp.asarray(x), 16, 8))
    assert frames.shape == (11, 16)
    np.testing.assert_array_equal(frames[1], x[8:24])
