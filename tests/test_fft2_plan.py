"""Axis-generic transform core: N-D specs, fft2/rfft2, pencil placement
(DESIGN.md §9).

Covers the tentpole claims:
  * N-D spec resolution: shape-tuple normalization, scalar-n sugar hits
    the SAME cache key, and the new plan-time ValueErrors (non-pow2 axes,
    r2c on a non-contiguous axis, pencil axes not divisible by D);
  * fft2/ifft2/rfft2/irfft2 match the numpy oracles at every placement
    this host can run, with the 2-D chain transpose-free in the traced
    program and in the byte counters;
  * the distributed pencil runs ONE exchange leg (collective_bytes), is
    bitwise-identical between overlap engines and — with matched kernel
    tiles — to the local plan;
  * the deprecated `ops` shims warn exactly once per entry point and
    never from the internal global_twiddle path.
"""

import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.fft as fft_api
from repro import compat
from repro.fft import spec as spec_mod
from repro.fft.spec import resolve_placement
from repro.kernels.fft import plan as kplan


def _rel_err(got_r, got_i, want):
    got = np.asarray(got_r) + 1j * np.asarray(got_i)
    scale = np.abs(want).max() or 1.0
    return float(np.abs(got - want).max() / scale)


@pytest.fixture(scope="module")
def mesh():
    return compat.make_mesh((jax.device_count(),), ("data",))


# ---------------------------------------------------------------------------
# N-D spec resolution (pure, device-count independent)


def _resolve(**kw):
    base = dict(kind="c2c", batch_shape=(), placement="auto",
                layout="zero_copy", impl="matfft", precision="f32",
                interpret=False, batch_tile=None, num_devices=None,
                axes=None, natural_order=True, fuse_twiddle=False)
    base.update(kw)
    return spec_mod.resolve(**base)


def test_shape_tuple_normalization():
    s = _resolve(n=1024)
    assert s.shape == (1024,) and s.ndim == 1 and s.n == 1024
    s = _resolve(shape=(64, 128))
    assert s.shape == (64, 128) and s.ndim == 2 and s.n == 64 * 128
    assert s.operand_shape == (64, 128)
    # an int shape is 1-D sugar too; list normalizes to a tuple
    assert _resolve(shape=256).shape == (256,)
    assert _resolve(shape=[32, 64]).shape == (32, 64)


def test_scalar_n_sugar_same_cache_key():
    fft_api.clear_plan_cache()
    p1 = fft_api.plan(kind="c2c", n=512, batch_shape=(2,))
    p2 = fft_api.plan(kind="c2c", shape=(512,), batch_shape=(2,))
    assert p2 is p1
    assert fft_api.cache_info()["hits"] == 1
    # and the resolved specs are equal, so the frozen dataclass hashes match
    assert _resolve(n=512) == _resolve(shape=(512,))


def test_exactly_one_of_n_and_shape():
    with pytest.raises(ValueError, match="exactly one"):
        _resolve(n=64, shape=(64,))
    with pytest.raises(ValueError, match="exactly one"):
        _resolve()


def test_non_pow2_axis_raises_naming_the_axis():
    with pytest.raises(ValueError, match=r"axis 1 of shape \(64, 96\)"):
        _resolve(shape=(64, 96))
    with pytest.raises(ValueError, match="axis 0"):
        _resolve(shape=(48, 64))
    with pytest.raises(ValueError, match="power of two"):
        fft_api.plan(kind="c2c", shape=(64, 96))


def test_r2c_non_contiguous_axis_raises():
    with pytest.raises(ValueError, match="contiguous"):
        _resolve(kind="r2c", shape=(64, 128), r2c_axis=0)
    with pytest.raises(ValueError, match="contiguous"):
        _resolve(kind="r2c", shape=(64, 128), r2c_axis=-2)
    # -1 and its positive alias are the supported (normalized) axis
    assert _resolve(kind="r2c", shape=(64, 128), r2c_axis=-1).kind == "r2c"
    assert _resolve(kind="r2c", shape=(64, 128), r2c_axis=1).kind == "r2c"
    with pytest.raises(ValueError, match="contiguous"):
        fft_api.plan(kind="r2c", shape=(64, 128), r2c_axis=0)


def test_pencil_axis_not_divisible_by_d_raises():
    with pytest.raises(ValueError, match="axis 0.*not divisible by D"):
        _resolve(shape=(4, 64), placement="distributed", num_devices=8,
                 axes=("data",))
    with pytest.raises(ValueError, match="axis 1.*not divisible by D"):
        _resolve(shape=(64, 4), placement="distributed", num_devices=8,
                 axes=("data",))
    with pytest.raises(ValueError, match="power-of-two device count"):
        _resolve(shape=(64, 64), placement="distributed", num_devices=6,
                 axes=("data",))


def test_pencil_axis0_leaf_cap_and_3d_rejected():
    with pytest.raises(ValueError, match="MAX_LEAF"):
        _resolve(shape=(2 * kplan.MAX_LEAF, 64), placement="distributed",
                 num_devices=8, axes=("data",))
    with pytest.raises(ValueError, match="3-D"):
        _resolve(shape=(8, 8, 8), placement="distributed", num_devices=8,
                 axes=("data",))


def test_local_nd_axis_caps():
    # contiguous axis gets MAX_LEAF**2; earlier axes a single kernel pass
    with pytest.raises(ValueError, match="MAX_LEAF"):
        _resolve(shape=(2 * kplan.MAX_LEAF, 64), placement="local")
    s = _resolve(shape=(64, 2 * kplan.MAX_LEAF), placement="local")
    assert s.placement == "local"


def test_pencil_normalizes_twiddle_knobs():
    s = _resolve(shape=(64, 64), placement="distributed", num_devices=8,
                 axes=("data",), natural_order=False, fuse_twiddle=True)
    # the pencil engine has no outer twiddle and is always natural-order
    assert s.natural_order is True and s.fuse_twiddle is False


def test_resolve_placement_2d():
    # no mesh -> local; too-big non-contiguous axis -> clear error
    assert resolve_placement((64, 64), 1, 0, None) == "local"
    with pytest.raises(ValueError, match="pass mesh"):
        resolve_placement((2 * kplan.MAX_LEAF, 64), 1, 0, None)
    # 1-D batch of images -> segmented (the paper's map-only regime)
    assert resolve_placement((64, 64), 16, 1, 8) == "segmented"
    assert resolve_placement((64, 64), 3, 1, 8) == "local"  # indivisible
    # single image, divisible axes -> pencil; indivisible -> local
    assert resolve_placement((64, 64), 1, 0, 8) == "distributed"
    assert resolve_placement((4, 64), 1, 0, 8) == "local"
    # 1-D behavior unchanged (regression)
    assert resolve_placement(1 << 20, 1, 0, 8) == "distributed"
    assert resolve_placement(1024, 16, 1, None) == "local"


def test_pencil_overlap_resolution():
    from repro.core.fft.distributed import (plan_pencil,
                                            resolve_overlap_pencil)
    # auto declines small images; explicit chunk counts are honoured but
    # must divide the exchange slab width n1/D
    assert resolve_overlap_pencil((64, 64), 8, "auto") is None
    assert resolve_overlap_pencil((16384, 16384), 8, "auto") == 4
    assert resolve_overlap_pencil((64, 64), 8, 4) == 4
    for bad in (0, -1, 3, 16, "weird", 2.5, True):
        with pytest.raises(ValueError, match="overlap"):
            resolve_overlap_pencil((64, 64), 8, bad)
    assert plan_pencil((64, 64), 8, chunks=4).chunks == 4
    # surfaces through spec resolution pre-cache-key
    with pytest.raises(ValueError, match="divide"):
        _resolve(shape=(64, 64), placement="distributed", num_devices=8,
                 axes=("data",), overlap=3)
    s = _resolve(shape=(64, 64), placement="distributed", num_devices=8,
                 axes=("data",), overlap="auto")
    assert s.overlap == "off"


# ---------------------------------------------------------------------------
# pencil cost accounting: ONE exchange leg


def test_pencil_plan_one_exchange_leg(mesh):
    d = jax.device_count()
    n0 = n1 = 64 * d
    p = fft_api.plan(kind="c2c", shape=(n0, n1), mesh=mesh,
                     placement="distributed", overlap="off")
    assert p.dist.n_exchanges == 1
    # total payload crosses ICI exactly once: 2 planes * 4 bytes * points
    assert p.collective_bytes == 2 * 4 * n0 * n1
    assert p.exposed_collective_bytes == p.collective_bytes
    p_on = fft_api.plan(kind="c2c", shape=(n0, n1), mesh=mesh,
                        placement="distributed", overlap=2)
    assert p_on.collective_bytes == p.collective_bytes
    assert p_on.exposed_collective_bytes * 2 == p_on.collective_bytes
    # vs the 1-D engine at the same point count: one leg, not three
    p1d = fft_api.plan(kind="c2c", n=n0 * n1, mesh=mesh,
                       placement="distributed", overlap="off")
    assert p1d.collective_bytes == 3 * p.collective_bytes


def test_fftn_byte_counters():
    shape = (128, 4096)
    zc = kplan.fftn_hbm_bytes(shape, "zero_copy")
    naive = kplan.fftn_hbm_bytes(shape, "copy")
    assert zc < naive
    # zero-copy: contiguous-axis pass + ONE col pass, no transpose bytes
    n = 128 * 4096
    assert zc == 128 * kplan.fft_hbm_bytes(4096) + 2 * 2 * 4 * n
    # naive: same passes + a swapaxes round-trip there and back
    assert naive == zc + 2 * (2 * 2 * 4 * n)
    # the plan folds them
    assert (fft_api.plan(kind="c2c", shape=shape).hbm_bytes_per_row == zc)
    assert (fft_api.plan(kind="c2c", shape=shape,
                         layout="copy").hbm_bytes_per_row == naive)
    # rfft2 undercuts the complex transform
    assert kplan.rfftn_hbm_bytes(shape) < zc
    assert (fft_api.plan(kind="r2c", shape=shape).hbm_bytes_per_row
            == kplan.rfftn_hbm_bytes(shape))


def test_fftn_flops_and_macs():
    p = fft_api.plan(kind="c2c", shape=(64, 256), batch_shape=(3,))
    n = 64 * 256
    assert p.flops_per_row == pytest.approx(5.0 * n * np.log2(n))
    assert p.flops == 3 * p.flops_per_row
    # per-axis GEMM sum: 64 rows of len-256 + 256 cols of len-64
    want = (64 * kplan.make_plan(256).gemm_macs
            + 256 * kplan.make_plan(64).gemm_macs)
    assert p.gemm_macs_per_row == want
    pr = fft_api.plan(kind="r2c", shape=(64, 256), batch_shape=(3,))
    assert pr.flops_per_row < p.flops_per_row
    assert pr.gemm_macs_per_row < p.gemm_macs_per_row
    assert not pr.fused_untangle  # N-D untangle is the deferred epilogue


# ---------------------------------------------------------------------------
# execution: local / segmented / pencil vs the numpy oracles


def test_fft2_local_and_roundtrip(rng):
    for shape in ((64, 64), (16, 1 << 15)):  # incl. level-1 contiguous axis
        xr = rng.standard_normal((2, *shape)).astype(np.float32)
        xi = rng.standard_normal((2, *shape)).astype(np.float32)
        p = fft_api.plan(kind="c2c", shape=shape, batch_shape=(2,))
        yr, yi = p.execute(jnp.asarray(xr), jnp.asarray(xi))
        assert _rel_err(yr, yi, np.fft.fft2(xr + 1j * xi)) < 5e-6
        br, bi = p.execute_inverse(yr, yi)
        assert float(jnp.abs(br - xr).max()) / np.abs(xr).max() < 1e-5
        p.execute(jnp.asarray(xr), jnp.asarray(xi))
        assert p.trace_counts["forward"] == 1


def test_rfft2_local_and_inverse(rng):
    x = rng.standard_normal((2, 64, 128)).astype(np.float32)
    sr, si = fft_api.rfft2(jnp.asarray(x))
    assert sr.shape == (2, 64, 65)
    assert _rel_err(sr, si, np.fft.rfft2(x)) < 5e-6
    back = fft_api.irfft2(sr, si)
    assert float(jnp.abs(back - x).max()) / np.abs(x).max() < 1e-5


def test_fft2_helpers_match_plan(rng):
    xr = rng.standard_normal((32, 64)).astype(np.float32)
    xi = rng.standard_normal((32, 64)).astype(np.float32)
    yr, yi = fft_api.fft2(jnp.asarray(xr), jnp.asarray(xi))
    p = fft_api.plan(kind="c2c", shape=(32, 64))
    wr, wi = p.execute(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(wr))
    br, bi = fft_api.ifft2(yr, yi)
    assert _rel_err(br, bi, (xr + 1j * xi).astype(np.complex64)) < 5e-6


def test_fft2_helpers_reject_1d_operands(rng):
    # numpy.fft.fft2 raises for <2-D input; the wrappers must not
    # silently plan a 1-D transform
    v = jnp.zeros((64,), jnp.float32)
    for fn in (lambda: fft_api.fft2(v, v), lambda: fft_api.ifft2(v, v),
               lambda: fft_api.rfft2(v), lambda: fft_api.irfft2(v, v)):
        with pytest.raises(ValueError, match="trailing TWO axes"):
            fn()


def test_fft3_local(rng):
    xr = rng.standard_normal((8, 16, 32)).astype(np.float32)
    xi = rng.standard_normal((8, 16, 32)).astype(np.float32)
    p = fft_api.plan(kind="c2c", shape=(8, 16, 32))
    yr, yi = p.execute(jnp.asarray(xr), jnp.asarray(xi))
    assert _rel_err(yr, yi, np.fft.fftn(xr + 1j * xi)) < 5e-6


def test_segmented_2d_c2c_and_r2c(mesh, rng):
    d = jax.device_count()
    xs = rng.standard_normal((2 * d, 32, 64)).astype(np.float32)
    ys = rng.standard_normal((2 * d, 32, 64)).astype(np.float32)
    p = fft_api.plan(kind="c2c", shape=(32, 64), batch_shape=(2 * d,),
                     mesh=mesh, placement="segmented")
    zr, zi = p.execute(jnp.asarray(xs), jnp.asarray(ys))
    assert _rel_err(zr, zi, np.fft.fft2(xs + 1j * ys)) < 5e-6
    pr = fft_api.plan(kind="r2c", shape=(32, 64), batch_shape=(2 * d,),
                      mesh=mesh, placement="segmented")
    sr, si = pr.execute_real(jnp.asarray(xs))
    assert _rel_err(sr, si, np.fft.rfft2(xs)) < 5e-6


def test_pencil_matches_numpy_and_engines_bitwise(mesh, rng):
    d = jax.device_count()
    n0 = n1 = 8 * d
    bt = n1 // d  # matched kernel tiles: local == pencil bitwise
    xr = rng.standard_normal((n0, n1)).astype(np.float32)
    xi = rng.standard_normal((n0, n1)).astype(np.float32)
    want = np.fft.fft2(xr + 1j * xi)

    p_off = fft_api.plan(kind="c2c", shape=(n0, n1), mesh=mesh,
                         placement="distributed", overlap="off",
                         batch_tile=bt)
    yr0, yi0 = p_off.execute(jnp.asarray(xr), jnp.asarray(xi))
    assert _rel_err(yr0, yi0, want) < 5e-6
    p_off.execute(jnp.asarray(xr), jnp.asarray(xi))
    assert p_off.trace_counts["forward"] == 1

    p_on = fft_api.plan(kind="c2c", shape=(n0, n1), mesh=mesh,
                        placement="distributed", overlap=2, batch_tile=bt)
    yr1, yi1 = p_on.execute(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_array_equal(np.asarray(yr1), np.asarray(yr0))
    np.testing.assert_array_equal(np.asarray(yi1), np.asarray(yi0))

    p_loc = fft_api.plan(kind="c2c", shape=(n0, n1), placement="local",
                         batch_tile=bt)
    lr, li = p_loc.execute(jnp.asarray(xr), jnp.asarray(xi))
    np.testing.assert_array_equal(np.asarray(yr0), np.asarray(lr))
    np.testing.assert_array_equal(np.asarray(yi0), np.asarray(li))

    # inverse roundtrip through the pencil plan (always natural-order)
    br, bi = p_off.execute_inverse(yr0, yi0)
    assert float(jnp.abs(br - xr).max()) / np.abs(xr).max() < 1e-5


def test_pencil_r2c_slice_path(mesh, rng):
    d = jax.device_count()
    n0 = n1 = 8 * d
    x = rng.standard_normal((n0, n1)).astype(np.float32)
    p = fft_api.plan(kind="r2c", shape=(n0, n1), mesh=mesh,
                     placement="distributed", overlap="off")
    sr, si = p.execute_real(jnp.asarray(x))
    assert sr.shape == (n0, n1 // 2 + 1)
    assert _rel_err(sr, si, np.fft.rfft2(x)) < 5e-6
    assert p.dist.n_exchanges == 1


def test_fftn_traced_program_is_transpose_free(rng):
    """The zero-copy 2-D chain is reshapes + pallas_calls only; the naive
    layout must still show its transposes (it's the measured baseline)."""
    from repro.fft import executors as ex
    a = jnp.zeros((2, 64, 128), jnp.float32)

    def prims(layout):
        fn = lambda xr, xi: ex.fftn(xr, xi, (64, 128), layout=layout)  # noqa: E731
        return [str(e.primitive) for e in jax.make_jaxpr(fn)(a, a).eqns]

    zc = prims("zero_copy")
    assert zc.count("pallas_call") == 2  # one per axis pass
    assert "transpose" not in zc, zc
    assert "transpose" in prims("copy")

    # rfftn: pack kernel + col pass + vectorized untangle, still no
    # materialized transpose
    fn = lambda x: ex.rfftn(x, (64, 128))  # noqa: E731
    rz = [str(e.primitive)
          for e in jax.make_jaxpr(fn)(a[0]).eqns]
    assert "transpose" not in rz, rz


def test_fft_conv2d_matches_direct(rng):
    from repro.core.spectral import fft_conv2d
    x = rng.standard_normal((2, 24, 30)).astype(np.float32)
    k = rng.standard_normal((5, 7)).astype(np.float32)
    got = np.asarray(fft_conv2d(jnp.asarray(x), jnp.asarray(k)))
    # direct full 2-D convolution, cropped to the leading h x w window
    want = np.zeros_like(x)
    h, w = x.shape[-2:]
    for b in range(x.shape[0]):
        full = np.zeros((h + 4, w + 6), np.float64)
        for i in range(5):
            for j in range(7):
                full[i:i + h, j:j + w] += k[i, j] * x[b].astype(np.float64)
        want[b] = full[:h, :w]
    assert np.abs(got - want).max() / np.abs(want).max() < 5e-6


# ---------------------------------------------------------------------------
# deprecation warnings: once per shim entry point, never from internals


def test_ops_shims_warn_once_per_entry_point(rng):
    from repro.kernels.fft import ops
    ops._reset_deprecation_warnings()
    x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    z = jnp.zeros_like(x)

    calls = {
        "fft": lambda: ops.fft(x, z),
        "ifft": lambda: ops.ifft(x, z),
        "rfft": lambda: ops.rfft(x),
        "irfft": lambda: ops.irfft(x[:, :33], z[:, :33]),
    }
    for name, call in calls.items():
        with pytest.warns(DeprecationWarning, match=f"ops.{name} is"):
            call()
        # exactly once: the second call must NOT warn
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            call()
        assert not [w for w in rec
                    if issubclass(w.category, DeprecationWarning)
                    and "repro.kernels.fft.ops" in str(w.message)], name


def test_ops_internal_global_twiddle_never_warns(rng):
    from repro.kernels.fft import ops
    ops._reset_deprecation_warnings()
    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops.fft(x, jnp.zeros_like(x),
                global_twiddle=(4096, jnp.zeros((1,), jnp.int32)))
        ops.fft_cols(x, jnp.zeros_like(x))  # layout-level internal
    assert not [w for w in rec
                if issubclass(w.category, DeprecationWarning)
                and "repro.kernels.fft.ops" in str(w.message)]
    # ...and the set is still clean, so a later public call warns fresh
    with pytest.warns(DeprecationWarning, match="ops.fft is"):
        ops.fft(x, jnp.zeros_like(x))
