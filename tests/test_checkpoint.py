"""Checkpoint manager: atomicity, commit markers, GC, async, resume."""

import json
import shutil

import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(x=0.0):
    return {"a": jnp.full((4, 4), 1.0 + x), "b": {"c": jnp.full((2,), 2.0 + x)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(3, t, tmp_path)
    got = restore(tmp_path, 3, t)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_latest_ignores_torn_checkpoint(tmp_path):
    save(1, _tree(), tmp_path)
    save(2, _tree(), tmp_path)
    # simulate a crash mid-save of step 3: directory without COMMIT
    torn = tmp_path / "step_00000003"
    shutil.copytree(tmp_path / "step_00000002", torn)
    (torn / "COMMIT").unlink()
    assert latest_step(tmp_path) == 2


def test_shape_mismatch_raises(tmp_path):
    save(1, _tree(), tmp_path)
    bad = {"a": jnp.zeros((5, 5)), "b": {"c": jnp.zeros((2,))}}
    with pytest.raises(ValueError, match="shape"):
        restore(tmp_path, 1, bad)


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    mgr._gc()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]


def test_async_save_then_restore_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=3)
    mgr.save_async(7, _tree(0.5))
    mgr.wait()
    step, got = mgr.restore_latest(_tree())
    assert step == 7
    assert float(got["a"][0, 0]) == 1.5
