"""Fault-tolerance behaviour: retries, speculation, resume, replicas."""

import time

import pytest

from repro.core.pipeline import BlockStore, JobConfig, MapOnlyJob


def _store(tmp_path, blocks=6, replication=1):
    store = BlockStore(tmp_path / "in", block_bytes=64,
                       replication=replication)
    store.put_bytes(bytes(64 * blocks))
    return store


def test_retry_then_succeed(tmp_path):
    store = _store(tmp_path)
    fails = {"n": 0}

    def flaky(data, idx):
        if idx == 2 and fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("injected")
        return data

    job = MapOnlyJob(store, tmp_path / "out", flaky,
                     JobConfig(workers=2, max_retries=5))
    stats = job.run()
    assert stats.blocks_done == 6
    assert stats.retries == 2


def test_poisoned_block_fails_job_after_budget(tmp_path):
    store = _store(tmp_path)

    def poison(data, idx):
        if idx == 1:
            raise RuntimeError("always fails")
        return data

    job = MapOnlyJob(store, tmp_path / "out", poison,
                     JobConfig(workers=2, max_retries=3))
    with pytest.raises(RuntimeError, match="block 1 failed 3 times"):
        job.run()
    # other blocks still completed and are resumable
    assert job.manifest.tasks[1].status == "FAILED"


def test_crash_resume_skips_done_blocks(tmp_path):
    store = _store(tmp_path)
    job = MapOnlyJob(store, tmp_path / "out", lambda b, i: b,
                     JobConfig(workers=2))
    job.run()
    # a restarted job re-reads the manifest and has nothing to do
    job2 = MapOnlyJob(store, tmp_path / "out", lambda b, i: b,
                      JobConfig(workers=2))
    stats = job2.run()
    assert stats.attempts == 0


def test_running_state_resets_to_pending_on_reopen(tmp_path):
    store = _store(tmp_path)
    job = MapOnlyJob(store, tmp_path / "out", lambda b, i: b)
    job.manifest.update(3, status="RUNNING")  # simulate crash mid-task
    job2 = MapOnlyJob(store, tmp_path / "out", lambda b, i: b,
                      JobConfig(workers=2))
    assert 3 in job2.manifest.pending()


def test_speculative_execution_fires(tmp_path):
    store = _store(tmp_path, blocks=8)

    def slow_tail(data, idx):
        time.sleep(0.6 if idx == 7 else 0.01)
        return data

    job = MapOnlyJob(store, tmp_path / "out", slow_tail,
                     JobConfig(workers=4, straggler_factor=3.0,
                               min_completed_for_speculation=3))
    stats = job.run()
    assert stats.blocks_done == 8
    assert stats.speculative_launches >= 1


def test_replica_fallback_on_corruption(tmp_path):
    store = _store(tmp_path, replication=2)
    good = store.read_block(0)
    store.corrupt_block(0, replica=0)
    assert store.read_block(0) == good  # checksum catches, replica serves


def test_all_replicas_corrupt_raises(tmp_path):
    store = _store(tmp_path, replication=2)
    store.corrupt_block(0, replica=0)
    store.corrupt_block(0, replica=1)
    with pytest.raises(IOError):
        store.read_block(0)


def test_idempotent_output_writes(tmp_path):
    """Two attempts writing the same block must be benign (speculation)."""
    store = _store(tmp_path)
    store.write_output_block(tmp_path / "out", 0, b"x" * 64)
    store.write_output_block(tmp_path / "out", 0, b"x" * 64)
    files = list((tmp_path / "out").glob("block_*.bin"))
    assert len(files) == 1
