"""Collective-byte accounting from HLO text (pure parsing, no jax)."""

from repro.launch.hlo_analysis import collective_stats, _shape_bytes


HLO = """
HloModule test
  %ag = bf16[64,1024]{1,0} all-gather(bf16[4,1024]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[1024,1024]{1,0} all-reduce(f32[1024,1024]{1,0} %y), replica_groups=[16,16]<=[256], to_apply=%add
  %rs = f32[64,1024]{1,0} reduce-scatter(f32[1024,1024]{1,0} %z), replica_groups={{0,1}}, dimensions={0}
  %a2a = bf16[8,128]{1,0} all-to-all(bf16[8,128]{1,0} %w), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[256]{0} collective-permute(f32[256]{0} %v), source_target_pairs={{0,1}}
  %ags = bf16[32]{0} all-gather-start(bf16[2]{0} %q), replica_groups={{0,1}}
  %agd = bf16[32]{0} all-gather-done(bf16[32]{0} %ags)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[64,1024]") == 64 * 1024 * 2
    assert _shape_bytes("f32[256]") == 1024
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_collective_stats_counts_and_bytes():
    s = collective_stats(HLO)
    assert s["all-gather"]["count"] == 2     # ag + ag-start (done not counted)
    assert s["all-reduce"]["count"] == 1
    assert s["reduce-scatter"]["count"] == 1
    assert s["all-to-all"]["count"] == 1
    assert s["collective-permute"]["count"] == 1

    # all-gather: (N-1)/N * result bytes, N=16
    ag = 64 * 1024 * 2
    assert abs(s["all-gather"]["bytes"]
               - (15 / 16 * ag + 1 / 2 * 32 * 2)) < 1e-6
    # all-reduce: 2*(N-1)/N * bytes, N=16 (iota form)
    ar = 1024 * 1024 * 4
    assert abs(s["all-reduce"]["bytes"] - 2 * 15 / 16 * ar) < 1e-6
    # reduce-scatter: (N-1) * result bytes, N=2
    rs = 64 * 1024 * 4
    assert abs(s["reduce-scatter"]["bytes"] - rs) < 1e-6
    # collective-permute: full operand
    assert s["collective-permute"]["bytes"] == 1024
    assert s["total_bytes"] > 0


def test_empty_module():
    s = collective_stats("HloModule empty\n ROOT %r = f32[2]{0} add(...)")
    assert s["total_bytes"] == 0
