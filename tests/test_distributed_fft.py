"""Multi-device distributed FFT + segmented map-only invariants.

Device count is locked at first backend init, so multi-device cases run in
a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro import compat
    from repro.core.fft.distributed import distributed_fft, distributed_ifft, plan_distributed
    from repro.core.fft.segmented import segmented_fft
    from repro.kernels.fft import ops as fft_ops
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    out = {}

    # distributed vs numpy across lengths (>= D^2 = 64)
    errs = {}
    for n in [64, 4096, 65536]:
        x = rng.standard_normal(n).astype(np.float32)
        y = rng.standard_normal(n).astype(np.float32)
        yr, yi = distributed_fft(jnp.asarray(x), jnp.asarray(y), mesh)
        want = np.fft.fft(x + 1j * y)
        scale = np.abs(want).max()
        errs[n] = float(max(np.abs(np.asarray(yr) - want.real).max(),
                            np.abs(np.asarray(yi) - want.imag).max()) / scale)
    out["dist_errs"] = errs

    # roundtrip
    x = rng.standard_normal(4096).astype(np.float32)
    y = rng.standard_normal(4096).astype(np.float32)
    fr, fi = distributed_fft(jnp.asarray(x), jnp.asarray(y), mesh)
    br, bi = distributed_ifft(fr, fi, mesh)
    out["roundtrip_err"] = float(max(np.abs(np.asarray(br) - x).max(),
                                     np.abs(np.asarray(bi) - y).max()))

    # plan constraint: n < D^2 must raise
    try:
        plan_distributed(32, 8)
        out["plan_raises"] = False
    except ValueError:
        out["plan_raises"] = True

    # segmented (map-only): correct AND zero collectives in compiled HLO
    xs = rng.standard_normal((16, 512)).astype(np.float32)
    ys = rng.standard_normal((16, 512)).astype(np.float32)
    zr, zi = segmented_fft(jnp.asarray(xs), jnp.asarray(ys), mesh,
                           batch_axes=("data", "model"))
    want = np.fft.fft(xs + 1j * ys, axis=-1)
    out["seg_err"] = float(np.abs((np.asarray(zr) + 1j * np.asarray(zi))
                                  - want).max() / np.abs(want).max())
    sh = NamedSharding(mesh, P(("data", "model"), None))
    spec = P(("data", "model"), None)
    inner = compat.shard_map(lambda a, b: fft_ops.fft(a, b), mesh=mesh,
                             in_specs=(spec, spec), out_specs=(spec, spec),
                             check_vma=False)
    txt = jax.jit(inner, in_shardings=(sh, sh), out_shardings=(sh, sh)).lower(
        jax.ShapeDtypeStruct((16, 512), jnp.float32),
        jax.ShapeDtypeStruct((16, 512), jnp.float32)).compile().as_text()
    out["seg_collectives"] = sum(
        txt.count(k) for k in ("all-gather(", "all-reduce(", "all-to-all(",
                               "collective-permute(", "reduce-scatter("))

    # distributed (cross-device) DOES use all-to-alls: count them
    lowered = jax.jit(lambda a, b: distributed_fft(a, b, mesh)).lower(
        jax.ShapeDtypeStruct((4096,), jnp.float32),
        jax.ShapeDtypeStruct((4096,), jnp.float32))
    out["dist_a2a"] = lowered.compile().as_text().count("all-to-all")

    # ---- overlapped exchange engine (chunked ppermute pipeline) ----
    import repro.fft as fft_api
    n = 4096  # n1 = n2 = 64, n1l = n2l = 8 on the 8-device mesh
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    xj, yj = jnp.asarray(x), jnp.asarray(y)

    # bitwise parity vs the monolithic path: every chunk count incl. the
    # degenerate 1 and the maximal n2l (single-column slabs), natural
    # order both ways, fuse_twiddle both ways
    parity = {}
    cases = ([(True, False, k) for k in (1, 4, 8)]
             + [(True, True, k) for k in (4, 8)]
             + [(False, False, 4), (False, True, 4)])
    base = {}
    for natural, fuse, k in cases:
        if (natural, fuse) not in base:
            br, bi = distributed_fft(xj, yj, mesh, natural_order=natural,
                                     fuse_twiddle=fuse, overlap="off")
            base[(natural, fuse)] = (np.asarray(br), np.asarray(bi))
        br, bi = base[(natural, fuse)]
        zr, zi = distributed_fft(xj, yj, mesh, natural_order=natural,
                                 fuse_twiddle=fuse, overlap=k)
        parity[f"nat={natural},fuse={fuse},chunks={k}"] = bool(
            (np.asarray(zr) == br).all() and (np.asarray(zi) == bi).all())
    out["overlap_parity"] = parity

    # zero retrace on repeat execute of an overlapped plan, and the
    # exposed-vs-total collective byte split
    p_on = fft_api.plan(kind="c2c", n=n, mesh=mesh,
                        placement="distributed", overlap=4)
    p_on.execute(xj, yj); p_on.execute(xj, yj)
    out["overlap_traces"] = p_on.trace_counts["forward"]
    out["overlap_exposed"] = p_on.exposed_collective_bytes
    out["overlap_total"] = p_on.collective_bytes

    # the overlapped engine compiles to collective-permutes, no all-to-all
    txt = jax.jit(lambda a, b: p_on.execute(a, b)).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32)).compile().as_text()
    out["overlap_a2a"] = txt.count("all-to-all")
    out["overlap_ppermute"] = txt.count("collective-permute")

    # overlapped inverse roundtrip through the cached plan's
    # execute_inverse (distributed_ifft no longer re-enters the facade)
    fr, fi = distributed_fft(xj, yj, mesh, overlap=4)
    br, bi = distributed_ifft(fr, fi, mesh, overlap=4)
    out["overlap_roundtrip_err"] = float(
        max(np.abs(np.asarray(br) - x).max(),
            np.abs(np.asarray(bi) - y).max()))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_distributed_matches_numpy(results):
    for n, err in results["dist_errs"].items():
        assert err < 5e-6, (n, err)


def test_distributed_roundtrip(results):
    assert results["roundtrip_err"] < 1e-4


def test_plan_rejects_too_small(results):
    assert results["plan_raises"]


def test_segmented_correct_and_collective_free(results):
    """The paper's map-only property: zero reduce/exchange ops compiled."""
    assert results["seg_err"] < 5e-6
    assert results["seg_collectives"] == 0


def test_distributed_uses_all_to_all(results):
    assert results["dist_a2a"] >= 3  # two transposes + natural-order pass


def test_overlap_bitwise_parity(results):
    """Chunked ppermute rounds are pure data movement around the identical
    slab kernels: every overlap config must match the monolithic
    all_to_all path bit for bit."""
    assert all(results["overlap_parity"].values()), results["overlap_parity"]


def test_overlap_zero_retrace_and_exposed_bytes(results):
    assert results["overlap_traces"] == 1
    # chunks=4 exposes exactly a quarter of the collective payload
    assert results["overlap_exposed"] * 4 == results["overlap_total"]


def test_overlap_compiles_to_ppermutes(results):
    """The overlapped engine replaces every all_to_all with ppermute
    rounds: 3 exchanges x 4 chunks x (D-1)=7 rounds x 2 planes."""
    assert results["overlap_a2a"] == 0
    assert results["overlap_ppermute"] >= 3 * 4 * 7


def test_overlap_inverse_roundtrip(results):
    assert results["overlap_roundtrip_err"] < 1e-4
