"""End-to-end system behaviour: the paper's full workflow + LM serving."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 block_of_segments, segments_of_block)
from repro.core.pipeline.records import segment_block_bytes
from repro.kernels.fft import ops as fft_ops
from repro.models.transformer import TransformerLM
from repro.serve import greedy_generate
from repro.sharding.rules import init_params


def test_paper_workflow_end_to_end(tmp_path, rng):
    """Figure 1 flow: put -> map-only batched FFT -> direct writes ->
    getmerge; the merged output must equal numpy's FFT of the whole file."""
    fft_len, nseg = 256, 64
    sig = (rng.standard_normal((nseg, fft_len))
           + 1j * rng.standard_normal((nseg, fft_len))).astype(np.complex64)
    inter = np.stack([sig.real, sig.imag], -1).astype(np.float32).tobytes()

    store = BlockStore(tmp_path / "in",
                       block_bytes=segment_block_bytes(fft_len, 8),
                       replication=2)
    store.put_bytes(inter)
    assert len(store.blocks) == 8  # 64 segments / 8 per block

    def map_fn(data, idx):
        re, im = segments_of_block(data, fft_len)
        yr, yi = fft_ops.fft(jnp.asarray(re), jnp.asarray(im))
        return block_of_segments(np.asarray(yr), np.asarray(yi))

    job = MapOnlyJob(store, tmp_path / "out", map_fn, JobConfig(workers=4))
    stats = job.run()
    assert stats.blocks_done == 8
    job.merge(tmp_path / "merged.bin")

    got = np.frombuffer((tmp_path / "merged.bin").read_bytes(),
                        np.float32).reshape(-1, fft_len, 2)
    got_c = got[..., 0] + 1j * got[..., 1]
    want = np.fft.fft(sig, axis=-1)
    assert np.abs(got_c - want).max() / np.abs(want).max() < 5e-6


def test_prefill_decode_consistency_dense(rng):
    """Stepwise decode from a prefill must reproduce the full forward."""
    cfg = get_config("qwen3-0.6b").reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    S, K = 24, 4
    toks = rng.integers(1, cfg.vocab_size, (2, S + K))
    full = np.asarray(model.forward(params, {"tokens": jnp.asarray(toks)}))
    lg, caches = model.prefill(params, {"tokens": jnp.asarray(toks[:, :S])},
                               cache_len=S + K)
    errs = [np.abs(np.asarray(lg)[:, 0] - full[:, S - 1]).max()]
    for t in range(K - 1):
        lg, caches = model.decode_step(
            params, caches, jnp.asarray(toks[:, S + t:S + t + 1]),
            jnp.int32(S + t))
        errs.append(np.abs(np.asarray(lg)[:, 0] - full[:, S + t]).max())
    assert max(errs) / np.abs(full).max() < 1e-4


def test_greedy_generation_runs(rng):
    cfg = get_config("qwen2-0.5b").reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 8)))}
    out = greedy_generate(model, params, batch, 5)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.vocab_size
