"""Stream executor behaviour: output parity, coalescing, faults, journal.

The contract under test (core/pipeline/stream.py): the overlapped pipeline
is a drop-in for the serial map loop — bitwise-identical merged output
(including coalesced batches + the remainder tail), the same retry /
speculation / crash-restart semantics, and exactly two cached plans for a
coalesced run (full batch + tail) with zero retraces.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 SegmentFFTTransform, StagingPool)
from repro.core.pipeline.maponly import Manifest, TaskState
from repro.core.pipeline.records import (block_of_segments,
                                         segment_block_bytes,
                                         segments_of_block)
import repro.fft as fft_api

FFT_LEN = 128
SEG_PER_BLOCK = 16


def _signal_store(tmp_path, blocks=6, replication=1):
    rng = np.random.default_rng(7)
    sig = rng.standard_normal(
        (SEG_PER_BLOCK * blocks, FFT_LEN, 2)).astype(np.float32)
    store = BlockStore(tmp_path / "in",
                       block_bytes=segment_block_bytes(FFT_LEN, SEG_PER_BLOCK),
                       replication=replication)
    store.put_bytes(sig.tobytes())
    assert len(store.blocks) == blocks
    return store


def _serial_map_fn(data, idx):
    re, im = segments_of_block(data, FFT_LEN)
    p = fft_api.plan(kind="c2c", n=FFT_LEN, batch_shape=re.shape[:-1],
                     impl="ref")
    yr, yi = p.execute(re, im)
    return block_of_segments(np.asarray(yr), np.asarray(yi))


def _run_serial(store, tmp_path):
    job = MapOnlyJob(store, tmp_path / "out_serial", _serial_map_fn,
                     JobConfig(workers=2))
    job.run()
    job.merge(tmp_path / "serial.bin")
    return (tmp_path / "serial.bin").read_bytes()


# ---------------------------------------------------------------------------
# bitwise parity + coalescing


def test_stream_bitwise_identical_with_tail(tmp_path):
    """coalesce=4 over 6 blocks -> one full batch + one remainder tail."""
    store = _signal_store(tmp_path, blocks=6)
    expect = _run_serial(store, tmp_path)

    job = MapOnlyJob(store, tmp_path / "out_stream",
                     transform=SegmentFFTTransform(FFT_LEN, impl="ref"),
                     # speculation off: a scheduling-stall twin would add
                     # an extra batch and break the exact counts below
                     config=JobConfig(coalesce=4, inflight=2,
                                      speculation=False),
                     pipelined=True)
    stats = job.run()
    job.merge(tmp_path / "stream.bin")
    assert (tmp_path / "stream.bin").read_bytes() == expect
    assert stats.blocks_done == 6
    assert stats.batches == 2  # 4-block batch + 2-block tail
    assert stats.coalesced_blocks == 4
    assert all(v >= 0 for v in stats.stage_s.values())
    # journal fd released after the run (incl. the late-finisher drain)
    assert job.manifest._fh is None


def test_stream_mapfn_path_identical(tmp_path):
    """pipelined=True with a classic map_fn matches the serial output."""
    store = _signal_store(tmp_path, blocks=5)
    expect = _run_serial(store, tmp_path)
    job = MapOnlyJob(store, tmp_path / "out_mapfn", _serial_map_fn,
                     JobConfig(), pipelined=True)
    stats = job.run()
    job.merge(tmp_path / "mapfn.bin")
    assert (tmp_path / "mapfn.bin").read_bytes() == expect
    assert stats.blocks_done == 5
    assert stats.batches == 5  # opaque bytes never coalesce


def test_coalescing_uses_exactly_two_plans_zero_retrace(tmp_path):
    """8 = 4+4 blocks -> ONE cached plan; 6 = 4+2 -> full + tail plans.

    Each plan must be traced exactly once however many batches reuse it
    (the cufftPlanMany amortization the stream dispatcher exists to feed).
    """
    store = _signal_store(tmp_path, blocks=8)
    fft_api.clear_plan_cache()
    job = MapOnlyJob(store, tmp_path / "out",
                     transform=SegmentFFTTransform(FFT_LEN, impl="ref"),
                     config=JobConfig(coalesce=4, inflight=2,
                                      speculation=False),
                     pipelined=True)
    job.run()
    info = fft_api.cache_info()
    assert info["size"] == 1, info  # both batches share the full plan
    full = fft_api.plan(kind="c2c", n=FFT_LEN,
                        batch_shape=(4 * SEG_PER_BLOCK,), impl="ref")
    assert full.trace_counts["forward"] == 1

    store2 = _signal_store(tmp_path / "t2", blocks=6)
    fft_api.clear_plan_cache()
    job2 = MapOnlyJob(store2, tmp_path / "out2",
                      transform=SegmentFFTTransform(FFT_LEN, impl="ref"),
                      config=JobConfig(coalesce=4, inflight=2,
                                       speculation=False),
                      pipelined=True)
    job2.run()
    info = fft_api.cache_info()
    assert info["size"] == 2, info  # full batch + remainder tail
    for rows in (4 * SEG_PER_BLOCK, 2 * SEG_PER_BLOCK):
        p = fft_api.plan(kind="c2c", n=FFT_LEN, batch_shape=(rows,),
                         impl="ref")
        assert p.trace_counts["forward"] == 1, (rows, p.trace_counts)


# ---------------------------------------------------------------------------
# fault tolerance


class _FlakyEncodeTransform(SegmentFFTTransform):
    """Writeback-stage fault injection: encode of one block fails once."""

    def __init__(self, *a, fail_index: int, **kw):
        super().__init__(*a, **kw)
        self.fail_index = fail_index
        self.fails = 0

    def encode(self, host, row0, d):
        if d.index == self.fail_index and self.fails < 1:
            self.fails += 1
            raise RuntimeError("injected writeback failure")
        return super().encode(host, row0, d)


class _FlakyDecodeTransform(SegmentFFTTransform):
    """Read-stage fault injection: decode of one block fails twice."""

    def __init__(self, *a, fail_index: int, **kw):
        super().__init__(*a, **kw)
        self.fail_index = fail_index
        self.fails = 0

    def decode(self, data, index):
        if index == self.fail_index and self.fails < 2:
            self.fails += 1
            raise RuntimeError("injected decode failure")
        return super().decode(data, index)


def test_midstream_writeback_failure_retries(tmp_path):
    store = _signal_store(tmp_path, blocks=6)
    expect = _run_serial(store, tmp_path)
    tr = _FlakyEncodeTransform(FFT_LEN, impl="ref", fail_index=3)
    job = MapOnlyJob(store, tmp_path / "out", transform=tr,
                     config=JobConfig(coalesce=4, inflight=2, max_retries=3,
                                      speculation=False),
                     pipelined=True)
    stats = job.run()
    job.merge(tmp_path / "m.bin")
    assert (tmp_path / "m.bin").read_bytes() == expect
    assert tr.fails == 1
    assert stats.retries == 1
    assert stats.blocks_done == 6


def test_midstream_decode_failure_retries(tmp_path):
    store = _signal_store(tmp_path, blocks=6)
    expect = _run_serial(store, tmp_path)
    tr = _FlakyDecodeTransform(FFT_LEN, impl="ref", fail_index=1)
    job = MapOnlyJob(store, tmp_path / "out", transform=tr,
                     config=JobConfig(coalesce=3, max_retries=5,
                                      speculation=False),
                     pipelined=True)
    stats = job.run()
    job.merge(tmp_path / "m.bin")
    assert (tmp_path / "m.bin").read_bytes() == expect
    assert stats.retries == 2


def test_realize_failure_releases_staging_and_retries(tmp_path):
    """Device errors surface at realize (async dispatch); each transient
    failure must return its staging set to the pool or the dispatcher
    starves after capacity leaks (inflight+2 sets)."""
    store = _signal_store(tmp_path, blocks=8)
    expect = _run_serial(store, tmp_path)

    class Boom:
        def __array__(self, *a, **k):
            raise RuntimeError("injected realize failure")

    class FlakyRealize(SegmentFFTTransform):
        fails = 0

        def realize(self, handle):
            if self.fails < 5:  # > pool capacity for inflight=1
                self.fails += 1
                (_, _), batch = handle
                # np.asarray raises INSIDE the base realize: the finally
                # there must still return `batch` to the pool
                return super().realize(((Boom(), Boom()), batch))
            return super().realize(handle)

    tr = FlakyRealize(FFT_LEN, impl="ref")
    job = MapOnlyJob(store, tmp_path / "out", transform=tr,
                     config=JobConfig(coalesce=2, inflight=1, max_retries=9,
                                      speculation=False),
                     pipelined=True)
    stats = job.run()
    job.merge(tmp_path / "m.bin")
    assert (tmp_path / "m.bin").read_bytes() == expect
    assert tr.fails == 5
    assert stats.blocks_done == 8


def test_launch_failure_discards_batch_and_retries(tmp_path):
    """A launch that dies after gather must discard the gathered staging
    (it has no realize to release it) — repeated failures would otherwise
    deadlock the pool."""
    store = _signal_store(tmp_path, blocks=8)
    expect = _run_serial(store, tmp_path)

    class FlakyLaunch(SegmentFFTTransform):
        fails = 0

        def launch(self, batch):
            if self.fails < 5:  # > pool capacity for inflight=1
                self.fails += 1
                raise RuntimeError("injected launch failure")
            return super().launch(batch)

    tr = FlakyLaunch(FFT_LEN, impl="ref")
    job = MapOnlyJob(store, tmp_path / "out", transform=tr,
                     config=JobConfig(coalesce=2, inflight=1, max_retries=9,
                                      speculation=False),
                     pipelined=True)
    stats = job.run()
    job.merge(tmp_path / "m.bin")
    assert (tmp_path / "m.bin").read_bytes() == expect
    assert tr.fails == 5
    assert stats.blocks_done == 8


def test_stream_poisoned_block_fails_job(tmp_path):
    store = _signal_store(tmp_path, blocks=4)
    tr = _FlakyDecodeTransform(FFT_LEN, impl="ref", fail_index=2)
    tr.fails = -10**9  # never stops failing
    job = MapOnlyJob(store, tmp_path / "out", transform=tr,
                     config=JobConfig(coalesce=2, max_retries=3),
                     pipelined=True)
    with pytest.raises(RuntimeError, match="block 2 failed 3 times"):
        job.run()
    assert job.manifest.tasks[2].status == "FAILED"


def test_stream_resume_skips_done_blocks(tmp_path):
    store = _signal_store(tmp_path, blocks=6)
    kwargs = dict(transform=SegmentFFTTransform(FFT_LEN, impl="ref"),
                  config=JobConfig(coalesce=4), pipelined=True)
    MapOnlyJob(store, tmp_path / "out", **kwargs).run()
    stats = MapOnlyJob(store, tmp_path / "out", **kwargs).run()
    assert stats.attempts == 0  # manifest remembers DONE across restarts


def test_stream_speculation_fires(tmp_path):
    store = _signal_store(tmp_path, blocks=8)

    class SlowTail(SegmentFFTTransform):
        def encode(self, host, row0, d):
            time.sleep(0.8 if d.index == 7 else 0.005)
            return super().encode(host, row0, d)

    job = MapOnlyJob(store, tmp_path / "out",
                     transform=SlowTail(FFT_LEN, impl="ref"),
                     config=JobConfig(coalesce=1, inflight=4, writers=3,
                                      straggler_factor=2.0,
                                      min_completed_for_speculation=3),
                     pipelined=True)
    stats = job.run()
    assert stats.blocks_done == 8
    assert stats.speculative_launches >= 1


def test_mapfn_straggler_rescued_by_speculation(tmp_path):
    """A hung map_fn must not block the dispatcher: launch goes through
    the MapFnTransform compute pool, so a speculative twin completes the
    block and the job finishes while the primary is still stuck."""
    store = _signal_store(tmp_path, blocks=8)
    release = threading.Event()
    seen: list[int] = []

    def hang_once(data, idx):
        seen.append(idx)
        if idx == 5 and seen.count(5) == 1:
            release.wait(timeout=30)  # primary attempt hangs
        return data

    job = MapOnlyJob(store, tmp_path / "out", hang_once,
                     JobConfig(straggler_factor=2.0,
                               min_completed_for_speculation=3,
                               poll_interval_s=0.01),
                     pipelined=True)
    stats = job.run()
    release.set()  # unblock the abandoned primary thread
    assert stats.blocks_done == 8
    assert stats.speculative_launches >= 1
    job.merge(tmp_path / "m.bin")  # every block's output landed


# ---------------------------------------------------------------------------
# staging pool back-pressure


def test_staging_pool_bounds_and_reuse():
    stop = threading.Event()
    pool = StagingPool(capacity=1, stop=stop)
    a = pool.acquire((4, 8))
    got = []

    def second():
        got.append(pool.acquire((4, 8)))

    t = threading.Thread(target=second)
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive()  # capacity 1 -> second acquire blocks
    pool.release((4, 8), a)
    t.join(timeout=2.0)
    assert not t.is_alive()
    assert got and got[0][0] is a[0]  # the SAME buffer was recycled


# ---------------------------------------------------------------------------
# manifest journal (append-only + compaction + crash replay)


def test_manifest_journal_is_o1_per_transition(tmp_path):
    m = Manifest(tmp_path / "j.json", num_blocks=64)
    base = (tmp_path / "j.json").stat().st_size
    m.update(0, status="RUNNING")
    one = (tmp_path / "j.json").stat().st_size - base
    for i in range(1, 33):
        m.update(i, status="RUNNING")
    grown = (tmp_path / "j.json").stat().st_size - base
    # append-only: each transition costs ~one line, NOT a table rewrite
    assert one < 128
    assert grown <= 33 * one + 64
    assert m.appends == 33


def test_manifest_crash_replay(tmp_path):
    path = tmp_path / "j.json"
    m = Manifest(path, num_blocks=4)
    m.update(0, status="DONE", finished_at=1.0)
    m.update(1, status="RUNNING", started_at=2.0)
    m.update(2, status="FAILED", attempts=3, error="boom")
    # crash: no compaction, journal is snapshot + 3 update lines
    assert len(path.read_text().splitlines()) == 4

    m2 = Manifest(path, num_blocks=4)
    assert m2.tasks[0].status == "DONE"
    assert m2.tasks[1].status == "PENDING"  # RUNNING at crash -> retry
    assert m2.tasks[2].status == "FAILED"
    assert m2.tasks[2].error == "boom"
    assert m2.tasks[3].status == "PENDING"
    # compaction on open: back to a single snapshot line
    assert len(path.read_text().splitlines()) == 1


def test_manifest_tolerates_torn_tail_write(tmp_path):
    path = tmp_path / "j.json"
    m = Manifest(path, num_blocks=3)
    m.update(0, status="DONE")
    with open(path, "a") as f:  # crash mid-append: half a JSON line
        f.write('{"type": "update", "index": 2, "fie')
    m2 = Manifest(path, num_blocks=3)
    assert m2.tasks[0].status == "DONE"  # durable prefix survives
    assert m2.tasks[2].status == "PENDING"  # torn record dropped


def test_manifest_reads_legacy_format(tmp_path):
    path = tmp_path / "j.json"
    legacy = {str(i): vars(TaskState(i)) for i in range(3)}
    legacy["1"]["status"] = "DONE"
    path.write_text(json.dumps(legacy))
    m = Manifest(path, num_blocks=3)
    assert m.tasks[1].status == "DONE"
    assert m.tasks[0].status == "PENDING"


def test_manifest_crash_mid_compact_replays_same_states(
        tmp_path, monkeypatch):
    """A crash inside _compact (power cut between tmp-write and rename)
    must leave the journal byte-identical, so a reopen replays the SAME
    task states — and must not leak the tmp snapshot file."""
    import os as _os

    path = tmp_path / "j.json"
    m = Manifest(path, num_blocks=4)
    m.update(0, status="DONE", finished_at=1.0)
    m.update(1, status="RUNNING", started_at=2.0)
    m.update(3, status="FAILED", attempts=3, error="boom")
    m.close()
    with open(path, "a") as f:  # plus a torn tail from the same crash
        f.write('{"type": "update", "index": 2, "fie')
    journal_before = path.read_bytes()

    real_replace = _os.replace

    def crash_replace(src, dst):
        raise OSError("simulated crash mid-compact")

    monkeypatch.setattr("repro.core.pipeline.maponly.os.replace",
                        crash_replace)
    with pytest.raises(OSError, match="mid-compact"):
        Manifest(path, num_blocks=4)
    monkeypatch.setattr("repro.core.pipeline.maponly.os.replace",
                        real_replace)

    # the journal is untouched and no .mtmp_ snapshot leaked
    assert path.read_bytes() == journal_before
    assert not list(tmp_path.glob(".mtmp_*"))

    m2 = Manifest(path, num_blocks=4)
    assert m2.tasks[0].status == "DONE"
    assert m2.tasks[1].status == "PENDING"  # RUNNING at crash -> retry
    assert m2.tasks[2].status == "PENDING"  # torn record dropped
    assert m2.tasks[3].status == "FAILED"
    assert m2.tasks[3].error == "boom"
    # and the successful reopen compacted back to one snapshot line
    assert len(path.read_text().splitlines()) == 1
    m2.update(2, status="DONE")  # journal usable after recovery
    assert Manifest(path, num_blocks=4).tasks[2].status == "DONE"
