"""Roofline-methodology invariants.

1. Demonstrates the XLA gap the dry-run works around: cost_analysis counts
   a while-loop body once, ignoring trip count.
2. Validates the per-period extrapolation: with scans unrolled, cost is
   exactly linear in depth, so C(4p) == C(1p) + 3*(C(2p) - C(1p)).
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch.hlo_analysis import cost_analysis_dict
from repro.models.scanning import set_unroll
from repro.models.transformer import TransformerLM
from repro.sharding.rules import abstract_params


def _flops(compiled) -> float:
    return cost_analysis_dict(compiled.cost_analysis())["flops"]


def test_cost_analysis_scan_gap():
    """The motivating bug: scan flops counted once regardless of length."""
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f_scan(x):
        h, _ = jax.lax.scan(lambda h, _: (h @ h, None), x, None, length=10)
        return h

    def f_unroll(x):
        h = x
        for _ in range(10):
            h = h @ h
        return h

    fs = _flops(jax.jit(f_scan).lower(x).compile())
    fu = _flops(jax.jit(f_unroll).lower(x).compile())
    assert fu > 5 * fs  # scan undercounts ~10x


def _loss_flops(cfg, b=2, s=64):
    model = TransformerLM(cfg)
    params = abstract_params(model.param_specs())
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return _flops(jax.jit(model.loss).lower(params, batch).compile())


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "gemma3-1b"])
def test_extrapolation_is_exact_when_unrolled(arch):
    cfg0 = get_config(arch).reduced()
    period = len(cfg0.layer_pattern)
    set_unroll(True)
    try:
        c1 = _loss_flops(dataclasses.replace(cfg0, num_layers=period))
        c2 = _loss_flops(dataclasses.replace(cfg0, num_layers=2 * period))
        c4 = _loss_flops(dataclasses.replace(cfg0, num_layers=4 * period))
    finally:
        set_unroll(False)
    extrapolated = c1 + 3 * (c2 - c1)
    assert abs(extrapolated - c4) / c4 < 0.02


def test_unrolled_flops_exceed_scanned():
    cfg = get_config("qwen2-0.5b").reduced()
    set_unroll(True)
    try:
        unrolled = _loss_flops(cfg)
    finally:
        set_unroll(False)
    scanned = _loss_flops(cfg)
    assert unrolled > 2 * scanned  # 6 layers of real work vs 1 counted
