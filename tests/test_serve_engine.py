"""Smoke tests for the ServeEngine LM stub (prefill + greedy decode).

The serve package's tier-1 floor: the engine must produce the requested
number of tokens, deterministically for greedy decode, and its jit'd
prefill/decode steps must be reusable across calls (the launcher times a
second call as steady state, so a second call has to work — the decode
step donates its caches, which only matters within one generate call).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import TransformerLM
from repro.serve import ServeEngine, greedy_generate
from repro.sharding.rules import init_params

ARCH = "qwen2-0.5b"


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH).reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (2, 8)))}
    return cfg, model, params, batch


def test_generate_shape_dtype_and_range(setup):
    cfg, model, params, batch = setup
    engine = ServeEngine(model)
    out = engine.generate(params, batch, max_new_tokens=5)
    assert out.shape == (2, 5)
    assert out.dtype == batch["tokens"].dtype
    toks = np.asarray(out)
    assert ((toks >= 0) & (toks < cfg.vocab_size)).all()


def test_generate_is_deterministic_and_reusable(setup):
    _, model, params, batch = setup
    engine = ServeEngine(model)
    first = np.asarray(engine.generate(params, batch, max_new_tokens=4))
    again = np.asarray(engine.generate(params, batch, max_new_tokens=4))
    np.testing.assert_array_equal(first, again)


def test_greedy_generate_matches_engine(setup):
    _, model, params, batch = setup
    engine_out = np.asarray(
        ServeEngine(model).generate(params, batch, max_new_tokens=3))
    fn_out = np.asarray(
        greedy_generate(model, params, batch, max_new_tokens=3))
    np.testing.assert_array_equal(engine_out, fn_out)
