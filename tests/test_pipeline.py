"""BlockStore / record / map-only pipeline behaviour + property tests."""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 block_of_segments, segments_of_block)
from repro.core.pipeline.records import segment_block_bytes


def test_split_merge_identity(tmp_path, rng):
    data = rng.bytes(1 << 18)
    store = BlockStore(tmp_path / "s", block_bytes=1 << 14)
    store.put_bytes(data)
    assert len(store.blocks) == 16
    job = MapOnlyJob(store, tmp_path / "o", lambda b, i: b,
                     JobConfig(workers=3))
    job.run()
    job.merge(tmp_path / "m.bin")
    assert (tmp_path / "m.bin").read_bytes() == data


@settings(max_examples=20, deadline=None)
@given(nbytes=st.integers(1, 1 << 12), block=st.sampled_from([64, 256, 4096]))
def test_split_merge_identity_property(tmp_path_factory, nbytes, block):
    tmp = tmp_path_factory.mktemp("bs")
    data = np.random.default_rng(nbytes).bytes(nbytes)
    store = BlockStore(tmp / "s", block_bytes=block)
    store.put_bytes(data)
    out = b"".join(store.read_block(i) for i in range(len(store.blocks)))
    assert out == data
    # offsets cover the file exactly once, in order
    offs = [b.offset for b in store.blocks]
    assert offs == sorted(offs)
    assert sum(b.nbytes for b in store.blocks) == nbytes


def test_block_names_sort_by_offset(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=8)
    store.put_bytes(bytes(100))
    names = [b.name() for b in store.blocks]
    assert names == sorted(names)  # the getmerge ordering guarantee


def test_record_layout_roundtrip(rng):
    re = rng.standard_normal((7, 128)).astype(np.float32)
    im = rng.standard_normal((7, 128)).astype(np.float32)
    data = block_of_segments(re, im)
    r2, i2 = segments_of_block(data, 128)
    np.testing.assert_array_equal(re, r2)
    np.testing.assert_array_equal(im, i2)


def test_record_rejects_partial_segment():
    with pytest.raises(ValueError):
        segments_of_block(bytes(12), 128)


def test_segment_block_bytes():
    # paper's example: 1024-pt single-precision complex = 8KB per segment
    assert segment_block_bytes(1024, 1) == 8192


def test_getmerge_missing_block_raises(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=16)
    store.put_bytes(bytes(64))
    (tmp_path / "o").mkdir()
    store.write_output_block(tmp_path / "o", 0, bytes(16))
    with pytest.raises(IOError, match="missing"):
        store.getmerge(tmp_path / "o", tmp_path / "m.bin")


def test_manifest_reopen(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=32, replication=2)
    store.put_bytes(bytes(range(100)) * 2)
    again = BlockStore.open(tmp_path / "s")
    assert [vars(b) for b in again.blocks] == [vars(b) for b in store.blocks]
    assert again.read_block(1) == store.read_block(1)


def test_put_file_streams_and_matches_put_bytes(tmp_path, rng):
    data = rng.bytes(100_000)  # deliberately not block-aligned
    src = tmp_path / "input.bin"
    src.write_bytes(data)
    by_bytes = BlockStore(tmp_path / "a", block_bytes=1 << 14)
    by_bytes.put_bytes(data)
    by_file = BlockStore(tmp_path / "b", block_bytes=1 << 14)
    by_file.put_file(src)
    assert ([vars(b) for b in by_file.blocks]
            == [vars(b) for b in by_bytes.blocks])
    assert by_file.total_bytes == len(data)
    out = b"".join(by_file.read_block(i) for i in range(len(by_file.blocks)))
    assert out == data


def test_put_bytes_accepts_memoryview_and_arrays(tmp_path, rng):
    arr = rng.standard_normal(1000).astype(np.float32)
    store = BlockStore(tmp_path / "s", block_bytes=512)
    store.put_array(arr)
    joined = b"".join(store.read_block(i) for i in range(len(store.blocks)))
    assert joined == arr.tobytes()


def test_blocks_carry_both_crc32_and_sha(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=16)
    store.put_bytes(bytes(64))
    for b in store.blocks:
        assert len(b.crc32) == 8  # hot-path checksum
        assert len(b.checksum) == 16  # replica-repair ground truth
    # crc32 catches hot-path corruption exactly like the old sha did
    store.corrupt_block(0)
    with pytest.raises(IOError):
        store.read_block(0)


def test_legacy_manifest_without_crc_verifies_via_sha(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=16)
    store.put_bytes(bytes(range(32)))
    doc = json.loads((tmp_path / "s" / "manifest.json").read_text())
    for b in doc["blocks"]:
        del b["crc32"]  # manifest written by the pre-crc code
    (tmp_path / "s" / "manifest.json").write_text(json.dumps(doc))
    again = BlockStore.open(tmp_path / "s")
    assert again.blocks[0].crc32 == ""
    assert again.read_block(0) == store.read_block(0)  # sha fallback
    again.corrupt_block(1)
    with pytest.raises(IOError):
        again.read_block(1)


def test_getmerge_streams_large_blocks(tmp_path, rng, monkeypatch):
    import repro.core.pipeline.blockstore as bs
    monkeypatch.setattr(bs, "MERGE_CHUNK", 1 << 10)  # force many chunks
    data = rng.bytes(1 << 16)
    store = BlockStore(tmp_path / "s", block_bytes=1 << 14)
    store.put_bytes(data)
    out = tmp_path / "o"
    for i in range(len(store.blocks)):
        store.write_output_block(out, i, store.read_block(i))
    n = store.getmerge(out, tmp_path / "m.bin")
    assert n == len(data)
    assert (tmp_path / "m.bin").read_bytes() == data
