"""BlockStore / record / map-only pipeline behaviour + property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 block_of_segments, segments_of_block)
from repro.core.pipeline.records import segment_block_bytes


def test_split_merge_identity(tmp_path, rng):
    data = rng.bytes(1 << 18)
    store = BlockStore(tmp_path / "s", block_bytes=1 << 14)
    store.put_bytes(data)
    assert len(store.blocks) == 16
    job = MapOnlyJob(store, tmp_path / "o", lambda b, i: b,
                     JobConfig(workers=3))
    job.run()
    job.merge(tmp_path / "m.bin")
    assert (tmp_path / "m.bin").read_bytes() == data


@settings(max_examples=20, deadline=None)
@given(nbytes=st.integers(1, 1 << 12), block=st.sampled_from([64, 256, 4096]))
def test_split_merge_identity_property(tmp_path_factory, nbytes, block):
    tmp = tmp_path_factory.mktemp("bs")
    data = np.random.default_rng(nbytes).bytes(nbytes)
    store = BlockStore(tmp / "s", block_bytes=block)
    store.put_bytes(data)
    out = b"".join(store.read_block(i) for i in range(len(store.blocks)))
    assert out == data
    # offsets cover the file exactly once, in order
    offs = [b.offset for b in store.blocks]
    assert offs == sorted(offs)
    assert sum(b.nbytes for b in store.blocks) == nbytes


def test_block_names_sort_by_offset(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=8)
    store.put_bytes(bytes(100))
    names = [b.name() for b in store.blocks]
    assert names == sorted(names)  # the getmerge ordering guarantee


def test_record_layout_roundtrip(rng):
    re = rng.standard_normal((7, 128)).astype(np.float32)
    im = rng.standard_normal((7, 128)).astype(np.float32)
    data = block_of_segments(re, im)
    r2, i2 = segments_of_block(data, 128)
    np.testing.assert_array_equal(re, r2)
    np.testing.assert_array_equal(im, i2)


def test_record_rejects_partial_segment():
    with pytest.raises(ValueError):
        segments_of_block(bytes(12), 128)


def test_segment_block_bytes():
    # paper's example: 1024-pt single-precision complex = 8KB per segment
    assert segment_block_bytes(1024, 1) == 8192


def test_getmerge_missing_block_raises(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=16)
    store.put_bytes(bytes(64))
    (tmp_path / "o").mkdir()
    store.write_output_block(tmp_path / "o", 0, bytes(16))
    with pytest.raises(IOError, match="missing"):
        store.getmerge(tmp_path / "o", tmp_path / "m.bin")


def test_manifest_reopen(tmp_path):
    store = BlockStore(tmp_path / "s", block_bytes=32, replication=2)
    store.put_bytes(bytes(range(100)) * 2)
    again = BlockStore.open(tmp_path / "s")
    assert [vars(b) for b in again.blocks] == [vars(b) for b in store.blocks]
    assert again.read_block(1) == store.read_block(1)
