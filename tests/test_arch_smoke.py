"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes + finite values (the assignment's requirement)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.transformer import TransformerLM
from repro.sharding.rules import init_params
from repro.train.trainer import TrainerConfig, make_train_step


def _batch(cfg, rng, b=2, s=32):
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (b, s)))}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, 16, cfg.d_model)), jnp.float32)
    if cfg.num_prefix_embeds:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key, rng):
    cfg = get_config(arch).reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), key)
    batch = _batch(cfg, rng)
    logits = model.forward(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s + cfg.num_prefix_embeds, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch, key, rng):
    cfg = get_config(arch).reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), key)
    # warmup_steps=0: with warmup, lr(step 0) == 0 and params would
    # (correctly) not move on the very first step
    tc = TrainerConfig(optimizer="adamw", base_lr=1e-3, warmup_steps=0,
                       total_steps=10)
    opt, step_fn = make_train_step(model, tc)
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    batch = _batch(cfg, rng)
    batch["labels"] = batch["tokens"]
    new_state, metrics = jax.jit(step_fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, new_state["params"], params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-3b", "zamba2-7b",
                                  "whisper-base", "mixtral-8x22b"])
def test_decode_step_shapes(arch, key, rng):
    cfg = get_config(arch).reduced()
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), key)
    caches = model.init_cache(2, 64)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)))
    logits, new_caches = model.decode_step(params, caches, tok, jnp.int32(5))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


def test_exact_config_params_match_spec():
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    spec = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (nl, d, h, kv, ff, v), arch


def test_moe_configs():
    m = get_config("mixtral-8x22b")
    assert (m.num_experts, m.num_experts_per_tok) == (8, 2)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.num_experts, l4.num_experts_per_tok, l4.shared_expert) == (16, 1, True)


def test_pattern_configs():
    assert get_config("gemma3-1b").layer_pattern == "LLLLLG"
    assert get_config("zamba2-7b").layer_pattern == "MMMMMS"
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("rwkv6-3b").is_attention_free
