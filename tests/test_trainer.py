"""Trainer integration: convergence, resume, compression, accumulation."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data import TokenPipeline, synthetic_corpus
from repro.models.transformer import TransformerLM
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("train")
    cfg = get_config("qwen2-0.5b").reduced()
    model = TransformerLM(cfg)
    store = synthetic_corpus(tmp / "corpus", vocab_size=cfg.vocab_size,
                             n_tokens=150_000, block_tokens=16384)
    return tmp, cfg, model, store


def test_loss_decreases_and_resumes(setup):
    tmp, cfg, model, store = setup
    pipe = TokenPipeline(store, batch=4, seq=64)
    tc = TrainerConfig(total_steps=25, warmup_steps=5, base_lr=1e-3,
                       ckpt_dir=str(tmp / "ckpt"), ckpt_every=10, log_every=5)
    tr = Trainer(model, tc)
    state = tr.restore_or_init(jax.random.PRNGKey(0))
    state, hist = tr.run(state, iter(pipe), steps=25)
    assert hist[-1]["loss"] < hist[0]["loss"]

    # kill + relaunch: trainer must resume from the last committed step
    tr2 = Trainer(model, tc)
    state2 = tr2.restore_or_init(jax.random.PRNGKey(1))
    assert int(state2["step"]) == 25


def test_grad_compression_still_learns(setup):
    tmp, cfg, model, store = setup
    pipe = TokenPipeline(store, batch=4, seq=64)
    tc = TrainerConfig(total_steps=15, warmup_steps=3, base_lr=1e-3,
                       grad_compression=True, log_every=5)
    tr = Trainer(model, tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    assert "errors" in state  # error-feedback state present
    state, hist = tr.run(state, iter(pipe), steps=15)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_grad_accum_matches_big_batch(setup):
    """accum=2 over half-batches == one step over the full batch."""
    tmp, cfg, model, store = setup
    batch = next(iter(TokenPipeline(store, batch=4, seq=32)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    from repro.train.trainer import make_train_step
    tc1 = TrainerConfig(optimizer="sgd", base_lr=1e-2, warmup_steps=0,
                        total_steps=10, grad_accum=1)
    tc2 = TrainerConfig(optimizer="sgd", base_lr=1e-2, warmup_steps=0,
                        total_steps=10, grad_accum=2)
    _, step1 = make_train_step(model, tc1)
    _, step2 = make_train_step(model, tc2)
    from repro.sharding.rules import init_params
    from repro.optim.optimizers import get_optimizer
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt = get_optimizer("sgd")
    state = {"params": params, "opt_state": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    s1, m1 = jax.jit(step1)(state, batch)
    micro = {k: v.reshape(2, 2, *v.shape[1:]) for k, v in batch.items()}
    state2 = {"params": params, "opt_state": opt.init(params),
              "step": jnp.zeros((), jnp.int32)}
    s2, m2 = jax.jit(step2)(state2, micro)
    # same data split in halves -> same averaged gradient (up to fp error)
    a = jax.tree.leaves(s1["params"])[0]
    b = jax.tree.leaves(s2["params"])[0]
    assert float(jnp.abs(a - b).max()) < 5e-3


def test_adafactor_runs(setup):
    tmp, cfg, model, store = setup
    pipe = TokenPipeline(store, batch=4, seq=32)
    tc = TrainerConfig(optimizer="adafactor", total_steps=6, warmup_steps=1,
                       base_lr=1e-2, log_every=2)
    tr = Trainer(model, tc)
    state = tr.init_state(jax.random.PRNGKey(0))
    state, hist = tr.run(state, iter(pipe), steps=6)
    assert np.isfinite(hist[-1]["loss"])
