"""Out-of-core FFT: factorization, layout contract, streamed execution,
and the two-phase crash-resume protocol (DESIGN.md §11).

Streamed runs are tiny (2^12..2^14 points) but exercise the REAL path:
an on-disk BlockStore, both StreamExecutor passes, the shuffle journal,
and the phase manifests. impl="ref" everywhere a streamed result is
compared with the in-memory oracle — they must launch identical
panel-shaped plans for the bitwise contract to hold.
"""

import numpy as np
import pytest

import repro.fft as fft_api
from repro.core.fft.outofcore import (corner_turn, reference_out_of_core)
from repro.core.pipeline import BlockStore, JobConfig
from repro.core.resilience import FaultInjector, FaultPlan, FaultRule

pytestmark = pytest.mark.outofcore

N = 1 << 12          # 4096 points: n1 = n2 = 64
BUDGET = 8 * N // 4  # operand/4 -> multiple jobs per pass
IMPL = "ref"


def _signal(n=N, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 2)).astype(np.float32)


def _make_store(tmp_path, sig, block_bytes=None):
    f = fft_api.factor_out_of_core(len(sig), BUDGET)
    store = BlockStore(tmp_path / "in",
                       block_bytes=block_bytes or f.pass1_panel_bytes)
    store.put_bytes(sig.tobytes())
    return store


def _plan(tmp_path, store, n=N, cfg=None):
    return fft_api.plan(kind="c2c", n=n, placement="out_of_core",
                        store=store, work_dir=tmp_path / "ooc",
                        budget_bytes=BUDGET, impl=IMPL, job_config=cfg)


def _shuffle_killer(f, attempts=4):
    """A schedule that kills one pass-1 job's scatter past its retries."""
    victim = f.pass1_jobs // 2
    return FaultInjector(FaultPlan((
        FaultRule(site="ooc.shuffle", index=victim * f.pass1_jobs + victim,
                  calls=tuple(range(1, attempts + 1))),)))


# ---------------------------------------------------------------------------
# factorization + analytic model


def test_factor_near_square_and_model():
    f = fft_api.factor_out_of_core(1 << 20, 1 << 22)
    assert f.n1 * f.n2 == f.n and f.n2 in (f.n1, 2 * f.n1)
    assert f.t2 * f.pass1_jobs == f.n2
    assert f.t1 * f.pass2_jobs == f.n1
    assert f.passes == 2
    assert f.io_bytes == 4 * f.operand_bytes
    assert f.shuffle_bytes == 2 * f.operand_bytes
    assert f.working_set_bytes <= f.budget_bytes
    assert f.tiles == f.pass1_jobs * f.pass2_jobs


def test_factor_rejects_non_pow2_and_tiny_budget():
    with pytest.raises(ValueError, match="power of"):
        fft_api.factor_out_of_core(1000, 1 << 20)
    with pytest.raises(ValueError, match="budget"):
        fft_api.factor_out_of_core(1 << 20, 1 << 10)


def test_factor_rejects_block_not_tiling_panel():
    with pytest.raises(ValueError, match="block_bytes"):
        fft_api.factor_out_of_core(1 << 12, BUDGET, block_bytes=3 * 256)


def test_planner_validates_out_of_core_args(tmp_path):
    store = _make_store(tmp_path, _signal())
    with pytest.raises(ValueError, match="out_of_core"):
        fft_api.plan(kind="r2c", n=N, placement="out_of_core", store=store,
                     work_dir=tmp_path / "o", budget_bytes=BUDGET)
    with pytest.raises(ValueError, match="store"):
        fft_api.plan(kind="c2c", n=N, placement="out_of_core",
                     work_dir=tmp_path / "o", budget_bytes=BUDGET)
    # store= without the placement is an error, not silently ignored
    with pytest.raises(ValueError, match="placement"):
        fft_api.plan(kind="c2c", n=N, store=store)


# ---------------------------------------------------------------------------
# layout contract + numerics


def test_corner_turn_identity_vs_numpy(tmp_path):
    """out == T(np.fft.fft(T(s))): the decimated-in/transposed-out
    contract, checked against numpy at float32-appropriate tolerance."""
    sig = _signal()
    store = _make_store(tmp_path, sig)
    p = _plan(tmp_path, store)
    p.execute()
    dest = tmp_path / "merged.bin"
    p.merge(dest)
    got = np.frombuffer(dest.read_bytes(), np.float32).reshape(N, 2)
    got = got[:, 0] + 1j * got[:, 1]
    s = (sig[:, 0] + 1j * sig[:, 1]).astype(np.complex128)
    want = corner_turn(
        np.fft.fft(corner_turn(s, p.factors)), p.factors)
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 1e-5


def test_streamed_bitwise_equals_oracle(tmp_path):
    sig = _signal()
    store = _make_store(tmp_path, sig)
    p = _plan(tmp_path, store)
    stats = p.execute()
    dest = tmp_path / "merged.bin"
    p.merge(dest)
    assert dest.read_bytes() == reference_out_of_core(
        sig, p.factors, impl=IMPL)
    assert stats.pass1_attempts == p.factors.pass1_jobs
    assert stats.io["total"] == p.factors.io_bytes


def test_multi_block_panels(tmp_path):
    """Panels spanning several store blocks read block-granular."""
    sig = _signal()
    f = fft_api.factor_out_of_core(N, BUDGET)
    store = _make_store(tmp_path, sig,
                        block_bytes=f.pass1_panel_bytes // 4)
    p = _plan(tmp_path, store)
    p.execute()
    dest = tmp_path / "merged.bin"
    p.merge(dest)
    assert dest.read_bytes() == reference_out_of_core(sig, f, impl=IMPL)


# ---------------------------------------------------------------------------
# crash-resume: the two-phase manifest protocol


def test_resume_mid_shuffle_redoes_only_lost_job(tmp_path):
    """Kill one pass-1 job past its retry budget; the resumed run must
    re-run ONLY that job (FAILED demotes to PENDING on the new
    invocation, DONE work is never redone) and merge bitwise output."""
    sig = _signal()
    store = _make_store(tmp_path, sig)
    f = fft_api.factor_out_of_core(N, BUDGET)
    cfg = JobConfig(readers=2, writers=2, inflight=2, speculation=False,
                    max_retries=3, injector=_shuffle_killer(f))
    p = _plan(tmp_path, store, cfg=cfg)
    with pytest.raises(RuntimeError, match="failed"):
        p.execute()  # the exhausted job aborts the run mid-shuffle
    # and the pass-2 guard refuses the incomplete shuffle independently
    with pytest.raises(RuntimeError, match="complete shuffle"):
        p.run_pass2()

    p2 = _plan(tmp_path, store)
    stats = p2.execute()
    assert stats.pass1_attempts == 1  # only the killed job re-ran
    assert stats.pass2_attempts == f.pass2_jobs
    dest = tmp_path / "merged.bin"
    p2.merge(dest)
    assert dest.read_bytes() == reference_out_of_core(sig, f, impl=IMPL)


def test_resume_between_phases_redoes_no_pass1_work(tmp_path):
    """Crash after the shuffle completed: resume runs zero pass-1
    attempts and streams pass 2 from the journaled tiles."""
    sig = _signal()
    store = _make_store(tmp_path, sig)
    p = _plan(tmp_path, store)
    p.run_pass1()  # "crash" here: phase 1 durable, phase 2 never started

    p2 = _plan(tmp_path, store)
    stats = p2.execute()
    assert stats.pass1_attempts == 0
    assert stats.pass2_attempts == p2.factors.pass2_jobs
    dest = tmp_path / "merged.bin"
    p2.merge(dest)
    assert dest.read_bytes() == reference_out_of_core(
        sig, p2.factors, impl=IMPL)


def test_resume_mid_pass2_redoes_only_unfinished(tmp_path):
    """Kill one pass-2 tile gather past its retries: the resumed run
    re-runs no pass-1 work and only the lost pass-2 job."""
    sig = _signal()
    store = _make_store(tmp_path, sig)
    f = fft_api.factor_out_of_core(N, BUDGET)
    victim = f.pass2_jobs // 2
    inj = FaultInjector(FaultPlan((
        FaultRule(site="ooc.pass2", index=victim * f.pass1_jobs,
                  calls=(1, 2, 3, 4)),)))
    cfg = JobConfig(readers=2, writers=2, inflight=2, speculation=False,
                    max_retries=3, injector=inj)
    p = _plan(tmp_path, store, cfg=cfg)
    with pytest.raises(RuntimeError, match="failed"):
        p.execute()  # pass 1 + shuffle complete; one pass-2 job dies

    p2 = _plan(tmp_path, store)
    stats = p2.execute()
    assert stats.pass1_attempts == 0
    assert stats.pass2_attempts == 1
    dest = tmp_path / "merged.bin"
    p2.merge(dest)
    assert dest.read_bytes() == reference_out_of_core(sig, f, impl=IMPL)


def test_pass2_guard_requires_complete_shuffle(tmp_path):
    store = _make_store(tmp_path, _signal())
    p = _plan(tmp_path, store)
    with pytest.raises(RuntimeError, match="complete shuffle"):
        p.run_pass2()


def test_merge_requires_complete_output(tmp_path):
    store = _make_store(tmp_path, _signal())
    p = _plan(tmp_path, store)
    with pytest.raises(IOError, match="missing"):
        p.merge(tmp_path / "merged.bin")


# ---------------------------------------------------------------------------
# plan-cache observability (repro.fft.cache_info)


def test_cache_info_counts_hits_and_misses(tmp_path):
    n = 1 << 13  # n1=64, n2=128: the two passes cache DISTINCT plans
    budget = 8 * n // 4
    fft_api.clear_plan_cache()
    base = fft_api.cache_info()
    assert base["entries"] == 0 and base["hits"] == 0
    f = fft_api.factor_out_of_core(n, budget)
    store = BlockStore(tmp_path / "in", block_bytes=f.pass1_panel_bytes)
    store.put_bytes(_signal(n).tobytes())
    p = fft_api.plan(kind="c2c", n=n, placement="out_of_core", store=store,
                     work_dir=tmp_path / "ooc", budget_bytes=budget,
                     impl=IMPL)
    p.execute()
    info = fft_api.cache_info()
    # one cached plan per pass, re-hit by every subsequent job
    assert info["misses"] == 2 and info["entries"] == 2
    jobs = f.pass1_jobs + f.pass2_jobs
    assert info["hits"] == jobs - 2
    fft_api.clear_plan_cache()
    assert fft_api.cache_info()["entries"] == 0
