"""Zero-copy four-step + real-input fast path invariants (DESIGN.md §3-4).

Covers the three tentpole claims:
  * the zero-copy layout is numerically identical (bitwise) to the legacy
    reshape+swapaxes path it replaces;
  * no standalone transpose op remains between the two leaf passes — the
    traced program is reshapes + pallas_calls only;
  * rfft/irfft match numpy's real-input transforms in every regime
    (tiny fallback, fused leaf epilogue, level-1 host untangle) and the
    byte counters show the expected savings.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.fft import ops, plan
from repro.kernels.fft.matfft import matfft_cols


def _rel_err(got_r, got_i, want_r, want_i):
    scale = float(np.abs(np.asarray(want_r)).max()
                  + np.abs(np.asarray(want_i)).max()) or 1.0
    return max(float(np.abs(got_r - want_r).max()),
               float(np.abs(got_i - want_i).max())) / scale


# ---------------------------------------------------------------------------
# zero-copy four-step


@pytest.mark.parametrize("n", [32768, 1 << 16])
def test_zero_copy_bitmatches_copy_layout(rng, n):
    """Same GEMMs, same per-row reduction order -> bitwise-equal planes."""
    xr = rng.standard_normal((2, n)).astype(np.float32)
    xi = rng.standard_normal((2, n)).astype(np.float32)
    zr, zi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), layout="zero_copy")
    cr, ci = ops.fft(jnp.asarray(xr), jnp.asarray(xi), layout="copy")
    assert np.array_equal(np.asarray(zr), np.asarray(cr))
    assert np.array_equal(np.asarray(zi), np.asarray(ci))


@pytest.mark.parametrize("n", [32768])
def test_zero_copy_matches_numpy(rng, n):
    xr = rng.standard_normal((3, n)).astype(np.float32)
    xi = rng.standard_normal((3, n)).astype(np.float32)
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), layout="zero_copy")
    want = np.fft.fft(xr + 1j * xi)
    assert _rel_err(np.asarray(yr), np.asarray(yi),
                    want.real, want.imag) < 5e-6


def _top_level_primitives(fn, *args):
    return [str(eqn.primitive) for eqn in jax.make_jaxpr(fn)(*args).eqns]


def test_no_transpose_between_leaf_passes():
    """The zero-copy level-1 program is reshapes + pallas_calls ONLY: the
    column-strided BlockSpecs absorbed all three host transposes. The
    legacy layout must still show them (it's the measured baseline)."""
    n = 32768
    a = jnp.zeros((2, n), jnp.float32)

    prims = _top_level_primitives(
        lambda xr, xi: ops.fft(xr, xi, layout="zero_copy"), a, a)
    assert prims.count("pallas_call") == 2
    assert "transpose" not in prims, prims

    legacy = _top_level_primitives(
        lambda xr, xi: ops.fft(xr, xi, layout="copy"), a, a)
    assert "transpose" in legacy


def test_zero_copy_ragged_batch_tile(rng):
    """A non-pow2 batch_tile must not drop columns (regression: a ragged
    col tile left trailing output blocks unwritten -> NaN)."""
    n = 32768
    xr = rng.standard_normal((1, n)).astype(np.float32)
    xi = rng.standard_normal((1, n)).astype(np.float32)
    yr, yi = ops.fft(jnp.asarray(xr), jnp.asarray(xi), layout="zero_copy",
                     batch_tile=24)
    want = np.fft.fft(xr + 1j * xi)
    assert _rel_err(np.asarray(yr), np.asarray(yi),
                    want.real, want.imag) < 5e-6


def test_fft_cols_matches_transposed_fft(rng):
    """fft_cols == fft(x.T) without the materialized transpose."""
    L, C = 512, 64
    xr = rng.standard_normal((L, C)).astype(np.float32)
    xi = rng.standard_normal((L, C)).astype(np.float32)
    yr, yi = ops.fft_cols(jnp.asarray(xr), jnp.asarray(xi))
    wr, wi = ops.fft(jnp.asarray(xr.T.copy()), jnp.asarray(xi.T.copy()))
    assert yr.shape == (C, L)
    assert _rel_err(np.asarray(yr), np.asarray(yi),
                    np.asarray(wr), np.asarray(wi)) < 5e-6
    prims = _top_level_primitives(
        lambda a, b: ops.fft_cols(a, b), jnp.asarray(xr), jnp.asarray(xi))
    assert "transpose" not in prims, prims


@pytest.mark.parametrize("out_major", ["row", "col"])
def test_matfft_cols_epilogue_and_layouts(rng, out_major):
    """Column kernel with fused epilogue == transpose + fft + multiply."""
    B, L, C = 2, 256, 16
    xr = rng.standard_normal((B, L, C)).astype(np.float32)
    xi = rng.standard_normal((B, L, C)).astype(np.float32)
    er = rng.standard_normal((C, L)).astype(np.float32)
    ei = rng.standard_normal((C, L)).astype(np.float32)
    yr, yi = matfft_cols(jnp.asarray(xr), jnp.asarray(xi),
                         out_major=out_major,
                         epilogue=(jnp.asarray(er), jnp.asarray(ei)))
    # oracle: batched fft of the transposed columns, then the row multiply
    cols_r = np.swapaxes(xr, 1, 2).reshape(B * C, L)
    cols_i = np.swapaxes(xi, 1, 2).reshape(B * C, L)
    fr, fi = (np.asarray(a) for a in
              ops.fft(jnp.asarray(cols_r), jnp.asarray(cols_i)))
    tr = np.tile(er, (B, 1))
    ti = np.tile(ei, (B, 1))
    wr = fr * tr - fi * ti
    wi = fr * ti + fi * tr
    if out_major == "col":
        wr = np.swapaxes(wr.reshape(B, C, L), 1, 2)
        wi = np.swapaxes(wi.reshape(B, C, L), 1, 2)
    assert yr.shape == wr.shape
    assert _rel_err(np.asarray(yr), np.asarray(yi), wr, wi) < 5e-6


# ---------------------------------------------------------------------------
# real-input fast path


# 2: fallback; 8..16384: fused leaf epilogue (n//2 <= MAX_LEAF covers up to
# 32768); 65536: level-1 half-length transform + host untangle.
@pytest.mark.parametrize("n", [2, 8, 256, 1024, 8192, 32768, 1 << 16])
def test_rfft_matches_numpy(rng, n):
    x = rng.standard_normal((3, n)).astype(np.float32)
    yr, yi = ops.rfft(jnp.asarray(x))
    want = np.fft.rfft(x)
    assert yr.shape == (3, n // 2 + 1)
    assert _rel_err(np.asarray(yr), np.asarray(yi),
                    want.real, want.imag) < 5e-6


@pytest.mark.parametrize("n", [8, 1024, 32768, 1 << 16])
def test_irfft_roundtrip(rng, n):
    x = rng.standard_normal((2, n)).astype(np.float32)
    yr, yi = ops.rfft(jnp.asarray(x))
    back = ops.irfft(yr, yi)
    assert back.shape == x.shape
    assert float(jnp.abs(back - x).max()) / np.abs(x).max() < 1e-5


def test_irfft_matches_numpy(rng):
    """irfft of a spectrum we did NOT produce (independent oracle)."""
    n = 1024
    spec = (rng.standard_normal((2, n // 2 + 1))
            + 1j * rng.standard_normal((2, n // 2 + 1)))
    spec[:, 0] = spec[:, 0].real
    spec[:, -1] = spec[:, -1].real
    got = ops.irfft(jnp.asarray(spec.real.astype(np.float32)),
                    jnp.asarray(spec.imag.astype(np.float32)))
    want = np.fft.irfft(spec, n)
    assert float(np.abs(np.asarray(got) - want).max()) \
        / np.abs(want).max() < 1e-5


def test_rfft_real_bins(rng):
    """DC and Nyquist bins of a real signal are real."""
    x = rng.standard_normal((4, 512)).astype(np.float32)
    yr, yi = ops.rfft(jnp.asarray(x))
    scale = float(np.abs(np.asarray(yr)).max())
    assert float(jnp.abs(yi[:, 0]).max()) / scale < 1e-5
    assert float(jnp.abs(yi[:, -1]).max()) / scale < 1e-5


def test_rfft_single_pallas_call():
    """Fused-leaf rfft is ONE kernel: pack and untangle never touch HBM."""
    prims = _top_level_primitives(lambda x: ops.rfft(x),
                                  jnp.zeros((4, 4096), jnp.float32))
    assert prims.count("pallas_call") == 1
    assert "transpose" not in prims


# ---------------------------------------------------------------------------
# byte counters (the benchmark/acceptance arithmetic)


def test_fused_untangle_flag_matches_byte_counters():
    """The PR-1 limit regime is now explicit: `plan.fused_untangle` says
    whether the rfft untangle fused into one leaf kernel, and the byte
    counters must agree with the flag in both regimes (DESIGN.md §4)."""
    import repro.fft as fft_api

    for n in [8, 4096, 8192, 32768]:  # n//2 <= MAX_LEAF: fused epilogue
        p = fft_api.plan(kind="r2c", n=n, batch_shape=(1,))
        assert p.fused_untangle, n
        # one kernel: read the real plane, write the one-sided spectrum
        assert plan.rfft_hbm_bytes(n) == 4 * n + 2 * 4 * (n // 2 + 1)
        assert p.hbm_bytes_per_row == plan.rfft_hbm_bytes(n)

    for n in [1 << 16, 1 << 17]:  # n > 2*MAX_LEAF: host pack + untangle
        p = fft_api.plan(kind="r2c", n=n, batch_shape=(1,))
        assert not p.fused_untangle, n
        m = n // 2
        pack = 4 * n + 2 * 4 * m
        untangle = 2 * 2 * 4 * m + 2 * 4 * (m + 1)
        assert plan.rfft_hbm_bytes(n) == \
            pack + plan.fft_hbm_bytes(m, "zero_copy") + untangle
        assert p.hbm_bytes_per_row == plan.rfft_hbm_bytes(n)

    # c2c plans never untangle
    assert not fft_api.plan(kind="c2c", n=4096,
                            batch_shape=(1,)).fused_untangle


def test_hbm_byte_counters():
    for n in [32768, 1 << 16, 1 << 20]:
        assert plan.fft_hbm_bytes(n, "zero_copy") < plan.fft_hbm_bytes(n, "copy")
        # 4 traversals vs 10
        assert plan.fft_hbm_bytes(n, "zero_copy") * 10 \
            == plan.fft_hbm_bytes(n, "copy") * 4
    # leaf sizes: single pass, layouts identical
    assert plan.fft_hbm_bytes(4096, "zero_copy") == plan.fft_hbm_bytes(4096, "copy")
    # fused rfft regime: ~half the bytes of the complex transform
    for n in [4096, 8192, 32768]:
        assert plan.rfft_hbm_bytes(n) <= 0.55 * plan.fft_hbm_bytes(n)
