"""Seeded chaos runs over full jobs (marked ``chaos``; gated in test.sh/CI
next to benchmarks/bench_chaos.py).

The acceptance property of the resilience layer: a deterministic fault
schedule spanning several injection sites and a double-digit share of
blocks changes a job's ATTEMPT counts, never its output bits. The
schedules here are pure functions of their seeds — every failure in this
file replays identically anywhere.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.pipeline import (BlockStore, JobConfig, MapOnlyJob,
                                 SegmentFFTTransform)
from repro.core.pipeline.records import segment_block_bytes
from repro.core.resilience import FaultInjector, FaultPlan, FaultRule

pytestmark = pytest.mark.chaos

FFT_LEN = 256
SEGMENTS = 64          # 128 KB blocks
BLOCKS = 8
MAX_RETRIES = 8
PIPELINE_SITES = ("blockstore.read", "blockstore.replica",
                  "blockstore.write", "stream.decode", "stream.writeback")
SERIAL_SITES = ("blockstore.read", "blockstore.replica",
                "blockstore.write", "maponly.attempt")


def _make_store(root, replication=2):
    rng = np.random.default_rng(7)
    sig = rng.standard_normal((BLOCKS * SEGMENTS, FFT_LEN, 2))
    store = BlockStore(root, block_bytes=segment_block_bytes(
        FFT_LEN, SEGMENTS), replication=replication)
    store.put_bytes(sig.astype(np.float32).tobytes())
    assert len(store.blocks) == BLOCKS
    return store


def _chaos_plan(sites, seed=1407, extra=()):
    plan = FaultPlan.random(seed, BLOCKS, sites=sites, rate=0.25)
    plan = FaultPlan(plan.rules + tuple(extra), meta=dict(plan.meta))
    # the gate's preconditions: a real storm, not a token fault
    assert len({r.site for r in plan.rules}) >= 3
    assert len({r.index for r in plan.rules}) >= max(1, BLOCKS // 10)
    return plan


def _run(store, out_dir, injector, pipelined):
    cfg = JobConfig(workers=2, readers=2, writers=2, coalesce=4, inflight=2,
                    speculation=False, poll_interval_s=0.005,
                    max_retries=MAX_RETRIES, injector=injector)
    store.injector = injector
    if pipelined:
        job = MapOnlyJob(store, out_dir, config=cfg, pipelined=True,
                         transform=SegmentFFTTransform(FFT_LEN, impl="ref"))
    else:
        job = MapOnlyJob(store, out_dir, lambda data, i: data, config=cfg)
    stats = job.run()
    merged = out_dir.parent / f"{out_dir.name}.bin"
    job.merge(merged)
    return stats, merged.read_bytes()


def test_pipelined_chaos_bitwise_identical(tmp_path):
    store = _make_store(tmp_path / "in")
    _, clean = _run(store, tmp_path / "clean", None, pipelined=True)

    store.corrupt_block(0, replica=0)  # physical rot on top of the plan
    plan = _chaos_plan(PIPELINE_SITES,
                       extra=(FaultRule("stream.launch", 2),
                              FaultRule("stream.realize", 3)))
    inj = FaultInjector(plan)
    stats, chaotic = _run(store, tmp_path / "chaos", inj, pipelined=True)

    assert chaotic == clean                      # not one bit different
    assert inj.total_fired >= 3
    assert stats.retries >= inj.total_fired - 1  # replica faults heal in-read
    assert stats.attempts <= BLOCKS * MAX_RETRIES
    assert not stats.failed_blocks
    assert store.stats.fallback_reads >= 1 and store.stats.repairs >= 1


def test_serial_chaos_bitwise_identical(tmp_path):
    store = _make_store(tmp_path / "in")
    _, clean = _run(store, tmp_path / "clean", None, pipelined=False)

    inj = FaultInjector(_chaos_plan(SERIAL_SITES))
    stats, chaotic = _run(store, tmp_path / "chaos", inj, pipelined=False)

    assert chaotic == clean
    assert inj.total_fired >= 3
    assert stats.attempts <= BLOCKS * MAX_RETRIES
    assert not stats.failed_blocks


def test_chaos_schedule_replays_identically(tmp_path):
    """Same seed -> the same faults fire and the same output emerges,
    run after run (the no-flake property chaos testing depends on)."""
    outs, fired = [], []
    for run in range(2):
        store = _make_store(tmp_path / f"in{run}")
        inj = FaultInjector(_chaos_plan(PIPELINE_SITES))
        _, data = _run(store, tmp_path / f"out{run}", inj, pipelined=True)
        outs.append(data)
        fired.append(inj.fired)
    assert outs[0] == outs[1]
    assert fired[0] == fired[1]


def test_exhausted_budget_reports_failed_blocks(tmp_path):
    """A block scheduled to fault on EVERY call must exhaust its budget
    and surface as a structured failed_blocks record + chained cause."""
    store = _make_store(tmp_path / "in")
    inj = FaultInjector(FaultPlan((
        FaultRule("stream.decode", 3, calls=tuple(range(1, 50))),)))
    cfg = JobConfig(readers=2, writers=2, coalesce=4, inflight=2,
                    speculation=False, poll_interval_s=0.005,
                    max_retries=3, injector=inj)
    job = MapOnlyJob(store, tmp_path / "out", config=cfg, pipelined=True,
                     transform=SegmentFFTTransform(FFT_LEN, impl="ref"))
    with pytest.raises(RuntimeError, match="block 3 failed 3 times") as ei:
        job.run()
    assert "injected fault at stream.decode" in repr(ei.value.__cause__)
    assert job.stats.failed_blocks[0]["index"] == 3
    assert job.stats.failed_blocks[0]["attempts"] == 3


_DEGRADE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax
    from repro import compat
    from repro.core.resilience import (FaultInjector, FaultPlan,
                                       clear_events, events)
    from repro.core.resilience import meshstate
    import repro.fft as fft_api

    mesh = compat.make_mesh((8,), ("x",))
    n = 1 << 12
    rng = np.random.default_rng(0)
    xr = rng.standard_normal(n).astype(np.float32)
    xi = rng.standard_normal(n).astype(np.float32)
    want = np.fft.fft(xr + 1j * xi)

    fft_api.plan(kind="c2c", n=n, mesh=mesh, placement="distributed")
    inj = FaultInjector(FaultPlan.random(0, 0, rate=0.0, device_loss=(6, 7)))
    clear_events()
    inj.apply_device_loss(mesh)
    p = fft_api.plan(kind="c2c", n=n, mesh=mesh, placement="distributed",
                     fallback="degrade")
    yr, yi = p.execute(xr, xi)
    got = np.asarray(yr) + 1j * np.asarray(yi)
    out = {
        "placement": p.placement,
        "devices": int(p.mesh.devices.size) if p.mesh is not None else 0,
        "rel_err": float(np.abs(got - want).max() / np.abs(want).max()),
        "events": [e["reason"] for e in events("plan_downgrade")],
        "stale_keys": sum(1 for k in fft_api.planner._PLAN_CACHE
                          if k[1] is not None
                          and k[1].devices.size == 8),
    }
    meshstate.restore_devices()
    print(json.dumps(out))
""")


def test_device_loss_degrades_to_shrunk_mesh(tmp_path):
    """Losing 2/8 devices mid-session: fallback="degrade" must re-plan on
    the 4-device healthy sub-mesh (not raise, not hang on dead devices),
    stay numerically correct, log the downgrade, and invalidate the stale
    8-device plan."""
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    proc = subprocess.run([sys.executable, "-c", _DEGRADE_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    import json
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["placement"] == "distributed"
    assert out["devices"] == 4                 # largest healthy pow2
    assert out["rel_err"] < 1e-4
    assert out["events"] == ["mesh_degraded"]
    assert out["stale_keys"] == 0
