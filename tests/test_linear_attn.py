"""Chunked GLA == naive recurrence (the RWKV6/Mamba2 core invariant)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.models.linear_attn import chunked_gla, naive_gla, step_gla


def _data(seed, b, t, h, dk, dv, decay_lo=-3.0, decay_hi=2.5):
    r = np.random.default_rng(seed)
    q = r.standard_normal((b, t, h, dk)).astype(np.float32)
    k = r.standard_normal((b, t, h, dk)).astype(np.float32)
    v = r.standard_normal((b, t, h, dv)).astype(np.float32)
    lw = -np.exp(r.uniform(decay_lo, decay_hi, (b, t, h, dk))).astype(np.float32)
    return map(jnp.asarray, (q, k, v, lw))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1000), t=st.sampled_from([16, 48, 128]),
       bonus=st.booleans(), dk=st.sampled_from([4, 8]),
       dv=st.sampled_from([4, 16]))
def test_chunked_matches_naive(seed, t, bonus, dk, dv):
    b, h = 2, 3
    q, k, v, lw = _data(seed, b, t, h, dk, dv)
    u = jnp.asarray(np.random.default_rng(seed + 1)
                    .standard_normal((h, dk)).astype(np.float32)) if bonus else None
    o_ref, s_ref = naive_gla(q, k, v, lw, u=u)
    o_chk, s_chk = chunked_gla(q, k, v, lw, u=u, chunk=16)
    scale = float(jnp.abs(o_ref).max()) or 1.0
    assert float(jnp.abs(o_ref - o_chk).max()) / scale < 1e-4
    sscale = float(jnp.abs(s_ref).max()) or 1.0
    assert float(jnp.abs(s_ref - s_chk).max()) / sscale < 1e-4


def test_extreme_decay_no_overflow():
    """Decays far below the clamp must stay finite (the f32 safety claim)."""
    b, t, h, dk, dv = 1, 64, 2, 8, 8
    q, k, v, _ = _data(0, b, t, h, dk, dv)
    lw = jnp.full((b, t, h, dk), -1e9, jnp.float32)  # instant forgetting
    o, s = chunked_gla(q, k, v, lw)
    assert bool(jnp.isfinite(o).all()) and bool(jnp.isfinite(s).all())
    o_ref, _ = naive_gla(q, k, v, lw)
    assert float(jnp.abs(o - o_ref).max()) / (float(jnp.abs(o_ref).max()) or 1) < 1e-4


def test_state_continuation():
    """chunked(x[:64]) state feeding chunked(x[64:]) == chunked(x) whole."""
    b, t, h, dk, dv = 2, 128, 2, 8, 8
    q, k, v, lw = _data(3, b, t, h, dk, dv)
    o_all, s_all = chunked_gla(q, k, v, lw)
    o1, s1 = chunked_gla(q[:, :64], k[:, :64], v[:, :64], lw[:, :64])
    o2, s2 = chunked_gla(q[:, 64:], k[:, 64:], v[:, 64:], lw[:, 64:],
                         initial_state=s1)
    got = jnp.concatenate([o1, o2], axis=1)
    scale = float(jnp.abs(o_all).max())
    assert float(jnp.abs(got - o_all).max()) / scale < 1e-4
    assert float(jnp.abs(s2 - s_all).max()) / float(jnp.abs(s_all).max()) < 1e-4


def test_step_decode_matches_chunked():
    b, t, h, dk, dv = 1, 32, 2, 8, 8
    q, k, v, lw = _data(7, b, t, h, dk, dv)
    u = jnp.asarray(np.random.default_rng(8).standard_normal((h, dk)), jnp.float32)
    o_ref, _ = chunked_gla(q, k, v, lw, u=u)
    s = jnp.zeros((b, h, dk, dv))
    outs = []
    for i in range(t):
        o, s = step_gla(q[:, i:i + 1], k[:, i:i + 1], v[:, i:i + 1],
                        lw[:, i:i + 1], u, s)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    scale = float(jnp.abs(o_ref).max())
    assert float(jnp.abs(got - o_ref).max()) / scale < 1e-4
