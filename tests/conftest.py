# NOTE: no XLA_FLAGS here on purpose — tests must see the host's real
# single CPU device. Only launch/dryrun.py (never imported by tests)
# forces the 512-device count.
import functools
import inspect
import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback: on a bare environment the 6 property-test modules must
# still collect and run. The shim replays a fixed number of seeded examples
# through the same @settings/@given decorator surface the tests already use.
# Install the real package (requirements.txt) to get shrinking + the database.

try:
    import hypothesis  # noqa: F401
except ImportError:
    def _make_strategies():
        st = types.ModuleType("hypothesis.strategies")

        def integers(min_value, max_value):
            return lambda rng: int(rng.integers(min_value, max_value + 1))

        def floats(min_value, max_value):
            return lambda rng: float(rng.uniform(min_value, max_value))

        def sampled_from(elements):
            elements = list(elements)
            return lambda rng: elements[int(rng.integers(len(elements)))]

        def booleans():
            return lambda rng: bool(rng.integers(2))

        st.integers, st.floats = integers, floats
        st.sampled_from, st.booleans = sampled_from, booleans
        return st

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 10)):
                    drawn = {k: draw(rng) for k, draw in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            # hide the strategy-supplied params so pytest doesn't treat
            # them as fixtures (what real hypothesis does)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco

    def _settings(**kwargs):
        def deco(fn):
            fn._max_examples = kwargs.get("max_examples", 10)
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings = _given, _settings
    _hyp.strategies = _make_strategies()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "chaos: seeded fault-injection runs over full pipelined jobs "
        "(deterministic; gated in test.sh/CI alongside bench_chaos.py)")
    config.addinivalue_line(
        "markers",
        "outofcore: streamed out-of-core FFT runs over a real on-disk "
        "BlockStore (small sizes; the big gate is bench_outofcore.py)")
    config.addinivalue_line(
        "markers",
        "serve: FFT-as-a-service front-end tests (admission control, "
        "dynamic batching, deadlines; the load gate is bench_serve.py)")
    config.addinivalue_line(
        "markers",
        "verify: ABFT silent-corruption defense tests (invariant checks, "
        "corrupt fault rules, quarantine-and-recompute; the storm gate "
        "is bench_verify.py)")
    config.addinivalue_line(
        "markers",
        "tune: measuring-autotuner and persistent-wisdom tests "
        "(determinism, wisdom round-trips, corrupt-file degradation; "
        "the measured-vs-analytic gate is bench_tune.py)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
