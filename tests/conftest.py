# NOTE: no XLA_FLAGS here on purpose — tests must see the host's real
# single CPU device. Only launch/dryrun.py (never imported by tests)
# forces the 512-device count.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
