"""Chunked attention == plain softmax attention (incl. SWA and decode)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import chunked_attention


def _plain_attention(q, k, v, causal=True, window=None):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = np.einsum("bqkgh,btkh->bkgqt", qg, k).astype(np.float64) / np.sqrt(hd)
    skv = k.shape[1]
    qpos = np.arange(sq)[:, None]
    kpos = np.arange(skv)[None, :]
    mask = np.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bkgqt,btkh->bqkgh", p, v)
    return o.reshape(b, sq, h, hd)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), sq=st.sampled_from([16, 63, 128]),
       window=st.sampled_from([None, 32]),
       qc=st.sampled_from([32, 64]), kc=st.sampled_from([16, 32]))
def test_chunked_matches_plain(seed, sq, window, qc, kc):
    r = np.random.default_rng(seed)
    b, h, kvh, hd = 2, 4, 2, 16
    q = r.standard_normal((b, sq, h, hd)).astype(np.float32)
    k = r.standard_normal((b, sq, kvh, hd)).astype(np.float32)
    v = r.standard_normal((b, sq, kvh, hd)).astype(np.float32)
    got = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, window=window, q_chunk=qc, kv_chunk=kc)
    want = _plain_attention(q, k, v, causal=True, window=window)
    assert np.abs(np.asarray(got) - want).max() < 2e-4


def test_non_causal_cross_attention(rng):
    b, sq, skv, h, hd = 1, 8, 24, 2, 8
    q = rng.standard_normal((b, sq, h, hd)).astype(np.float32)
    k = rng.standard_normal((b, skv, h, hd)).astype(np.float32)
    v = rng.standard_normal((b, skv, h, hd)).astype(np.float32)
    got = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=False, q_chunk=4, kv_chunk=8)
    want = _plain_attention(q, k, v, causal=False)
    assert np.abs(np.asarray(got) - want).max() < 2e-4


def test_grad_is_finite(rng):
    b, s, h, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)

    def f(q, k, v):
        return jnp.sum(chunked_attention(q, k, v, q_chunk=16, kv_chunk=8) ** 2)

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.isfinite(g).all())
